"""Server aggregation throughput: compiled plans vs the per-leaf path.

One FL round used to walk the adapter tree in Python, issuing one device
computation (two Pallas launches) per LoRA pair -- O(pairs x clients)
host dispatch.  The compiled :class:`~repro.core.plan.CompiledRound`
packs the cohort into (width, dtype) buckets and lowers the whole round
into one jitted call with one fused launch per bucket.  This bench
measures both paths on a transformer-sized adapter tree with a mixed-rank
cohort and reports, per strategy x backend:

* round latency (legacy vs plan) and the speedup,
* tracked dispatches per round (legacy pallas: 2 x pairs; plan: 1 call)
  and the reduction factor,
* plan-cache hit rate and the plan's fused-launch count,
* a plan-vs-legacy numerical parity check (the CI smoke gate).

A separate **svd leg** gates the factored low-rank engine
(``repro.core.lowrank``): at (m, n, sum r) = (768, 768, 32) the
strategy's factored path must match the explicit dense fallback in
product space and beat it by >= 5x wall-clock on CPU.

``--json PATH`` writes the machine-readable ``BENCH_agg.json`` so the
perf trajectory is tracked across PRs; ``--smoke`` runs a tiny case and
exits non-zero if the plan path and the legacy shim disagree beyond
tolerance, the dispatch reduction falls under 5x, the factored svd
speedup falls under 5x, or the plan path is slower than the legacy shim
(geomean speedup < 1.0) on any backend -- the plan is only worth its
complexity if it wins everywhere it claims to.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_strategy, list_strategies
from repro.core.plan import dispatch_counter
from repro.lora import init_adapters, set_ranks
from repro.obs import bench_payload, time_fn

BENCH_METHODS = ("rbla", "zeropad", "fedavg", "rbla_ranked", "flora",
                 "svd", "rbla_clipped", "rbla_trimmed", "rbla_median")

#: the factored-SVD gate case: min(m, n) = 768 >= 8 * sum(ranks) = 256,
#: where the dense O(m*n*min(m,n)) SVD is far off the factored
#: O((m+n)*k^2 + k^3) engine -- the smoke gate requires >= 5x
SVD_GATE_SPECS = {"proj": (768, 768)}
SVD_GATE_CLIENTS = 4
SVD_GATE_RANK = 8                      # sum(r_i) = 32

#: transformer-sized adapter tree: {path: (fan_out, fan_in)}
FULL_SPECS = {
    "attn_q": (512, 512), "attn_k": (512, 512), "attn_v": (512, 512),
    "attn_o": (512, 512), "mlp_up": (2048, 512), "mlp_gate": (2048, 512),
    "mlp_down": (512, 2048), "head": (512, 512),
}
SMOKE_SPECS = {"fc1": (24, 16), "fc2": (16, 24), "fc3": (24, 16),
               "fc4": (16, 24)}


def build_cohort(specs, n, r_max, seed=0):
    """n clients, mixed ranks in [1, r_max], both factors randomized."""
    rng = np.random.default_rng(seed)
    ranks = rng.integers(1, r_max + 1, n)
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    cohort = []
    for i in range(n):
        ad = init_adapters(keys[i], specs, r_max, int(ranks[i]))
        ad = jax.tree.map(
            lambda x: x + jnp.asarray(rng.normal(size=x.shape) * 0.1,
                                      x.dtype)
            if x.dtype == jnp.float32 else x, ad)
        cohort.append(set_ranks(ad, int(ranks[i])))
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    return cohort, jnp.asarray(ranks, jnp.int32), w


def bench_us(fn, iters=3):
    # min-over-iters timing lives in repro.obs.timing now; this shim
    # just converts to the microseconds the report rows use
    return time_fn(fn, iters=iters, reduce="min") * 1e6


def count_dispatches(fn):
    dispatch_counter.reset()
    out = fn()
    jax.block_until_ready(out)
    return dispatch_counter.reset(), out


def max_abs_diff(a, b):
    return max((float(jnp.max(jnp.abs(
        jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))),
        default=0.0)


def configured(method, ranks, r_max):
    # always a with_options copy: each strategy x backend row gets its
    # own (empty) plan cache, so the reported hit/miss stats are per-row
    # rather than contaminated across rows / earlier in-process use
    s = get_strategy(method)
    if s.rank_contract == "stacked":
        return s.with_options(
            stack_r_cap=int(np.asarray(ranks).sum()) + r_max)
    return s.with_options()


def run_case(specs, n, r_max, iters, tol):
    cohort, ranks, w = build_cohort(specs, n, r_max)
    results, failures = [], []
    for method in BENCH_METHODS:
        for backend in ("ref", "pallas"):
            s = configured(method, ranks, r_max)

            def legacy():
                return s.aggregate_adapters(
                    cohort, w, r_max=r_max, client_ranks=ranks,
                    backend=backend, use_plan=False)

            def plan():
                return s.aggregate_adapters(
                    cohort, w, r_max=r_max, client_ranks=ranks,
                    backend=backend)

            legacy_disp, legacy_out = count_dispatches(legacy)
            plan_disp, plan_out = count_dispatches(plan)
            diff = max_abs_diff(legacy_out, plan_out)
            legacy_us = bench_us(legacy, iters)
            plan_us = bench_us(plan, iters)
            rounds = list(s.__dict__.get("_plan_cache", {}).values())
            rd = next(r for r in rounds if r.spec.kind == backend)
            stats = dict(s.__dict__.get("plan_stats",
                                        {"hits": 0, "misses": 0}))
            row = {
                "strategy": method, "backend": backend,
                "legacy_us": round(legacy_us, 1),
                "plan_us": round(plan_us, 1),
                "speedup": round(legacy_us / max(plan_us, 1e-9), 2),
                "legacy_dispatches": legacy_disp or None,
                "plan_dispatches": plan_disp,
                "dispatch_reduction": (
                    round(legacy_disp / max(plan_disp, 1), 1)
                    if legacy_disp else None),
                "plan_kind": rd.kind,
                "kernel_launches": rd.n_kernel_launches,
                "fallback_pairs": rd.n_fallback_pairs,
                "plan_cache": stats,
                "max_abs_diff": diff,
            }
            results.append(row)
            mode = ("pallas" if jax.default_backend() in ("tpu", "gpu")
                    else "pallas-interpret") if backend == "pallas" \
                else "core-ref"
            print(f"agg/{method}/{backend}/n{n}_r{r_max}_p{len(specs)},"
                  f"{plan_us:.0f},plan-{mode}")
            print(f"agg/{method}/{backend}/n{n}_r{r_max}_p{len(specs)},"
                  f"{legacy_us:.0f},legacy-{mode}")
            if diff > tol:
                failures.append(
                    f"{method}/{backend}: plan vs legacy diff {diff:.2e} "
                    f"> tol {tol:.0e}")
    return results, failures


def run_svd_factored_case(iters, tol):
    """The lowrank-engine leg: the svd strategy's factored path vs the
    explicit dense fallback at (m, n, sum r) = (768, 768, 32).

    Gates (hard in ``--smoke``): the served products must agree (factors
    are only unique up to the truncation basis, so parity is checked in
    product space) and the factored round must be >= 5x faster than the
    dense one on CPU.
    """
    rng = np.random.default_rng(7)
    cohort = []
    keys = jax.random.split(jax.random.PRNGKey(7), SVD_GATE_CLIENTS)
    for i in range(SVD_GATE_CLIENTS):
        ad = init_adapters(keys[i], SVD_GATE_SPECS, SVD_GATE_RANK,
                           SVD_GATE_RANK)
        ad = jax.tree.map(
            lambda x: x + jnp.asarray(rng.normal(size=x.shape) * 0.1,
                                      x.dtype)
            if x.dtype == jnp.float32 else x, ad)
        cohort.append(ad)
    ranks = jnp.full((SVD_GATE_CLIENTS,), SVD_GATE_RANK, jnp.int32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, SVD_GATE_CLIENTS), jnp.float32)
    factored = get_strategy("svd").with_options()          # auto->factored
    dense = get_strategy("svd").with_options(svd_method="dense")

    def run(s):
        return s.aggregate_adapters(cohort, w, r_max=SVD_GATE_RANK,
                                    client_ranks=ranks, backend="ref")

    out_f = run(factored)
    out_d = run(dense)
    # product-space parity, normalized by the served update's own scale
    delta_f = np.asarray(out_f["proj"]["B"], np.float32) @ np.asarray(
        out_f["proj"]["A"], np.float32)
    delta_d = np.asarray(out_d["proj"]["B"], np.float32) @ np.asarray(
        out_d["proj"]["A"], np.float32)
    scale = max(float(np.abs(delta_d).max()), 1e-12)
    rel_diff = float(np.abs(delta_f - delta_d).max()) / scale
    factored_us = bench_us(lambda: run(factored), iters)
    dense_us = bench_us(lambda: run(dense), iters)
    speedup = dense_us / max(factored_us, 1e-9)
    m, n = next(iter(SVD_GATE_SPECS.values()))
    k = SVD_GATE_CLIENTS * SVD_GATE_RANK
    print(f"agg/svd_factored/m{m}_n{n}_k{k},{factored_us:.0f},"
          "lowrank-factored")
    print(f"agg/svd_dense/m{m}_n{n}_k{k},{dense_us:.0f},dense-fallback")
    row = {
        "case": {"m": m, "n": n, "sum_ranks": k},
        "dense_us": round(dense_us, 1),
        "factored_us": round(factored_us, 1),
        "speedup": round(speedup, 2),
        "product_rel_diff": rel_diff,
    }
    failures = []
    if rel_diff > tol:
        failures.append(
            f"svd factored-vs-dense product diff {rel_diff:.2e} > "
            f"tol {tol:.0e}")
    return row, failures


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny case + hard parity/dispatch gate (CI)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write machine-readable results (BENCH_agg.json)")
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--tol", type=float, default=5e-4,
                   help="max abs plan-vs-legacy deviation tolerated")
    args = p.parse_args(argv)

    specs = SMOKE_SPECS if args.smoke else FULL_SPECS
    n = 6 if args.smoke else 32
    r_max = 8 if args.smoke else 32
    print(f"# registered strategies: {','.join(list_strategies())}")
    results, failures = run_case(specs, n, r_max, args.iters, args.tol)
    svd_row, svd_failures = run_svd_factored_case(args.iters, args.tol)
    failures += svd_failures

    pallas_rows = [r for r in results
                   if r["backend"] == "pallas" and r["dispatch_reduction"]]
    ref_rows = [r for r in results if r["backend"] == "ref"]
    # per-backend geomean of plan-vs-legacy speedups: the regression
    # gate -- a plan that loses to the per-leaf shim anywhere is a bug
    backend_speedup = {
        b: round(float(np.exp(np.mean(np.log(
            [r["speedup"] for r in results if r["backend"] == b])))), 2)
        for b in ("ref", "pallas")}
    summary = {
        "min_dispatch_reduction": min(
            (r["dispatch_reduction"] for r in pallas_rows), default=None),
        "mean_ref_wall_clock_speedup": round(float(np.mean(
            [r["speedup"] for r in ref_rows])), 2) if ref_rows else None,
        "plan_speedup_by_backend": backend_speedup,
        "max_abs_diff": max(r["max_abs_diff"] for r in results),
        "svd_factored_speedup": svd_row["speedup"],
    }
    print(f"# summary: {json.dumps(summary)}")

    if args.json:
        # shared payload shape (env header + obs snapshot) keeps this
        # file comparable with BENCH_serve.json runs from other machines
        payload = bench_payload(
            "agg_throughput", smoke=bool(args.smoke),
            case={"n_clients": n, "r_max": r_max, "n_pairs": len(specs)},
            results=results, svd_factored=svd_row, summary=summary)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    if failures:
        for msg in failures:
            print(f"# PARITY FAILURE: {msg}")
        raise SystemExit(1)
    if args.smoke:
        bad = [r for r in pallas_rows if r["dispatch_reduction"] < 5]
        if bad:
            print(f"# DISPATCH GATE FAILURE: {bad}")
            raise SystemExit(1)
        if svd_row["speedup"] < 5:
            print(f"# SVD FACTORED GATE FAILURE: {svd_row}")
            raise SystemExit(1)
        slow = {b: v for b, v in backend_speedup.items() if v < 1.0}
        if slow:
            print("# PLAN SPEEDUP GATE FAILURE: plan slower than legacy "
                  f"on {slow}")
            raise SystemExit(1)
        print("# smoke gate OK: plan==shim within tolerance, "
              "dispatch reduction >= 5x, factored svd >= 5x over dense, "
              "plan >= legacy on every backend")


if __name__ == "__main__":
    main()
