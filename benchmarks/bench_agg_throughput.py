"""Server aggregation throughput: RBLA vs zero-padding vs FedAvg, pure-jnp
core vs the Pallas kernel (interpret mode on CPU -- relative numbers
document the harness; absolute TPU numbers require hardware).

The paper motivates RBLA partly by zero-padding's wasted compute on
structural zeros; this bench quantifies server-side aggregation cost per
round as adapter stacks grow.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregate, stacked_rank_masks
from repro.kernels import rbla_agg

CASES = [
    # (n_clients, r_max, fan_in, n_tensors)
    (10, 64, 1024, 8),
    (10, 128, 4096, 8),
    (32, 64, 1024, 8),
]


def bench(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main():
    rng = np.random.default_rng(0)
    for n, r, d, nt in CASES:
        ranks = jnp.asarray(rng.integers(1, r + 1, n), jnp.int32)
        masks = stacked_rank_masks(r, ranks)[:, :, None]
        tree = {f"t{i}": jnp.asarray(
            rng.normal(size=(n, r, d)), jnp.float32) * masks
            for i in range(nt)}
        mtree = {f"t{i}": masks for i in range(nt)}
        w = jnp.ones(n)

        for method in ("rbla", "zeropad", "fedavg"):
            f = jax.jit(lambda t, m, w, meth=method: aggregate(
                t, m, w, method=meth))
            us = bench(f, tree, mtree, w)
            print(f"agg/{method}/n{n}_r{r}_d{d}x{nt},{us:.0f},core-jnp")

        x0 = tree["t0"]
        us = bench(lambda x: rbla_agg(x, ranks, w, interpret=True), x0)
        print(f"agg/rbla_kernel/n{n}_r{r}_d{d}x1,{us:.0f},"
              "pallas-interpret")


if __name__ == "__main__":
    main()
