"""Server aggregation throughput: compiled plans vs the per-leaf path.

One FL round used to walk the adapter tree in Python, issuing one device
computation (two Pallas launches) per LoRA pair -- O(pairs x clients)
host dispatch.  The compiled :class:`~repro.core.plan.CompiledRound`
packs the cohort into (width, dtype) buckets and lowers the whole round
into one jitted call with one fused launch per bucket.  This bench
measures both paths on a transformer-sized adapter tree with a mixed-rank
cohort and reports, per strategy x backend:

* round latency (legacy vs plan) and the speedup,
* tracked dispatches per round (legacy pallas: 2 x pairs; plan: 1 call)
  and the reduction factor,
* plan-cache hit rate and the plan's fused-launch count,
* a plan-vs-legacy numerical parity check (the CI smoke gate).

``--json PATH`` writes the machine-readable ``BENCH_agg.json`` so the
perf trajectory is tracked across PRs; ``--smoke`` runs a tiny case and
exits non-zero if the plan path and the legacy shim disagree beyond
tolerance or the dispatch reduction falls under 5x.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_strategy, list_strategies
from repro.core.plan import dispatch_counter
from repro.lora import init_adapters, set_ranks

BENCH_METHODS = ("rbla", "zeropad", "fedavg", "rbla_ranked", "flora")

#: transformer-sized adapter tree: {path: (fan_out, fan_in)}
FULL_SPECS = {
    "attn_q": (512, 512), "attn_k": (512, 512), "attn_v": (512, 512),
    "attn_o": (512, 512), "mlp_up": (2048, 512), "mlp_gate": (2048, 512),
    "mlp_down": (512, 2048), "head": (512, 512),
}
SMOKE_SPECS = {"fc1": (24, 16), "fc2": (16, 24), "fc3": (24, 16),
               "fc4": (16, 24)}


def build_cohort(specs, n, r_max, seed=0):
    """n clients, mixed ranks in [1, r_max], both factors randomized."""
    rng = np.random.default_rng(seed)
    ranks = rng.integers(1, r_max + 1, n)
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    cohort = []
    for i in range(n):
        ad = init_adapters(keys[i], specs, r_max, int(ranks[i]))
        ad = jax.tree.map(
            lambda x: x + jnp.asarray(rng.normal(size=x.shape) * 0.1,
                                      x.dtype)
            if x.dtype == jnp.float32 else x, ad)
        cohort.append(set_ranks(ad, int(ranks[i])))
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    return cohort, jnp.asarray(ranks, jnp.int32), w


def bench(fn, iters=3):
    out = fn()                                  # compile / first trace
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def count_dispatches(fn):
    dispatch_counter.reset()
    out = fn()
    jax.block_until_ready(out)
    return dispatch_counter.reset(), out


def max_abs_diff(a, b):
    return max((float(jnp.max(jnp.abs(
        jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))),
        default=0.0)


def configured(method, ranks, r_max):
    # always a with_options copy: each strategy x backend row gets its
    # own (empty) plan cache, so the reported hit/miss stats are per-row
    # rather than contaminated across rows / earlier in-process use
    s = get_strategy(method)
    if s.rank_contract == "stacked":
        return s.with_options(
            stack_r_cap=int(np.asarray(ranks).sum()) + r_max)
    return s.with_options()


def run_case(specs, n, r_max, iters, tol):
    cohort, ranks, w = build_cohort(specs, n, r_max)
    results, failures = [], []
    for method in BENCH_METHODS:
        for backend in ("ref", "pallas"):
            s = configured(method, ranks, r_max)

            def legacy():
                return s.aggregate_adapters(
                    cohort, w, r_max=r_max, client_ranks=ranks,
                    backend=backend, use_plan=False)

            def plan():
                return s.aggregate_adapters(
                    cohort, w, r_max=r_max, client_ranks=ranks,
                    backend=backend)

            legacy_disp, legacy_out = count_dispatches(legacy)
            plan_disp, plan_out = count_dispatches(plan)
            diff = max_abs_diff(legacy_out, plan_out)
            legacy_us, _ = bench(legacy, iters)
            plan_us, _ = bench(plan, iters)
            rounds = list(s.__dict__.get("_plan_cache", {}).values())
            rd = next(r for r in rounds if r.spec.kind == backend)
            stats = dict(s.__dict__.get("plan_stats",
                                        {"hits": 0, "misses": 0}))
            row = {
                "strategy": method, "backend": backend,
                "legacy_us": round(legacy_us, 1),
                "plan_us": round(plan_us, 1),
                "speedup": round(legacy_us / max(plan_us, 1e-9), 2),
                "legacy_dispatches": legacy_disp or None,
                "plan_dispatches": plan_disp,
                "dispatch_reduction": (
                    round(legacy_disp / max(plan_disp, 1), 1)
                    if legacy_disp else None),
                "plan_kind": rd.kind,
                "kernel_launches": rd.n_kernel_launches,
                "fallback_pairs": rd.n_fallback_pairs,
                "plan_cache": stats,
                "max_abs_diff": diff,
            }
            results.append(row)
            mode = ("pallas" if jax.default_backend() in ("tpu", "gpu")
                    else "pallas-interpret") if backend == "pallas" \
                else "core-ref"
            print(f"agg/{method}/{backend}/n{n}_r{r_max}_p{len(specs)},"
                  f"{plan_us:.0f},plan-{mode}")
            print(f"agg/{method}/{backend}/n{n}_r{r_max}_p{len(specs)},"
                  f"{legacy_us:.0f},legacy-{mode}")
            if diff > tol:
                failures.append(
                    f"{method}/{backend}: plan vs legacy diff {diff:.2e} "
                    f"> tol {tol:.0e}")
    return results, failures


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny case + hard parity/dispatch gate (CI)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write machine-readable results (BENCH_agg.json)")
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--tol", type=float, default=5e-4,
                   help="max abs plan-vs-legacy deviation tolerated")
    args = p.parse_args(argv)

    specs = SMOKE_SPECS if args.smoke else FULL_SPECS
    n = 6 if args.smoke else 32
    r_max = 8 if args.smoke else 32
    print(f"# registered strategies: {','.join(list_strategies())}")
    results, failures = run_case(specs, n, r_max, args.iters, args.tol)

    pallas_rows = [r for r in results
                   if r["backend"] == "pallas" and r["dispatch_reduction"]]
    ref_rows = [r for r in results if r["backend"] == "ref"]
    summary = {
        "min_dispatch_reduction": min(
            (r["dispatch_reduction"] for r in pallas_rows), default=None),
        "mean_ref_wall_clock_speedup": round(float(np.mean(
            [r["speedup"] for r in ref_rows])), 2) if ref_rows else None,
        "max_abs_diff": max(r["max_abs_diff"] for r in results),
    }
    print(f"# summary: {json.dumps(summary)}")

    if args.json:
        payload = {
            "bench": "agg_throughput",
            "backend": jax.default_backend(),
            "smoke": bool(args.smoke),
            "case": {"n_clients": n, "r_max": r_max,
                     "n_pairs": len(specs)},
            "results": results,
            "summary": summary,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    if failures:
        for msg in failures:
            print(f"# PARITY FAILURE: {msg}")
        raise SystemExit(1)
    if args.smoke:
        bad = [r for r in pallas_rows if r["dispatch_reduction"] < 5]
        if bad:
            print(f"# DISPATCH GATE FAILURE: {bad}")
            raise SystemExit(1)
        print("# smoke gate OK: plan==shim within tolerance, "
              "dispatch reduction >= 5x")


if __name__ == "__main__":
    main()
