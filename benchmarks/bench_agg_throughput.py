"""Server aggregation throughput across strategies and backends.

Every registered aggregation strategy is benchmarked on its reference
(jnp) tree path; strategies with a kernel path are also benchmarked on
``backend="pallas"`` (interpreter mode on CPU -- relative numbers document
the harness; absolute TPU numbers require hardware).

The paper motivates RBLA partly by zero-padding's wasted compute on
structural zeros; this bench quantifies server-side aggregation cost per
round as adapter stacks grow.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_strategy, list_strategies, stacked_rank_masks
from repro.kernels import flora_stack, rbla_agg

CASES = [
    # (n_clients, r_max, fan_in, n_tensors)
    (10, 64, 1024, 8),
    (10, 128, 4096, 8),
    (32, 64, 1024, 8),
]

BENCH_METHODS = ("rbla", "zeropad", "fedavg", "rbla_ranked")


def bench(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main():
    rng = np.random.default_rng(0)
    print(f"# registered strategies: {','.join(list_strategies())}")
    for n, r, d, nt in CASES:
        ranks = jnp.asarray(rng.integers(1, r + 1, n), jnp.int32)
        masks = stacked_rank_masks(r, ranks)[:, :, None]
        tree = {f"t{i}": jnp.asarray(
            rng.normal(size=(n, r, d)), jnp.float32) * masks
            for i in range(nt)}
        mtree = {f"t{i}": masks for i in range(nt)}
        w = jnp.ones(n)

        for method in BENCH_METHODS:
            s = get_strategy(method)
            f = jax.jit(lambda t, m, ww, s=s: s.aggregate_tree(
                t, m, ww, client_ranks=ranks))
            us = bench(f, tree, mtree, w)
            print(f"agg/{method}/n{n}_r{r}_d{d}x{nt},{us:.0f},core-ref")

        # flora is pair-structured and rank-changing: bench it on whole
        # adapter pairs (ref tree path) and its copy/scale kernel, which
        # reads sum(ranks)*d vs the reduction kernels' n*r*d
        pairs = [{"A": jnp.asarray(rng.normal(size=(r, d)), jnp.float32),
                  "B": jnp.asarray(rng.normal(size=(d, r)), jnp.float32),
                  "rank": jnp.asarray(int(ranks[i]), jnp.int32)}
                 for i in range(n)]
        flora = get_strategy("flora").with_options(
            stack_r_cap=int(np.asarray(ranks).sum()) + r)
        us = bench(lambda: flora.aggregate_adapters(
            [{"t": p} for p in pairs], w, r_max=r,
            client_ranks=ranks, backend="ref"), iters=3)
        print(f"agg/flora/n{n}_r{r}_d{d}x1,{us:.0f},core-ref")

        segs = tuple(int(v) for v in np.asarray(ranks))
        xs = tree["t0"]
        us = bench(lambda: flora_stack(
            xs, jnp.ones(n), segs=segs, out_rows=sum(segs)), iters=3)
        mode = "pallas" if jax.default_backend() in ("tpu", "gpu") \
            else "pallas-interpret"
        print(f"agg/flora_stack_kernel/n{n}_r{r}_d{d}x1,{us:.0f},{mode}")

        x0 = tree["t0"]
        for method in BENCH_METHODS:
            s = get_strategy(method)
            if not s.supports_pallas:
                continue
            wt = s.transform_weights(w, ranks)
            # mirror the strategy's kernel call: fedavg (use_mask=False)
            # runs the kernel with full-rank masks
            kranks = ranks if s.use_mask else jnp.full((n,), r, jnp.int32)
            us = bench(lambda x, ww, s=s, kr=kranks: rbla_agg(
                x, kr, ww, method=s.pallas_method), x0, wt)
            mode = "pallas" if jax.default_backend() in ("tpu", "gpu") \
                else "pallas-interpret"
            print(f"agg/{method}_kernel/n{n}_r{r}_d{d}x1,{us:.0f},{mode}")


if __name__ == "__main__":
    main()
