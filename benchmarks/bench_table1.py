"""Paper Table 1: rounds-to-target-accuracy per aggregation method.

Synthetic analogues of the paper's six dataset x model columns, full
participation, seed 42.  Targets are chosen per dataset (see
EXPERIMENTS.md SSRepro for the mapping to the paper's targets).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.fl import FLConfig, run_simulation

# lr 0.05: full fine-tune diverges at 0.1 under the staircase non-IID
# (the paper used 0.01 with more rounds; 0.05 is the stable compromise at
# our reduced round budget)
COLUMNS = [
    # (dataset, model, optimizer, lr, target_acc)
    ("mnist", "mlp", "sgd", 0.05, 0.90),
    ("fmnist", "mlp", "sgd", 0.05, 0.70),
    ("mnist", "cnn_mnist", "sgd", 0.05, 0.90),
    ("fmnist", "cnn_mnist", "sgd", 0.05, 0.75),
    ("cifar", "cnn_cifar", "adam", 1e-3, 0.50),
    ("cinic", "cnn_cifar", "adam", 1e-3, 0.40),
]

METHODS = ["zeropad", "fft", "rbla"]
# beyond-paper strategies (svd became dispatchable with the strategy
# registry; any register_strategy'd name can be listed here)
EXTRA_METHODS = ["rbla_ranked", "rbla_norm", "svd"]

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run(columns, methods, rounds, n_per_class, participation=1.0,
        verbose=False, out_path=None):
    results = {}
    for dataset, model, opt, lr, target in columns:
        for method in methods:
            cfg = FLConfig(dataset=dataset, model=model, method=method,
                           optimizer=opt, lr=lr, rounds=rounds,
                           n_per_class=n_per_class,
                           n_test_per_class=max(50, n_per_class // 4),
                           local_epochs=2, participation=participation,
                           seed=42)
            t0 = time.time()
            hist = run_simulation(cfg, verbose=verbose)
            r2t = hist.rounds_to_target(target)
            best = max(hist.test_acc)
            key = f"{dataset}/{model}/{method}"
            results[key] = {
                "rounds_to_target": r2t, "target": target,
                "best_acc": best, "final_acc": hist.test_acc[-1],
                "curve": hist.test_acc, "wall_s": time.time() - t0,
            }
            if out_path:           # incremental write (long CPU runs)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
            print(f"table1/{key},{(time.time()-t0)*1e6/max(rounds,1):.0f},"
                  f"rounds_to_{target:.0%}="
                  f"{r2t if r2t else f'N/A(best={best:.4f})'}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--n-per-class", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 50 rounds, all six columns")
    ap.add_argument("--columns", type=int, default=2,
                    help="how many dataset columns (CNNs are slow on CPU)")
    ap.add_argument("--extra", action="store_true",
                    help="include beyond-paper aggregation variants")
    args = ap.parse_args()

    columns = COLUMNS if args.full else COLUMNS[: args.columns]
    rounds = 50 if args.full else args.rounds
    methods = METHODS + (EXTRA_METHODS if args.extra else [])
    os.makedirs(ART, exist_ok=True)
    run(columns, methods, rounds, args.n_per_class,
        out_path=os.path.join(ART, "table1.json"))


if __name__ == "__main__":
    main()
