"""Distributed RBLA (shard_map masked psum) vs host aggregation.

Runs in a SUBPROCESS with 8 forced host devices (so the parent process /
other benches keep seeing 1 CPU device), checks numerical equivalence with
the single-host core implementation, and times both.
"""
from __future__ import annotations

import os
import subprocess
import sys

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import get_strategy, stacked_rank_masks

strategy = get_strategy("rbla")
n, r, d = 8, 64, 2048
rng = np.random.default_rng(0)
ranks = jnp.asarray(rng.integers(1, r + 1, n), jnp.int32)
masks = stacked_rank_masks(r, ranks)[:, :, None]
x = jnp.asarray(rng.normal(size=(n, r, d)), jnp.float32) * masks
w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("clients",))
agg = strategy.make_distributed_aggregator(mesh, client_axis="clients")
sh = NamedSharding(mesh, P("clients"))
xd = jax.device_put(x, sh)
md = jax.device_put(jnp.broadcast_to(masks, x.shape), sh)
wd = jax.device_put(w, sh)

out = agg(xd, md, wd)
want = strategy.aggregate_tree({"t": x}, {"t": masks}, w)["t"]
np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                           rtol=1e-5, atol=1e-6)

def bench(f, *a, iters=10):
    f(*a); t0 = time.time()
    for _ in range(iters):
        o = f(*a)
    jax.block_until_ready(o)
    return (time.time() - t0) / iters * 1e6

us_dist = bench(agg, xd, md, wd)
host = jax.jit(lambda x, m, w: strategy.aggregate_tree({"t": x}, {"t": m},
                                                       w)["t"])
us_host = bench(host, x, masks, w)
print(f"agg/distributed_psum/8dev_n{n}_r{r}_d{d},{us_dist:.0f},"
      f"equivalent=True")
print(f"agg/host_jit/n{n}_r{r}_d{d},{us_host:.0f},reference")
"""


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    proc = subprocess.run([sys.executable, "-c", CHILD], env=env,
                          capture_output=True, text=True, timeout=600)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise SystemExit("distributed aggregation bench failed")


if __name__ == "__main__":
    main()
