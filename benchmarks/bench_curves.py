"""Paper Figs. 5-10: learning curves, full participation vs random 20%.

Writes CSV curves per (dataset, model, method, participation) to
benchmarks/artifacts/curves/.
"""
from __future__ import annotations

import argparse
import csv
import os
import time

from repro.fl import FLConfig, run_simulation

ART = os.path.join(os.path.dirname(__file__), "artifacts", "curves")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--n-per-class", type=int, default=300)
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--model", default="mlp")
    args = ap.parse_args()

    os.makedirs(ART, exist_ok=True)
    for participation, label in [(1.0, "full"), (0.2, "rand20")]:
        rows = {}
        for method in ["rbla", "zeropad", "fft"]:
            cfg = FLConfig(dataset=args.dataset, model=args.model,
                           method=method, rounds=args.rounds,
                           n_per_class=args.n_per_class,
                           n_test_per_class=100, local_epochs=2,
                           lr=0.05,
                           participation=participation, seed=42)
            t0 = time.time()
            hist = run_simulation(cfg)
            rows[method] = hist.test_acc
            print(f"curves/{args.dataset}/{args.model}/{method}/{label},"
                  f"{(time.time()-t0)*1e6/args.rounds:.0f},"
                  f"final={hist.test_acc[-1]:.4f}")
        path = os.path.join(
            ART, f"{args.dataset}_{args.model}_{label}.csv")
        with open(path, "w", newline="") as f:
            wr = csv.writer(f)
            wr.writerow(["round"] + list(rows))
            for i in range(args.rounds):
                wr.writerow([i + 1] + [f"{rows[m][i]:.4f}" for m in rows])


if __name__ == "__main__":
    main()
