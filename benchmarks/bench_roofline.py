"""Roofline table from the dry-run artifacts (EXPERIMENTS.md SSRoofline).

Reads benchmarks/artifacts/dryrun/*.json and renders the per-(arch x shape)
three-term roofline with the dominant bottleneck and useful-FLOPs ratio.
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh: str = "pod1", tag: str = "") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, f"*_{mesh}*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") != tag or r["mesh"] != mesh:
            continue
        recs.append(r)
    return recs


def fmt_row(r: dict) -> str:
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | - | - | - | - | skipped |"
                f" {r['skipped'][:40]}... |")
    rf = r["roofline"]
    dom = rf["dominant"]
    return ("| {arch} | {shape} | {c:.3f} | {m:.3f} | {x:.3f} | {dom} | "
            "{ratio:.3f} | {mem:.1f} GB |".format(
                arch=r["arch"], shape=r["shape"], c=rf["compute_s"],
                m=rf["memory_s"], x=rf["collective_s"], dom=dom,
                ratio=rf["useful_flops_ratio"],
                mem=(r.get("memory_analysis", {}).get(
                    "argument_size_in_bytes", 0) +
                    r.get("memory_analysis", {}).get(
                        "temp_size_in_bytes", 0)) / 1e9))


def main(mesh: str = "pod1", tag: str = "") -> None:
    recs = load(mesh, tag)
    print(f"# Roofline ({mesh}, {len(recs)} combos"
          + (f", tag={tag}" if tag else "") + ")")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant"
          " | useful_ratio | dev mem |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))
    # CSV for run.py harness: name,us_per_call,derived
    for r in recs:
        if "skipped" in r:
            continue
        rf = r["roofline"]
        step = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        print(f"roofline/{r['arch']}/{r['shape']}/{mesh},"
              f"{step * 1e6:.1f},dominant={rf['dominant']}")


if __name__ == "__main__":
    import sys
    main(*(sys.argv[1:] or ["pod1"]))
