"""Kernel micro-benchmarks: fused LoRA matmul vs unfused jnp reference.

interpret=True on CPU: correctness-oriented; wall numbers document harness
overhead, not TPU performance.  The derived column reports the HBM-traffic
model that motivates the fusion: the fused kernel reads x once instead of
twice (base + LoRA paths).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import lora_matmul, lora_matmul_ref

CASES = [
    # (m, k, n, r)
    (1024, 1024, 1024, 16),
    (4096, 1024, 1024, 64),
    (1024, 4096, 1024, 64),
]


def bench(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def traffic_model(m, k, n, r, bytes_per=2):
    """bytes moved: fused reads x once; unfused reads it twice."""
    fused = (m * k + k * n + r * k + n * r + m * n) * bytes_per
    unfused = (2 * m * k + k * n + r * k + n * r + 2 * m * n) * bytes_per
    return fused, unfused


def main():
    rng = np.random.default_rng(0)
    for m, k, n, r in CASES:
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
        w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.bfloat16)
        a = jnp.asarray(rng.normal(size=(r, k)) * 0.05, jnp.bfloat16)
        b = jnp.asarray(rng.normal(size=(n, r)) * 0.05, jnp.bfloat16)

        ref = jax.jit(lambda *s: lora_matmul_ref(*s, 0.25))
        us_ref = bench(ref, x, w, a, b)
        us_ker = bench(lambda *s: lora_matmul(*s, 0.25, interpret=True),
                       x, w, a, b)
        fused, unfused = traffic_model(m, k, n, r)
        print(f"kernel/lora_matmul_ref/m{m}k{k}n{n}r{r},{us_ref:.0f},"
              f"model_bytes={unfused}")
        print(f"kernel/lora_matmul_pallas/m{m}k{k}n{n}r{r},{us_ker:.0f},"
              f"model_bytes={fused} ({100*(1-fused/unfused):.0f}% less"
              " traffic)")


if __name__ == "__main__":
    main()
