"""Benchmark harness: one bench per paper table/figure + systems benches.

Prints ``name,us_per_call,derived`` CSV lines (plus markdown tables where
a bench renders one).  Heavy paper-scale settings are opt-in via each
bench's CLI; the defaults here finish on a CPU container.

``--json`` additionally writes ``BENCH_agg.json`` (per-strategy /
per-backend round latency, dispatch counts, plan-cache hit rate from the
aggregation-throughput bench) so the perf trajectory is tracked across
PRs.
"""
from __future__ import annotations

import sys
import time
import traceback


def _run(name, fn):
    print(f"# --- {name} ---", flush=True)
    t0 = time.time()
    try:
        fn()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        return True
    except Exception:
        traceback.print_exc()
        print(f"# {name} FAILED", flush=True)
        return False


def main() -> None:
    ok = True
    write_json = "--json" in sys.argv

    def table1():
        from benchmarks import bench_table1
        sys.argv = ["bench_table1", "--rounds", "20", "--n-per-class",
                    "250", "--columns", "2"]
        bench_table1.main()

    def curves():
        from benchmarks import bench_curves
        sys.argv = ["bench_curves", "--rounds", "12", "--n-per-class",
                    "250"]
        bench_curves.main()

    def agg():
        from benchmarks import bench_agg_throughput
        bench_agg_throughput.main(
            ["--json", "BENCH_agg.json"] if write_json else [])

    def kernels():
        from benchmarks import bench_kernels
        bench_kernels.main()

    def dist():
        from benchmarks import bench_distributed_agg
        bench_distributed_agg.main()

    def roofline():
        from benchmarks import bench_roofline
        bench_roofline.main("pod1")

    for name, fn in [("table1 (paper Table 1)", table1),
                     ("curves (paper Figs 5-10)", curves),
                     ("agg_throughput", agg),
                     ("kernels", kernels),
                     ("distributed_agg", dist),
                     ("roofline (dry-run artifacts)", roofline)]:
        ok = _run(name, fn) and ok
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
