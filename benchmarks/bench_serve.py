"""Multi-tenant adapter serving throughput: one executable vs jit-per-adapter.

The FLaaS read path (``repro.serving``) packs every tenant's (A, B) pair
into the paged :class:`~repro.serving.AdapterStore` and serves a mixed
request batch with ONE launch of the batched multi-adapter kernel
(:func:`~repro.kernels.batched_lora_matmul`): adapter ids, offsets, ranks,
and scales are runtime data, so a single compiled executable covers every
tenant mix.  The baseline is what naive FLaaS serving does instead --
group the batch by tenant and run a **jit-per-adapter** LoRA matmul per
group (one dispatch per tenant present, one executable per distinct
(group size, rank) shape).

The bench runs the whole loop continuously: an
:class:`~repro.fl.AsyncAggregator` folds client updates (rbla), its
``on_publish`` hook hot-swaps each advanced global into the live store,
and serving keeps drawing mixed batches -- verifying along the way that
neither tenant-mix churn nor ``publish()`` ever retraces the serving
executable.

Reported per case:

* batched and per-tenant baseline requests/sec and the speedup,
* serving executable trace count across the run (must stay at its
  post-warmup value: the no-retrace gate),
* publish latency and the version delta across the run,
* batched-vs-reference numerical parity (the CI smoke gate).

``--smoke`` runs a reduced case and exits non-zero if parity breaks, the
speedup at 128 tenants falls under 4x, the serving executable retraces,
or a publish forces a recompile.  ``--json PATH`` writes the
machine-readable ``BENCH_serve.json`` (with the same environment header
as ``BENCH_agg.json``).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClientUpdate, ServerState
from repro.fl import AsyncAggregator
from repro.kernels import lora_matmul_ref
from repro.kernels.lora_matmul.ops import trace_counts
from repro.lora import init_adapters, set_ranks
from repro.obs import bench_payload, block
from repro.serving import AdapterStore, ServingEngine, merged_reference

PATH = "proj"


def _pow2(v: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(v, 1)))), 0)


def build_rig(n_tenants, width, r_max, seed=0):
    """Store + engine with ``n_tenants`` heterogeneous-rank tenants, all
    serving re-slices of one global (the steady FLaaS state)."""
    rng = np.random.default_rng(seed)
    specs = {PATH: (width, width)}
    weights = {PATH: jnp.asarray(rng.normal(size=(width, width)) * 0.05,
                                 jnp.float32)}
    store = AdapterStore(specs, r_max=r_max,
                         init_pages=_pow2(n_tenants),
                         init_tenant_capacity=_pow2(n_tenants + 1))
    engine = ServingEngine(weights, store)
    ranks = rng.integers(1, r_max + 1, n_tenants)
    for t in range(n_tenants):
        store.register(f"tenant-{t}", rank=int(ranks[t]))
    glob = init_adapters(jax.random.PRNGKey(seed), specs, r_max, r_max)
    glob = jax.tree.map(
        lambda x: x + jnp.asarray(rng.normal(size=x.shape) * 0.1, x.dtype)
        if x.dtype == jnp.float32 else x, glob)
    engine.publish(glob)
    return store, engine, glob, ranks


def make_batches(n_batches, batch, width, n_tenants, seed=1):
    """Pre-drawn mixed request batches -- every batch a different tenant
    mix (ids are slots 1..n_tenants; slot 0 is the null adapter)."""
    rng = np.random.default_rng(seed)
    xs = [jnp.asarray(rng.normal(size=(batch, width)), jnp.float32)
          for _ in range(n_batches)]
    ids = [jnp.asarray(rng.integers(1, n_tenants + 1, batch), jnp.int32)
           for _ in range(n_batches)]
    return xs, ids


def bench_batched(engine, xs, ids, iters):
    """Requests/sec through the single batched executable."""
    y = engine.apply(PATH, xs[0], ids[0])          # compile / warm
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    done = 0
    for it in range(iters):
        for x, i in zip(xs, ids):
            y = engine.apply(PATH, x, i)
            done += x.shape[0]
    jax.block_until_ready(y)
    return done / (time.perf_counter() - t0)


def bench_per_tenant(engine, xs, ids, iters):
    """The jit-per-adapter baseline: slice the batch per tenant and run
    one jitted single-adapter LoRA matmul per group.  Group sizes pad to
    powers of two so the jit cache warms to O(log batch x distinct
    ranks) executables instead of churning every batch."""
    snap = engine.snapshot()
    a_rows, b_rows = snap.pair_buffers(PATH)
    tbl = snap.table(PATH)
    off = np.asarray(tbl.off)
    rank = np.asarray(tbl.rank)
    scale = np.asarray(tbl.scale)
    w = engine.weights[PATH]
    per = jax.jit(lora_matmul_ref)

    def serve_batch(x, id_arr):
        id_np = np.asarray(id_arr)
        outs = []
        for t in np.unique(id_np):
            sel = np.nonzero(id_np == t)[0]
            xg = x[jnp.asarray(sel)]
            pad = _pow2(len(sel))
            xg = jnp.pad(xg, ((0, pad - len(sel)), (0, 0)))
            a_t = jax.lax.dynamic_slice_in_dim(a_rows, int(off[t]),
                                               int(rank[t])) \
                if rank[t] else a_rows[:1] * 0
            b_t = jax.lax.dynamic_slice_in_dim(b_rows, int(off[t]),
                                               int(rank[t])) \
                if rank[t] else b_rows[:1] * 0
            outs.append(per(xg, w, a_t, jnp.swapaxes(b_t, 0, 1),
                            float(scale[t]))[:len(sel)])
        return outs

    out = serve_batch(xs[0], ids[0])               # compile / warm
    jax.block_until_ready(out)
    for x, i in zip(xs, ids):                      # warm every shape
        jax.block_until_ready(serve_batch(x, i))
    t0 = time.perf_counter()
    done = 0
    for it in range(iters):
        for x, i in zip(xs, ids):
            out = serve_batch(x, i)
            done += x.shape[0]
    jax.block_until_ready(out)
    return done / (time.perf_counter() - t0)


def publish_loop(engine, store, glob, r_max, rounds, serve_fn):
    """aggregate -> publish -> serve continuously: fold client updates
    through an AsyncAggregator whose on_publish hook hot-swaps the live
    store; serve between folds.  Returns (mean publish seconds, versions
    advanced)."""
    state = ServerState(adapters=glob, base_trainable={}, r_max=r_max)
    agg = AsyncAggregator("rbla", state, backend="ref",
                          on_publish=engine.publisher())
    rng = np.random.default_rng(5)
    v0 = store.version
    t_pub = 0.0
    n_pub = 0
    width = glob[PATH]["A"].shape[-1]
    for rnd in range(rounds):
        r = int(rng.integers(1, r_max + 1))
        upd = init_adapters(jax.random.PRNGKey(100 + rnd),
                            {PATH: (width, width)}, r_max, r)
        upd = jax.tree.map(
            lambda x: x + jnp.asarray(rng.normal(size=x.shape) * 0.05,
                                      x.dtype)
            if x.dtype == jnp.float32 else x, upd)
        upd = set_ranks(upd, r)
        t0 = time.perf_counter()
        agg.submit(ClientUpdate(adapters=upd, base_trainable={},
                                n_examples=1.0, rank=r))
        block([b for pair in store.snapshot().buffers.values()
               for b in pair])
        t_pub += time.perf_counter() - t0
        n_pub += 1
        serve_fn()
    return t_pub / max(n_pub, 1), store.version - v0


def run_case(n_tenants, width, r_max, batch, n_batches, iters, rounds,
             tol):
    failures = []
    store, engine, glob, ranks = build_rig(n_tenants, width, r_max)
    xs, ids = make_batches(n_batches, batch, width, n_tenants)

    # parity vs the per-request reference before anything is timed
    got = engine.apply(PATH, xs[0], ids[0])
    want = merged_reference(engine, PATH, xs[0], ids[0])
    diff = float(jnp.abs(jnp.asarray(got, jnp.float32)
                         - want).max())
    scale_ref = max(float(jnp.abs(want).max()), 1e-12)
    rel = diff / scale_ref
    if rel > tol:
        failures.append(f"batched vs reference rel diff {rel:.2e} > "
                        f"tol {tol:.0e}")

    batched_rps = bench_batched(engine, xs, ids, iters)
    traces_mid = trace_counts.get("batched_lora_matmul", 0)
    per_tenant_rps = bench_per_tenant(engine, xs, ids, iters)

    # continuous aggregate -> publish -> serve; serving must not retrace
    idx = [0]

    def serve_once():
        x, i = xs[idx[0] % len(xs)], ids[idx[0] % len(ids)]
        jax.block_until_ready(engine.apply(PATH, x, i))
        idx[0] += 1

    publish_s, versions = publish_loop(engine, store, glob, r_max, rounds,
                                       serve_once)
    traces_end = trace_counts.get("batched_lora_matmul", 0)
    if traces_end != traces_mid:
        failures.append(
            f"serving retraced: {traces_mid} -> {traces_end} executables "
            "across tenant-mix churn + publishes")
    # post-publish parity: serving reflects the newest published global
    got2 = engine.apply(PATH, xs[0], ids[0])
    want2 = merged_reference(engine, PATH, xs[0], ids[0])
    rel2 = float(jnp.abs(jnp.asarray(got2, jnp.float32) - want2).max()) \
        / max(float(jnp.abs(want2).max()), 1e-12)
    if rel2 > tol:
        failures.append(f"post-publish rel diff {rel2:.2e} > {tol:.0e}")

    speedup = batched_rps / max(per_tenant_rps, 1e-9)
    row = {
        "case": {"n_tenants": n_tenants, "width": width, "r_max": r_max,
                 "batch": batch, "n_batches": n_batches,
                 "rank_multiset": sorted(int(v) for v in ranks)[:8]
                 + (["..."] if n_tenants > 8 else [])},
        "batched_rps": round(batched_rps, 1),
        "per_tenant_rps": round(per_tenant_rps, 1),
        "speedup": round(speedup, 2),
        "serving_traces": traces_end,
        "publish_ms": round(publish_s * 1e3, 2),
        "versions_published": versions,
        "parity_rel_diff": rel,
        "post_publish_rel_diff": rel2,
    }
    print(f"serve/batched/t{n_tenants}_w{width}_b{batch},"
          f"{1e6 / max(batched_rps, 1e-9) * batch:.0f},"
          f"{batched_rps:.0f}rps")
    print(f"serve/per_tenant/t{n_tenants}_w{width}_b{batch},"
          f"{1e6 / max(per_tenant_rps, 1e-9) * batch:.0f},"
          f"{per_tenant_rps:.0f}rps")
    print(f"serve/publish/t{n_tenants}_w{width},{publish_s * 1e6:.0f},"
          f"{versions}swaps")
    return row, failures


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="reduced case + hard parity/speedup/no-retrace "
                        "gate (CI)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write machine-readable results "
                        "(BENCH_serve.json)")
    p.add_argument("--tenants", type=int, default=None)
    p.add_argument("--width", type=int, default=None)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--rounds", type=int, default=4,
                   help="aggregate->publish->serve rounds")
    p.add_argument("--tol", type=float, default=5e-4,
                   help="max relative batched-vs-reference deviation")
    args = p.parse_args(argv)

    n_tenants = args.tenants or 128
    width = args.width or (128 if args.smoke else 512)
    batch = args.batch or (256 if args.smoke else 512)
    r_max = 8
    n_batches = 4 if args.smoke else 8

    row, failures = run_case(n_tenants, width, r_max, batch, n_batches,
                             args.iters, args.rounds, args.tol)
    summary = {
        "speedup_vs_jit_per_adapter": row["speedup"],
        "serving_traces": row["serving_traces"],
        "publish_ms": row["publish_ms"],
        "max_rel_diff": max(row["parity_rel_diff"],
                            row["post_publish_rel_diff"]),
    }
    print(f"# summary: {json.dumps(summary)}")

    if args.json:
        payload = bench_payload(
            "serve", smoke=bool(args.smoke),
            case=row["case"], results=row, summary=summary)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    if failures:
        for msg in failures:
            print(f"# SERVE GATE FAILURE: {msg}")
        raise SystemExit(1)
    if args.smoke:
        if n_tenants >= 128 and row["speedup"] < 4:
            print(f"# SERVE SPEEDUP GATE FAILURE: {row}")
            raise SystemExit(1)
        print("# smoke gate OK: batched==reference, "
              f">=4x over jit-per-adapter at {n_tenants} tenants, "
              "zero serving retraces across tenant mixes and publishes")


if __name__ == "__main__":
    main()
