"""Sync-round vs async-fold aggregation under a straggler distribution.

Two questions, both on CPU-runnable synthetic cohorts:

1. **Server cost**: what does one synchronous cohort ``aggregate`` cost
   vs folding the same updates one at a time (``AsyncAggregator``,
   streaming fold or replay)?  Async folding trades one big reduction
   for N small ones -- the per-update cost is what an FLaaS server
   actually pays per arrival.

2. **Time-to-aggregate**: with log-normal client report latencies (a
   heavy straggler tail), when does each client's update actually land
   in the served global?  A sync round incorporates *everything* at
   ``max(latency) + t_agg``; the async server incorporates each update
   at ``latency_i + t_fold``.  We report the mean/median incorporation
   time and the time until 50% / 90% of the cohort's update mass is
   serving -- the straggler tail hits sync rounds directly, async barely.

Run: ``PYTHONPATH=src python benchmarks/bench_async_agg.py``
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from repro.fl import AsyncAggregator
from repro.fl.selection import ClientLatencyModel
from repro.lora import init_adapters, set_ranks

SPECS = {f"blk{i}": (1024, 1024) for i in range(4)}
R_MAX = 64
METHODS = ("rbla", "zeropad", "fedavg", "rbla_ranked", "flora")
N_CLIENTS = 10
SEED = 0


def make_cohort(n=N_CLIENTS, seed=SEED):
    rng = np.random.default_rng(seed)
    ranks = rng.integers(4, R_MAX + 1, n)
    updates = []
    for i in range(n):
        ad = init_adapters(jax.random.PRNGKey(seed + i), SPECS, R_MAX,
                           int(ranks[i]))
        ad = jax.tree.map(
            lambda x: x + jnp.asarray(0.01 * rng.normal(size=x.shape),
                                      x.dtype)
            if x.dtype == jnp.float32 else x, ad)
        updates.append(ClientUpdate(adapters=set_ranks(ad, int(ranks[i])),
                                    base_trainable={},
                                    n_examples=float(rng.integers(50, 500)),
                                    rank=int(ranks[i])))
    return updates, ranks


def make_state(strategy):
    r_storage = strategy.server_storage_rank(R_MAX) or R_MAX
    adapters = init_adapters(jax.random.PRNGKey(999), SPECS, r_storage,
                             R_MAX)
    return ServerState(adapters=adapters, base_trainable={}, r_max=R_MAX)


def timed(fn, iters=3):
    """fn must return a pytree of arrays (we block on every leaf)."""
    jax.block_until_ready(jax.tree.leaves(fn()))   # warm up / compile
    t0 = time.time()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(jax.tree.leaves(out))
    return (time.time() - t0) / iters


def bench_method(method, updates):
    s = get_strategy(method)
    if s.rank_contract == "stacked":
        # wide cap: pure stacking, no SVD re-projection mid-bench
        s = s.with_options(stack_r_cap=int(sum(u.rank for u in updates))
                           + R_MAX)
    weights = [u.n_examples for u in updates]
    state0 = make_state(s)     # built once: only aggregation is timed

    # return the adapters tree (arrays), not the ServerState dataclass --
    # block_until_ready must see array leaves to measure compute
    t_sync = timed(lambda: s.aggregate(state0, updates, weights=weights,
                                       backend="ref").adapters)

    def fold_all():
        agg = AsyncAggregator(s, state0, staleness="constant",
                              backend="ref")
        for u in updates:
            agg.submit(u)
        return agg.state.adapters
    t_async_total = timed(fold_all)
    return t_sync, t_async_total / len(updates)


def time_to_quality(latencies, weights, t_sync, t_fold):
    """When is X% of the cohort's update mass serving, per mode?"""
    order = np.argsort(latencies)
    lat, w = latencies[order], weights[order] / weights.sum()
    # async: update i serves at latency_i + fold time (folds are short;
    # queueing is negligible at these rates)
    async_t = lat + t_fold
    mass = np.cumsum(w)
    t50_async = float(async_t[np.searchsorted(mass, 0.5)])
    t90_async = float(async_t[np.searchsorted(mass, 0.9)])
    # sync: nothing serves until the slowest client + one aggregate
    t_round = float(lat.max() + t_sync)
    return t50_async, t90_async, t_round


def main():
    updates, ranks = make_cohort()
    weights = np.asarray([u.n_examples for u in updates])
    lat_model = ClientLatencyModel(N_CLIENTS, median_s=30.0, sigma=0.25,
                                   straggler_sigma=1.0, seed=SEED)
    latencies = np.asarray([lat_model.sample(i) for i in range(N_CLIENTS)])

    print(f"# cohort: n={N_CLIENTS} clients, ranks {ranks.min()}.."
          f"{ranks.max()}, {len(SPECS)} pairs of {list(SPECS.values())[0]}"
          f" at r_max={R_MAX}")
    print(f"# latency: log-normal, median 30s, straggler_sigma 1.0 -> "
          f"min {latencies.min():.0f}s max {latencies.max():.0f}s")
    print("# method, sync_round_ms, async_fold_ms_per_update, "
          "t50_async_s, t90_async_s, t_sync_round_s, speedup_t90")
    for method in METHODS:
        t_sync, t_fold = bench_method(method, updates)
        t50a, t90a, t_round = time_to_quality(latencies, weights,
                                              t_sync, t_fold)
        print(f"async_agg/{method},{t_sync * 1e3:.1f},{t_fold * 1e3:.1f},"
              f"{t50a:.1f},{t90a:.1f},{t_round:.1f},"
              f"{t_round / max(t90a, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
