"""Sync-round vs async-fold aggregation, and the quantized upload path.

Three questions, all on CPU-runnable synthetic cohorts:

1. **Server cost**: what does one synchronous cohort ``aggregate`` cost
   vs folding the same updates one at a time (``AsyncAggregator``,
   streaming fold or replay)?  Async folding trades one big reduction
   for N small ones -- the per-update cost is what an FLaaS server
   actually pays per arrival.

2. **Time-to-aggregate**: with log-normal client report latencies (a
   heavy straggler tail), when does each client's update actually land
   in the served global?  A sync round incorporates *everything* at
   ``max(latency) + t_agg``; the async server incorporates each update
   at ``latency_i + t_fold``.  We report the mean/median incorporation
   time and the time until 50% / 90% of the cohort's update mass is
   serving -- the straggler tail hits sync rounds directly, async barely.

3. **Quantized transport** (``repro.core.codec``): per upload codec, the
   wire bytes a client ships, the reduction vs fp32, the end-to-end
   parity of the fused-dequant aggregate against the fp32 baseline, and
   whether alternating codec mixes re-traces warm plans.

Plus a fourth, the **durability leg** (``docs/durability.md``): the WAL
overhead per fold, a checkpoint's write cost, and a crash recovery's
restore+replay cost, with every upload redelivered and the server killed
mid-stream along the way.

``--json PATH`` writes the machine-readable ``BENCH_async.json`` so the
wire-cost trajectory is tracked across PRs; ``--smoke`` runs a tiny case
and exits non-zero if (a) the quantized aggregate drifts past its
codec's tolerance from the fp32 baseline (``none`` must be bit-exact),
(b) int8 cuts upload bytes by less than 3.5x at 128 clients, (c)
alternating between two warm codec mixes adds plan misses or executor
retraces -- the codec is only free if the plan cache survives it -- (d)
running the same warm fold loop with metrics enabled adds jitted
executors or more than ``OBS_OVERHEAD_FRAC`` wall overhead vs metrics
disabled (the ``repro.obs`` overhead guarantee; see
``docs/observability.md``) -- or the **chaos gate** trips: a redelivered
upload double-folds, crash recovery is not bit-exact, recovery re-traces
a warm fold executor, or a failed publish tears the serving snapshot.

Run: ``PYTHONPATH=src python benchmarks/bench_async_agg.py``
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from repro.fl import AsyncAggregator, DurableAggregator
from repro.fl.comm import tree_bytes
from repro.fl.selection import ClientLatencyModel
from repro.lora import init_adapters, set_ranks
from repro.obs import bench_payload, set_enabled, time_fn

FULL_SPECS = {f"blk{i}": (1024, 1024) for i in range(4)}
FULL_R_MAX = 64
#: smoke tree is tiny but wide enough that int8's per-row fp32 scale
#: overhead (4 bytes per rank row) stays under the 3.5x reduction gate
SMOKE_SPECS = {"blk0": (96, 128), "blk1": (128, 96)}
SMOKE_R_MAX = 8
METHODS = ("rbla", "zeropad", "fedavg", "rbla_ranked", "flora")
N_CLIENTS = 10
N_WIRE_CLIENTS = 128           # cohort size for the wire-reduction gate
SEED = 0

#: end-to-end aggregate tolerance per codec (relative Frobenius vs the
#: fp32 baseline): bf16 has ~2^-8 relative error, int8 ~1/254 per row
#: before averaging; ``none`` must be bit-exact
CODEC_TOL = {"none": 0.0, "bf16": 1e-2, "int8": 2e-2}
WIRE_GATE_REDUCTION = 3.5
#: metrics-enabled wall overhead bound vs disabled, plus a small absolute
#: slack so a 1-vCPU CI box's scheduler jitter cannot flake a
#: milliseconds-long smoke loop
OBS_OVERHEAD_FRAC = 0.05
OBS_OVERHEAD_ABS_S = 2e-3


def make_cohort(n, seed, specs, r_max):
    rng = np.random.default_rng(seed)
    ranks = rng.integers(max(r_max // 16, 2), r_max + 1, n)
    updates = []
    for i in range(n):
        ad = init_adapters(jax.random.PRNGKey(seed + i), specs, r_max,
                           int(ranks[i]))
        ad = jax.tree.map(
            lambda x: x + jnp.asarray(0.01 * rng.normal(size=x.shape),
                                      x.dtype)
            if x.dtype == jnp.float32 else x, ad)
        updates.append(ClientUpdate(adapters=set_ranks(ad, int(ranks[i])),
                                    base_trainable={},
                                    n_examples=float(rng.integers(50, 500)),
                                    rank=int(ranks[i])))
    return updates, ranks


def make_state(strategy, specs, r_max):
    r_storage = strategy.server_storage_rank(r_max) or r_max
    adapters = init_adapters(jax.random.PRNGKey(999), specs, r_storage,
                             r_max)
    return ServerState(adapters=adapters, base_trainable={}, r_max=r_max)


def bench_method(method, updates, specs, r_max):
    s = get_strategy(method)
    if s.rank_contract == "stacked":
        # wide cap: pure stacking, no SVD re-projection mid-bench
        s = s.with_options(stack_r_cap=int(sum(u.rank for u in updates))
                           + r_max)
    weights = [u.n_examples for u in updates]
    state0 = make_state(s, specs, r_max)   # built once: only agg is timed

    # return the adapters tree (arrays), not the ServerState dataclass --
    # block_until_ready must see array leaves to measure compute
    t_sync = time_fn(lambda: s.aggregate(state0, updates, weights=weights,
                                         backend="ref").adapters)

    def fold_all():
        agg = AsyncAggregator(s, state0, staleness="constant",
                              backend="ref")
        for u in updates:
            agg.submit(u)
        return agg.state.adapters
    t_async_total = time_fn(fold_all)
    return t_sync, t_async_total / len(updates)


def time_to_quality(latencies, weights, t_sync, t_fold):
    """When is X% of the cohort's update mass serving, per mode?"""
    order = np.argsort(latencies)
    lat, w = latencies[order], weights[order] / weights.sum()
    # async: update i serves at latency_i + fold time (folds are short;
    # queueing is negligible at these rates)
    async_t = lat + t_fold
    mass = np.cumsum(w)
    t50_async = float(async_t[np.searchsorted(mass, 0.5)])
    t90_async = float(async_t[np.searchsorted(mass, 0.9)])
    # sync: nothing serves until the slowest client + one aggregate
    t_round = float(lat.max() + t_sync)
    return t50_async, t90_async, t_round


# ----------------------------------------------------- quantized uploads --
def _rel_err(a, b):
    """Relative Frobenius distance over the adapters' float leaves."""
    num = den = 0.0
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not jnp.issubdtype(jnp.asarray(la).dtype, jnp.floating):
            continue
        d = jnp.asarray(la, jnp.float32) - jnp.asarray(lb, jnp.float32)
        num += float(jnp.sum(d * d))
        den += float(jnp.sum(jnp.asarray(la, jnp.float32) ** 2))
    return (num / max(den, 1e-30)) ** 0.5


def bench_codecs(updates, specs, r_max):
    """One buffered flush per codec through the full service path; the
    fp32 run is the parity baseline.  Wire bytes come from the service's
    own intake accounting (post-codec, pre-decode)."""
    s = get_strategy("rbla")
    n = len(updates)
    rows, baseline = [], None
    for name in codec.CODECS:
        enc = [codec.encode_update(u, name) for u in updates]
        agg = AsyncAggregator("rbla", make_state(s, specs, r_max),
                              buffer_size=n, backend="ref")
        t0 = time.time()
        for u in enc:
            agg.submit(u)
        jax.block_until_ready(jax.tree.leaves(agg.state.adapters))
        flush_ms = (time.time() - t0) * 1e3
        if baseline is None:
            baseline = agg
        rows.append({
            "codec": name,
            "wire_bytes_per_client": agg.wire_bytes_received // n,
            "reduction_vs_fp32": (baseline.wire_bytes_received
                                  / max(agg.wire_bytes_received, 1)),
            "parity_rel_err": _rel_err(baseline.state.adapters,
                                       agg.state.adapters),
            "flush_ms": flush_ms,
        })
    return rows


def wire_reduction_at_scale(specs, r_max, n=N_WIRE_CLIENTS):
    """Upload-byte reduction of int8 vs fp32 over an n-client cohort
    (pure accounting -- no aggregation)."""
    updates, _ = make_cohort(n, SEED + 1, specs, r_max)
    plain = sum(tree_bytes(u.adapters) + tree_bytes(u.base_trainable)
                for u in updates)
    quant = sum(tree_bytes(codec.encode_adapters(u.adapters, "int8"))
                + tree_bytes(u.base_trainable) for u in updates)
    return plain / max(quant, 1), plain, quant


def retrace_check(updates, specs, r_max):
    """Warm two codec mixes, then alternate: the per-(width, dtype,
    codec-mix) plan cache must absorb every repeat -- zero new misses,
    zero new jitted executors."""
    s = get_strategy("rbla")
    n = len(updates)
    half = ["int8" if i % 2 else "bf16" for i in range(n)]
    mixes = [["int8"] * n, half]
    agg = AsyncAggregator(s, make_state(s, specs, r_max), buffer_size=n,
                          backend="ref")
    for mix in mixes:                                   # warm both
        for u, c in zip(updates, mix):
            agg.submit(codec.encode_update(u, c))
    strat = agg.strategy
    stats0 = dict(strat.__dict__.get("plan_stats", {}))
    execs0 = len(strat.__dict__.get("_plan_exec_cache", {}))
    for _ in range(2):                                  # alternate, warm
        for mix in mixes:
            for u, c in zip(updates, mix):
                agg.submit(codec.encode_update(u, c))
    stats1 = dict(strat.__dict__.get("plan_stats", {}))
    execs1 = len(strat.__dict__.get("_plan_exec_cache", {}))
    return {
        "new_plan_misses": stats1.get("misses", 0) - stats0.get("misses", 0),
        "new_executors": execs1 - execs0,
        "plan_hits": stats1.get("hits", 0) - stats0.get("hits", 0),
    }


def obs_overhead_check(updates, specs, r_max, iters=5):
    """The observability overhead gate: the same warm fold loop with
    metrics enabled must add zero jitted executors and no more than
    ``OBS_OVERHEAD_FRAC`` wall time (plus ``OBS_OVERHEAD_ABS_S`` noise
    slack) over metrics disabled.  Min-over-iters on both sides -- same
    1-vCPU-noise reasoning as every other timing here."""
    s = get_strategy("rbla")
    n = len(updates)

    def run():
        agg = AsyncAggregator(s, make_state(s, specs, r_max),
                              buffer_size=n, backend="ref")
        for _ in range(3):              # 3 flushes: a timeable region
            for u in updates:
                agg.submit(u)
        return agg.state.adapters

    prev = set_enabled(False)
    try:
        t_off = time_fn(run, iters=iters)
        set_enabled(True)
        execs0 = len(s.__dict__.get("_plan_exec_cache", {}))
        t_on = time_fn(run, iters=iters)
        execs1 = len(s.__dict__.get("_plan_exec_cache", {}))
    finally:
        set_enabled(prev)
    return {
        "t_disabled_ms": t_off * 1e3,
        "t_enabled_ms": t_on * 1e3,
        "overhead_frac": t_on / max(t_off, 1e-12) - 1.0,
        "new_executors": execs1 - execs0,
    }


# --------------------------------------------------------- crash recovery --
def recovery_check(updates, specs, r_max):
    """Durability leg: what the WAL + checkpoint layer costs per upload,
    what one snapshot and one crash recovery cost, and the chaos
    invariants the ``--smoke`` gate enforces -- redeliver every upload
    (zero double-folds), crash mid-stream and recover (bit-exact state),
    and recovery must reuse the warm fold executors (zero retraces: the
    registry strategy singleton keeps its plan cache across service
    incarnations)."""
    s = get_strategy("rbla")
    n = len(updates)
    ids = [f"u{i}" for i in range(n)]

    oracle = AsyncAggregator(s, make_state(s, specs, r_max), backend="ref")
    t0 = time.time()
    for u, uid in zip(updates, ids):
        oracle.submit(u, update_id=uid)
    jax.block_until_ready(jax.tree.leaves(oracle.state.adapters))
    plain_ms = (time.time() - t0) * 1e3 / n

    with tempfile.TemporaryDirectory() as d:
        agg = DurableAggregator(s, make_state(s, specs, r_max), dir=d,
                                checkpoint_every=0, wal_fsync=False,
                                backend="ref")
        cut = n // 2
        double_folds = 0
        t0 = time.time()
        for u, uid in zip(updates[:cut], ids[:cut]):
            v0 = agg.version
            agg.submit(u, update_id=uid)
            v1 = agg.version
            # at-least-once transport: redeliver every upload -- the
            # dedup window must fold it exactly once
            agg.submit(u, update_id=uid)
            double_folds += int(agg.version != v1 or v1 != v0 + 1)
        durable_ms = (time.time() - t0) * 1e3 / cut
        t0 = time.time()
        agg.checkpoint()
        checkpoint_ms = (time.time() - t0) * 1e3
        for u, uid in zip(updates[cut:], ids[cut:]):       # the WAL tail
            agg.submit(u, update_id=uid)
            agg.submit(u, update_id=uid)
        wal_bytes = agg.wal.bytes_written
        execs0 = len(s.__dict__.get("_plan_exec_cache", {}))
        agg.close()                                        # crash

        t0 = time.time()
        recovered = DurableAggregator(s, make_state(s, specs, r_max),
                                      dir=d, checkpoint_every=0,
                                      wal_fsync=False, backend="ref")
        restore_ms = (time.time() - t0) * 1e3
        execs1 = len(s.__dict__.get("_plan_exec_cache", {}))

    bit_exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(recovered.state.adapters),
                        jax.tree.leaves(oracle.state.adapters)))
    double_folds += int(recovered.version != oracle.version)
    return {
        "plain_fold_ms_per_update": plain_ms,
        "durable_fold_ms_per_update": durable_ms,
        "wal_overhead_frac": durable_ms / max(plain_ms, 1e-9) - 1.0,
        "checkpoint_ms": checkpoint_ms,
        "restore_ms": restore_ms,
        "n_replayed": recovered.n_replayed,
        "wal_bytes": wal_bytes,
        "bit_exact_recovery": bit_exact,
        "double_folds": double_folds,
        "new_executors": execs1 - execs0,
    }


def serving_chaos_check(specs, r_max):
    """No torn serving snapshots under publish failures: hot-swaps that
    raise must leave readers on the last committed snapshot (outputs
    bit-identical before/after the failed attempt), and the retried
    publish must land the newest pending tree."""
    from repro.serving import AdapterStore, ServingEngine

    rng = np.random.default_rng(SEED)
    store = AdapterStore(specs, r_max=r_max)
    weights = {p: jnp.asarray(rng.normal(size=(fi, fo)) * 0.1, jnp.float32)
               for p, (fo, fi) in specs.items()}
    eng = ServingEngine(weights, store, interpret=True)

    def tree(seed):
        ad = init_adapters(jax.random.PRNGKey(seed), specs, r_max, r_max)
        return jax.tree.map(
            lambda x: x + jnp.asarray(
                rng.normal(size=x.shape), x.dtype)
            if x.dtype == jnp.float32 else x, ad)

    eng.publish(tree(0))
    path = next(iter(specs))
    x = jnp.asarray(rng.normal(size=(4, specs[path][1])), jnp.float32)
    tid = jnp.zeros((4,), jnp.int32)
    y_before = eng.apply(path, x, tid)

    orig, broken = store.publish, {"on": True}

    def flaky_publish(t):
        if broken["on"]:
            raise RuntimeError("injected publish fault")
        return orig(t)

    store.publish = flaky_publish
    pub = eng.publisher(max_backoff=2)

    class _S:
        def __init__(self, adapters):
            self.adapters = adapters

    pub(_S(tree(1)))                       # fails -> quarantined
    y_during = eng.apply(path, x, tid)     # readers: last committed snap
    torn = not np.array_equal(np.asarray(y_before), np.asarray(y_during))
    failures = eng.n_publish_failures
    broken["on"] = False
    pub(_S(tree(2)))                       # backoff skip
    pub(_S(tree(3)))                       # retry lands the newest tree
    recovered = store.version == 2
    store.publish = orig
    return {"publish_failures": failures, "torn_snapshot": torn,
            "recovered_publish": recovered}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny case + hard gates (CI)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write machine-readable results (BENCH_async.json)")
    args = p.parse_args(argv)

    specs = SMOKE_SPECS if args.smoke else FULL_SPECS
    r_max = SMOKE_R_MAX if args.smoke else FULL_R_MAX
    n = 6 if args.smoke else N_CLIENTS
    updates, ranks = make_cohort(n, SEED, specs, r_max)
    weights = np.asarray([u.n_examples for u in updates])
    lat_model = ClientLatencyModel(n, median_s=30.0, sigma=0.25,
                                   straggler_sigma=1.0, seed=SEED)
    latencies = np.asarray([lat_model.sample(i) for i in range(n)])

    print(f"# cohort: n={n} clients, ranks {ranks.min()}.."
          f"{ranks.max()}, {len(specs)} pairs of {list(specs.values())[0]}"
          f" at r_max={r_max}")
    print(f"# latency: log-normal, median 30s, straggler_sigma 1.0 -> "
          f"min {latencies.min():.0f}s max {latencies.max():.0f}s")
    print("# method, sync_round_ms, async_fold_ms_per_update, "
          "t50_async_s, t90_async_s, t_sync_round_s, speedup_t90, "
          "wire_bytes_per_client")
    method_rows = []
    plain_wire = (tree_bytes(updates[0].adapters)
                  + tree_bytes(updates[0].base_trainable))
    for method in METHODS:
        t_sync, t_fold = bench_method(method, updates, specs, r_max)
        t50a, t90a, t_round = time_to_quality(latencies, weights,
                                              t_sync, t_fold)
        print(f"async_agg/{method},{t_sync * 1e3:.1f},{t_fold * 1e3:.1f},"
              f"{t50a:.1f},{t90a:.1f},{t_round:.1f},"
              f"{t_round / max(t90a, 1e-9):.2f}x,{plain_wire}")
        method_rows.append({"method": method, "sync_ms": t_sync * 1e3,
                            "fold_ms": t_fold * 1e3, "t90_async_s": t90a,
                            "t_sync_round_s": t_round,
                            "wire_bytes_per_client": plain_wire})

    print("# codec, wire_bytes_per_client, reduction_vs_fp32, "
          "parity_rel_err, flush_ms")
    codec_rows = bench_codecs(updates, specs, r_max)
    for row in codec_rows:
        print(f"async_agg/codec/{row['codec']},"
              f"{row['wire_bytes_per_client']},"
              f"{row['reduction_vs_fp32']:.2f}x,"
              f"{row['parity_rel_err']:.2e},{row['flush_ms']:.1f}")

    reduction, plain_b, quant_b = wire_reduction_at_scale(specs, r_max)
    print(f"# wire @ {N_WIRE_CLIENTS} clients: fp32 {plain_b} B, "
          f"int8 {quant_b} B -> {reduction:.2f}x reduction")
    retrace = retrace_check(updates, specs, r_max)
    print(f"# codec-mix alternation: {retrace['plan_hits']} plan hits, "
          f"{retrace['new_plan_misses']} new misses, "
          f"{retrace['new_executors']} new executors")
    obs_row = obs_overhead_check(updates, specs, r_max)
    print(f"# obs overhead: metrics off {obs_row['t_disabled_ms']:.1f}ms, "
          f"on {obs_row['t_enabled_ms']:.1f}ms "
          f"({obs_row['overhead_frac'] * 100:+.1f}%), "
          f"{obs_row['new_executors']} new executors")
    rec = recovery_check(updates, specs, r_max)
    print(f"# durability: fold {rec['plain_fold_ms_per_update']:.1f}ms -> "
          f"{rec['durable_fold_ms_per_update']:.1f}ms/update with WAL "
          f"({rec['wal_overhead_frac'] * 100:+.0f}%), checkpoint "
          f"{rec['checkpoint_ms']:.1f}ms, recover {rec['restore_ms']:.1f}ms "
          f"({rec['n_replayed']} replayed), bit_exact="
          f"{rec['bit_exact_recovery']}, double_folds={rec['double_folds']},"
          f" new_executors={rec['new_executors']}")
    serve_chaos = serving_chaos_check(specs, r_max)
    print(f"# publish chaos: {serve_chaos['publish_failures']} injected "
          f"failures, torn_snapshot={serve_chaos['torn_snapshot']}, "
          f"recovered_publish={serve_chaos['recovered_publish']}")

    if args.json:
        payload = bench_payload(
            "async_agg", smoke=bool(args.smoke),
            case={"specs": {k: list(v) for k, v in specs.items()},
                  "r_max": r_max, "n_clients": n,
                  "n_wire_clients": N_WIRE_CLIENTS},
            results={
                "methods": method_rows,
                "codecs": codec_rows,
                "wire_reduction_int8_at_scale": reduction,
                "retrace": retrace,
                "obs_overhead": obs_row,
                "recovery": rec,
                "serving_chaos": serve_chaos,
            })
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    if args.smoke:
        failures = []
        for row in codec_rows:
            tol = CODEC_TOL[row["codec"]]
            if row["parity_rel_err"] > tol:
                failures.append(
                    f"{row['codec']} parity {row['parity_rel_err']:.2e} "
                    f"> tol {tol:g}")
        if reduction < WIRE_GATE_REDUCTION:
            failures.append(
                f"int8 wire reduction {reduction:.2f}x < "
                f"{WIRE_GATE_REDUCTION}x at {N_WIRE_CLIENTS} clients")
        if retrace["new_plan_misses"] or retrace["new_executors"]:
            failures.append(
                f"codec-mix alternation re-traced: "
                f"{retrace['new_plan_misses']} misses, "
                f"{retrace['new_executors']} executors")
        if obs_row["new_executors"]:
            failures.append(
                f"metrics-enabled fold loop added "
                f"{obs_row['new_executors']} jitted executors")
        allowed = (obs_row["t_disabled_ms"] * OBS_OVERHEAD_FRAC
                   + OBS_OVERHEAD_ABS_S * 1e3)
        if obs_row["t_enabled_ms"] - obs_row["t_disabled_ms"] > allowed:
            failures.append(
                f"metrics overhead {obs_row['overhead_frac'] * 100:.1f}% "
                f"(+{obs_row['t_enabled_ms'] - obs_row['t_disabled_ms']:.2f}"
                f"ms) past {OBS_OVERHEAD_FRAC * 100:.0f}% "
                f"+ {OBS_OVERHEAD_ABS_S * 1e3:.0f}ms")
        # chaos gate (docs/durability.md): exactly-once, bit-exact,
        # no torn serving, no recovery retraces
        if rec["double_folds"]:
            failures.append(
                f"{rec['double_folds']} redelivered uploads double-folded "
                "past the dedup window")
        if not rec["bit_exact_recovery"]:
            failures.append(
                "crash recovery diverged from the uninterrupted run "
                "(must be bit-exact for incremental strategies)")
        if rec["new_executors"]:
            failures.append(
                f"crash recovery re-traced {rec['new_executors']} fold "
                "executors (registry singleton must keep plans warm)")
        if serve_chaos["torn_snapshot"]:
            failures.append(
                "a failed publish tore the serving snapshot (readers must "
                "stay on the last committed version)")
        if not serve_chaos["recovered_publish"]:
            failures.append(
                "publish retry never landed after the fault cleared")
        if failures:
            for msg in failures:
                print(f"# SMOKE FAIL: {msg}")
            return 1
        print("# smoke gate OK: codec parity within tolerance, int8 wire "
              f"reduction >= {WIRE_GATE_REDUCTION}x, zero retraces on "
              "codec-mix alternation, metrics overhead within "
              f"{OBS_OVERHEAD_FRAC * 100:.0f}%, chaos gate clean "
              "(exactly-once, bit-exact recovery, no torn serving "
              "snapshots, zero recovery retraces)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
