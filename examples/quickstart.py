"""Quickstart: 6 rounds of heterogeneous-rank LoRA federated learning with
RBLA aggregation on a synthetic MNIST analogue.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.fl import FLConfig, run_simulation

cfg = FLConfig(
    dataset="mnist", model="mlp",
    method="rbla",              # any registered AggregationStrategy name:
                                # "zeropad", "fft", "rbla_ranked",
                                # "rbla_norm", "svd" (see docs/strategies.md)
    rounds=6, n_clients=10,
    n_per_class=200, n_test_per_class=50,
    local_epochs=2, lr=0.05,
    r_max=64,                   # client i gets rank ~ r_max * 0.1 * |labels|
    seed=42,
)

if __name__ == "__main__":
    hist = run_simulation(cfg, verbose=True)
    print("\nper-round test accuracy:",
          " ".join(f"{a:.3f}" for a in hist.test_acc))
