"""Heterogeneous ranks under the hood: Alg. 2 slicing, delta masks, and the
difference between zero-padding and RBLA on a single adapter -- then the
same aggregation as a distributed shard_map psum on 8 simulated devices.

    PYTHONPATH=src python examples/heterogeneous_ranks.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import get_strategy, rbla_leaf, stacked_rank_masks, \
    zeropad_leaf
from repro.lora import init_pair, set_ranks, pair_masks

R_MAX, FAN_IN, FAN_OUT = 8, 16, 12
N_CLIENTS = 8

print("== Alg. 2: clients slice the server adapter to their rank ==")
server_pair = init_pair(jax.random.PRNGKey(0), FAN_OUT, FAN_IN, R_MAX,
                        R_MAX)
for rank in (2, 5, 8):
    client = set_ranks(server_pair, rank)
    live_rows = int((np.abs(np.asarray(client["A"])).sum(-1) > 0).sum())
    print(f"  client rank {rank}: live A rows = {live_rows}/{R_MAX}")

print("\n== zero-padding dilution vs RBLA preservation (paper Sec. 3) ==")
rng = np.random.default_rng(42)
ranks = jnp.asarray(rng.integers(1, R_MAX + 1, N_CLIENTS), jnp.int32)
masks = stacked_rank_masks(R_MAX, ranks)[:, :, None]
stacked = jnp.asarray(rng.normal(size=(N_CLIENTS, R_MAX, FAN_IN)),
                      jnp.float32) * masks + masks  # mean ~1 on live rows
w = jnp.ones(N_CLIENTS)
zp = zeropad_leaf(stacked, masks, w)
rb = rbla_leaf(stacked, masks, w)
owners = np.asarray(masks[:, :, 0]).sum(0)
for row in range(R_MAX):
    print(f"  row {row}: owners={int(owners[row])}  "
          f"|zp|={float(jnp.abs(zp[row]).mean()):.3f}  "
          f"|rbla|={float(jnp.abs(rb[row]).mean()):.3f}")
print("  (zero-padding shrinks scarce rows by owners/n; RBLA does not)")

print("\n== FLoRA stacking: rank-growing, noise-free aggregation ==")
# the flora strategy concatenates client factors instead of averaging
# rows: the served update is *exactly* the convex combination of the
# clients' effective updates, at the price of a growing global rank
from repro.lora import init_adapters, set_ranks as _set_ranks

SPECS = {"fc": (FAN_OUT, FAN_IN)}
cohort, keys = [], jax.random.split(jax.random.PRNGKey(3), 3)
cranks = (2, 3, 5)
for k, r in zip(keys, cranks):
    ad = init_adapters(k, SPECS, R_MAX, r)
    ad = jax.tree.map(lambda x: x + 0.1 if x.dtype == jnp.float32 else x,
                      ad)           # randomize B too (it inits to zero)
    cohort.append(_set_ranks(ad, r))
flora = get_strategy("flora").with_options(stack_r_cap=32)
wf = jnp.ones(len(cohort))
glob = flora.aggregate_adapters(cohort, wf, r_max=R_MAX,
                                client_ranks=jnp.asarray(cranks))
print(f"  client ranks {cranks} -> stacked global rank "
      f"{int(glob['fc']['rank'])} (storage {glob['fc']['A'].shape[-2]})")
eff = np.asarray(glob["fc"]["B"] @ glob["fc"]["A"]) / int(glob["fc"]["rank"])
want = sum(np.asarray(c["fc"]["B"] @ c["fc"]["A"]) / r
           for c, r in zip(cohort, cranks)) / len(cohort)
print(f"  served update == mean client update: max |diff| = "
      f"{np.abs(eff - want).max():.2e}  (stacking is noise-free)")
nxt = flora.aggregate_adapters(cohort, wf, r_max=R_MAX,
                               client_ranks=jnp.asarray(cranks),
                               prev_global=glob)
print(f"  next round stacks the previous global as one more contributor: "
      f"rank {int(glob['fc']['rank'])} -> {int(nxt['fc']['rank'])}")
capped = flora.with_options(stack_r_cap=R_MAX).aggregate_adapters(
    cohort, wf, r_max=R_MAX, client_ranks=jnp.asarray(cranks))
print(f"  with stack_r_cap={R_MAX} the same cohort SVD-reprojects back "
      f"to rank {int(capped['fc']['rank'])}")

print("\n== the same aggregation as a pod-level collective ==")
# every registered strategy carries its own distributed shard_map path:
mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(8), ("clients",))
agg = get_strategy("rbla").make_distributed_aggregator(
    mesh, client_axis="clients")
sh = NamedSharding(mesh, P("clients"))
out = agg(jax.device_put(stacked, sh),
          jax.device_put(jnp.broadcast_to(masks, stacked.shape), sh),
          jax.device_put(w, sh))
np.testing.assert_allclose(np.asarray(out), np.asarray(rb), rtol=1e-5,
                           atol=1e-6)
print(f"  masked-psum over {len(jax.devices())} devices matches the "
      "host result (max |diff| = "
      f"{float(jnp.abs(out - rb).max()):.2e})")
