"""Heterogeneous ranks under the hood: Alg. 2 slicing, delta masks, and the
difference between zero-padding and RBLA on a single adapter -- then the
same aggregation as a distributed shard_map psum on 8 simulated devices.

    PYTHONPATH=src python examples/heterogeneous_ranks.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import get_strategy, rbla_leaf, stacked_rank_masks, \
    zeropad_leaf
from repro.lora import init_pair, set_ranks, pair_masks

R_MAX, FAN_IN, FAN_OUT = 8, 16, 12
N_CLIENTS = 8

print("== Alg. 2: clients slice the server adapter to their rank ==")
server_pair = init_pair(jax.random.PRNGKey(0), FAN_OUT, FAN_IN, R_MAX,
                        R_MAX)
for rank in (2, 5, 8):
    client = set_ranks(server_pair, rank)
    live_rows = int((np.abs(np.asarray(client["A"])).sum(-1) > 0).sum())
    print(f"  client rank {rank}: live A rows = {live_rows}/{R_MAX}")

print("\n== zero-padding dilution vs RBLA preservation (paper Sec. 3) ==")
rng = np.random.default_rng(42)
ranks = jnp.asarray(rng.integers(1, R_MAX + 1, N_CLIENTS), jnp.int32)
masks = stacked_rank_masks(R_MAX, ranks)[:, :, None]
stacked = jnp.asarray(rng.normal(size=(N_CLIENTS, R_MAX, FAN_IN)),
                      jnp.float32) * masks + masks  # mean ~1 on live rows
w = jnp.ones(N_CLIENTS)
zp = zeropad_leaf(stacked, masks, w)
rb = rbla_leaf(stacked, masks, w)
owners = np.asarray(masks[:, :, 0]).sum(0)
for row in range(R_MAX):
    print(f"  row {row}: owners={int(owners[row])}  "
          f"|zp|={float(jnp.abs(zp[row]).mean()):.3f}  "
          f"|rbla|={float(jnp.abs(rb[row]).mean()):.3f}")
print("  (zero-padding shrinks scarce rows by owners/n; RBLA does not)")

print("\n== the same aggregation as a pod-level collective ==")
# every registered strategy carries its own distributed shard_map path:
mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(8), ("clients",))
agg = get_strategy("rbla").make_distributed_aggregator(
    mesh, client_axis="clients")
sh = NamedSharding(mesh, P("clients"))
out = agg(jax.device_put(stacked, sh),
          jax.device_put(jnp.broadcast_to(masks, stacked.shape), sh),
          jax.device_put(w, sh))
np.testing.assert_allclose(np.asarray(out), np.asarray(rb), rtol=1e-5,
                           atol=1e-6)
print(f"  masked-psum over {len(jax.devices())} devices matches the "
      "host result (max |diff| = "
      f"{float(jnp.abs(out - rb).max()):.2e})")
