"""LoRA fine-tuning of a transformer LM on synthetic Markov token streams
-- the single-host analogue of the pod-scale ``launch/train.py`` loop.

Default is a quick ~15M-param demonstration; ``--preset 100m --steps 300``
runs the full ~100M-parameter / few-hundred-step driver (slow on CPU, the
configuration the assignment names; on TPU it is minutes).

    PYTHONPATH=src python examples/finetune_lm.py --steps 60
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, BlockSpec, Stage
from repro.data import make_lm_dataset
from repro.lora import attach_ranks, strip_ranks
from repro.models.model import make_model
from repro.optim import adam, apply_updates

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)  ~params
    "15m": (4, 256, 8, 4, 1024, 2048),
    "100m": (12, 768, 12, 4, 3072, 16384),
}


def make_cfg(preset: str) -> ArchConfig:
    l, d, h, kv, f, v = PRESETS[preset]
    return ArchConfig(
        name=f"lm-{preset}", arch_type="dense", source="examples",
        d_model=d, n_heads=h, n_kv_heads=kv, head_dim=d // h, d_ff=f,
        vocab_size=v,
        stages=(Stage(unit=(BlockSpec(),), repeat=l),),
        dtype="float32", lora_r_max=32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="15m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    model = make_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    adapters = model.init_adapters(jax.random.PRNGKey(1), rank=args.rank)
    n_lora = sum(int(x.size) for x in jax.tree.leaves(adapters))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{n_lora / 1e6:.2f}M LoRA params (rank {args.rank})")

    data = make_lm_dataset(cfg.vocab_size, args.seq + 1,
                           n_seqs=args.batch * 64, seed=42)
    factors, ranks = strip_ranks(adapters)
    # the base here is random, not pretrained: train embeddings + head
    # alongside the adapters (standard when no pretrained base exists);
    # all transformer blocks stay frozen + LoRA.
    trainable = (factors, {"embed": params["embed"],
                           "lm_head": params["lm_head"]})
    frozen = {k: v for k, v in params.items()
              if k not in ("embed", "lm_head")}
    opt = adam(args.lr)
    opt_state = opt.init(trainable)

    @jax.jit
    def step(trainable, opt_state, tokens):
        def loss_fn(tr):
            f, head = tr
            p = dict(frozen)
            p.update(head)
            return model.loss(p, attach_ranks(f, ranks),
                              {"tokens": tokens})
        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        return apply_updates(trainable, updates), opt_state, loss

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.steps):
        ix = rng.integers(0, len(data), args.batch)
        trainable, opt_state, loss = step(trainable, opt_state,
                                          jnp.asarray(data[ix]))
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    print(f"finished {args.steps} steps in {time.time() - t0:.1f}s; "
          "loss must be well below ln(vocab) = "
          f"{np.log(cfg.vocab_size):.2f} if LoRA learned the stream")


if __name__ == "__main__":
    main()
