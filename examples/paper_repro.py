"""End-to-end driver: the paper's experiment (Table 1 / Figs 5-10 analogue).

Trains the global model with federated LoRA across 10 staircase-non-IID
clients until target accuracy (or --rounds), for each requested
aggregation method, and prints the rounds-to-target comparison.

    PYTHONPATH=src python examples/paper_repro.py \
        --dataset mnist --model mlp --rounds 50 --target 0.95

The full-participation + random-20% pair reproduces the paper's left/right
subfigures.  Seed fixed to 42 like the paper.
"""
from __future__ import annotations

import argparse

from repro.fl import FLConfig, run_simulation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "fmnist", "cifar", "cinic"])
    ap.add_argument("--model", default="mlp",
                    choices=["mlp", "cnn_mnist", "cnn_cifar"])
    ap.add_argument("--methods", default="rbla,zeropad,fft",
                    help="comma-separated registered strategy names "
                         "(see repro.core.list_strategies(); e.g. add "
                         "rbla_ranked,rbla_norm,svd)")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--target", type=float, default=0.90)
    ap.add_argument("--n-per-class", type=int, default=400)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=None)
    args = ap.parse_args()

    opt = "adam" if args.dataset in ("cifar", "cinic") else "sgd"
    # 0.05: lr 0.1 diverges for the FFT baseline under the staircase
    lr = args.lr or (1e-3 if opt == "adam" else 0.05)

    summary = {}
    for method in args.methods.split(","):
        cfg = FLConfig(dataset=args.dataset, model=args.model,
                       method=method, rounds=args.rounds,
                       n_per_class=args.n_per_class,
                       n_test_per_class=max(50, args.n_per_class // 4),
                       local_epochs=2, optimizer=opt, lr=lr,
                       participation=args.participation, seed=42)
        print(f"=== {method} ===")
        hist = run_simulation(cfg, verbose=True)
        summary[method] = (hist.rounds_to_target(args.target),
                           max(hist.test_acc))

    print(f"\nrounds to reach {args.target:.0%} "
          f"({args.dataset}/{args.model}, "
          f"participation={args.participation}):")
    for method, (r2t, best) in summary.items():
        print(f"  {method:>10s}: "
              f"{r2t if r2t else f'N/A (best {best:.4f})'}")


if __name__ == "__main__":
    main()
