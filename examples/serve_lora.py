"""Serve a LoRA-adapted model: prefill a prompt batch, then decode with
the KV cache -- the decode_32k/long_500k path at laptop scale.

Uses a reduced h2o-danube config (SWA ring cache) by default; --arch picks
any assigned architecture's reduced variant.  The decode loop runs one
process-cached jitted step with the KV cache buffer **donated** back into
itself, so steady-state decode reuses a single cache allocation instead of
copying it every token; throughput is reported as aggregate tokens/sec
(batch x steps) after a one-step warmup.

    PYTHONPATH=src python examples/serve_lora.py --arch gemma2-9b --new 16

For *multi-tenant adapter* serving (many LoRA ranks, one executable) see
``benchmarks/bench_serve.py`` and ``docs/serving.md``.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import make_model


@functools.cache
def _decode_step_jit(model):
    """One jitted decode step per model, cached for the process (repeat
    runs never re-jit) -- the cache argument is donated so every step
    writes into the buffer it just read."""
    return jax.jit(model.decode_step, donate_argnums=(2,))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    adapters = model.init_adapters(jax.random.PRNGKey(1), rank=8)

    rng = np.random.default_rng(0)
    total = args.prompt_len + args.new
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.frontend_dim)),
            jnp.float32)
    n_prefix = 0
    if cfg.frontend == "vision_patches":
        batch["patches"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.n_prefix_tokens, cfg.frontend_dim)),
            jnp.float32)
        n_prefix = cfg.n_prefix_tokens

    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, a, b: model.prefill(p, a, b, capacity=total + n_prefix)
    )(params, adapters, batch)
    print(f"prefill {args.prompt_len} tokens x{args.batch}: "
          f"{time.time() - t0:.2f}s")

    decode = _decode_step_jit(model)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [tok]
    steps = args.new - 1
    t_first = time.time()
    timed_steps = 0
    t0 = None
    for i in range(steps):
        pos = jnp.asarray(args.prompt_len + n_prefix + i, jnp.int32)
        # donated: `caches` is consumed here and its buffer handed back
        # as the new cache -- one resident cache allocation for the loop
        logits, caches = decode(params, adapters, caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
        if t0 is None:                  # step 0 pays the compile; time
            jax.block_until_ready(tok)  # steady state from step 1 on
            print(f"decode warmup (compile): {time.time() - t_first:.2f}s")
            t0 = time.time()
        else:
            timed_steps += 1
    jax.block_until_ready(tok)
    dt = time.time() - t0 if timed_steps else 0.0
    if timed_steps:
        print(f"decoded {timed_steps} steady-state steps in {dt:.2f}s: "
              f"{timed_steps * args.batch / max(dt, 1e-9):.1f} tok/s "
              f"({timed_steps / max(dt, 1e-9):.1f} tok/s/seq greedy)")
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print("generated token ids (seq 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
