from .synthetic import SPECS, Dataset, make_dataset, make_lm_dataset
from .partition import ClientData, staircase_partition
from .pipeline import device_batches, epoch_batches, sample_batch_indices

__all__ = ["SPECS", "Dataset", "make_dataset", "make_lm_dataset",
           "ClientData", "staircase_partition", "device_batches",
           "epoch_batches", "sample_batch_indices"]
