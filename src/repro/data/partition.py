"""Staircase non-IID partitioner (paper Section 5.2).

Client 1 holds samples of label 0 only; client 2 holds labels {0,1}; ...
client N holds all labels -- a long-tail "stair" over label diversity.
Per-client sample counts also grow with the stair (specialized clinics are
small, general hospitals are big, in the paper's analogy).

The LoRA rank ratio assigned to each client scales with its label count:
``rank_i = max(1, round(r_max * ratio_step * n_labels_i))`` with
``ratio_step = 0.1`` per the paper.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .synthetic import Dataset


class ClientData(NamedTuple):
    x: np.ndarray
    y: np.ndarray
    n: int                 # true sample count (arrays may be padded)
    labels: tuple[int, ...]
    rank: int


def staircase_partition(ds: Dataset, n_clients: int, r_max: int,
                        ratio_step: float = 0.1, seed: int = 42,
                        pad_to_max: bool = True) -> list[ClientData]:
    rng = np.random.default_rng(seed)
    n_classes = int(ds.y.max()) + 1
    by_label = {c: np.flatnonzero(ds.y == c) for c in range(n_classes)}
    for idx in by_label.values():
        rng.shuffle(idx)
    cursor = {c: 0 for c in range(n_classes)}

    # label c is held by clients c..n_clients-1  -> split its samples among
    # them with weights growing toward later clients (long tail).
    shares: dict[int, list[tuple[int, int]]] = {c: [] for c in range(n_classes)}
    for c in range(n_classes):
        holders = list(range(min(c, n_clients - 1), n_clients))
        base = len(by_label[c]) // len(holders)
        counts = [base] * len(holders)
        counts[-1] += len(by_label[c]) - base * len(holders)
        for h, k in zip(holders, counts):
            shares[c].append((h, max(int(k), 1)))

    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        for h, k in shares[c]:
            lo = cursor[c]
            client_idx[h].extend(by_label[c][lo:lo + k].tolist())
            cursor[c] += k

    clients = []
    max_n = max(len(ix) for ix in client_idx)
    for i, ix in enumerate(client_idx):
        ix = np.asarray(ix, np.int64)
        rng.shuffle(ix)
        x, y = ds.x[ix], ds.y[ix]
        n = len(ix)
        if pad_to_max and n < max_n:    # pad by resampling (uniform jit shapes)
            extra = rng.choice(ix, size=max_n - n, replace=True) if n else \
                np.zeros(max_n, np.int64)
            x = np.concatenate([x, ds.x[extra]])
            y = np.concatenate([y, ds.y[extra]])
        labels = tuple(sorted(set(int(v) for v in ds.y[ix]))) if n else ()
        n_labels = len(labels)
        rank = max(1, round(r_max * ratio_step * max(n_labels, 1)))
        clients.append(ClientData(x, y, n, labels, min(rank, r_max)))
    return clients
