"""Batching pipeline: deterministic, jit-friendly batch index sampling.

Client datasets are padded to a common length (see ``partition``); batches
are drawn by sampling indices < n_true with a folded-in PRNG key, so one
compiled ``local_fit`` serves every client regardless of dataset size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_batch_indices(key: jax.Array, n_true: jax.Array, batch: int,
                         n_steps: int) -> jax.Array:
    """(n_steps, batch) int32 indices uniform in [0, n_true)."""
    u = jax.random.uniform(key, (n_steps, batch))
    return (u * jnp.maximum(n_true, 1).astype(jnp.float32)).astype(jnp.int32)


def epoch_batches(n: int, batch: int, seed: int) -> np.ndarray:
    """Host-side shuffled epoch index matrix (n_batches, batch)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    n_batches = n // batch
    return idx[: n_batches * batch].reshape(n_batches, batch)


def device_batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int):
    """Simple epoch iterator used by examples and eval loops."""
    for ix in epoch_batches(len(x), batch, seed):
        yield jnp.asarray(x[ix]), jnp.asarray(y[ix])
