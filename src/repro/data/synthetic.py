"""Learnable synthetic stand-ins for MNIST / FMNIST / CIFAR-10 / CINIC-10.

The container is offline, so the paper's datasets are unavailable.  We
generate class-conditional image distributions with the same shapes and
difficulty *ordering* (mnist < fmnist < cifar <= cinic) so the paper's
*relative* claims (rounds-to-target per aggregation method) can be
reproduced.  Construction per class:

  template_c  = smoothed random field (low-frequency, class-specific)
  x           = a * template_c + b * distractor + sigma * noise,

with per-sample amplitude jitter, a shared distractor field (makes classes
non-orthogonal), and per-dataset noise levels.  Labels are balanced.

Also provides a synthetic token-stream LM task for the large-model
fine-tuning examples (a k-th order Markov chain over the vocab, so there is
real mutual information for the model to learn).
"""
from __future__ import annotations

import zlib
from typing import NamedTuple

import numpy as np

SPECS = {
    #              H   W  C  noise  distract
    "mnist":      (28, 28, 1, 0.90, 0.6),
    "fmnist":     (28, 28, 1, 1.20, 0.9),
    "cifar":      (32, 32, 3, 1.60, 1.2),
    "cinic":      (32, 32, 3, 1.90, 1.4),
}

N_CLASSES = 10


class Dataset(NamedTuple):
    x: np.ndarray          # (n, H, W, C) float32 in ~[-1, 2]
    y: np.ndarray          # (n,) int32


def _smooth_field(rng: np.random.Generator, h: int, w: int, c: int,
                  cutoff: int = 6) -> np.ndarray:
    """Low-frequency random field via truncated 2-D Fourier synthesis."""
    field = np.zeros((h, w, c), np.float32)
    ys = np.linspace(0, 2 * np.pi, h, endpoint=False)[:, None, None]
    xs = np.linspace(0, 2 * np.pi, w, endpoint=False)[None, :, None]
    for fy in range(cutoff):
        for fx in range(cutoff):
            amp = rng.normal(size=(1, 1, c)) / (1.0 + fy + fx)
            phase = rng.uniform(0, 2 * np.pi, size=(1, 1, c))
            field += (amp * np.cos(fy * ys + fx * xs + phase)).astype(
                np.float32)
    field /= max(np.abs(field).max(), 1e-6)
    return field


def make_dataset(name: str, n_per_class: int, seed: int = 42,
                 split: str = "train") -> Dataset:
    h, w, c, noise, distract = SPECS[name]
    # class templates depend only on (name, seed); train/test share them
    # zlib.crc32: stable across processes (python's hash() is salted,
    # which would silently break the paper's fixed-seed-42 reproducibility)
    trng = np.random.default_rng(np.random.SeedSequence(
        [seed, zlib.crc32(name.encode())]))
    templates = np.stack([_smooth_field(trng, h, w, c)
                          for _ in range(N_CLASSES)])
    distractor = _smooth_field(trng, h, w, c)

    srng = np.random.default_rng(np.random.SeedSequence(
        [seed, zlib.crc32(name.encode()), 0 if split == "train" else 1]))
    n = n_per_class * N_CLASSES
    y = np.repeat(np.arange(N_CLASSES, dtype=np.int32), n_per_class)
    srng.shuffle(y)
    amp = srng.uniform(0.7, 1.3, size=(n, 1, 1, 1)).astype(np.float32)
    damp = srng.normal(0, 1, size=(n, 1, 1, 1)).astype(np.float32)
    eps = srng.normal(0, 1, size=(n, h, w, c)).astype(np.float32)
    x = (amp * templates[y] + distract * damp * distractor[None]
         + noise * eps)
    return Dataset(x.astype(np.float32), y)


# ------------------------------------------------------------ LM stream ----
def make_lm_dataset(vocab: int, seq_len: int, n_seqs: int,
                    seed: int = 42, p_follow: float = 0.9) -> np.ndarray:
    """Bigram-table token streams: tokens (n_seqs, seq_len) int32.

    next = T[prev] with prob ``p_follow`` (T a fixed random permutation),
    else uniform.  A LM that learns the table reaches cross-entropy
    ~= H(p_follow) + (1-p_follow) * ln(vocab), far below ln(vocab) -- a
    measurable target for the fine-tuning examples.
    """
    rng = np.random.default_rng(seed)
    table = rng.permutation(vocab)
    toks = np.zeros((n_seqs, seq_len), np.int64)
    toks[:, 0] = rng.integers(0, vocab, n_seqs)
    for t in range(1, seq_len):
        follow = rng.random(n_seqs) < p_follow
        toks[:, t] = np.where(follow, table[toks[:, t - 1]],
                              rng.integers(0, vocab, n_seqs))
    return toks.astype(np.int32)
