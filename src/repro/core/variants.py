"""Beyond-paper aggregation variants.

The paper's RBLA is the baseline we reproduce faithfully in
``aggregation.py``.  These variants push further; each is benchmarked
against RBLA in ``benchmarks/bench_table1.py`` and reported separately in
EXPERIMENTS.md (paper-faithful vs beyond-paper).

* ``rbla_ranked``   -- RBLA with rank-proportional client weights
                       (HetLoRA-flavoured: clients that trained more rows
                       carry more mass on the rows everyone shares).
* ``rbla_norm``     -- RBLA + per-row update-norm preservation: after the
                       masked mean, rescale each rank-row so its L2 norm
                       equals the weighted mean of the contributing rows'
                       norms (counters the norm shrinkage of averaging
                       near-orthogonal client updates).
* ``svd_project``   -- product-space aggregation: average the full updates
                       Delta_i = B_i @ A_i (no dilution: products are
                       already dense), then truncated-SVD back to rank
                       r_max factors.  Mathematically the strongest, but
                       O(m n min(m,n)) server cost -- the cost/quality
                       trade-off vs RBLA is part of the evaluation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .aggregation import rbla_leaf, _EPS

Array = jax.Array


def rank_proportional_weights(weights: Array, ranks: Array,
                              alpha: float = 1.0) -> Array:
    """w_i <- w_i * (rank_i / r_max)^alpha, renormalized."""
    ranks = ranks.astype(jnp.float32)
    scaled = weights.astype(jnp.float32) * (ranks / jnp.max(ranks)) ** alpha
    return scaled * (jnp.sum(weights) / (jnp.sum(scaled) + _EPS))


def rbla_norm_leaf(stacked: Array, mask: Array | None, weights: Array,
                   row_axis: int = 0) -> Array:
    """RBLA then per-row norm restoration along ``row_axis``.

    Averaging K near-orthogonal unit rows shrinks the result's norm by
    ~1/sqrt(K); this variant undoes that shrinkage so the aggregated
    adapter keeps the clients' update magnitude.
    """
    agg = rbla_leaf(stacked, mask, weights).astype(jnp.float32)
    x = stacked.astype(jnp.float32)
    m = jnp.ones_like(x) if mask is None else jnp.broadcast_to(
        mask.astype(jnp.float32), x.shape)
    leaf_row_axis = row_axis % agg.ndim           # row axis within the leaf
    # axes of `stacked` to reduce when computing a row norm
    reduce_axes = tuple(a for a in range(1, x.ndim) if a != leaf_row_axis + 1)
    row_norms = jnp.sqrt(jnp.sum((m * x) ** 2, axis=reduce_axes))  # (n, rows)
    # per-(client,row) participation: does client i own row r at all?
    owns = (jnp.max(m, axis=reduce_axes) > 0).astype(jnp.float32)  # (n, rows)
    w_rows = owns * weights.astype(jnp.float32)[:, None]
    target = jnp.sum(w_rows * row_norms, axis=0) / (
        jnp.sum(w_rows, axis=0) + _EPS)                            # (rows,)
    agg_norms = jnp.sqrt(jnp.sum(
        agg ** 2, axis=tuple(a - 1 for a in reduce_axes)))         # (rows,)
    scale = jnp.where(agg_norms > _EPS, target / (agg_norms + _EPS), 1.0)
    shape = [1] * agg.ndim
    shape[leaf_row_axis] = agg.shape[leaf_row_axis]
    return (agg * scale.reshape(shape)).astype(stacked.dtype)


def svd_project_pair(stacked_B: Array, stacked_A: Array, ranks: Array,
                     weights: Array, r_out: int,
                     scales: Array | None = None) -> tuple[Array, Array]:
    """Aggregate LoRA pairs in product space, refactor via truncated SVD.

    stacked_B: (n, out, r_max); stacked_A: (n, r_max, in).  Row-masking is
    implicit: padded rows are zero so they contribute nothing to B_i @ A_i.
    Returns (B, A) with inner dimension ``r_out``.

    Since the inputs are already factored, the truncation runs through
    the factored-form engine (``repro.core.lowrank``): the weighted mean
    of products is a product of concatenated factors, so no dense
    (out, in) Delta is ever materialized -- O((out+in)*k^2 + k^3) instead
    of O(out*in*min(out, in)), k = n * r_max.
    """
    from .lowrank import svd_project_stacked
    B, A = svd_project_stacked(stacked_B, stacked_A, weights, r_out,
                               scales=scales)
    return B.astype(stacked_B.dtype), A.astype(stacked_A.dtype)
