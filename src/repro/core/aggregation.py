"""Server-side aggregation strategies (the paper's core contribution).

Three strategies from the paper:

* ``rbla``      -- Rank-Based LoRA Aggregation (Eq. 7 / Alg. 1): per
                   rank-row weighted average over the clients that *own*
                   the row; unique high-rank rows are preserved verbatim.
* ``zeropad``   -- the HetLoRA-style baseline (paper Eq. 1-5): pad to
                   r_max, plain weighted average; missing rows dilute
                   toward zero.
* ``fedavg``    -- plain weighted mean, used for non-LoRA leaves and for
                   the FFT (full fine-tune) baseline.

All functions are pure, jit-able, and operate either on a single stacked
leaf ``(n_clients, *leaf_shape)`` or on whole pytrees of stacked leaves.
Masks carry the delta_{i,r} indicator (see ``masks.py``); a mask of ``None``
means "fully shared leaf" (bias, norm scale, full weight).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

_EPS = 1e-12


def _bcast_weights(weights: Array, ndim: int) -> Array:
    """Reshape (n,) client weights to broadcast against (n, *leaf)."""
    return weights.reshape(weights.shape + (1,) * (ndim - 1))


def fedavg_leaf(stacked: Array, weights: Array) -> Array:
    """Plain weighted mean over the client axis (axis 0)."""
    w = _bcast_weights(weights.astype(jnp.float32), stacked.ndim)
    num = jnp.sum(w * stacked.astype(jnp.float32), axis=0)
    den = jnp.sum(weights.astype(jnp.float32))
    return (num / (den + _EPS)).astype(stacked.dtype)


def zeropad_leaf(stacked: Array, mask: Array | None, weights: Array) -> Array:
    """Zero-padding baseline: mask the values (zeros beyond each client's
    rank) but normalize by the *total* weight mass -- this is exactly the
    dilution the paper criticizes (Eq. 3/5)."""
    x = stacked.astype(jnp.float32)
    if mask is not None:
        x = x * mask.astype(jnp.float32)
    w = _bcast_weights(weights.astype(jnp.float32), stacked.ndim)
    num = jnp.sum(w * x, axis=0)
    den = jnp.sum(weights.astype(jnp.float32))
    return (num / (den + _EPS)).astype(stacked.dtype)


def rbla_leaf(stacked: Array, mask: Array | None, weights: Array,
              prev: Array | None = None) -> Array:
    """RBLA (paper Eq. 7): per-element masked weighted average.

        C_r = sum_i delta_ir w_i A_ir / sum_i delta_ir w_i

    Where no participating client owns a row (denominator 0) the output is
    ``prev`` (the current server value) when given, else 0.  Retaining the
    previous value matters under partial participation: a round whose
    sampled clients are all low-rank must not wipe the high-rank rows the
    server already holds -- the paper's "preserve unique layers" principle
    extended to the random-selection setting (paper Figs. 5-10 right).
    """
    x = stacked.astype(jnp.float32)
    w = _bcast_weights(weights.astype(jnp.float32), stacked.ndim)
    if mask is None:
        m = jnp.ones_like(x)
    else:
        m = jnp.broadcast_to(mask.astype(jnp.float32), x.shape)
    num = jnp.sum(w * m * x, axis=0)
    den = jnp.sum(w * m, axis=0)
    fallback = (jnp.zeros_like(num) if prev is None
                else prev.astype(jnp.float32))
    return jnp.where(den > 0, num / (den + _EPS),
                     fallback).astype(stacked.dtype)


AGGREGATORS: dict[str, Callable[..., Array]] = {
    "rbla": rbla_leaf,
    "zeropad": zeropad_leaf,
}


def aggregate(stacked_tree: PyTree, mask_tree: PyTree, weights: Array,
              method: str = "rbla", prev_tree: PyTree | None = None
              ) -> PyTree:
    """Aggregate a pytree of stacked client leaves.

    Deprecated shim: resolves ``method`` through the strategy registry
    (``repro.core.strategy``) and runs its reference tree path.  New code
    should call ``get_strategy(method).aggregate_tree(...)`` directly.

    ``stacked_tree`` leaves are ``(n_clients, *shape)``; ``mask_tree`` has
    the same structure with leaves that broadcast against them (or ``None``
    for fully-shared leaves -- encode None as a 0-d ones array if the tree
    library would prune it).  ``prev_tree``: the server's current values,
    retained (by strategies that keep them, e.g. rbla) for rows no
    participant owns.
    """
    from .strategy import get_strategy
    return get_strategy(method).aggregate_tree(stacked_tree, mask_tree,
                                               weights, prev_tree)
