"""First-class aggregation strategies: one pluggable API over every path.

The paper's contribution (RBLA vs zero-padding) plus every beyond-paper
variant used to live as string dispatch (``method == "rbla"`` / ...)
duplicated across the core, fl, kernels, and benchmark layers.  This module
makes each method a single :class:`AggregationStrategy` that owns

* (a) its **leaf math** (:meth:`AggregationStrategy.leaf`),
* (b) its **pytree traversal** including ``prev_global`` retention
  semantics (:meth:`AggregationStrategy.aggregate_tree`),
* (c) an optional **distributed** shard_map path
  (:meth:`AggregationStrategy.make_distributed_aggregator` /
  :meth:`AggregationStrategy.allreduce_leaf`),
* (d) an optional **Pallas kernel** path
  (:meth:`AggregationStrategy.aggregate_tree_pallas`), and
* (e) a **per-update fold** for the async aggregation service
  (:meth:`AggregationStrategy.fold` + the ``supports_incremental``
  declaration; see ``repro.fl.async_agg`` and ``docs/async.md``),

behind a ``backend="auto" | "ref" | "pallas" | "distributed"`` selector that
picks the Pallas kernel on TPU/GPU and the jnp reference path on CPU.

Registering a new method is one class::

    from repro.core.strategy import AggregationStrategy, register_strategy

    @register_strategy
    class TrimmedMean(AggregationStrategy):
        name = "trimmed_mean"
        norm_by = "mask"

        def leaf(self, stacked, mask, weights, prev=None):
            ...  # (n_clients, *leaf) -> (*leaf)

after which ``FLConfig(method="trimmed_mean")``, the FL server, the
distributed aggregator factory, and the benchmarks all resolve it by name.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.obs import get_registry as _obs_registry

from .aggregation import _EPS, fedavg_leaf, rbla_leaf, zeropad_leaf
from .compat import shard_map_no_check
from .lowrank import product_factors, svd_project_stacked
from .masks import pad_to_rank
from .variants import rank_proportional_weights, rbla_norm_leaf

Array = jax.Array
PyTree = Any

BACKENDS = ("auto", "ref", "pallas", "distributed")

#: per-strategy-instance LRU bound on cached CompiledRounds (plans are
#: keyed by cohort rank multiset among other things, and a random-cohort
#: service sees many multisets; the expensive XLA executables underneath
#: are shared across multisets and are NOT evicted with the plan)
PLAN_CACHE_SIZE = 128

_PLAN_CACHE_HITS = _obs_registry().counter(
    "plan_cache_hits_total", "plan-cache hits, by strategy",
    labelnames=("strategy",))
_PLAN_CACHE_MISSES = _obs_registry().counter(
    "plan_cache_misses_total", "plan-cache misses (plan builds), by strategy",
    labelnames=("strategy",))


# ------------------------------------------------------------ server state --
@dataclasses.dataclass
class ServerState:
    """The FL server's round state: what Alg. 1 carries between rounds.

    ``current_rank`` is the per-leaf *live* rank of ``adapters`` after the
    last aggregation: a pytree mirroring ``adapters`` with each LoRA pair
    replaced by its rank leaf.  For fixed-rank strategies it is ``r_max``
    everywhere; rank-changing strategies (``rank_contract="stacked"``)
    vary it round to round while the storage shape stays static.
    """
    adapters: PyTree | None            # global LoRA adapters (None in FFT)
    base_trainable: PyTree             # non-LoRA trainables (or full params)
    round: int = 0
    r_max: int | None = None
    client_ranks: Array | None = None  # ranks of the last participant cohort
    current_rank: PyTree | None = None  # per-leaf live rank of ``adapters``


@dataclasses.dataclass
class ClientUpdate:
    """One participant's upload for a round."""
    adapters: PyTree | None
    base_trainable: PyTree
    n_examples: float = 1.0
    rank: int | None = None


@dataclasses.dataclass
class FoldState:
    """Accumulator threaded through a sequence of per-update folds.

    The async aggregation service (:class:`repro.fl.AsyncAggregator`)
    folds one :class:`ClientUpdate` at a time instead of waiting for a
    cohort; this carries what the running aggregate needs between folds:

    ``mass``
        accumulated raw weight mass (the denominator of the running
        weighted mean for base trainables and ``norm_by="weight"``
        strategies).
    ``row_mass``
        per-pair per-rank-row owner mass (RBLA's Eq. 7 denominator in
        streaming form, where the *transformed* adapter masses
        accumulate): a pytree mirroring the adapters with each pair
        replaced by a ``rank_leaf_shape + (r_storage,)`` f32 array.
        ``None`` for strategies that don't need it.
    ``n_folds``
        how many updates have been folded since the anchor.
    ``extra``
        strategy-private streaming bookkeeping (flora keeps its stacked
        segment ledger here -- per-pair segment ranks, masses, and the
        B-column scales currently applied -- so folds can re-scale in
        place instead of replaying from the anchor).
    ``momentum``
        server momentum buffer (FedBuff/FedAvgM-style): a pytree
        mirroring the adapters' float leaves, or ``None`` when the
        service runs without momentum.  The fold path updates it as
        ``m <- beta * m + (s_new - s_old)`` and publishes
        ``s_old + m`` -- the buffer lives on aggregated state only, so
        secure-aggregation-compatible buffering is unaffected (no
        per-client data is retained).
    """
    mass: float = 0.0
    row_mass: PyTree | None = None
    n_folds: int = 0
    extra: Any = None
    momentum: PyTree | None = None


# ---------------------------------------------------------------- registry --
_REGISTRY: dict[str, "AggregationStrategy"] = {}


def register_strategy(cls):
    """Class decorator: instantiate ``cls`` and register it under
    ``cls.name`` (plus any ``cls.aliases``).  Returns ``cls`` unchanged.

    Duplicate names (or aliases colliding with existing names) raise: a
    silent overwrite would reroute every ``FLConfig(method=...)`` user of
    the shadowed strategy.
    """
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} needs a non-empty .name")
    names = (inst.name,) + tuple(inst.aliases)
    taken = [n for n in names if n in _REGISTRY]
    if taken:
        raise ValueError(
            f"strategy name(s) {taken} already registered (by "
            f"{type(_REGISTRY[taken[0]]).__name__}); pick a unique .name / "
            ".aliases or remove the old entry explicitly")
    for n in names:
        _REGISTRY[n] = inst
    return cls


def get_strategy(name: "str | AggregationStrategy") -> "AggregationStrategy":
    """Resolve a strategy by registry name (or pass an instance through)."""
    if isinstance(name, AggregationStrategy):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregation strategy {name!r}; registered: "
            f"{list_strategies()}") from None


def list_strategies() -> list[str]:
    """Sorted primary names of every registered strategy."""
    return sorted({s.name for s in _REGISTRY.values()})


def resolve_backend(backend: str, strategy: "AggregationStrategy") -> str:
    """Map ``auto`` to ``pallas`` on TPU/GPU (when supported) else ``ref``."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; options: {BACKENDS}")
    if backend == "auto":
        if strategy.supports_pallas and jax.default_backend() in ("tpu",
                                                                  "gpu"):
            return "pallas"
        return "ref"
    return backend


# ------------------------------------------------------------ tree helpers --
def stack_trees(trees: Sequence[PyTree]) -> PyTree:
    """Stack per-client pytrees leafwise into (n_clients, *leaf) arrays."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _squeeze_mask(m):
    """0-d mask means 'fully shared leaf' -> None (no rank masking)."""
    return None if (m is not None and getattr(m, "ndim", 1) == 0) else m


def _is_pair(node) -> bool:
    # mirrors repro.lora.is_pair deliberately: core cannot depend on lora
    # at import time (lora itself builds on repro.core.masks)
    return (isinstance(node, Mapping) and "A" in node and "B" in node
            and "rank" in node)


def _map_pairs(fn, tree, *rest, strict: bool = False):
    """Map ``fn`` over every LoRA pair of ``tree`` (and parallel ``rest``
    trees, which may be ``None``).  ``strict`` raises on bare array leaves
    so pair-only strategies fail loudly on generic leaf trees."""
    if _is_pair(tree):
        return fn(tree, *rest)
    if isinstance(tree, Mapping):
        return {k: _map_pairs(fn, v, *[None if r is None else r[k]
                                       for r in rest], strict=strict)
                for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(
            _map_pairs(fn, v, *[None if r is None else r[i] for r in rest],
                       strict=strict) for i, v in enumerate(tree))
    if strict and tree is not None:
        raise NotImplementedError(
            "this strategy aggregates whole LoRA pairs ({'A','B','rank'}); "
            f"got a bare leaf of type {type(tree).__name__}")
    return tree


def _flat_pair_values(tree: PyTree) -> list:
    """Values sitting at pair positions of a ``_map_pairs`` output whose
    pairs were replaced by bare values (e.g. a ``row_mass`` tree), in
    ``_map_pairs`` traversal order."""
    vals: list = []

    def go(t):
        if isinstance(t, Mapping) and not _is_pair(t):
            for v in t.values():
                go(v)
        elif isinstance(t, (tuple, list)):
            for v in t:
                go(v)
        elif t is not None:
            vals.append(t)
    go(tree)
    return vals


def _fix_rank(tree: PyTree, r_max: int | None) -> PyTree:
    """Reset every pair's live rank to r_max: the server keeps the full
    stack; clients re-slice per Alg. 2."""
    def fix(pair):
        p = dict(pair)
        rm = p["A"].shape[-2] if r_max is None else r_max
        p["rank"] = jnp.full_like(jnp.asarray(p["rank"], jnp.int32), rm)
        return p
    return _map_pairs(fix, tree)


def adapter_live_ranks(tree: PyTree) -> PyTree:
    """Per-leaf live-rank tree: every LoRA pair replaced by its rank leaf
    (what :class:`ServerState` carries as ``current_rank``)."""
    return _map_pairs(lambda p: jnp.asarray(p["rank"], jnp.int32), tree)


def _infer_ranks(stacked_tree: PyTree) -> Array | None:
    """Recover the per-client rank vector from a stacked adapter tree's
    first scalar-rank pair (None if there is none)."""
    found = []

    def visit(pair):
        r = jnp.asarray(pair["rank"])
        if r.ndim == 1:
            found.append(r.astype(jnp.int32))
        return pair
    _map_pairs(visit, stacked_tree)
    return found[0] if found else None


def _retain_prev(tree: PyTree, prev: PyTree, client_ranks: Array) -> PyTree:
    """Rank-rows owned by no participant keep the server's current value
    (RBLA's 'preserve unique layers' under partial participation).  Row r
    is owned iff r < max(participant ranks) -- equivalent to the per-element
    den > 0 test when masks are rank-row masks and weights are positive."""
    rmax_part = jnp.max(jnp.asarray(client_ranks, jnp.int32))

    def fix(pair, prev_pair):
        r_storage = pair["A"].shape[-2]
        owned = lax.iota(jnp.int32, r_storage) < rmax_part
        return {
            "A": jnp.where(owned[:, None], pair["A"],
                           prev_pair["A"].astype(pair["A"].dtype)),
            "B": jnp.where(owned[None, :], pair["B"],
                           prev_pair["B"].astype(pair["B"].dtype)),
            "rank": pair["rank"],
        }
    return _map_pairs(fix, tree, prev)


# ------------------------------------------------------------ the protocol --
class AggregationStrategy:
    """One server-side aggregation method, every execution path.

    Subclasses set the class attributes and implement :meth:`leaf` (or
    override :meth:`aggregate_tree` for pair-structured methods); the
    distributed and Pallas paths come for free from ``norm_by`` /
    ``use_mask`` unless overridden.
    """
    name: str = ""
    aliases: tuple[str, ...] = ()
    #: denominator of the weighted mean: "mask" = sum_i w_i * delta_ir
    #: (RBLA Eq. 7), "weight" = sum_i w_i (zero-padding dilution / FedAvg)
    norm_by: str = "mask"
    #: apply delta_{i,r} rank-row masks at all (FedAvg turns this off)
    use_mask: bool = True
    #: rows no participant owns keep the previous global value
    retains_prev: bool = False
    supports_pallas: bool = False
    supports_distributed: bool = True
    #: method name understood by the rbla_agg Pallas kernel
    pallas_method: str = "rbla"
    #: declared output-rank contract: "fixed" = the aggregate's live rank
    #: is always r_max (the registry's historical assumption); "stacked" =
    #: the live rank varies with the cohort (e.g. flora) and callers must
    #: read it from the output pairs / ``ServerState.current_rank``
    rank_contract: str = "fixed"
    #: what a homogeneous-rank cohort degenerates to: "factors" = output
    #: factors equal FedAvg of the client factors, "product" = the served
    #: effective update equals the weighted mean of the clients' effective
    #: updates, None = intentionally neither (the property suite reads
    #: this; see tests/test_strategy_properties.py)
    fedavg_equivalence: str | None = "factors"
    #: incremental-capable declaration: True means folding a cohort's
    #: updates one at a time through :meth:`fold` (zero staleness,
    #: running-mass mixing) reproduces the one-shot ``aggregate`` of the
    #: same cohort on the ref backend, up to float reassociation.  False
    #: means :meth:`fold` is an approximation (FedAsync-style convex
    #: mixing) and exact async semantics need the replay path
    #: (:class:`repro.fl.AsyncAggregator` handles this automatically).
    supports_incremental: bool = False
    #: how :meth:`plan` lowers a round (see ``repro.core.plan``):
    #: "mean" = packed masked-mean buckets, "mean_norm" = + per-row norm
    #: restore, "stack" = flora's copy/scale stacking, "svd" = packed
    #: batched factored SVD (repro.core.lowrank), "jit" = whole-round
    #: jit of the reference math, None = eager legacy execution (the safe
    #: default for strategies whose leaf math the planner cannot assume)
    plan_mode: str | None = None
    #: Byzantine-robustness contract: "none" = plain (weighted-mean
    #: family, a single adversarial upload can move the aggregate
    #: arbitrarily far), "clipped" = per-row norm clipping bounds each
    #: client's displacement by ~clip_norm / (owner mass), "trimmed" /
    #: "median" = per-coordinate order statistics with breakdown point
    #: ~trim_frac (resp. 1/2) of a row's owners.  The property harness
    #: checks the declared contract with a 1e6x-norm adversary (see
    #: tests/test_strategy_properties.py).
    robustness: str = "none"

    def with_options(self, **options) -> "AggregationStrategy":
        """Return a configured copy of this strategy.

        Registered instances are shared singletons; per-run knobs (e.g.
        flora's ``stack_r_cap``) must never be set on them directly.  Only
        attributes the strategy already declares are accepted.
        """
        import copy
        inst = copy.copy(self)
        # compiled artifacts close over self and its options: never share
        for cached in ("_dist_agg_cache", "_plan_cache", "plan_stats",
                       "_fold_plan_cache", "_plan_exec_cache",
                       "_stack_memo"):
            inst.__dict__.pop(cached, None)
        for k, v in options.items():
            if not hasattr(inst, k) or k.startswith("_"):
                raise ValueError(
                    f"strategy {self.name!r} has no option {k!r}")
            setattr(inst, k, v)
        return inst

    def server_storage_rank(self, r_max: int | None) -> int | None:
        """Storage rank the server should allocate for global adapters.
        Fixed-rank strategies store exactly ``r_max``; rank-growing ones
        (flora) need headroom up to their cap."""
        return r_max

    # ------------------------------------------------------ compiled plans --
    def plan(self, state, cohort_spec):
        """Compiled round for ``cohort_spec``: ``plan(state, spec) ->
        CompiledRound`` (see ``repro.core.plan``).

        The round packs the cohort's pairs into (width, dtype) buckets,
        lowers leaf math + prev retention + weight transform into one
        jitted function issuing one fused launch per bucket, and is
        cached on this instance keyed by the spec (tree structure, rank
        multiset, backend, mesh) -- :attr:`plan_stats` counts hits and
        misses.  The cache is a bounded LRU (`PLAN_CACHE_SIZE`): a
        long-lived service with random cohort selection sees a new rank
        multiset most rounds, and while plans are cheap (mean-mode XLA
        executables are shared across multisets -- owner masks are
        runtime data), their host-side mask matrices should not
        accumulate forever.  ``state`` may carry the server state whose
        adapters the round retains; the spec already encodes its layout,
        so ``None`` is accepted.  Unsupported backends raise the same
        ``NotImplementedError`` the per-leaf paths raise.
        """
        from .plan import build_plan
        if cohort_spec.kind == "pallas" and not self.supports_pallas:
            raise NotImplementedError(
                f"strategy {self.name!r} has no Pallas kernel path; "
                "use backend='ref'")
        if (cohort_spec.kind == "distributed"
                and not self.supports_distributed):
            raise NotImplementedError(
                f"strategy {self.name!r} has no distributed path; "
                "use backend='ref'")
        from collections import OrderedDict
        cache = self.__dict__.setdefault("_plan_cache", OrderedDict())
        stats = self.__dict__.setdefault("plan_stats",
                                         {"hits": 0, "misses": 0})
        got = cache.get(cohort_spec)
        if got is not None:
            stats["hits"] += 1
            _PLAN_CACHE_HITS.labels(strategy=self.name).inc()
            cache.move_to_end(cohort_spec)
            return got
        stats["misses"] += 1
        _PLAN_CACHE_MISSES.labels(strategy=self.name).inc()
        built = build_plan(self, cohort_spec)
        cache[cohort_spec] = built
        while len(cache) > PLAN_CACHE_SIZE:
            cache.popitem(last=False)
        return built

    def _plan_round(self, stacked, kind, *, r_max, client_ranks, prev,
                    mesh, client_axis, interpret):
        """Best-effort plan for an already-stacked cohort; ``None`` when
        the cohort cannot be described host-side (traced leaves, bare
        leaves) -- the caller then runs the in-trace legacy path."""
        from .plan import PlanUnavailable, build_cohort_spec
        try:
            spec = build_cohort_spec(
                stacked, kind=kind, r_max=r_max, client_ranks=client_ranks,
                prev_tree=prev, interpret=interpret, mesh=mesh,
                client_axis=client_axis)
        except PlanUnavailable:
            return None
        return self.plan(None, spec)

    def _plan_encoded_round(self, client_adapters, codecs, kind, *, r_max,
                            client_ranks, prev, interpret,
                            client_axis="clients"):
        """Best-effort plan for an *encoded* (quantized-upload) cohort --
        per-client trees, never stacked; ``None`` sends the caller to the
        decode-eagerly fallback.  Shares :meth:`plan`'s cache, so a codec
        mix change re-plans while a rank-multiset repeat under the same
        mix hits."""
        from .plan import PlanUnavailable, build_encoded_cohort_spec
        try:
            spec = build_encoded_cohort_spec(
                client_adapters, codecs, kind=kind, r_max=r_max,
                client_ranks=client_ranks, prev_tree=prev,
                interpret=interpret, client_axis=client_axis)
            return self.plan(None, spec)
        except PlanUnavailable:
            return None

    # ------------------------------------------------------ (a) leaf math --
    def leaf(self, stacked: Array, mask: Array | None, weights: Array,
             prev: Array | None = None) -> Array:
        """Aggregate one stacked leaf (n_clients, *shape) -> (*shape)."""
        raise NotImplementedError

    def transform_weights(self, weights: Array,
                          client_ranks: Array | None = None) -> Array:
        """Hook: reweight clients before aggregation (rbla_ranked)."""
        return weights

    def _combine(self, num: Array, den_mask: Array | None,
                 den_w: Array | None) -> Array:
        """Numerator/denominator combine shared by the psum paths."""
        if self.norm_by == "mask":
            return jnp.where(den_mask > 0, num / (den_mask + _EPS), 0.0)
        return num / (den_w + _EPS)

    # ------------------------------------------------- (b) tree traversal --
    def aggregate_tree(self, stacked_tree: PyTree, mask_tree: PyTree,
                       weights: Array, prev_tree: PyTree | None = None, *,
                       r_max: int | None = None,
                       client_ranks: Array | None = None) -> PyTree:
        """Reference path: leafwise map over stacked (n, *leaf) trees.

        ``mask_tree`` leaves broadcast against the stacked leaves; 0-d
        leaves mean fully shared.  ``prev_tree`` is honored only by
        strategies with ``retains_prev``.
        """
        w = self.transform_weights(jnp.asarray(weights, jnp.float32),
                                   client_ranks)
        if prev_tree is not None and self.retains_prev:
            return jax.tree.map(
                lambda x, m, p: self.leaf(x, _squeeze_mask(m), w, p),
                stacked_tree, mask_tree, prev_tree,
                is_leaf=lambda v: v is None)
        return jax.tree.map(
            lambda x, m: self.leaf(x, _squeeze_mask(m), w),
            stacked_tree, mask_tree, is_leaf=lambda v: v is None)

    # ---------------------------------------------- (c) distributed path --
    def allreduce_leaf(self, local: Array, mask: Array | None, weight: Array,
                       axis_name: str) -> Array:
        """Aggregate one shard's leaf with all peers over ``axis_name``
        (for use inside shard_map bodies; one client per shard)."""
        if not self.supports_distributed:
            raise NotImplementedError(
                f"strategy {self.name!r} has no distributed path")
        x = local.astype(jnp.float32)
        w = jnp.asarray(weight, jnp.float32)
        mask = _squeeze_mask(mask) if self.use_mask else None
        m = (jnp.ones_like(x) if mask is None
             else jnp.broadcast_to(mask.astype(jnp.float32), x.shape))
        num = lax.psum(w * m * x, axis_name)
        den_mask = (lax.psum(w * m, axis_name)
                    if self.norm_by == "mask" else None)
        den_w = lax.psum(w, axis_name) if self.norm_by == "weight" else None
        return self._combine(num, den_mask, den_w).astype(local.dtype)

    def aggregate_tree_distributed(self, stacked_tree: PyTree,
                                   mask_tree: PyTree, weights: Array,
                                   prev_tree: PyTree | None = None, *,
                                   r_max: int | None = None,
                                   client_ranks: Array | None = None,
                                   mesh=None,
                                   client_axis: str = "clients") -> PyTree:
        """Distributed path over an already-stacked tree.

        Transforms the weights host-side (a shard never sees the global
        rank vector), runs the shard_map aggregator, and re-applies
        ``prev_global`` retention.  Rank-changing strategies override this
        wholesale (their collective is a ragged concat, not a psum).
        """
        wt = self.transform_weights(jnp.asarray(weights, jnp.float32),
                                    client_ranks)
        out = self._aggregate_distributed(stacked_tree, mask_tree, wt, mesh,
                                          client_axis)
        if (prev_tree is not None and self.retains_prev
                and client_ranks is not None):
            out = _retain_prev(out, prev_tree, client_ranks)
        return out

    def make_distributed_aggregator(self, mesh, client_axis: str = "data"):
        """Build a jitted SPMD aggregator over ``client_axis`` of ``mesh``.

        Inputs are sharded pytrees whose leading axis enumerates clients
        (one or more clients per shard); local clients are reduced locally
        (masked partial sums) then combined with psum -- a two-level tree
        reduction.  Weights must already be transformed
        (:meth:`transform_weights` needs the global rank vector, which a
        shard does not see).
        """
        if not self.supports_distributed:
            raise NotImplementedError(
                f"strategy {self.name!r} has no distributed path; "
                "use backend='ref'")
        cache = self.__dict__.setdefault("_dist_agg_cache", {})
        if (mesh, client_axis) in cache:    # one trace+compile per mesh,
            return cache[(mesh, client_axis)]   # not one per FL round
        from jax.sharding import PartitionSpec as P

        def body(stacked_tree, mask_tree, weights):
            wf = weights.astype(jnp.float32)

            def agg_leaf(x, m):
                m = _squeeze_mask(m) if self.use_mask else None
                xf = x.astype(jnp.float32)
                w = wf.reshape(wf.shape + (1,) * (xf.ndim - 1))
                mf = (jnp.ones_like(xf) if m is None
                      else jnp.broadcast_to(m.astype(jnp.float32), xf.shape))
                num = lax.psum(jnp.sum(w * mf * xf, axis=0), client_axis)
                den_mask = (lax.psum(jnp.sum(w * mf, axis=0), client_axis)
                            if self.norm_by == "mask" else None)
                den_w = (lax.psum(jnp.sum(wf), client_axis)
                         if self.norm_by == "weight" else None)
                return self._combine(num, den_mask, den_w).astype(x.dtype)

            return jax.tree.map(agg_leaf, stacked_tree, mask_tree,
                                is_leaf=lambda v: v is None)

        fn = jax.jit(shard_map_no_check(
            body, mesh, in_specs=(P(client_axis), P(client_axis),
                                  P(client_axis)),
            out_specs=P()))
        cache[(mesh, client_axis)] = fn
        return fn

    # --------------------------------------------------- (d) Pallas path --
    def aggregate_tree_pallas(self, stacked_tree: PyTree, weights: Array,
                              client_ranks: Array | None,
                              prev_tree: PyTree | None = None, *,
                              r_max: int | None = None,
                              interpret: bool | None = None) -> PyTree:
        """Kernel path over an adapter tree of stacked LoRA pairs.

        A leaves (n, r_max, fan_in) hit the kernel directly; B leaves
        (n, fan_out, r_max) via a rank-axis transpose.  Layer-stacked pairs
        (leading dims / per-layer rank vectors) fall back to the reference
        leaf math -- the kernel wants a single rank-row axis.  ``r_max``
        is ignored by fixed-rank strategies (the caller's finalize resets
        live ranks); rank-changing ones need it for their cap logic.
        """
        if not self.supports_pallas:
            raise NotImplementedError(
                f"strategy {self.name!r} has no Pallas kernel path; "
                "use backend='ref'")
        from repro.kernels.rbla_agg.ops import rbla_agg
        from repro.lora import pair_masks

        w = self.transform_weights(jnp.asarray(weights, jnp.float32),
                                   client_ranks)
        ranks = (None if client_ranks is None
                 else jnp.asarray(client_ranks, jnp.int32))

        def agg_pair(pair, prev_pair):
            A, B = pair["A"], pair["B"]
            r_storage = A.shape[-2]
            n = A.shape[0]
            pranks = ranks
            if pranks is None and jnp.asarray(pair["rank"]).ndim == 1:
                pranks = jnp.asarray(pair["rank"], jnp.int32)
            if not self.use_mask:
                pranks = jnp.full((n,), r_storage, jnp.int32)
            if A.ndim != 3 or B.ndim != 3 or pranks is None:
                masks = pair_masks(pair)       # works on stacked pairs
                prev_A = prev_pair["A"] if prev_pair is not None else None
                prev_B = prev_pair["B"] if prev_pair is not None else None
                return {"A": self.leaf(A, masks["A"], w, prev_A),
                        "B": self.leaf(B, masks["B"], w, prev_B),
                        "rank": pair["rank"][0]}
            outA = rbla_agg(A, pranks, w, method=self.pallas_method,
                            interpret=interpret)
            outB = rbla_agg(jnp.swapaxes(B, 1, 2), pranks, w,
                            method=self.pallas_method, interpret=interpret).T
            out = {"A": outA, "B": outB, "rank": pair["rank"][0]}
            if prev_pair is not None and self.retains_prev:
                out = _retain_prev(out, prev_pair, pranks)
            return out

        return _map_pairs(agg_pair, stacked_tree, prev_tree, strict=True)

    # ----------------------------------------------------- mid-level API --
    def aggregate_adapters(self, client_adapters: Sequence[PyTree],
                           weights: Array, *, r_max: int | None = None,
                           client_ranks: Array | None = None,
                           prev_global: PyTree | None = None,
                           backend: str = "auto", mesh=None,
                           client_axis: str = "clients",
                           interpret: bool | None = None,
                           use_plan: bool = True,
                           donate: bool = False) -> PyTree:
        """Aggregate per-client adapter trees into the global adapter.

        Stacks the uploads and routes the round through a cached
        :class:`~repro.core.plan.CompiledRound` (packed buffers, one
        fused launch per bucket -- see :meth:`plan`); the per-leaf
        ``aggregate_tree*`` paths remain the plan's oracles and the
        in-trace fallback (``use_plan=False``, or leaves/ranks hidden by
        jit tracing).  ``donate=True`` donates ``prev_global``'s A/B
        buffers to the round -- the caller must not touch them after.

        Output rank bookkeeping follows :meth:`finalize_tree`: fixed-rank
        strategies reset the live rank to ``r_max`` (clients re-slice,
        Alg. 2), while rank-changing ones (``rank_contract="stacked"``)
        keep the live rank their aggregation wrote -- read it from the
        output pairs.

        When the same cohort re-participates (the same client arrays
        resubmitted -- benchmarks, replay, weight-only re-aggregation),
        the host-side re-stacking is skipped: uploads are fingerprinted
        by buffer identity (jax arrays are immutable) and the previous
        stacked tree is reused, which also lets the compiled round reuse
        its packed buckets (see ``plan_stats['pack_reuses']``).
        """
        from repro.lora import adapter_masks

        from .plan import BufferMemo

        from .codec import cohort_codecs
        codecs = cohort_codecs(client_adapters)
        if codecs is not None:
            # encoded uploads (repro.core.codec): the mean family plans
            # them directly -- per-client wire-dtype payloads, dequant
            # fused into the packed kernels, no stacked fp32 staging
            # buffer.  Everything else (stack/svd/jit/eager/distributed,
            # intra-client codec mixes, unplannable cohorts) decodes
            # eagerly and takes the standard path below.
            kind_enc = resolve_backend(backend, self)
            if (use_plan and "mixed" not in codecs
                    and getattr(self, "plan_mode", None) in ("mean",
                                                             "mean_norm")
                    and kind_enc in ("ref", "pallas")):
                prev_enc = prev_global if self.retains_prev else None
                round_ = self._plan_encoded_round(
                    client_adapters, codecs, kind_enc, r_max=r_max,
                    client_ranks=client_ranks, prev=prev_enc,
                    interpret=interpret, client_axis=client_axis)
                if round_ is not None:
                    return round_(client_adapters, weights, prev_enc,
                                  donate=donate)
            from .codec import decode_adapters
            client_adapters = [decode_adapters(a) for a in client_adapters]

        leaves = [leaf for ad in client_adapters
                  for leaf in jax.tree.leaves(ad)]
        memo = self.__dict__.get("_stack_memo")
        if memo is None:
            # require_repeat: a normal FL loop (fresh uploads every
            # round) must retain only a fingerprint between rounds, not
            # a cohort-sized stacked copy
            memo = self.__dict__["_stack_memo"] = BufferMemo(
                require_repeat=True)
        stacked = memo.lookup(leaves)
        if stacked is None:
            stacked = stack_trees(client_adapters)
            # identity-memoized only for immutable non-traced jax
            # buffers seen on consecutive rounds, released as soon as
            # the uploads die -- the BufferMemo invariants
            memo.store(leaves, stacked)
        if client_ranks is None:
            client_ranks = _infer_ranks(stacked)
        w = jnp.asarray(weights, jnp.float32)
        prev = prev_global if self.retains_prev else None
        kind = resolve_backend(backend, self)
        if use_plan:
            round_ = self._plan_round(
                stacked, kind, r_max=r_max, client_ranks=client_ranks,
                prev=prev, mesh=mesh, client_axis=client_axis,
                interpret=interpret)
            if round_ is not None:
                return round_(stacked, w, prev, donate=donate)
        if kind == "pallas":
            out = self.aggregate_tree_pallas(stacked, w, client_ranks, prev,
                                             r_max=r_max,
                                             interpret=interpret)
        else:
            # the kernel path derives masks from ranks; only the jnp/psum
            # paths need the materialized delta_{i,r} mask tree
            masks = stack_trees([adapter_masks(a) for a in client_adapters])
            if kind == "distributed":
                out = self.aggregate_tree_distributed(
                    stacked, masks, w, prev, r_max=r_max,
                    client_ranks=client_ranks, mesh=mesh,
                    client_axis=client_axis)
            else:
                out = self.aggregate_tree(stacked, masks, w, prev,
                                          r_max=r_max,
                                          client_ranks=client_ranks)
        return self.finalize_tree(out, r_max)

    def finalize_tree(self, out: PyTree, r_max: int | None) -> PyTree:
        """Post-aggregation rank bookkeeping.  Fixed-rank strategies reset
        every pair's live rank to ``r_max`` (the server keeps the full
        stack; clients re-slice per Alg. 2).  Rank-changing strategies
        override this to a no-op: their aggregation already wrote the new
        live rank into each pair."""
        return _fix_rank(out, r_max)

    def _aggregate_distributed(self, stacked, masks, w, mesh, client_axis):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from .plan import default_client_mesh

        n = int(w.shape[0])
        if mesh is None:
            mesh = default_client_mesh(n, client_axis)
        agg = self.make_distributed_aggregator(mesh, client_axis)
        # 0-d "fully shared" masks can't shard over clients: materialize
        full_masks = jax.tree.map(
            lambda x, m: (jnp.ones(x.shape, jnp.float32) if m.ndim == 0
                          else jnp.broadcast_to(m.astype(jnp.float32),
                                                x.shape)),
            stacked, masks)
        sh = NamedSharding(mesh, P(client_axis))
        return agg(jax.device_put(stacked, sh),
                   jax.device_put(full_masks, sh), jax.device_put(w, sh))

    # ---------------------------------------------------- high-level API --
    def aggregate(self, state: ServerState,
                  client_updates: Sequence[ClientUpdate],
                  weights: Array | None = None, *, backend: str = "auto",
                  mesh=None, client_axis: str = "clients",
                  donate: bool = False) -> ServerState:
        """One server round: fold a participant cohort into ``state``.

        Non-LoRA trainables are FedAvg'd; adapters go through this
        strategy on the selected backend.  ``weights`` defaults to the
        updates' ``n_examples``.  ``donate=True`` donates the incoming
        ``state.adapters`` buffers to the round (callers must not read
        the old state afterwards -- the FL server loop holds only the
        returned state).  Returns the next round's state.
        """
        updates = list(client_updates)
        if weights is None:
            weights = [u.n_examples for u in updates]
        w = jnp.asarray(weights, jnp.float32)
        # this cohort's ranks; None (inferred from the pairs downstream)
        # if any update omits its rank -- never a stale previous cohort's
        got = [u.rank for u in updates]
        ranks = (jnp.asarray(got, jnp.int32)
                 if updates and all(r is not None for r in got) else None)

        new_base = state.base_trainable
        base_trees = [u.base_trainable for u in updates]
        if updates and jax.tree.leaves(base_trees[0]):
            new_base = jax.tree.map(lambda x: fedavg_leaf(x, w),
                                    stack_trees(base_trees))

        new_adapters = state.adapters
        ad_trees = [u.adapters for u in updates]
        if (state.adapters is not None and updates
                and all(a is not None for a in ad_trees)):
            new_adapters = self.aggregate_adapters(
                ad_trees, w, r_max=state.r_max, client_ranks=ranks,
                prev_global=state.adapters, backend=backend, mesh=mesh,
                client_axis=client_axis, donate=donate)

        current_rank = (adapter_live_ranks(new_adapters)
                        if new_adapters is not None else state.current_rank)
        return ServerState(adapters=new_adapters, base_trainable=new_base,
                           round=state.round + 1, r_max=state.r_max,
                           client_ranks=(ranks if ranks is not None
                                         else state.client_ranks),
                           current_rank=current_rank)

    # ---------------------------------------------------- per-update fold --
    def init_fold(self, state: ServerState) -> FoldState:
        """Fresh accumulator for a sequence of :meth:`fold` calls anchored
        at ``state`` (strategies that stream per-row mass override this to
        allocate it)."""
        return FoldState()

    def fold(self, state: ServerState, update: ClientUpdate,
             weight: float | None = None, *,
             fold_state: FoldState | None = None, backend: str = "auto",
             interpret: bool | None = None
             ) -> tuple[ServerState, FoldState]:
        """Fold ONE arriving update into ``state`` (the async hot path).

        ``weight`` is the update's *effective mass* -- its ``n_examples``
        already scaled by any staleness discount (defaults to plain
        ``n_examples``).  The strategy's own weight semantics (masks,
        ``transform_weights``, prev retention) apply underneath.

        Default implementation: the update is aggregated as a
        single-element cohort through :meth:`aggregate` (so every
        strategy-specific transform runs), then convex-mixed into the
        current state with mixing rate ``alpha = w / (mass + w)`` -- a
        running weighted mean in the style of FedAsync (Xie et al., 2019),
        whose constant-rate variant the caller gets by managing ``mass``.
        On ``backend="pallas"`` the mix is the ``axpy_fold`` kernel (one
        O(size) pass per update, independent of cohort size).

        Exact-incremental strategies (``supports_incremental=True``)
        guarantee that folding a cohort one update at a time reproduces
        the one-shot cohort :meth:`aggregate`; for the rest this default
        is an approximation and :class:`repro.fl.AsyncAggregator` replays
        the buffered cohort instead.  Returns ``(new_state, fold_state)``.
        """
        fs = fold_state if fold_state is not None else self.init_fold(state)
        w = float(update.n_examples if weight is None else weight)
        if w <= 0:
            raise ValueError(f"fold needs a positive weight, got {w}")
        agg = self.aggregate(state, [update], weights=[w], backend=backend)
        alpha = w / (fs.mass + w)
        kind = resolve_backend(backend, self)
        new_adapters = state.adapters
        if state.adapters is not None and agg.adapters is not None:
            new_adapters = _mix_trees(state.adapters, agg.adapters, alpha,
                                      kind=kind, interpret=interpret)
        new_base = _mix_trees(state.base_trainable, agg.base_trainable,
                              alpha, kind=kind, interpret=interpret)
        new_fs = FoldState(mass=fs.mass + w, row_mass=fs.row_mass,
                           n_folds=fs.n_folds + 1)
        current_rank = (adapter_live_ranks(new_adapters)
                        if new_adapters is not None else state.current_rank)
        return ServerState(
            adapters=new_adapters, base_trainable=new_base,
            round=state.round + 1, r_max=state.r_max,
            client_ranks=agg.client_ranks,
            current_rank=current_rank), new_fs


def _mix_leaf(old: Array, new: Array, alpha, *, kind: str = "ref",
              interpret: bool | None = None) -> Array:
    """One fold step on one leaf: ``old + alpha * (new - old)``.

    ``alpha`` may be a scalar (uniform server mixing) or broadcastable
    per-row (RBLA's per-rank-row running mean).  ``kind="pallas"``
    dispatches 2-D leaves with vector alpha (or any >=1-D leaf with
    scalar alpha) to the ``axpy_fold`` kernel.
    """
    if not jnp.issubdtype(jnp.asarray(old).dtype, jnp.floating):
        return new                      # int bookkeeping (rank leaves)
    a = jnp.asarray(alpha, jnp.float32)
    if kind == "pallas" and old.ndim >= 1 and a.ndim <= 1:
        from repro.kernels.rbla_agg.ops import axpy_fold
        return axpy_fold(old, new, a, interpret=interpret)
    of = old.astype(jnp.float32)
    a = a.reshape(a.shape + (1,) * (old.ndim - a.ndim))
    return (of + a * (new.astype(jnp.float32) - of)).astype(old.dtype)


def _mix_trees(old: PyTree, new: PyTree, alpha, *, kind: str = "ref",
               interpret: bool | None = None) -> PyTree:
    """Leafwise :func:`_mix_leaf` over parallel pytrees (scalar alpha)."""
    return jax.tree.map(
        lambda o, n: _mix_leaf(o, n, alpha, kind=kind, interpret=interpret),
        old, new)


# --------------------------------------------------------- the strategies --
@register_strategy
class FedAvgStrategy(AggregationStrategy):
    """Plain weighted mean (non-LoRA leaves and the FFT baseline)."""
    name = "fedavg"
    aliases = ("fft",)
    norm_by = "weight"
    use_mask = False
    supports_pallas = True
    pallas_method = "zeropad"          # full-rank masks => weighted mean
    # the default fold IS the exact streaming form of a weighted mean
    supports_incremental = True
    plan_mode = "mean"

    def leaf(self, stacked, mask, weights, prev=None):
        return fedavg_leaf(stacked, weights)


@register_strategy
class ZeropadStrategy(AggregationStrategy):
    """HetLoRA-style zero-padding baseline (paper Eq. 1-5): mask values,
    normalize by total weight mass -- missing rows dilute toward zero."""
    name = "zeropad"
    norm_by = "weight"
    supports_pallas = True
    pallas_method = "zeropad"
    plan_mode = "mean"
    # zeropad = weighted mean of masked uploads, so the default fold's
    # running mix streams it exactly (a single-element aggregate is the
    # masked upload; rows nobody owns stay exactly zero through mixing)
    supports_incremental = True

    def leaf(self, stacked, mask, weights, prev=None):
        return zeropad_leaf(stacked, mask, weights)


@register_strategy
class RBLAStrategy(AggregationStrategy):
    """Rank-Based LoRA Aggregation (paper Eq. 7 / Alg. 1): per rank-row
    weighted mean over owners; unowned rows keep the previous global."""
    name = "rbla"
    norm_by = "mask"
    retains_prev = True
    supports_pallas = True
    pallas_method = "rbla"
    supports_incremental = True
    plan_mode = "mean"

    def leaf(self, stacked, mask, weights, prev=None):
        return rbla_leaf(stacked, mask, weights, prev)

    # ---------------------------------------------------- streaming fold --
    def _fold_adapter_weight(self, update: ClientUpdate, w: float,
                             rank: int) -> float:
        """Hook: the mass this update's adapter rows enter with (the
        streaming analogue of :meth:`transform_weights`; ``rbla_ranked``
        scales it by the client's rank)."""
        return w

    def init_fold(self, state: ServerState) -> FoldState:
        if state.adapters is None:
            return FoldState()

        def zeros(pair):
            r_storage = pair["A"].shape[-2]
            shape = jnp.asarray(pair["rank"]).shape + (r_storage,)
            return jnp.zeros(shape, jnp.float32)
        return FoldState(row_mass=_map_pairs(zeros, state.adapters))

    def _packed_fold(self, adapters, upd, row_mass, wa, interpret):
        """Fold via the packed layout: the state's pairs bucket by
        (width, dtype) exactly like a cohort plan, and the whole update
        folds in one jitted call issuing one fused ``axpy_fold`` per
        bucket -- instead of two launches per pair.  Returns
        ``(new_adapters, new_row_mass)`` or ``None`` when the layout
        cannot be packed (the per-pair path handles everything)."""
        from .plan import (PlanUnavailable, _make_rebuilder, _walk_pairs,
                           build_fold_plan, build_state_spec)
        try:
            spec = build_state_spec(adapters, interpret=interpret)
            state_pairs = list(_walk_pairs(adapters))
            upd_pairs = list(_walk_pairs(upd))
        except PlanUnavailable:
            return None
        if len(state_pairs) != len(upd_pairs) or any(
                sp["A"].shape != up["A"].shape
                or sp["B"].shape != up["B"].shape
                for (_, sp), (_, up) in zip(state_pairs, upd_pairs)):
            return None
        cache = self.__dict__.setdefault("_fold_plan_cache", {})
        entry = cache.get(spec)
        if entry is None:
            entry = build_fold_plan(self, spec)
            cache[spec] = entry
        fold_fn, _ = entry
        state_ab = [{"A": p["A"], "B": p["B"]} for _, p in state_pairs]
        upd_ab = [{"A": p["A"], "B": p["B"]} for _, p in upd_pairs]
        rank_leaves = [jnp.asarray(p["rank"], jnp.int32)
                       for _, p in upd_pairs]
        mass_leaves = _flat_pair_values(row_mass)
        new_ab, new_mass = fold_fn(state_ab, upd_ab, mass_leaves,
                                   jnp.float32(wa), rank_leaves)
        rebuild = _make_rebuilder(adapters)
        new_adapters = rebuild(
            [{"A": o["A"], "B": o["B"], "rank": p["rank"]}
             for o, (_, p) in zip(new_ab, state_pairs)])
        return new_adapters, rebuild(new_mass)

    def fold(self, state, update, weight=None, *, fold_state=None,
             backend="auto", interpret=None):
        """Exact streaming RBLA: Eq. 7's per-rank-row weighted mean in
        running form.  Row ``rho`` of the accumulated owner mass ``d``
        gives the arriving update mixing rate ``w / (d_rho + w)`` on the
        rows it owns and 0 elsewhere, so rows no client has touched keep
        the anchor value (retention for free) and folding a cohort one
        update at a time reproduces the one-shot cohort aggregate.
        """
        fs = fold_state if fold_state is not None else self.init_fold(state)
        w = float(update.n_examples if weight is None else weight)
        if w <= 0:
            raise ValueError(f"fold needs a positive weight, got {w}")
        kind = resolve_backend(backend, self)
        if kind == "distributed":       # one update: nothing to distribute
            kind = "ref"

        new_adapters, new_row_mass = state.adapters, fs.row_mass
        rank_seen = update.rank
        wa = w
        packed = None
        if state.adapters is not None and update.adapters is not None:
            upd = update.adapters
            if rank_seen is None:
                ranks = []
                _map_pairs(lambda p: ranks.append(int(np.max(np.asarray(
                    jax.device_get(p["rank"]))))) or p, upd)
                rank_seen = max(ranks) if ranks else None
            wa = self._fold_adapter_weight(update, w, int(rank_seen or 1))
            if kind == "pallas":
                # packed hot path: one fused axpy_fold launch per
                # (width, dtype) bucket instead of two per pair
                packed = self._packed_fold(state.adapters, upd,
                                           fs.row_mass, wa, interpret)
        if packed is not None:
            new_adapters, new_row_mass = packed
        elif state.adapters is not None and update.adapters is not None:
            masses: list[Array] = []

            def fold_pair(pair, upd_pair, dmass):
                r_storage = pair["A"].shape[-2]
                rank = jnp.asarray(upd_pair["rank"], jnp.int32)
                owned = (lax.iota(jnp.int32, r_storage)
                         < rank[..., None]).astype(jnp.float32)
                alpha = jnp.where(owned > 0, wa / (dmass + wa), 0.0)
                masses.append(dmass + wa * owned)
                if (kind == "pallas" and pair["A"].ndim == 2
                        and alpha.ndim == 1):
                    from repro.kernels.rbla_agg.ops import axpy_fold
                    A = axpy_fold(pair["A"], upd_pair["A"], alpha,
                                  interpret=interpret)
                    B = jnp.swapaxes(
                        axpy_fold(jnp.swapaxes(pair["B"], 0, 1),
                                  jnp.swapaxes(upd_pair["B"], 0, 1),
                                  alpha, interpret=interpret), 0, 1)
                else:
                    A = _mix_leaf(pair["A"], upd_pair["A"],
                                  alpha[..., :, None])
                    B = _mix_leaf(pair["B"], upd_pair["B"],
                                  alpha[..., None, :])
                return {"A": A, "B": B, "rank": pair["rank"]}

            new_adapters = _map_pairs(fold_pair, state.adapters, upd,
                                      fs.row_mass, strict=True)
            mass_it = iter(masses)      # same traversal order as above
            new_row_mass = _map_pairs(lambda p: next(mass_it),
                                      state.adapters)

        new_base = state.base_trainable
        if jax.tree.leaves(update.base_trainable):
            new_base = _mix_trees(state.base_trainable,
                                  update.base_trainable,
                                  w / (fs.mass + w), kind=kind,
                                  interpret=interpret)

        new_fs = FoldState(mass=fs.mass + w, row_mass=new_row_mass,
                           n_folds=fs.n_folds + 1)
        current_rank = (adapter_live_ranks(new_adapters)
                        if new_adapters is not None else state.current_rank)
        return ServerState(
            adapters=new_adapters, base_trainable=new_base,
            round=state.round + 1, r_max=state.r_max,
            client_ranks=(jnp.asarray([rank_seen], jnp.int32)
                          if rank_seen is not None else state.client_ranks),
            current_rank=current_rank), new_fs


@register_strategy
class RBLARankedStrategy(RBLAStrategy):
    """RBLA with rank-proportional client weights (HetLoRA-flavoured)."""
    name = "rbla_ranked"

    def _fold_adapter_weight(self, update, w, rank):
        # streaming analogue of rank_proportional_weights: a masked
        # weighted mean depends only on weight *ratios*, so the global
        # (1/max_rank)^alpha scale and the renormalization constant both
        # cancel and w * rank is exact (alpha=1, the aggregate default)
        return w * float(max(rank, 1))

    def transform_weights(self, weights, client_ranks=None):
        if client_ranks is None:
            raise ValueError("rbla_ranked needs client_ranks to reweight "
                             "clients by rank; pass client_ranks (or use "
                             "aggregate_adapters on adapter trees, which "
                             "infers them)")
        return rank_proportional_weights(weights,
                                         jnp.asarray(client_ranks))

    def allreduce_leaf(self, local, mask, weight, axis_name):
        raise NotImplementedError(
            "rbla_ranked cannot reweight inside a shard_map body (a shard "
            "never sees the global rank vector); apply "
            "rank_proportional_weights to the weights first and use the "
            "'rbla' strategy")


@register_strategy
class RBLANormStrategy(AggregationStrategy):
    """RBLA + per-row update-norm preservation (pair-structured: the row
    axis differs between A and B, so it traverses whole pairs)."""
    name = "rbla_norm"
    norm_by = "mask"
    supports_pallas = True             # packed_agg(norm_restore=True)
    supports_distributed = False
    # homogeneous cohorts do NOT degenerate to FedAvg: the per-row norm
    # restoration rescales even fully-shared rows (that is the point)
    fedavg_equivalence = None
    # packed masked mean + per-row norm restore on ref AND pallas;
    # layer-stacked pairs stay on the (refusing) reference path
    plan_mode = "mean_norm"

    def leaf(self, stacked, mask, weights, prev=None):
        return rbla_leaf(stacked, mask, weights, prev)

    def aggregate_tree(self, stacked_tree, mask_tree, weights,
                       prev_tree=None, *, r_max=None, client_ranks=None):
        w = jnp.asarray(weights, jnp.float32)

        def agg_pair(pair, masks):
            if pair["A"].ndim != 3 or pair["B"].ndim != 3:
                raise NotImplementedError(
                    "rbla_norm supports scalar-rank pairs (got "
                    f"A.ndim={pair['A'].ndim}); the per-row norm target "
                    "needs a per-layer loop for layer-stacked pairs")
            return {
                "A": rbla_norm_leaf(pair["A"], masks["A"], w, row_axis=0),
                "B": rbla_norm_leaf(pair["B"], masks["B"], w, row_axis=1),
                "rank": pair["rank"][0],
            }
        return _map_pairs(agg_pair, stacked_tree, mask_tree, strict=True)

    # --------------------------------------------------- (d) Pallas path --
    def aggregate_tree_pallas(self, stacked_tree, weights, client_ranks,
                              prev_tree=None, *, r_max=None,
                              interpret=None):
        """Kernel path: the masked mean *and* the per-row norm restore
        fuse into one ``packed_agg(norm_restore=True)`` launch per side
        (the compiled plan fuses all pairs into one launch per bucket);
        the row-norm reduction keeps the whole row in one block."""
        from repro.kernels.rbla_agg.ops import packed_agg
        from .masks import stacked_rank_masks

        w = jnp.asarray(weights, jnp.float32)
        ranks = (None if client_ranks is None
                 else jnp.asarray(client_ranks, jnp.int32))

        def agg_pair(pair, _prev):
            A, B = pair["A"], pair["B"]
            pranks = ranks
            if pranks is None and jnp.asarray(pair["rank"]).ndim == 1:
                pranks = jnp.asarray(pair["rank"], jnp.int32)
            if A.ndim != 3 or B.ndim != 3 or pranks is None:
                raise NotImplementedError(
                    "rbla_norm supports scalar-rank pairs (got "
                    f"A.ndim={A.ndim}); the per-row norm target needs a "
                    "per-layer loop for layer-stacked pairs")
            masks = stacked_rank_masks(A.shape[-2], pranks)
            outA = packed_agg(A, masks, w, norm_by="mask",
                              norm_restore=True, interpret=interpret)
            outB = packed_agg(jnp.swapaxes(B, 1, 2), masks, w,
                              norm_by="mask", norm_restore=True,
                              interpret=interpret).T
            return {"A": outA.astype(A.dtype), "B": outB.astype(B.dtype),
                    "rank": pair["rank"][0]}
        return _map_pairs(agg_pair, stacked_tree, prev_tree, strict=True)


class RobustRBLAStrategy(AggregationStrategy):
    """Byzantine-tolerant RBLA family (pair-structured): the masked
    rank-row aggregation of Eq. 7 with the weighted mean replaced by a
    robust reduction over each row's owners.  Three registered variants
    share this base:

    * ``rbla_clipped`` -- every client rank-row is L2-clipped to
      ``clip_norm`` before the standard masked weighted mean; honest
      well-scaled uploads (norms under the clip) aggregate *exactly* like
      ``rbla``, an adversary's displacement is bounded by
      ``clip_norm * w_adv / (owner mass)``.
    * ``rbla_trimmed`` -- per-coordinate trimmed mean over a row's
      owners: drop ``k = min(floor(trim_frac * c), (c-1)//2)`` smallest
      and largest values among the ``c`` owners.  Breakdown point
      ~``trim_frac``.
    * ``rbla_median`` -- coordinate-wise median over a row's owners
      (even ``c``: mean of the middle two).  Breakdown point 1/2.

    Trimmed/median are *unweighted* over owners: example counts are
    client-reported and therefore adversary-controlled, so order
    statistics run on values, not masses.  Rows with no owner retain the
    previous global, exactly like ``rbla``.  All three lower through the
    packed mean-family plan (one fused ``packed_robust`` launch per
    (width, dtype) bucket); there is no distributed path -- order
    statistics need every client's value on one device, and clipping
    needs whole rows (``use backend='ref'`` or ``'pallas'``).  Folding is
    non-incremental by construction (a robust reduction is not a running
    mean), so the async service uses the exact replay path.
    """
    norm_by = "mask"
    use_mask = True
    retains_prev = True
    supports_pallas = True
    supports_distributed = False
    # robust reductions intentionally are not weighted means, so no
    # FedAvg degeneracy is declared (clipped matches rbla only while
    # every row norm is under the clip)
    fedavg_equivalence = None
    supports_incremental = False
    plan_mode = "mean"                 # packed buckets + robust combine
    #: L2 clip applied per (client, rank-row) by "clipped"
    clip_norm: float = 100.0
    #: per-end trim fraction of a row's owners used by "trimmed"
    trim_frac: float = 0.2

    def leaf(self, stacked, mask, weights, prev=None):
        # non-pair leaves (base trainables) have no rank-row structure to
        # defend; they keep the plain masked mean
        return rbla_leaf(stacked, mask, weights, prev)

    def _robust_pair(self, agg, pair, prev_pair, w, ranks):
        A, B = pair["A"], pair["B"]
        pranks = ranks
        if pranks is None and jnp.asarray(pair["rank"]).ndim == 1:
            pranks = jnp.asarray(pair["rank"], jnp.int32)
        if A.ndim != 3 or B.ndim != 3 or pranks is None:
            raise NotImplementedError(
                f"{self.name} supports scalar-rank pairs (got "
                f"A.ndim={A.ndim}); layer-stacked pairs lower through "
                "the compiled plan, which packs per-layer rows")
        from .masks import stacked_rank_masks
        masks = stacked_rank_masks(A.shape[-2], pranks)
        pA = pB = None
        if prev_pair is not None:
            pA, pB = prev_pair["A"], prev_pair["B"].T
        outA = agg(A, masks, w, pA)
        outB = agg(jnp.swapaxes(B, 1, 2), masks, w, pB).T
        return {"A": outA.astype(A.dtype), "B": outB.astype(B.dtype),
                "rank": pair["rank"][0]}

    def aggregate_tree(self, stacked_tree, mask_tree, weights,
                       prev_tree=None, *, r_max=None, client_ranks=None):
        from repro.kernels.rbla_agg.ref import packed_robust_ref
        w = jnp.asarray(weights, jnp.float32)
        ranks = (None if client_ranks is None
                 else jnp.asarray(client_ranks, jnp.int32))

        def agg(x, masks, wt, prev):
            return packed_robust_ref(x, masks, wt, prev,
                                     mode=self.robustness,
                                     clip_norm=self.clip_norm,
                                     trim_frac=self.trim_frac)
        return _map_pairs(
            lambda pair, prev_pair: self._robust_pair(agg, pair, prev_pair,
                                                      w, ranks),
            stacked_tree, prev_tree, strict=True)

    # --------------------------------------------------- (d) Pallas path --
    def aggregate_tree_pallas(self, stacked_tree, weights, client_ranks,
                              prev_tree=None, *, r_max=None,
                              interpret=None):
        """Kernel path: one fused ``packed_robust`` launch per side (the
        compiled plan fuses all pairs into one launch per bucket)."""
        from repro.kernels.rbla_agg.ops import packed_robust
        w = jnp.asarray(weights, jnp.float32)
        ranks = (None if client_ranks is None
                 else jnp.asarray(client_ranks, jnp.int32))

        def agg(x, masks, wt, prev):
            return packed_robust(x, masks, wt, prev, mode=self.robustness,
                                 clip_norm=self.clip_norm,
                                 trim_frac=self.trim_frac,
                                 interpret=interpret)
        return _map_pairs(
            lambda pair, prev_pair: self._robust_pair(agg, pair, prev_pair,
                                                      w, ranks),
            stacked_tree, prev_tree, strict=True)


@register_strategy
class RBLAClippedStrategy(RobustRBLAStrategy):
    name = "rbla_clipped"
    aliases = ("clipped",)
    robustness = "clipped"


@register_strategy
class RBLATrimmedStrategy(RobustRBLAStrategy):
    name = "rbla_trimmed"
    aliases = ("trimmed",)
    robustness = "trimmed"


@register_strategy
class RBLAMedianStrategy(RobustRBLAStrategy):
    name = "rbla_median"
    aliases = ("median",)
    robustness = "median"


@register_strategy
class SVDStrategy(AggregationStrategy):
    """Product-space aggregation: weighted-average the effective updates
    ``(r_out / rank_i) * B_i @ A_i`` (no dilution -- products are dense),
    truncated-SVD back to rank-``r_out`` factors, re-pad to storage rank.

    The ``r_out / rank_i`` scale matches effective updates under the
    ``alpha / rank`` LoRA convention: serving the aggregate at ``r_max``
    reproduces the weighted mean of the clients' effective deltas.

    The truncation runs through the factored low-rank engine
    (``repro.core.lowrank``): the weighted product mean is itself a
    product of concatenated factors, so the server cost is
    O((out + in) * k^2 + k^3) with k = n * r_storage -- no dense
    (out, in) delta is ever materialized -- instead of the
    O(out * in * min(out, in)) the paper flags.  Layer-stacked
    (leading-dim) pairs batch through the same engine.  ``svd_method``
    and the ``rsvd_*`` knobs (``with_options``-able) route the engine:
    "auto" is exact (factored while k <= min(out, in), dense beyond),
    "randomized" trades exactness for the range-finder sketch.
    """
    name = "svd"
    norm_by = "mask"
    supports_pallas = True             # engine math IS the kernel path
    supports_distributed = True        # gathered factors, replicated SVD
    plan_mode = "svd"                  # packed batched factored SVD
    # FedAvg-equivalence holds in product space only when the truncated
    # SVD is lossless (sum of client ranks <= r_out), which a random
    # cohort does not guarantee -- declared None; the exactness case is
    # covered by test_svd_single_client_preserves_effective_update
    fedavg_equivalence = None
    #: lowrank engine knobs: "auto" | "factored" | "dense" | "randomized"
    svd_method: str = "auto"
    rsvd_oversample: int = 8
    rsvd_power_iters: int = 2

    def _pair_scales(self, pranks, r_out: int):
        """Per-contributor ``r_out / rank`` scales, raw (n, *rank_lead)
        shape -- ``svd_project_stacked`` owns the broadcast alignment
        against the pair's leading dims."""
        return (jnp.float32(r_out) /
                jnp.maximum(jnp.asarray(pranks, jnp.float32), 1.0))

    def _project(self, B, A, w, r_out: int, scales):
        return svd_project_stacked(B, A, w, r_out, scales=scales,
                                   method=self.svd_method,
                                   oversample=self.rsvd_oversample,
                                   power_iters=self.rsvd_power_iters)

    def aggregate_tree(self, stacked_tree, mask_tree, weights,
                       prev_tree=None, *, r_max=None, client_ranks=None):
        w = jnp.asarray(weights, jnp.float32)

        def agg_pair(pair, _masks):
            A, B = pair["A"], pair["B"]
            r_storage = A.shape[-2]
            r_out = r_storage if r_max is None else min(r_max, r_storage)
            pranks = jnp.asarray(pair["rank"] if client_ranks is None
                                 else client_ranks, jnp.int32)
            scales = self._pair_scales(pranks, r_out)
            Bo, Ao = self._project(B, A, w, r_out, scales)
            return {"A": pad_to_rank(Ao.astype(A.dtype), -2, r_storage),
                    "B": pad_to_rank(Bo.astype(B.dtype), -1, r_storage),
                    "rank": pair["rank"][0]}
        return _map_pairs(agg_pair, stacked_tree, mask_tree, strict=True)

    # --------------------------------------------------- (d) Pallas path --
    def aggregate_tree_pallas(self, stacked_tree, weights, client_ranks,
                              prev_tree=None, *, r_max=None,
                              interpret=None):
        """The factored engine is matmul/QR-dominated: XLA's fused
        matmuls are the accelerator path, so the kernel backend shares
        the factored tree math (there is no reduction a hand-written
        Pallas kernel would beat here)."""
        return self.aggregate_tree(stacked_tree, None, weights, prev_tree,
                                   r_max=r_max, client_ranks=client_ranks)

    # ---------------------------------------------- (c) distributed path --
    def make_distributed_aggregator(self, mesh, client_axis: str = "data"):
        raise NotImplementedError(
            "svd's distributed path gathers the low-rank factors "
            "(all_gather moves (out+in)*r per client; a dense out*in "
            "delta psum would defeat the factored engine) and projects "
            "replicated -- use aggregate_tree_distributed / "
            "aggregate_adapters(backend='distributed') instead")

    def aggregate_tree_distributed(self, stacked_tree, mask_tree, weights,
                                   prev_tree=None, *, r_max=None,
                                   client_ranks=None, mesh=None,
                                   client_axis: str = "clients"):
        """Gathered-factor collective: each shard all_gathers the
        cohort's low-rank factors and rank vector -- O((out + in) * r)
        bytes per client on the wire, never a dense delta -- and runs
        the factored projection replicated.  Ranks ride as runtime data
        (the output storage is static), so one compiled round serves
        every rank multiset of this cohort shape."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from .plan import default_client_mesh

        w = jnp.asarray(weights, jnp.float32)
        n = int(w.shape[0])
        if mesh is None:
            mesh = default_client_mesh(n, client_axis)
        cr = (None if client_ranks is None
              else jnp.asarray(client_ranks, jnp.int32))
        cache = self.__dict__.setdefault("_dist_agg_cache", {})
        key = (mesh, client_axis, r_max, cr is not None, self.svd_method,
               self.rsvd_oversample, self.rsvd_power_iters)
        fn = cache.get(key)
        if fn is None:
            has_cr = cr is not None

            def body(st, wv, crv):
                wf = lax.all_gather(wv, client_axis, tiled=True)
                crf = (lax.all_gather(crv, client_axis, tiled=True)
                       if has_cr else None)

                def agg_pair(pair):
                    Ag = lax.all_gather(pair["A"], client_axis, tiled=True)
                    Bg = lax.all_gather(pair["B"], client_axis, tiled=True)
                    rg = lax.all_gather(
                        jnp.asarray(pair["rank"], jnp.int32), client_axis,
                        tiled=True)
                    r_storage = Ag.shape[-2]
                    r_out = (r_storage if r_max is None
                             else min(r_max, r_storage))
                    pranks = crf if has_cr else rg
                    scales = self._pair_scales(pranks, r_out)
                    Bo, Ao = self._project(Bg, Ag, wf, r_out, scales)
                    return {"A": pad_to_rank(Ao.astype(Ag.dtype), -2,
                                             r_storage),
                            "B": pad_to_rank(Bo.astype(Bg.dtype), -1,
                                             r_storage),
                            "rank": rg[0]}
                return _map_pairs(agg_pair, st, strict=True)

            fn = jax.jit(shard_map_no_check(
                body, mesh,
                in_specs=(P(client_axis), P(client_axis),
                          P(client_axis) if has_cr else P()),
                out_specs=P()))
            cache[key] = fn
        sh = NamedSharding(mesh, P(client_axis))
        return fn(jax.device_put(stacked_tree, sh), jax.device_put(w, sh),
                  jax.device_put(cr, sh) if cr is not None else
                  jnp.zeros((n,), jnp.int32))


@register_strategy
class FloraStrategy(AggregationStrategy):
    """FLoRA-style *stacking* aggregation (Wang et al., 2024).

    Instead of averaging factors row-by-row, the participating clients'
    A/B factors are concatenated along the rank axis, so the aggregate is
    **noise-free** (no cross-client interference) but **rank-growing**:
    the output's live rank is the sum of the contributors' ranks.  The
    previous global is retained by treating it as one more stacked
    contributor (mass ``prev_weight`` x the mean client weight), ILoRA-
    style concatenation plumbing serves the result.

    Scaling: contributor ``i`` (normalized mass ``m_i``, rank ``r_i``)
    enters with ``s_i = m_i * R_out / r_i`` folded into its B columns, so
    that serving the aggregate at rank ``R_out`` under the ``alpha/rank``
    LoRA convention reproduces the convex combination of the
    contributors' effective updates ``sum_i m_i * (alpha/r_i) B_i A_i``
    *exactly*.  A rows pass through verbatim.

    Rank cap: storage is padded to ``stack_r_cap`` (default ``2*r_max``).
    When the stacked rank would exceed the cap, the contributors are
    SVD re-projected back to ``r_max`` in product space instead (same
    math as the ``svd`` strategy, but over the ragged contributor list),
    and rank growth restarts from there next round.

    All paths need **concrete** client ranks: the stack/reproject
    decision and the concat offsets depend on their sum, which cannot be
    resolved under tracing.  Aggregate outside jit (the FL server does).
    """
    name = "flora"
    aliases = ("stacking",)
    rank_contract = "stacked"
    fedavg_equivalence = "product"
    retains_prev = True
    supports_pallas = True
    supports_distributed = True
    norm_by = "weight"
    plan_mode = "stack"
    # exact streaming below the cap: fold keeps a per-pair segment ledger
    # (FoldState.extra) and re-scales B columns in place, so one-at-a-time
    # folding reproduces the one-shot cohort stack bit-for-allclose; at a
    # cap crossing it re-projects in product space (see fold's docstring)
    supports_incremental = True
    stack_r_cap: int | None = None     # None -> 2 * r_max at aggregation
    prev_weight: float = 1.0           # prev global mass / mean client mass

    # ------------------------------------------------------ rank plumbing --
    def resolve_cap(self, r_max: int | None,
                    r_storage: int | None = None) -> int:
        if self.stack_r_cap is not None:
            return int(self.stack_r_cap)
        base = r_max if r_max is not None else r_storage
        if base is None:
            raise ValueError("flora needs r_max (or an explicit "
                             "stack_r_cap) to size the stacked storage")
        return 2 * int(base)

    def server_storage_rank(self, r_max: int | None) -> int | None:
        cap = self.resolve_cap(r_max)
        self._validate_cap(cap, np.zeros(0, np.int64), r_max)  # fail fast
        return cap

    @staticmethod
    def _concrete_ranks(ranks) -> np.ndarray:
        if ranks is None:
            raise ValueError(
                "flora needs the client ranks (pass client_ranks, or "
                "aggregate adapter trees whose pairs carry scalar ranks)")
        if isinstance(ranks, jax.core.Tracer):
            raise NotImplementedError(
                "flora stacking needs concrete client ranks: the output "
                "rank is their sum, which cannot be decided under "
                "jit tracing -- aggregate outside jit")
        arr = np.asarray(jax.device_get(ranks)).astype(np.int64)
        if arr.ndim == 2:            # layer-stacked (n, L): must be uniform
            if not np.all(arr == arr[:, :1]):
                raise NotImplementedError(
                    "flora supports layer-stacked pairs only when each "
                    "client's rank is uniform across layers")
            arr = arr[:, 0]
        return arr.reshape(-1)

    def _validate_cap(self, cap: int, ranks: np.ndarray,
                      r_max: int | None) -> None:
        mx = int(ranks.max()) if ranks.size else 0
        if cap < mx:
            raise ValueError(
                f"flora: stack_r_cap={cap} < max client rank {mx}; a "
                "single contributor would not fit the stacked storage -- "
                "raise stack_r_cap to at least the largest client rank")
        if r_max is not None and cap < r_max:
            raise ValueError(
                f"flora: stack_r_cap={cap} < r_max={r_max}: the SVD "
                "re-projection target would not fit the stacked storage")

    # -------------------------------------------------------- core pair op --
    def _stack_pair(self, A: Array, B: Array, ranks: np.ndarray, w: Array,
                    prev_A: Array | None, prev_B: Array | None,
                    prev_rank: int | None, r_max: int | None):
        """Stack (or SVD-reproject) one gathered pair.

        ``A``: (n, *lead, r_st, fan_in); ``B``: (n, *lead, fan_out, r_st).
        ``ranks``/``prev_rank`` are host ints (static); ``w`` may be
        traced.  Returns (A_out, B_out, r_out) at ``stack_r_cap`` storage.
        Contributor order is prev-first, so the leading rows of the new
        global continue the old one (clients that re-slice the top rows
        keep maximal continuity).
        """
        n = A.shape[0]
        cap = self.resolve_cap(r_max, r_storage=A.shape[-2])
        self._validate_cap(cap, ranks, r_max)
        wf = jnp.asarray(w, jnp.float32)

        seg_ranks: list[int] = []
        A_parts, B_parts, masses = [], [], []
        if prev_A is not None and prev_rank:
            seg_ranks.append(int(prev_rank))
            A_parts.append(prev_A[..., :int(prev_rank), :])
            B_parts.append(prev_B[..., :int(prev_rank)])
            masses.append(self.prev_weight * jnp.mean(wf))
        for i in range(n):
            r_i = int(ranks[i])
            if r_i <= 0:
                continue
            seg_ranks.append(r_i)
            A_parts.append(A[i][..., :r_i, :])
            B_parts.append(B[i][..., :, :r_i])
            masses.append(wf[i])
        if not seg_ranks:
            raise ValueError("flora: empty cohort (all ranks are zero)")
        m = jnp.stack(masses)
        mhat = m / (jnp.sum(m) + _EPS)
        r_total = int(sum(seg_ranks))

        if r_total <= cap:
            r_out = r_total
            scales = mhat * (jnp.float32(r_out) /
                             jnp.asarray(seg_ranks, jnp.float32))
            A_out = jnp.concatenate([a.astype(jnp.float32)
                                     for a in A_parts], axis=-2)
            B_out = jnp.concatenate(
                [b.astype(jnp.float32) * scales[i]
                 for i, b in enumerate(B_parts)], axis=-1)
        else:
            # over the cap: product-space re-projection back to r_max,
            # in factored form (repro.core.lowrank) -- the convex sum of
            # contributor products is a product of concatenated factors,
            # so no dense (out, in) delta is built (batched over any
            # leading layer/expert dims)
            r_out = min(int(r_max if r_max is not None else A.shape[-2]),
                        cap)
            B_cat = jnp.concatenate(
                [b.astype(jnp.float32)
                 * (mhat[i] * (jnp.float32(r_out)
                               / jnp.float32(seg_ranks[i])))
                 for i, b in enumerate(B_parts)], axis=-1)
            A_cat = jnp.concatenate([a.astype(jnp.float32)
                                     for a in A_parts], axis=-2)
            B_out, A_out = product_factors(B_cat, A_cat, r_out)
        A_out = pad_to_rank(A_out.astype(A.dtype), -2, cap)
        B_out = pad_to_rank(B_out.astype(B.dtype), -1, cap)
        return A_out, B_out, r_out

    def _pair_ranks(self, pair, client_ranks) -> np.ndarray:
        got = (pair["rank"] if client_ranks is None else client_ranks)
        return self._concrete_ranks(got)

    @staticmethod
    def _out_rank_leaf(stacked_rank_leaf, r_out: int) -> Array:
        # drop the client axis: scalar-rank -> (), layer-stacked -> (L,)
        shape = jnp.asarray(stacked_rank_leaf).shape[1:]
        return jnp.full(shape, r_out, jnp.int32)

    @staticmethod
    def _prev_rank_of(prev_pair) -> int | None:
        if prev_pair is None:
            return None
        return int(np.max(np.asarray(jax.device_get(prev_pair["rank"]))))

    def finalize_tree(self, out: PyTree, r_max: int | None) -> PyTree:
        return out                       # live ranks already written

    # ---------------------------------------------------- per-update fold --
    def init_fold(self, state: ServerState) -> FoldState:
        """Open a per-pair segment ledger anchored at ``state``: the
        anchor enters the stream as the prev contributor (its B columns
        currently carry scale 1)."""
        if state.adapters is None:
            return FoldState()
        pairs = []

        def grab(pair):
            r_live = int(np.max(np.asarray(jax.device_get(pair["rank"]))))
            pairs.append({
                "prev_rank": r_live,       # anchor segment rows
                "seg_ranks": [],           # client segment ranks, in order
                "seg_w": [],               # client segment masses
                # applied B-column scales, [prev] + clients, aligned with
                # the segment order; the anchor starts unscaled
                "applied": [1.0] if r_live else [],
                "anchor_mass": None,       # set after a cap re-projection
            })
            return pair
        _map_pairs(grab, state.adapters)
        return FoldState(extra={"w_list": [], "pairs": pairs})

    def fold(self, state, update, weight=None, *, fold_state=None,
             backend="auto", interpret=None):
        """Exact streaming stack (below the cap): every contributor owns
        a disjoint B-column segment, and the one-shot scales
        ``m_i_hat * R_out / r_i`` change *multiplicatively* as the cohort
        grows -- so the fold keeps a per-pair ledger of segment ranks,
        masses, and currently-applied scales (:class:`FoldState.extra`)
        and re-scales existing columns by ``desired / applied`` before
        writing the arriving client's rows at the next static offset.
        Folding a cohort one update at a time therefore reproduces the
        one-shot cohort :meth:`aggregate` exactly (the anchor's mass is
        re-derived as ``prev_weight x mean of the weights seen so far``,
        which at the last fold equals the one-shot bookkeeping).

        A stale update is *down-weighted* -- its small effective mass
        shrinks its segment's scale -- never dropped.

        When a fold would cross ``stack_r_cap``, the ledgered stack is
        re-projected in product space back to ``r_max`` (the same SVD the
        one-shot over-cap path runs, on the mathematically identical
        matrix) and the re-projected state becomes a fresh anchor whose
        mass is everything folded so far; streaming after a mid-stream
        crossing can differ from a one-shot that truncated only once.
        """
        fs = fold_state if fold_state is not None else self.init_fold(state)
        if fs.extra is None:
            fs = dataclasses.replace(self.init_fold(state), mass=fs.mass,
                                     n_folds=fs.n_folds)
        w = float(update.n_examples if weight is None else weight)
        if w <= 0:
            raise ValueError(f"fold needs a positive weight, got {w}")

        new_adapters = state.adapters
        extra = fs.extra
        rank_seen = update.rank
        if state.adapters is not None and update.adapters is not None:
            w_list = extra["w_list"] + [w]
            mean_w = sum(w_list) / len(w_list)
            idx = [0]
            new_pairs = []

            def fold_pair(pair, upd_pair):
                meta = extra["pairs"][idx[0]]
                idx[0] += 1
                rk = np.asarray(jax.device_get(upd_pair["rank"]))
                if rk.size > 1 and not np.all(rk == rk.flat[0]):
                    # same contract the one-shot path enforces in
                    # _concrete_ranks: segment offsets must be shared
                    # across layers
                    raise NotImplementedError(
                        "flora supports layer-stacked pairs only when "
                        "each client's rank is uniform across layers")
                r_upd = int(rk.max()) if rk.size else 0
                storage = pair["A"].shape[-2]
                cap = self.resolve_cap(state.r_max, r_storage=storage)
                self._validate_cap(cap, np.asarray([r_upd]), state.r_max)
                prev_rank = meta["prev_rank"]
                prev_mass = (meta["anchor_mass"]
                             if meta["anchor_mass"] is not None
                             else self.prev_weight * mean_w)
                seg_ranks = (([prev_rank] if prev_rank else [])
                             + meta["seg_ranks"]
                             + ([r_upd] if r_upd else []))
                masses = (([prev_mass] if prev_rank else [])
                          + meta["seg_w"] + ([w] if r_upd else []))
                if not seg_ranks:
                    raise ValueError("flora: empty fold (rank 0 update "
                                     "into an empty state)")
                r_out = int(sum(seg_ranks))
                m = np.asarray(masses, np.float64)
                mhat = m / (m.sum() + _EPS)
                A, B = pair["A"], pair["B"]
                off = r_out - r_upd        # the new segment's row offset

                if r_out <= cap:
                    desired = mhat * (float(r_out)
                                      / np.asarray(seg_ranks, np.float64))
                    # re-scale every existing segment's B columns in place
                    applied = meta["applied"] + ([1.0] if r_upd else [])
                    colscale = np.ones(storage, np.float32)
                    o = 0
                    for j, rj in enumerate(seg_ranks):
                        colscale[o:o + rj] = desired[j] / applied[j]
                        o += rj
                    B = B.astype(jnp.float32) * jnp.asarray(colscale)
                    if r_upd:
                        B = B.at[..., :, off:off + r_upd].set(
                            jnp.float32(desired[-1])
                            * upd_pair["B"][..., :, :r_upd].astype(
                                jnp.float32))
                        A = A.at[..., off:off + r_upd, :].set(
                            upd_pair["A"][..., :r_upd, :].astype(A.dtype))
                    new_pairs.append({
                        "prev_rank": prev_rank,
                        "seg_ranks": meta["seg_ranks"]
                        + ([r_upd] if r_upd else []),
                        "seg_w": meta["seg_w"] + ([w] if r_upd else []),
                        "applied": list(desired),
                        "anchor_mass": meta["anchor_mass"],
                    })
                    rank_out = r_out
                else:
                    # cap crossing: product-space re-projection to r_max,
                    # over the mathematically identical matrix the
                    # one-shot over-cap path builds -- factored, so the
                    # ledgered stack plus the arriving segment concatenate
                    # into (storage + r_upd)-wide factors and no dense
                    # (out, in) delta is ever materialized
                    r_t = min(int(state.r_max if state.r_max is not None
                                  else storage), cap)
                    desired = mhat * (float(r_t)
                                      / np.asarray(seg_ranks, np.float64))
                    applied = meta["applied"] + ([1.0] if r_upd else [])
                    colscale = np.zeros(storage, np.float32)
                    o = 0
                    n_old = len(seg_ranks) - (1 if r_upd else 0)
                    for j in range(n_old):
                        rj = seg_ranks[j]
                        colscale[o:o + rj] = desired[j] / applied[j]
                        o += rj
                    B_cat = B.astype(jnp.float32) * jnp.asarray(colscale)
                    A_cat = A.astype(jnp.float32)
                    if r_upd:
                        B_cat = jnp.concatenate(
                            [B_cat, jnp.float32(desired[-1])
                             * upd_pair["B"][..., :, :r_upd].astype(
                                 jnp.float32)], axis=-1)
                        A_cat = jnp.concatenate(
                            [A_cat, upd_pair["A"][..., :r_upd, :].astype(
                                jnp.float32)], axis=-2)
                    B_new, A_new = product_factors(B_cat, A_cat, r_t)
                    B = pad_to_rank(B_new.astype(B.dtype), -1, storage)
                    A = pad_to_rank(A_new.astype(A.dtype), -2, storage)
                    new_pairs.append({
                        "prev_rank": r_t, "seg_ranks": [], "seg_w": [],
                        "applied": [1.0],
                        "anchor_mass": float(m.sum()),
                    })
                    rank_out = r_t
                return {"A": A, "B": B.astype(pair["B"].dtype),
                        "rank": jnp.full_like(
                            jnp.asarray(pair["rank"], jnp.int32),
                            rank_out)}

            new_adapters = _map_pairs(fold_pair, state.adapters,
                                      update.adapters, strict=True)
            extra = {"w_list": w_list, "pairs": new_pairs}
            if rank_seen is None:
                rank_seen = max((p["seg_ranks"][-1] for p in new_pairs
                                 if p["seg_ranks"]), default=None)

        kind = resolve_backend(backend, self)
        if kind == "distributed":      # one update: nothing to distribute
            kind = "ref"
        new_base = state.base_trainable
        if jax.tree.leaves(update.base_trainable):
            new_base = _mix_trees(state.base_trainable,
                                  update.base_trainable,
                                  w / (fs.mass + w), kind=kind,
                                  interpret=interpret)

        new_fs = FoldState(mass=fs.mass + w, n_folds=fs.n_folds + 1,
                           extra=extra)
        current_rank = (adapter_live_ranks(new_adapters)
                        if new_adapters is not None else state.current_rank)
        return ServerState(
            adapters=new_adapters, base_trainable=new_base,
            round=state.round + 1, r_max=state.r_max,
            client_ranks=(jnp.asarray([rank_seen], jnp.int32)
                          if rank_seen is not None else state.client_ranks),
            current_rank=current_rank), new_fs

    # ------------------------------------------------- (b) tree traversal --
    def aggregate_tree(self, stacked_tree, mask_tree, weights,
                       prev_tree=None, *, r_max=None, client_ranks=None):
        w = jnp.asarray(weights, jnp.float32)

        def agg_pair(pair, _masks, prev_pair):
            ranks = self._pair_ranks(pair, client_ranks)
            pA = prev_pair["A"] if prev_pair is not None else None
            pB = prev_pair["B"] if prev_pair is not None else None
            A_out, B_out, r_out = self._stack_pair(
                pair["A"], pair["B"], ranks, w, pA, pB,
                self._prev_rank_of(prev_pair), r_max)
            return {"A": A_out, "B": B_out,
                    "rank": self._out_rank_leaf(pair["rank"], r_out)}
        return _map_pairs(agg_pair, stacked_tree, mask_tree, prev_tree,
                          strict=True)

    # ---------------------------------------------- (c) distributed path --
    def make_distributed_aggregator(self, mesh, client_axis: str = "data"):
        raise NotImplementedError(
            "flora's distributed path is a ragged concat "
            "(gather-then-stack), not a uniform masked psum -- the base "
            "leafwise aggregator would silently average the stacked "
            "factors; use aggregate_tree_distributed / "
            "aggregate_adapters(backend='distributed') instead")

    def aggregate_tree_distributed(self, stacked_tree, mask_tree, weights,
                                   prev_tree=None, *, r_max=None,
                                   client_ranks=None, mesh=None,
                                   client_axis: str = "clients"):
        """Ragged-concat collective: ranks differ per client, so there is
        no uniform psum.  Each shard all-gathers the cohort's factors
        (gather-then-stack) and computes the stacked pair replicated; the
        concat offsets are static (host-known ranks) so the gathered
        layout compiles to plain slices."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        w = jnp.asarray(weights, jnp.float32)
        ranks = self._concrete_ranks(
            client_ranks if client_ranks is not None
            else _infer_ranks(stacked_tree))
        n = int(w.shape[0])
        if mesh is None:
            from .plan import default_client_mesh
            mesh = default_client_mesh(n, client_axis)
        prev_rank_tree = (None if prev_tree is None else
                          _map_pairs(self._prev_rank_of, prev_tree))

        # one trace+compile per (mesh, cohort rank multiset, prev ranks,
        # r_max), not one per FL round: the closure is static in exactly
        # these values (jit itself re-traces on leaf-shape changes)
        cache = self.__dict__.setdefault("_dist_agg_cache", {})
        prev_leaves, prev_def = jax.tree.flatten(prev_rank_tree)
        key = (mesh, client_axis, tuple(int(r) for r in ranks), r_max,
               tuple(prev_leaves), prev_def)
        fn = cache.get(key)
        if fn is None:
            def body(st, wv, pv):
                wf = lax.all_gather(wv, client_axis, tiled=True)

                def agg_pair(pair, prev_pair, prev_rank):
                    Ag = lax.all_gather(pair["A"], client_axis, tiled=True)
                    Bg = lax.all_gather(pair["B"], client_axis, tiled=True)
                    pA = prev_pair["A"] if prev_pair is not None else None
                    pB = prev_pair["B"] if prev_pair is not None else None
                    A_out, B_out, r_out = self._stack_pair(
                        Ag, Bg, ranks, wf, pA, pB, prev_rank, r_max)
                    shape = pair["rank"].shape[1:]
                    return {"A": A_out, "B": B_out,
                            "rank": jnp.full(shape, r_out, jnp.int32)}
                return _map_pairs(agg_pair, st, pv, prev_rank_tree,
                                  strict=True)

            fn = jax.jit(shard_map_no_check(
                body, mesh,
                in_specs=(P(client_axis), P(client_axis), P()),
                out_specs=P()))
            cache[key] = fn
        sh = NamedSharding(mesh, P(client_axis))
        return fn(jax.device_put(stacked_tree, sh),
                  jax.device_put(w, sh), prev_tree)

    # --------------------------------------------------- (d) Pallas path --
    def aggregate_tree_pallas(self, stacked_tree, weights, client_ranks,
                              prev_tree=None, *, r_max=None,
                              interpret=None):
        """Kernel path: the stack is a pure copy/scale (no reduction), so
        the ``flora_stack`` kernel places each contributor's live rows at
        its static offset in one pass.  Layer-stacked (leading-dim) pairs
        and over-cap cohorts (SVD re-projection) fall back to the
        reference pair math."""
        from repro.kernels.rbla_agg.ops import flora_stack

        w = jnp.asarray(weights, jnp.float32)

        def agg_pair(pair, prev_pair):
            A, B = pair["A"], pair["B"]
            ranks = self._pair_ranks(pair, client_ranks)
            prev_rank = self._prev_rank_of(prev_pair)
            pA = prev_pair["A"] if prev_pair is not None else None
            pB = prev_pair["B"] if prev_pair is not None else None
            cap = self.resolve_cap(r_max, r_storage=A.shape[-2])
            self._validate_cap(cap, ranks, r_max)

            has_prev = pA is not None and bool(prev_rank)
            seg_ranks = [int(prev_rank)] if has_prev else []
            live = [i for i in range(len(ranks)) if int(ranks[i]) > 0]
            seg_ranks += [int(ranks[i]) for i in live]
            r_total = int(sum(seg_ranks))
            if A.ndim != 3 or B.ndim != 3 or r_total > cap:
                # reference fallback: layer-stacked pairs / SVD reproject
                A_out, B_out, r_out = self._stack_pair(
                    A, B, ranks, w, pA, pB, prev_rank, r_max)
                return {"A": A_out, "B": B_out,
                        "rank": self._out_rank_leaf(pair["rank"], r_out)}

            # uniform-storage contributor stacks (prev first, like ref);
            # the kernel wants the rank axis leading, so B rides transposed
            r_st = max(A.shape[-2], pA.shape[-2] if has_prev else 0)
            keep = jnp.asarray(live, jnp.int32)
            partsA = [pad_to_rank(A.astype(jnp.float32), -2, r_st)[keep]]
            partsBt = [pad_to_rank(
                jnp.swapaxes(B, 1, 2).astype(jnp.float32), -2, r_st)[keep]]
            masses = [w[i] for i in live]
            if has_prev:
                partsA.insert(0, pad_to_rank(
                    pA.astype(jnp.float32), -2, r_st)[None])
                partsBt.insert(0, pad_to_rank(
                    jnp.swapaxes(pB, 0, 1).astype(jnp.float32),
                    -2, r_st)[None])
                masses.insert(0, self.prev_weight * jnp.mean(w))
            xA = jnp.concatenate(partsA, axis=0)
            xBt = jnp.concatenate(partsBt, axis=0)
            m = jnp.stack(masses)
            mhat = m / (jnp.sum(m) + _EPS)
            r_out = r_total
            scales = mhat * (jnp.float32(r_out) /
                             jnp.asarray(seg_ranks, jnp.float32))
            segs = tuple(seg_ranks)
            A_out = flora_stack(xA, jnp.ones_like(scales), segs=segs,
                                out_rows=cap, interpret=interpret)
            B_out = flora_stack(xBt, scales, segs=segs, out_rows=cap,
                                interpret=interpret).T
            return {"A": A_out.astype(A.dtype), "B": B_out.astype(B.dtype),
                    "rank": self._out_rank_leaf(pair["rank"], r_out)}
        return _map_pairs(agg_pair, stacked_tree, prev_tree, strict=True)


__all__ = [
    "AggregationStrategy", "ServerState", "ClientUpdate", "FoldState",
    "BACKENDS",
    "register_strategy", "get_strategy", "list_strategies",
    "resolve_backend", "stack_trees", "adapter_live_ranks",
    "FedAvgStrategy", "ZeropadStrategy", "RBLAStrategy",
    "RBLARankedStrategy", "RBLANormStrategy", "RobustRBLAStrategy",
    "RBLAClippedStrategy", "RBLATrimmedStrategy", "RBLAMedianStrategy",
    "SVDStrategy", "FloraStrategy",
]
