"""Rank-row indicator masks (the paper's delta function, Eq. 6).

The paper carries heterogeneous-rank LoRA adapters as ragged matrices and
defines, for every "layer" (rank-row) ``r`` of the padded adapter,

    delta_{i,r} = 1  if client i's adapter contains row r  (r < rank_i)
                  0  otherwise.

On TPU we need static shapes, so adapters are always stored padded to
``r_max`` and the raggedness lives in these masks.  Masks are computed with
``lax.broadcasted_iota`` so they trace cleanly under jit/pjit with traced
``rank`` scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def rank_mask(r_max: int, rank: Array | int, dtype=jnp.float32) -> Array:
    """``(r_max,)`` vector: 1.0 for rows < rank, 0.0 beyond (delta_{i,r})."""
    iota = lax.iota(jnp.int32, r_max)
    return (iota < jnp.asarray(rank, jnp.int32)).astype(dtype)


def axis_mask(shape: tuple[int, ...], axis: int, rank: Array | int,
              dtype=jnp.float32) -> Array:
    """Broadcastable mask of ``shape`` that is 1 where ``index[axis] < rank``.

    Used to mask a padded adapter along its rank axis: for LoRA ``A`` of
    shape ``(r_max, fan_in)`` the rank axis is 0, for ``B`` of shape
    ``(fan_out, r_max)`` it is 1 (or -1).
    """
    axis = axis % len(shape)
    iota = lax.broadcasted_iota(jnp.int32, shape, axis)
    return (iota < jnp.asarray(rank, jnp.int32)).astype(dtype)


def stacked_rank_masks(r_max: int, ranks: Array, dtype=jnp.float32) -> Array:
    """``(n_clients, r_max)`` matrix of delta_{i,r} for stacked clients."""
    ranks = jnp.asarray(ranks, jnp.int32)
    iota = lax.iota(jnp.int32, r_max)[None, :]
    return (iota < ranks[:, None]).astype(dtype)


def pad_to_rank(x: Array, axis: int, r_max: int) -> Array:
    """Zero-pad ``x`` along ``axis`` up to size ``r_max`` (static shapes)."""
    axis = axis % x.ndim
    cur = x.shape[axis]
    if cur > r_max:
        raise ValueError(f"cannot pad axis of size {cur} down to {r_max}")
    if cur == r_max:
        return x
    pads = [(0, 0, 0)] * x.ndim
    pads[axis] = (0, r_max - cur, 0)
    return lax.pad(x, jnp.zeros((), x.dtype), pads)


def slice_to_rank(x: Array, axis: int, rank: int) -> Array:
    """Client-side Alg. 2: extract the leading ``rank`` rows along ``axis``."""
    axis = axis % x.ndim
    return lax.slice_in_dim(x, 0, rank, axis=axis)
