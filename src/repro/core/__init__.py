"""RBLA core: rank-based aggregation of heterogeneous LoRA adapters.

This package is the paper's primary contribution (Eq. 6-7, Alg. 1-2) plus
its distributed (shard_map collective) form, beyond-paper variants, and the
pluggable :class:`~repro.core.strategy.AggregationStrategy` registry that
ties every method's reference, distributed, and Pallas paths together.
"""
from .masks import (axis_mask, pad_to_rank, rank_mask, slice_to_rank,
                    stacked_rank_masks)
from .aggregation import (aggregate, fedavg_leaf, rbla_leaf, zeropad_leaf,
                          AGGREGATORS)
from .variants import (rank_proportional_weights, rbla_norm_leaf,
                       svd_project_pair)
from .lowrank import (dense_svd, factored_svd, product_factors,
                      randomized_svd, randomized_svd_product,
                      svd_project_stacked, truncated_svd_product)
from .strategy import (AggregationStrategy, ClientUpdate, FoldState,
                       ServerState, BACKENDS, adapter_live_ranks,
                       get_strategy, list_strategies, register_strategy,
                       resolve_backend, stack_trees)
from .plan import (CohortSpec, CompiledRound, PlanUnavailable,
                   build_cohort_spec, build_encoded_cohort_spec,
                   dispatch_counter)
from .codec import (CODECS, cohort_codecs, decode_adapters, decode_pair,
                    decode_update, encode_adapters, encode_pair,
                    encode_update, stochastic_round, stochastic_round_tree,
                    tree_codec, validate_encoded_adapters)
from .distributed import (make_distributed_aggregator, rbla_allreduce,
                          rbla_tree_allreduce)

__all__ = [
    "axis_mask", "pad_to_rank", "rank_mask", "slice_to_rank",
    "stacked_rank_masks", "aggregate", "fedavg_leaf", "rbla_leaf",
    "zeropad_leaf", "AGGREGATORS", "make_distributed_aggregator",
    "rbla_allreduce", "rbla_tree_allreduce", "rank_proportional_weights",
    "rbla_norm_leaf", "svd_project_pair",
    "dense_svd", "factored_svd", "product_factors", "randomized_svd",
    "randomized_svd_product", "svd_project_stacked",
    "truncated_svd_product",
    "AggregationStrategy",
    "ClientUpdate", "FoldState", "ServerState", "BACKENDS",
    "adapter_live_ranks",
    "CohortSpec", "CompiledRound", "PlanUnavailable", "build_cohort_spec",
    "build_encoded_cohort_spec", "dispatch_counter",
    "CODECS", "cohort_codecs", "decode_adapters", "decode_pair",
    "decode_update", "encode_adapters", "encode_pair", "encode_update",
    "stochastic_round", "stochastic_round_tree", "tree_codec",
    "validate_encoded_adapters",
    "get_strategy",
    "list_strategies", "register_strategy", "resolve_backend",
    "stack_trees",
]
