"""Upload codecs for LoRA adapter transport (RBLA PR 8).

At FLaaS scale the binding cost is upload bytes, not FLOPs: every client
ships fp32 ``(A, B)`` factors each round.  This module defines the wire
formats clients apply *before* ``AsyncAggregator.submit``:

``none``
    fp32 pass-through (bit-exact baseline).
``bf16``
    plain ``astype(bfloat16)`` cast -- 2x smaller, exact for values whose
    mantissa fits in 8 bits.
``int8``
    symmetric per-row quantization on the *packed row convention* from
    :func:`repro.core.plan.pair_side_rows`: each of ``A``'s rank rows
    (``amax`` over the fan-in axis) and each of ``B``'s rank *columns*
    (``amax`` over the fan-out axis -- the packed layer transposes B, so
    its packed rows are columns) carries one fp32 scale
    ``max|row| / 127``; payload is ``clip(round(x / scale), -127, 127)``
    as int8.  ~4x smaller; scales travel as runtime data so the plan
    layer's per-(width, dtype) bucket cache survives and dequantization
    fuses into ``packed_agg`` -- no fp32 staging buffer is materialized.

An encoded int8 pair is the usual ``{"A", "B", "rank"}`` mapping plus
``"A_scale"`` / ``"B_scale"`` entries of shape ``(..., r_max)``; the pair
walkers in :mod:`repro.core.plan` test key *containment*, so encoded
pairs flow through the same pytrees.  ``decode_pair`` is idempotent on
plain fp32 pairs, which keeps server paths codec-agnostic.

The server-side half of quantized transport lives here too:
:func:`stochastic_round` (f32 -> bf16 with mantissa-noise rounding, the
olmax-style trick for unbiased low-precision accumulators) backs the
``accum_dtype="bfloat16"`` fold state in
:class:`repro.fl.async_agg.AsyncAggregator`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp

#: registered codec names, in negotiation-preference order.
CODECS = ("none", "bf16", "int8")

_INT8_QMAX = 127.0


# ----------------------------------------------------------- tree walk ----
# local pair predicates (repro.lora imports repro.core.masks; importing
# repro.lora from here would cycle through the package __init__)
def _is_pair(node: Any) -> bool:
    return (isinstance(node, Mapping) and "A" in node and "B" in node
            and "rank" in node)


def _map_pairs(fn, tree):
    if _is_pair(tree):
        return fn(tree)
    if isinstance(tree, Mapping):
        return {k: _map_pairs(fn, v) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(_map_pairs(fn, v) for v in tree)
    return tree


def _iter_pairs(tree, path=()):
    if _is_pair(tree):
        yield path, tree
        return
    if isinstance(tree, Mapping):
        for k, v in tree.items():
            yield from _iter_pairs(v, path + (k,))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _iter_pairs(v, path + (i,))


# -------------------------------------------------------------- codecs ----
def codec_of_pair(pair: Mapping) -> str:
    """Wire format of one (possibly encoded) pair."""
    if "A_scale" in pair or "B_scale" in pair:
        return "int8"
    if jnp.asarray(pair["A"]).dtype == jnp.bfloat16:
        return "bf16"
    return "none"


def tree_codec(adapters) -> str:
    """Codec of a whole adapter tree; ``"mixed"`` if pairs disagree."""
    seen = {codec_of_pair(p) for _, p in _iter_pairs(adapters)}
    if not seen:
        return "none"
    return seen.pop() if len(seen) == 1 else "mixed"


def cohort_codecs(client_adapters: Sequence) -> tuple | None:
    """Per-client codec names for a cohort, or ``None`` when every client
    uploaded plain fp32 (the fast path: zero codec overhead)."""
    codecs = tuple(tree_codec(a) for a in client_adapters)
    return None if all(c == "none" for c in codecs) else codecs


def _int8_encode_side(x, row_axis: int):
    """Quantize one factor along the packed-row axis.

    ``row_axis=-1`` treats trailing-axis vectors as rows (A); ``-2``
    quantizes columns (B, whose packed rows are columns).  Returns
    ``(q_int8, scale)`` with ``scale`` of shape ``x.shape`` minus the
    reduced axis -- ``(..., r_max)`` either way."""
    xf = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=row_axis)
    scale = jnp.where(amax > 0, amax / _INT8_QMAX, 1.0)
    s = jnp.expand_dims(scale, row_axis)
    q = jnp.clip(jnp.round(xf / s), -_INT8_QMAX, _INT8_QMAX)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def encode_pair(pair: Mapping, codec: str) -> dict:
    """Encode one pair for upload.  ``rank`` always stays exact."""
    if codec == "none":
        return dict(pair)
    if codec == "bf16":
        out = dict(pair)
        out["A"] = jnp.asarray(pair["A"]).astype(jnp.bfloat16)
        out["B"] = jnp.asarray(pair["B"]).astype(jnp.bfloat16)
        return out
    if codec == "int8":
        qa, sa = _int8_encode_side(pair["A"], row_axis=-1)
        qb, sb = _int8_encode_side(pair["B"], row_axis=-2)
        out = dict(pair)
        out.update(A=qa, B=qb, A_scale=sa, B_scale=sb)
        return out
    raise ValueError(f"unknown codec {codec!r}; options: {list(CODECS)}")


def decode_pair(pair: Mapping) -> dict:
    """Dequantize one pair to fp32.  Idempotent on plain pairs."""
    codec = codec_of_pair(pair)
    if codec == "none":
        return dict(pair)
    out = {k: v for k, v in pair.items()
           if k not in ("A_scale", "B_scale")}
    if codec == "bf16":
        out["A"] = jnp.asarray(pair["A"]).astype(jnp.float32)
        out["B"] = jnp.asarray(pair["B"]).astype(jnp.float32)
        return out
    sa = jnp.asarray(pair["A_scale"], jnp.float32)
    sb = jnp.asarray(pair["B_scale"], jnp.float32)
    out["A"] = jnp.asarray(pair["A"]).astype(jnp.float32) * sa[..., :, None]
    out["B"] = jnp.asarray(pair["B"]).astype(jnp.float32) * sb[..., None, :]
    return out


def encode_adapters(adapters, codec: str):
    """Encode every pair in an adapter tree; non-pair leaves untouched."""
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r}; options: {list(CODECS)}")
    if codec == "none":
        return adapters
    return _map_pairs(lambda p: encode_pair(p, codec), adapters)


def decode_adapters(adapters):
    """Dequantize every pair in a tree to fp32 (idempotent)."""
    return _map_pairs(decode_pair, adapters)


def encode_update(update, codec: str):
    """Encode a ``ClientUpdate``'s adapters (``base_trainable`` stays
    fp32 -- base rows are shared-dense and fold through plain FedAvg,
    outside the packed-plan codec contract)."""
    return dataclasses.replace(update,
                               adapters=encode_adapters(update.adapters,
                                                        codec))


def decode_update(update):
    """Dequantize a ``ClientUpdate`` (idempotent on plain updates)."""
    return dataclasses.replace(update,
                               adapters=decode_adapters(update.adapters))


# ---------------------------------------------------------- validation ----
class UploadValidationError(ValueError):
    """A rejected upload, tagged with the machine-readable ``reason``
    the ingestion metrics count it under (``fl_updates_rejected_total``;
    see ``docs/observability.md`` for the reason catalog).  Subclasses
    ``ValueError`` so existing ``except ValueError`` call sites and
    ``pytest.raises(ValueError, match=...)`` tests keep working."""

    def __init__(self, message: str, reason: str):
        super().__init__(message)
        self.reason = reason


def validate_encoded_adapters(adapters) -> None:
    """Ingestion sanity for encoded uploads (host-side, eager).

    Raises :class:`UploadValidationError` (a ``ValueError``) when any
    quantization scale is non-finite or non-positive (``reason
    "bad_scale"``), or when an int8 payload's decoded norm would overflow
    fp32 (``scale * 127 * sqrt(row_width)`` past ``finfo(f32).max`` --
    such an upload would poison ``FoldState`` masses irrecoverably;
    ``reason "overflow"``)."""
    for path, pair in _iter_pairs(adapters):
        name = "/".join(str(p) for p in path) or "<root>"
        for side, key in (("A", "A_scale"), ("B", "B_scale")):
            if key not in pair:
                continue
            s = jnp.asarray(pair[key], jnp.float32)
            if not bool(jnp.all(jnp.isfinite(s) & (s > 0))):
                raise UploadValidationError(
                    f"non-finite or non-positive quantization scale in "
                    f"{name}.{key}", reason="bad_scale")
            width = (pair[side].shape[-1] if side == "A"
                     else pair[side].shape[-2])
            limit = float(jnp.finfo(jnp.float32).max) / (
                _INT8_QMAX * math.sqrt(max(width, 1)))
            if bool(jnp.any(s > limit)):
                raise UploadValidationError(
                    f"quantization scale overflow in {name}.{key}: decoded "
                    f"row norm would exceed float32 range",
                    reason="overflow")


# ---------------------------------------------- stochastic accumulators ----
def stochastic_round(x, key, dtype=jnp.bfloat16):
    """Round f32 -> ``dtype`` (bf16) stochastically, olmax-style.

    Adds 16 uniform random bits to the f32 bit pattern and truncates the
    low mantissa half: ``bf16(bitcast(bitcast_u32(x) + u16) &
    0xFFFF0000)``.  Rounds up with probability ``frac/ulp``, so
    ``E[round(x)] == x`` exactly; bf16-representable values (low 16 bits
    zero) are fixed points regardless of the noise.  Non-finite inputs
    pass through unchanged (carry past the exponent would corrupt them;
    ingestion rejects them anyway)."""
    if jnp.dtype(dtype) != jnp.bfloat16:
        raise ValueError("stochastic_round targets bfloat16 storage; got "
                         f"{jnp.dtype(dtype)}")
    xf = jnp.asarray(x, jnp.float32)
    bits = jax.lax.bitcast_convert_type(xf, jnp.uint32)
    try:
        noise = jax.random.bits(key, xf.shape, jnp.uint32)
    except (AttributeError, TypeError):   # older jax: no random.bits
        noise = jax.random.randint(key, xf.shape, 0, 1 << 16,
                                   jnp.int32).astype(jnp.uint32)
    bits = (bits + (noise & jnp.uint32(0xFFFF))) & jnp.uint32(0xFFFF0000)
    rounded = jax.lax.bitcast_convert_type(bits, jnp.float32)
    rounded = jnp.where(jnp.isfinite(xf), rounded, xf)
    return rounded.astype(dtype)


def stochastic_round_tree(tree, key, dtype=jnp.bfloat16):
    """Per-leaf :func:`stochastic_round` over the float leaves of a
    pytree (integer leaves -- ``rank`` vectors, counters -- untouched).
    One key split per leaf keeps leaves independent and the whole map a
    pure function of ``(tree, key)``."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [stochastic_round(leaf, k, dtype)
           if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating) else leaf
           for leaf, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


__all__ = [
    "CODECS", "codec_of_pair", "tree_codec", "cohort_codecs",
    "encode_pair", "decode_pair", "encode_adapters", "decode_adapters",
    "encode_update", "decode_update", "validate_encoded_adapters",
    "UploadValidationError",
    "stochastic_round", "stochastic_round_tree",
]
