"""Compiled aggregation plans: packed cohort buffers, fused round launches.

Eager strategy execution walks the adapter pytree in Python and issues one
device computation (or one Pallas launch) per LoRA pair -- O(layers x
clients) host dispatch per FL round, the dominant server cost at scale.
This module turns a round into a **compiled plan**:

1. **Pack.**  All adapter pairs of a cohort are flattened into a small
   number of packed ``(n_clients, rows, width)`` buffers **bucketed by
   (row width, dtype)**.  A factors contribute their rank rows directly;
   B factors ride transposed so the rank axis leads everywhere.  Each
   packed row carries its owner metadata -- the delta_{i,r} rank-row mask
   column -- which is *static* given the cohort's rank multiset, so the
   whole (n, rows) owner-mask matrix is precomputed on the host once per
   plan.  Layer-stacked (leading-dim) pairs pack like everything else:
   layer ``l`` of a pair occupies its own row range with its own per-layer
   mask column, which is how the long-standing layer-stacked Pallas
   fallback disappears.
2. **Lower.**  The whole round -- leaf math, ``prev_global`` retention,
   the strategy's weight transform, finalize bookkeeping -- becomes a
   single jitted function issuing **one fused computation per bucket**
   (the ``packed_agg`` / ``packed_stack`` Pallas kernels on the pallas
   backend, a fused einsum on ref, one shard_map on distributed) instead
   of one launch per pair.  Server-state buffers can be **donated**.
3. **Cache.**  Plans are cached on the strategy instance keyed by the
   :class:`CohortSpec` -- tree structure, leaf shapes/dtypes, the rank
   multiset, backend, mesh -- the way ``make_distributed_aggregator``
   already caches per-mesh fns.  ``AggregationStrategy.plan(state, spec)``
   is the public entry; ``aggregate_adapters`` routes through it
   automatically and falls back to the per-leaf reference path only when
   the cohort cannot be described host-side (traced values, bare leaves).

The per-leaf ``aggregate_tree*`` methods remain as the plan's oracles:
every packed plan must reproduce them allclose (see ``tests/test_plan.py``
and the parity/property suites, which now exercise plans end to end).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import get_registry as _obs_registry

from .aggregation import _EPS
from .compat import shard_map_no_check
from .masks import pad_to_rank

PyTree = Any


class PlanUnavailable(Exception):
    """A compiled plan cannot be built for these inputs (traced values,
    bare leaves, mismatched prev shapes); callers fall back to the
    per-leaf reference path, which handles everything."""


class BufferMemo:
    """Single-entry memo keyed by buffer *identity* over immutable jax
    arrays (the stack/pack reuse caches).

    The invariants both users need, kept in one place:

    * an id fingerprint is trustworthy only while every fingerprinted
      buffer is alive (a live weakref pins the id to its object);
    * mutable numpy uploads and tracers are never stored (in-place
      mutation / trace leakage would make identity lie);
    * the payload is released *eagerly* -- ``weakref.finalize`` on the
      fingerprinted buffers drops the entry the moment any of them is
      collected, so a memo never pins a dead cohort's bytes;
    * with ``require_repeat=True`` a payload is kept only for a
      fingerprint seen on consecutive stores: a loop whose cohorts
      never repeat retains a tuple of ids and weakrefs (bytes), not a
      cohort-sized payload, and no finalizers accumulate on long-lived
      buffers that are never actually reused.
    """

    def __init__(self, require_repeat: bool = False):
        self._require_repeat = require_repeat
        self._entry = None             # (ids, payload, refs, token)
        self._candidate = None         # (ids, refs): seen once

    @staticmethod
    def fingerprintable(leaves) -> bool:
        return all(isinstance(v, jax.Array)
                   and not isinstance(v, jax.core.Tracer) for v in leaves)

    def lookup(self, leaves):
        """The stored payload iff ``leaves`` are exactly the buffers it
        was stored under; None otherwise."""
        entry = self._entry
        if entry is None:
            return None
        ids, payload, refs, _ = entry
        if any(r() is None for r in refs):
            if self._entry is entry:   # stale: release without waiting
                self._entry = None
            return None
        if ids != tuple(id(v) for v in leaves):
            return None
        return payload

    def store(self, leaves, payload) -> None:
        import weakref
        leaves = list(leaves)
        if not leaves or not self.fingerprintable(leaves):
            return
        ids = tuple(id(v) for v in leaves)
        if self._require_repeat:
            cand = self._candidate
            seen_before = (cand is not None and cand[0] == ids
                           and all(r() is not None for r in cand[1]))
            if not seen_before:        # first sight: fingerprint only
                self._candidate = (ids,
                                   [weakref.ref(v) for v in leaves])
                return
        token = object()
        self._entry = (ids, payload,
                       [weakref.ref(v) for v in leaves], token)
        wself = weakref.ref(self)

        def _release(wself=wself, token=token):
            m = wself()                # holds only the token: a newer
            if (m is not None and m._entry is not None
                    and m._entry[3] is token):
                m._entry = None        # entry is never clobbered
        for v in leaves:               # ANY buffer dying releases it
            weakref.finalize(v, _release)


class DispatchCounter:
    """Counts host->device computation dispatches issued by the tracked
    entry points: every Pallas kernel wrapper call (``repro.kernels``)
    and every :class:`CompiledRound` execution.  The aggregation
    benchmarks read this to report dispatches per round.

    The windowed ``count`` / ``reset()`` surface is the legacy public
    API; every ``inc`` also feeds the cumulative
    ``plan_dispatches_total`` metric (``repro.obs``), which ``reset()``
    deliberately does *not* touch -- windows are a caller concern,
    process totals are the registry's.
    """

    def __init__(self):
        self.count = 0
        self._total = _obs_registry().counter(
            "plan_dispatches_total",
            "tracked host->device dispatches (kernel wrappers + "
            "compiled-plan rounds), cumulative")

    def inc(self, n: int = 1) -> None:
        self.count += n
        self._total.inc(n)

    def reset(self) -> int:
        prev, self.count = self.count, 0
        return prev


dispatch_counter = DispatchCounter()

_PACK_RUNS = _obs_registry().counter(
    "plan_pack_runs_total", "packed-bucket builds, by strategy",
    labelnames=("strategy",))
_PACK_REUSES = _obs_registry().counter(
    "plan_pack_reuses_total",
    "packed-bucket memo reuses (same cohort buffers), by strategy",
    labelnames=("strategy",))


def default_client_mesh(n_clients: int, client_axis: str):
    """1-D client mesh over the largest device count dividing
    ``n_clients`` (every shard carries the same number of clients) --
    the shared default for every distributed aggregation path."""
    from jax.sharding import Mesh
    devs = jax.devices()
    k = max(i for i in range(1, len(devs) + 1) if n_clients % i == 0)
    return Mesh(np.asarray(devs[:k]), (client_axis,))


# ------------------------------------------------------------- cohort spec --
def _is_pair(node) -> bool:
    return (isinstance(node, Mapping) and "A" in node and "B" in node
            and "rank" in node)


def _walk_pairs(tree, path=()):
    """Yield ``(path, pair)`` for every LoRA pair; raise
    :class:`PlanUnavailable` on bare array leaves (plans pack whole
    pairs; generic leaf trees stay on the reference path)."""
    if _is_pair(tree):
        yield path, tree
        return
    if isinstance(tree, Mapping):
        for k, v in tree.items():
            yield from _walk_pairs(v, path + (k,))
        return
    if isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _walk_pairs(v, path + (i,))
        return
    if tree is None:
        return
    raise PlanUnavailable(
        f"bare leaf of type {type(tree).__name__} at {path}; plans pack "
        "whole LoRA pairs")


def _concrete(x, what: str) -> np.ndarray:
    if isinstance(x, jax.core.Tracer):
        raise PlanUnavailable(f"{what} is traced; plans are host-built")
    return np.asarray(jax.device_get(x))


@dataclasses.dataclass(frozen=True)
class PairMeta:
    """Static description of one stacked LoRA pair in a cohort."""
    path: tuple
    a_shape: tuple
    a_dtype: str
    b_shape: tuple
    b_dtype: str
    rank_shape: tuple          # stacked rank leaf shape, incl. client axis
    ranks: tuple               # flattened concrete stacked rank values
    prev_a_shape: tuple | None = None
    prev_b_shape: tuple | None = None
    prev_rank_shape: tuple | None = None
    prev_ranks: tuple | None = None

    def rank_values(self) -> np.ndarray:
        return np.asarray(self.ranks, np.int64).reshape(self.rank_shape)

    def prev_rank_values(self) -> np.ndarray | None:
        if self.prev_ranks is None:
            return None
        return np.asarray(self.prev_ranks,
                          np.int64).reshape(self.prev_rank_shape)


@dataclasses.dataclass(frozen=True)
class CohortSpec:
    """Hashable plan-cache key: everything a compiled round closes over.

    Two cohorts with the same spec share one compiled plan; a new rank
    multiset, tree structure, backend, mesh, or prev layout builds (and
    caches) a new one.
    """
    n_clients: int
    kind: str                       # resolved backend: ref|pallas|distributed
    r_max: int | None
    pairs: tuple[PairMeta, ...]
    client_ranks: tuple | None
    has_prev: bool
    interpret: bool | None = None
    mesh: Any = None
    client_axis: str = "clients"
    #: per-client upload codec names ("none"|"bf16"|"int8") for encoded
    #: cohorts (see repro.core.codec); None = plain fp32 stacked cohort.
    #: Part of the key: a codec-mix change re-plans (and re-traces the
    #: executor), a rank-multiset repeat under the same mix still hits.
    codecs: tuple | None = None

    def client_ranks_array(self):
        if self.client_ranks is None:
            return None
        return jnp.asarray(self.client_ranks, jnp.int32)


def build_cohort_spec(stacked_tree: PyTree, *, kind: str,
                      r_max: int | None = None, client_ranks=None,
                      prev_tree: PyTree | None = None,
                      interpret: bool | None = None, mesh=None,
                      client_axis: str = "clients") -> CohortSpec:
    """Describe a stacked cohort host-side.  Raises
    :class:`PlanUnavailable` when the description needs values tracing
    hides (rank leaves, weights under jit) or the tree has bare leaves."""
    if client_ranks is not None:
        client_ranks = tuple(
            int(v) for v in _concrete(client_ranks, "client_ranks").ravel())
    prev_pairs = (dict(_walk_pairs(prev_tree))
                  if prev_tree is not None else {})
    pairs = []
    n = None
    for path, pair in _walk_pairs(stacked_tree):
        A, B, rank = pair["A"], pair["B"], pair["rank"]
        if isinstance(A, jax.core.Tracer) or isinstance(B, jax.core.Tracer):
            raise PlanUnavailable("cohort leaves are traced")
        if A.ndim < 3 or B.ndim < 3:
            raise PlanUnavailable(
                f"pair at {path} is not stacked over clients")
        if n is None:
            n = int(A.shape[0])
        rk = _concrete(rank, f"rank leaf at {path}")
        meta = dict(path=path, a_shape=tuple(A.shape), a_dtype=str(A.dtype),
                    b_shape=tuple(B.shape), b_dtype=str(B.dtype),
                    rank_shape=tuple(rk.shape),
                    ranks=tuple(int(v) for v in rk.ravel()))
        if prev_tree is not None:
            if path not in prev_pairs:
                raise PlanUnavailable(f"prev tree missing pair at {path}")
            pp = prev_pairs[path]
            prk = _concrete(pp["rank"], f"prev rank leaf at {path}")
            meta.update(prev_a_shape=tuple(pp["A"].shape),
                        prev_b_shape=tuple(pp["B"].shape),
                        prev_rank_shape=tuple(prk.shape),
                        prev_ranks=tuple(int(v) for v in prk.ravel()))
        pairs.append(PairMeta(**meta))
    if not pairs:
        raise PlanUnavailable("no LoRA pairs in the cohort tree")
    return CohortSpec(n_clients=n, kind=kind, r_max=r_max,
                      pairs=tuple(pairs), client_ranks=client_ranks,
                      has_prev=prev_tree is not None, interpret=interpret,
                      mesh=mesh if kind == "distributed" else None,
                      client_axis=client_axis)


def build_encoded_cohort_spec(client_trees: Sequence, codecs, *, kind: str,
                              r_max: int | None = None, client_ranks=None,
                              prev_tree: PyTree | None = None,
                              interpret: bool | None = None,
                              client_axis: str = "clients") -> CohortSpec:
    """Describe an *encoded* cohort: per-client adapter trees carrying
    wire dtypes (``repro.core.codec``), never leafwise-stacked -- stacking
    int8 next to fp32 would either fail or promote, i.e. the forbidden
    fp32 staging buffer.  ``codecs`` is the per-client codec-name tuple
    (``cohort_codecs``); pair metadata records the **decoded** (f32)
    dtypes so bucketing and unpacking match the fp32 cohort exactly and
    only ``spec.codecs`` distinguishes the wire layout."""
    codecs = tuple(codecs)
    n = len(client_trees)
    if len(codecs) != n:
        raise PlanUnavailable(f"{len(codecs)} codecs for {n} clients")
    if any(c not in ("none", "bf16", "int8") for c in codecs):
        raise PlanUnavailable(
            "per-pair mixed codecs inside one client are not plannable")
    prev_pairs = (dict(_walk_pairs(prev_tree))
                  if prev_tree is not None else {})
    walked = [list(_walk_pairs(t)) for t in client_trees]
    paths = [p for p, _ in walked[0]]
    for i, wl in enumerate(walked[1:], start=1):
        if [p for p, _ in wl] != paths:
            raise PlanUnavailable(
                f"client {i}'s tree structure differs from client 0's")
    if client_ranks is not None:
        client_ranks = tuple(
            int(v) for v in _concrete(client_ranks, "client_ranks").ravel())
    inferred: list | None = [] if client_ranks is None else None
    pairs = []
    for pi, path in enumerate(paths):
        metas = []
        rks = []
        for i in range(n):
            pair = walked[i][pi][1]
            A, B = pair["A"], pair["B"]
            if (isinstance(A, jax.core.Tracer)
                    or isinstance(B, jax.core.Tracer)):
                raise PlanUnavailable("cohort leaves are traced")
            metas.append((tuple(A.shape), tuple(B.shape)))
            rks.append(_concrete(pair["rank"], f"rank leaf at {path}"))
        if any(m != metas[0] for m in metas[1:]):
            raise PlanUnavailable(
                f"clients disagree on pair shapes at {path}")
        rk = np.stack(rks)
        if inferred is not None and pi == 0 and rk.ndim == 1:
            inferred.extend(int(v) for v in rk)
        a_shape = (n,) + metas[0][0]
        b_shape = (n,) + metas[0][1]
        # decoded dtype: wire dtypes dequantize to f32; an all-"none"
        # pair keeps its own dtype (can't happen cohort-wide -- that
        # cohort has codecs=None and takes the stacked path)
        meta = dict(path=path, a_shape=a_shape, a_dtype="float32",
                    b_shape=b_shape, b_dtype="float32",
                    rank_shape=tuple(rk.shape),
                    ranks=tuple(int(v) for v in rk.ravel()))
        if prev_tree is not None:
            if path not in prev_pairs:
                raise PlanUnavailable(f"prev tree missing pair at {path}")
            pp = prev_pairs[path]
            prk = _concrete(pp["rank"], f"prev rank leaf at {path}")
            meta.update(prev_a_shape=tuple(pp["A"].shape),
                        prev_b_shape=tuple(pp["B"].shape),
                        prev_rank_shape=tuple(prk.shape),
                        prev_ranks=tuple(int(v) for v in prk.ravel()))
        pairs.append(PairMeta(**meta))
    if not pairs:
        raise PlanUnavailable("no LoRA pairs in the cohort trees")
    if client_ranks is None and inferred:
        client_ranks = tuple(inferred)
    return CohortSpec(n_clients=n, kind=kind, r_max=r_max,
                      pairs=tuple(pairs), client_ranks=client_ranks,
                      has_prev=prev_tree is not None, interpret=interpret,
                      mesh=None, client_axis=client_axis, codecs=codecs)


# ---------------------------------------------------------- packed layout --
@dataclasses.dataclass
class Slot:
    """One pair side's home inside a packed bucket."""
    pair_idx: int
    side: str                  # "A" | "B"
    lead: tuple                # leading (layer/expert) dims
    r_st: int                  # storage rank rows per lead index
    rows: int                  # prod(lead) * r_st
    width: int
    dtype: str
    offset: int = 0            # row offset inside the bucket


@dataclasses.dataclass
class Bucket:
    """All slots sharing (row width, dtype): one fused launch per round."""
    width: int
    dtype: str
    slots: list
    rows: int = 0
    mask: np.ndarray | None = None     # (n, rows) owner mask, host-built


def _side_geometry(meta: PairMeta, side: str):
    shape = meta.a_shape if side == "A" else meta.b_shape
    lead = tuple(shape[1:-2])
    if side == "A":
        r_st, width = shape[-2], shape[-1]
        dtype = meta.a_dtype
    else:
        r_st, width = shape[-1], shape[-2]
        dtype = meta.b_dtype
    rows = int(np.prod(lead, dtype=np.int64)) * r_st if lead else r_st
    return lead, int(r_st), int(rows), int(width), dtype


def _slot_mask(meta: PairMeta, slot: Slot, n: int,
               use_mask: bool) -> np.ndarray:
    """Per-row owner mask (n, rows): row (l, j) of client i is owned iff
    j < rank_i[l] -- the delta_{i,r} indicator in packed-row form."""
    if not use_mask:
        return np.ones((n, slot.rows), np.float32)
    rk = meta.rank_values()                      # (n, *rank_leaf_shape)
    mid = len(slot.lead) - (rk.ndim - 1)
    r = rk.reshape(rk.shape + (1,) * mid + (1,))
    m = np.arange(slot.r_st).reshape((1,) * (1 + len(slot.lead))
                                     + (slot.r_st,)) < r
    m = np.broadcast_to(m, (n,) + slot.lead + (slot.r_st,))
    return np.ascontiguousarray(
        m.reshape(n, slot.rows).astype(np.float32))


def _make_buckets(spec: CohortSpec, use_mask: bool) -> list:
    buckets: dict = {}
    for pi, meta in enumerate(spec.pairs):
        for side in ("A", "B"):
            lead, r_st, rows, width, dtype = _side_geometry(meta, side)
            key = (width, dtype)
            b = buckets.setdefault(key, Bucket(width=width, dtype=dtype,
                                               slots=[]))
            b.slots.append(Slot(pair_idx=pi, side=side, lead=lead,
                                r_st=r_st, rows=rows, width=width,
                                dtype=dtype, offset=b.rows))
            b.rows += rows
    out = list(buckets.values())
    for b in out:
        b.mask = np.concatenate(
            [_slot_mask(spec.pairs[s.pair_idx], s, spec.n_clients, use_mask)
             for s in b.slots], axis=1)
    return out


def pair_side_rows(x, side: str):
    """Rank-axis-leading row view of one LoRA pair side: A
    ``(..., r, fan_in)`` passes through, B ``(..., fan_out, r)`` rides
    transposed to ``(..., r, fan_out)`` -- THE packed row convention
    shared by plan buckets and the serving
    :class:`~repro.serving.AdapterStore`.  Involution: applying it twice
    (same side) restores the leaf layout."""
    if side == "B":
        x = jnp.swapaxes(x, -1, -2)
    return x


def _pack_side(x, slot: Slot):
    """(n, *lead, ...) leaf -> (n, rows, width) f32, rank axis leading."""
    x = pair_side_rows(x, slot.side)
    return x.reshape(x.shape[:1] + (slot.rows, slot.width)).astype(
        jnp.float32)


def _pack_prev_side(x, slot: Slot):
    """Like :func:`_pack_side` for an unstacked (server-state) leaf."""
    x = pair_side_rows(x, slot.side)
    return x.reshape((slot.rows, slot.width)).astype(jnp.float32)


def _unpack_slot(out, slot: Slot, meta: PairMeta):
    """(rows, width) f32 block -> the slot's original leaf layout."""
    y = out[slot.offset:slot.offset + slot.rows]
    y = y.reshape(slot.lead + (slot.r_st, slot.width))
    return pair_side_rows(y, slot.side).astype(slot.dtype)


# ------------------------------------------------------- tree (re)building --
def _make_rebuilder(tree) -> Callable:
    """Recipe to rebuild ``tree``'s container structure from a flat list
    of per-pair replacements (in :func:`_walk_pairs` order)."""
    counter = [0]

    def recipe(t):
        if _is_pair(t):
            i = counter[0]
            counter[0] += 1
            return ("pair", i)
        if isinstance(t, Mapping):
            return ("map", {k: recipe(v) for k, v in t.items()})
        if isinstance(t, (tuple, list)):
            return ("seq", type(t), [recipe(v) for v in t])
        return ("leaf", t)

    r = recipe(tree)

    def rebuild(pairs: Sequence):
        def go(node):
            tag = node[0]
            if tag == "pair":
                return pairs[node[1]]
            if tag == "map":
                return {k: go(v) for k, v in node[1].items()}
            if tag == "seq":
                return node[1](go(v) for v in node[2])
            return node[1]
        return go(r)
    return rebuild


def _ab_list(tree) -> list:
    return [{"A": p["A"], "B": p["B"]} for _, p in _walk_pairs(tree)]


# ------------------------------------------------------------ the product --
class CompiledRound:
    """One compiled aggregation round for a fixed :class:`CohortSpec`.

    ``__call__(stacked_tree, weights, prev_tree, donate=False)`` runs the
    round; with ``donate=True`` the previous global's A/B buffers are
    donated to XLA (the caller must not touch them afterwards -- jax
    raises on any use of a donated buffer).

    Attributes the benchmarks and tests read:

    ``kind``
        "packed" (fused buckets), "jit" (whole-round jit over the
        reference math), or "eager" (legacy per-leaf execution --
        unknown strategies and paths with their own caching).
    ``n_kernel_launches``
        fused device computations issued per round (packed plans:
        #buckets; others: best-effort 1 / None).
    ``n_fallback_pairs``
        pairs a packed plan still routes through reference pair math
        (e.g. flora's over-cap SVD re-projection).
    """

    def __init__(self, strategy, spec: CohortSpec, kind: str,
                 execute: Callable, *, n_kernel_launches: int | None = None,
                 n_fallback_pairs: int = 0):
        self.strategy = strategy
        self.spec = spec
        self.kind = kind
        self._execute = execute
        self.n_kernel_launches = n_kernel_launches
        self.n_fallback_pairs = n_fallback_pairs
        self.n_calls = 0

    def __call__(self, stacked_tree: PyTree, weights, prev_tree=None,
                 donate: bool = False) -> PyTree:
        dispatch_counter.inc()
        self.n_calls += 1
        return self._execute(stacked_tree, jnp.asarray(weights, jnp.float32),
                             prev_tree, donate)

    def describe(self) -> str:
        return (f"CompiledRound({self.strategy.name}/{self.spec.kind}, "
                f"kind={self.kind}, launches={self.n_kernel_launches}, "
                f"fallback_pairs={self.n_fallback_pairs})")


def _out_rank_leaves(spec: CohortSpec, r_out_per_pair=None):
    """Finalized rank leaves, host-built: fixed-rank plans write r_max
    (or the storage rank) directly; stack plans write each pair's static
    output rank."""
    leaves = []
    for i, meta in enumerate(spec.pairs):
        shape = tuple(meta.rank_shape[1:])
        if r_out_per_pair is not None:
            val = int(r_out_per_pair[i])
        else:
            val = int(spec.r_max if spec.r_max is not None
                      else meta.a_shape[-2])
        leaves.append(jnp.full(shape, val, jnp.int32))
    return leaves


# ------------------------------------------------------ packed mean plans --
def _bucket_mean_ref(x, mask_const, wt, prev, norm_by: str,
                     norm_restore: bool, scales=None):
    """Fused reference math for one bucket: the packed-row form of
    rbla/zeropad/fedavg leaf math (+ rbla_norm's per-row norm restore).
    ``scales`` (n, rows) dequantizes int8 payloads on the fly (the scale
    folds into the value einsum; the owner-mass denominator is
    scale-free)."""
    m = mask_const
    x = x.astype(jnp.float32)
    if scales is not None:
        x = scales[:, :, None] * x
    num = jnp.einsum("n,nr,nrd->rd", wt, m, x)
    if norm_by == "mask":
        den = jnp.einsum("n,nr->r", wt, m)[:, None]
        fb = prev if prev is not None else jnp.zeros_like(num)
        out = jnp.where(den > 0, num / (den + _EPS), fb)
    else:
        out = num / (jnp.sum(wt) + _EPS)
    if norm_restore:
        xm = m[:, :, None] * x
        row_norms = jnp.sqrt(jnp.einsum("nrd,nrd->nr", xm, xm))
        w_rows = (m > 0).astype(jnp.float32) * wt[:, None]
        target = (jnp.sum(w_rows * row_norms, axis=0)
                  / (jnp.sum(w_rows, axis=0) + _EPS))
        agg_norms = jnp.sqrt(jnp.sum(out ** 2, axis=1))
        scale = jnp.where(agg_norms > _EPS, target / (agg_norms + _EPS),
                          1.0)
        out = out * scale[:, None]
    return out


def _shape_key(spec: CohortSpec) -> tuple:
    """Everything a mean-mode *executor* (the jitted function) depends
    on: shapes, dtypes, backend, prev presence -- but NOT the rank
    multiset.  Owner masks and client ranks enter as runtime data, so
    one compiled executor serves every cohort with this layout and a new
    rank multiset costs a new (cheap) plan, not a new XLA compile.  The
    codec mix IS part of the key: wire dtypes and the group split change
    the traced computation."""
    return (spec.kind, spec.n_clients, spec.has_prev, spec.interpret,
            spec.mesh, spec.client_axis, spec.codecs,
            tuple((m.a_shape, m.a_dtype, m.b_shape, m.b_dtype)
                  for m in spec.pairs))


def _build_mean_round(strategy, spec: CohortSpec,
                      norm_restore: bool = False) -> CompiledRound:
    if spec.codecs is not None:
        return _build_encoded_mean_round(strategy, spec, norm_restore)
    buckets = _make_buckets(spec, strategy.use_mask)
    retains = strategy.retains_prev and spec.has_prev
    if retains:
        for meta in spec.pairs:       # mean plans overlay prev in place
            if (meta.prev_a_shape != meta.a_shape[1:]
                    or meta.prev_b_shape != meta.b_shape[1:]):
                raise PlanUnavailable(
                    "prev leaf shapes differ from the cohort's")
    cr = spec.client_ranks_array()
    norm_by = strategy.norm_by
    rank_leaves = _out_rank_leaves(spec)
    masks = [jnp.asarray(b.mask) for b in buckets]

    if spec.kind == "distributed":
        return _build_mean_distributed(strategy, spec, buckets, masks,
                                       rank_leaves, retains)

    # interpreted Pallas pays per-op Python overhead proportional to the
    # packed bucket's grid, so "one fused launch" *loses* to many small
    # compiled launches on CPU; route interpret-mode plans through the
    # fused XLA lowering and keep the true kernel for compiled backends
    from repro.kernels.runtime import auto_interpret
    use_kernel = (spec.kind == "pallas"
                  and not auto_interpret(spec.interpret))
    # robust reductions (trimmed/median/clipped) reuse the mean family's
    # packed buckets; the knobs are baked into the traced combine, so
    # they join the executor cache key
    robust = getattr(strategy, "robustness", "none")
    knobs = ((robust, float(getattr(strategy, "clip_norm", 0.0) or 0.0),
              float(getattr(strategy, "trim_frac", 0.0) or 0.0))
             if robust != "none" else ())

    exec_cache = strategy.__dict__.setdefault("_plan_exec_cache", {})
    key = ("mean", norm_restore, knobs, _shape_key(spec))
    fns = exec_cache.get(key)
    if fns is None:
        def pack_fn(ab):
            """Cohort uploads -> one packed (n, rows, width) buffer per
            bucket.  Split from the combine so a re-participating cohort
            (same upload buffers) reuses its packed buckets and only the
            combine re-runs -- the weight-only update."""
            xs = []
            for b in buckets:
                xs.append(jnp.concatenate(
                    [_pack_side(ab[s.pair_idx][s.side], s)
                     for s in b.slots],
                    axis=1) if len(b.slots) > 1 else _pack_side(
                        ab[b.slots[0].pair_idx][b.slots[0].side],
                        b.slots[0]))
            return xs

        def combine_fn(xs, wt_raw, prev_ab, ms, crv):
            wt = strategy.transform_weights(wt_raw, crv)
            outs = []
            for bi, b in enumerate(buckets):
                prev = None
                if retains:
                    parts = [_pack_prev_side(prev_ab[s.pair_idx][s.side],
                                             s) for s in b.slots]
                    prev = (jnp.concatenate(parts, axis=0)
                            if len(parts) > 1 else parts[0])
                if robust != "none":
                    if use_kernel:
                        from repro.kernels.rbla_agg.ops import (
                            packed_robust_inline)
                        out = packed_robust_inline(
                            xs[bi], ms[bi], wt, prev, mode=robust,
                            clip_norm=knobs[1], trim_frac=knobs[2],
                            interpret=spec.interpret)
                    elif (spec.kind == "pallas"
                          and robust in ("trimmed", "median")):
                        # interpret-mode order statistics: the fused
                        # odd-even network in plain XLA -- jnp.sort is a
                        # serial per-lane sort on CPU and the emulated
                        # kernel pays per-tile grid overhead
                        from repro.kernels.rbla_agg.ref import (
                            packed_robust_xla)
                        out = packed_robust_xla(
                            xs[bi], ms[bi], wt, prev, mode=robust,
                            clip_norm=knobs[1], trim_frac=knobs[2])
                    else:
                        from repro.kernels.rbla_agg.ref import (
                            packed_robust_ref)
                        out = packed_robust_ref(
                            xs[bi], ms[bi], wt, prev, mode=robust,
                            clip_norm=knobs[1], trim_frac=knobs[2])
                elif use_kernel:
                    from repro.kernels.rbla_agg.ops import packed_agg_inline
                    out = packed_agg_inline(xs[bi], ms[bi], wt, prev,
                                            norm_by=norm_by,
                                            norm_restore=norm_restore,
                                            interpret=spec.interpret)
                else:
                    out = _bucket_mean_ref(xs[bi], ms[bi], wt, prev,
                                           norm_by, norm_restore)
                outs.append(out)
            return [
                {s.side: _unpack_slot(outs[bi], s, spec.pairs[s.pair_idx])
                 for bi, b in enumerate(buckets) for s in b.slots
                 if s.pair_idx == pi}
                for pi in range(len(spec.pairs))]

        fns = (jax.jit(pack_fn), jax.jit(combine_fn),
               jax.jit(combine_fn, donate_argnums=(2,)))
        exec_cache[key] = fns
    pack, fn, fn_donate = fns
    rebuild = [None]
    # eager store is safe here: the fingerprinted buffers are the
    # *stacked* leaves, which outlive one call only when the strategy's
    # require_repeat stack memo decided the cohort repeats -- so for
    # fresh-per-round cohorts the packed payload is released at end of
    # round by the finalizers, and no finalizers accumulate on
    # long-lived user buffers (stacked leaves are new objects per round)
    pack_memo = BufferMemo()

    def execute(stacked_tree, w, prev_tree, donate):
        if rebuild[0] is None:
            rebuild[0] = _make_rebuilder(stacked_tree)
        ab = _ab_list(stacked_tree)
        stats = strategy.__dict__.setdefault(
            "plan_stats", {"hits": 0, "misses": 0})
        # same stacked buffers -> reuse the packed buckets; the memo
        # releases the packed payload as soon as the cohort's buffers
        # die (BufferMemo), so stale plans never pin cohort bytes
        leaves = [v for d in ab for v in (d["A"], d["B"])]
        xs = pack_memo.lookup(leaves)
        if xs is not None:
            stats["pack_reuses"] = stats.get("pack_reuses", 0) + 1
            _PACK_REUSES.labels(strategy=strategy.name).inc()
        else:
            xs = pack(ab)
            pack_memo.store(leaves, xs)
            stats["pack_runs"] = stats.get("pack_runs", 0) + 1
            _PACK_RUNS.labels(strategy=strategy.name).inc()
        prev_ab = _ab_list(prev_tree) if retains else None
        run = fn_donate if (donate and retains) else fn
        outs = run(xs, w, prev_ab, masks, cr)
        pairs = [{"A": o["A"], "B": o["B"], "rank": rank_leaves[i]}
                 for i, o in enumerate(outs)]
        return rebuild[0](pairs)

    return CompiledRound(strategy, spec, "packed", execute,
                         n_kernel_launches=len(buckets))


# ---------------------------------------------- encoded (quantized) plans --
def _enc_ab_list(tree) -> list:
    """Like :func:`_ab_list` but keeps the int8 codec's per-row scale
    leaves riding with each pair."""
    out = []
    for _, p in _walk_pairs(tree):
        d = {"A": p["A"], "B": p["B"]}
        for k in ("A_scale", "B_scale"):
            if k in p:
                d[k] = p[k]
        out.append(d)
    return out


def _pack_client_side(x, slot: Slot, wire: bool):
    """(*lead, ...) single-client leaf -> (rows, width); ``wire=True``
    keeps the upload's wire dtype (int8/bf16) so the packed payload never
    stages an fp32 copy."""
    x = pair_side_rows(x, slot.side)
    x = x.reshape((slot.rows, slot.width))
    return x if wire else x.astype(jnp.float32)


def _pack_client_scale(pair, slot: Slot):
    """Per-row dequant scales of one pair side -> (rows,) f32.  Both
    sides carry a ``(*lead, r)`` scale leaf on the packed row convention
    (B's packed rows are its columns), so the reshape is shared."""
    s = pair["A_scale" if slot.side == "A" else "B_scale"]
    return jnp.asarray(s, jnp.float32).reshape(slot.rows)


def _build_encoded_mean_round(strategy, spec: CohortSpec,
                              norm_restore: bool = False) -> CompiledRound:
    """Mean/robust packed round over an *encoded* cohort (per-client wire
    dtypes from ``spec.codecs``).

    Clients group by codec (static index tuples); each bucket packs one
    ``(n_g, rows, width)`` payload per group in the group's wire dtype
    plus ``(n_g, rows)`` f32 scales for int8 groups.  A uniform-codec
    cohort keeps the one-fused-launch-per-bucket property -- the scales
    ride into ``packed_agg``/``packed_robust`` as runtime data and
    dequantization happens inside the kernel.  A mixed mean combines
    per-group partial sums (dequant folded into each group's value
    einsum); mixed *robust* rounds must dequantize-and-concatenate
    in-trace before the cross-group order statistics -- unavoidable, and
    still one jitted computation per round."""
    buckets = _make_buckets(spec, strategy.use_mask)
    retains = strategy.retains_prev and spec.has_prev
    if retains:
        for meta in spec.pairs:       # mean plans overlay prev in place
            if (meta.prev_a_shape != meta.a_shape[1:]
                    or meta.prev_b_shape != meta.b_shape[1:]):
                raise PlanUnavailable(
                    "prev leaf shapes differ from the cohort's")
    cr = spec.client_ranks_array()
    norm_by = strategy.norm_by
    rank_leaves = _out_rank_leaves(spec)

    # static codec groups, first-appearance order
    order: dict = {}
    for i, c in enumerate(spec.codecs):
        if c not in ("none", "bf16", "int8"):
            raise PlanUnavailable(f"client {i} uses unknown codec {c!r}")
        order.setdefault(c, []).append(i)
    groups = [(c, tuple(ix)) for c, ix in order.items()]
    # per-bucket per-group owner masks (host-sliced once per plan)
    masks = [[jnp.asarray(b.mask[list(ix)]) for _, ix in groups]
             for b in buckets]
    gidx = [jnp.asarray(ix, jnp.int32) for _, ix in groups]

    from repro.kernels.runtime import auto_interpret
    use_kernel = (spec.kind == "pallas"
                  and not auto_interpret(spec.interpret))
    robust = getattr(strategy, "robustness", "none")
    knobs = ((robust, float(getattr(strategy, "clip_norm", 0.0) or 0.0),
              float(getattr(strategy, "trim_frac", 0.0) or 0.0))
             if robust != "none" else ())

    def _robust_bucket(x, m, wt_g, prev):
        """Uniform-path robust dispatch on an already-grouped payload
        (scales=None: pass f32; else fused dequant)."""
        def run(fn, **kw):
            return fn(x[0], m, wt_g, prev, mode=robust, clip_norm=knobs[1],
                      trim_frac=knobs[2], scales=x[1],
                      out_dtype=jnp.float32, **kw)
        if use_kernel:
            from repro.kernels.rbla_agg.ops import packed_robust_inline
            return run(packed_robust_inline, interpret=spec.interpret)
        if spec.kind == "pallas" and robust in ("trimmed", "median"):
            from repro.kernels.rbla_agg.ref import packed_robust_xla
            return run(packed_robust_xla)
        from repro.kernels.rbla_agg.ref import packed_robust_ref
        return run(packed_robust_ref)

    exec_cache = strategy.__dict__.setdefault("_plan_exec_cache", {})
    key = ("mean", norm_restore, knobs, _shape_key(spec))
    fns = exec_cache.get(key)
    if fns is None:
        def pack_fn(clients):
            """Per-client uploads -> per-(bucket, group) wire-dtype
            payloads + int8 scale planes.  No fp32 staging: each group's
            (n_g, rows, width) buffer keeps the upload dtype."""
            xs, ss = [], []
            for b in buckets:
                bx, bs = [], []
                for cname, ix in groups:
                    per_client = []
                    per_scale = []
                    for i in ix:
                        parts = [_pack_client_side(
                            clients[i][s.pair_idx][s.side], s,
                            wire=cname != "none") for s in b.slots]
                        per_client.append(
                            jnp.concatenate(parts, axis=0)
                            if len(parts) > 1 else parts[0])
                        if cname == "int8":
                            sp = [_pack_client_scale(
                                clients[i][s.pair_idx], s)
                                for s in b.slots]
                            per_scale.append(jnp.concatenate(sp)
                                             if len(sp) > 1 else sp[0])
                    bx.append(jnp.stack(per_client))
                    bs.append(jnp.stack(per_scale) if per_scale else None)
                xs.append(bx)
                ss.append(bs)
            return xs, ss

        def combine_fn(xs, ss, wt_raw, prev_ab, ms, crv):
            wt = strategy.transform_weights(wt_raw, crv)
            wt_g = [wt[ix] for ix in gidx]
            outs = []
            for bi, b in enumerate(buckets):
                prev = None
                if retains:
                    parts = [_pack_prev_side(prev_ab[s.pair_idx][s.side],
                                             s) for s in b.slots]
                    prev = (jnp.concatenate(parts, axis=0)
                            if len(parts) > 1 else parts[0])
                if len(groups) == 1:
                    # uniform codec: one fused launch per bucket, scales
                    # as runtime data
                    if robust != "none":
                        out = _robust_bucket((xs[bi][0], ss[bi][0]),
                                             ms[bi][0], wt_g[0], prev)
                    elif use_kernel:
                        from repro.kernels.rbla_agg.ops import (
                            packed_agg_inline)
                        out = packed_agg_inline(
                            xs[bi][0], ms[bi][0], wt_g[0], prev,
                            norm_by=norm_by, norm_restore=norm_restore,
                            scales=ss[bi][0], out_dtype=jnp.float32,
                            interpret=spec.interpret)
                    else:
                        out = _bucket_mean_ref(xs[bi][0], ms[bi][0],
                                               wt_g[0], prev, norm_by,
                                               norm_restore,
                                               scales=ss[bi][0])
                elif robust != "none":
                    # cross-group order statistics need every client in
                    # one buffer: dequantize-and-concat in-trace
                    cat = []
                    for gi in range(len(groups)):
                        xg = xs[bi][gi].astype(jnp.float32)
                        if ss[bi][gi] is not None:
                            xg = ss[bi][gi][:, :, None] * xg
                        cat.append(xg)
                    out = _robust_bucket(
                        (jnp.concatenate(cat, axis=0), None),
                        jnp.concatenate(ms[bi], axis=0),
                        jnp.concatenate(wt_g), prev)
                else:
                    # mixed mean: per-group partial sums, dequant folded
                    # into each group's value einsum (scale rides on the
                    # (n, r) mask plane, never on the payload)
                    rows = b.rows
                    num = jnp.zeros((rows, xs[bi][0].shape[-1]),
                                    jnp.float32)
                    den = jnp.zeros((rows,), jnp.float32)
                    tnum = jnp.zeros((rows,), jnp.float32)
                    town = jnp.zeros((rows,), jnp.float32)
                    for gi in range(len(groups)):
                        xg = xs[bi][gi].astype(jnp.float32)
                        m = ms[bi][gi]
                        sg = ss[bi][gi]
                        mv = m if sg is None else m * sg
                        num = num + jnp.einsum("n,nr,nrd->rd", wt_g[gi],
                                               mv, xg)
                        den = den + jnp.einsum("n,nr->r", wt_g[gi], m)
                        if norm_restore:
                            xm = m[:, :, None] * xg
                            qn = jnp.sqrt(
                                jnp.einsum("nrd,nrd->nr", xm, xm))
                            rn = qn if sg is None else sg * qn
                            own = ((m > 0).astype(jnp.float32)
                                   * wt_g[gi][:, None])
                            tnum = tnum + jnp.sum(own * rn, axis=0)
                            town = town + jnp.sum(own, axis=0)
                    if norm_by == "mask":
                        fb = (prev if prev is not None
                              else jnp.zeros_like(num))
                        out = jnp.where(den[:, None] > 0,
                                        num / (den[:, None] + _EPS), fb)
                    else:
                        out = num / (jnp.sum(wt) + _EPS)
                    if norm_restore:
                        target = tnum / (town + _EPS)
                        agg = jnp.sqrt(jnp.sum(out ** 2, axis=1))
                        out = out * jnp.where(
                            agg > _EPS, target / (agg + _EPS), 1.0)[:, None]
                outs.append(out)
            return [
                {s.side: _unpack_slot(outs[bi], s, spec.pairs[s.pair_idx])
                 for bi, b in enumerate(buckets) for s in b.slots
                 if s.pair_idx == pi}
                for pi in range(len(spec.pairs))]

        fns = (jax.jit(pack_fn), jax.jit(combine_fn),
               jax.jit(combine_fn, donate_argnums=(3,)))
        exec_cache[key] = fns
    pack, fn, fn_donate = fns
    rebuild = [None]
    pack_memo = BufferMemo()

    def execute(client_trees, w, prev_tree, donate):
        if rebuild[0] is None:
            rebuild[0] = _make_rebuilder(client_trees[0])
        clients = [_enc_ab_list(t) for t in client_trees]
        stats = strategy.__dict__.setdefault(
            "plan_stats", {"hits": 0, "misses": 0})
        leaves = [v for ab in clients for d in ab for v in d.values()]
        packed = pack_memo.lookup(leaves)
        if packed is not None:
            stats["pack_reuses"] = stats.get("pack_reuses", 0) + 1
            _PACK_REUSES.labels(strategy=strategy.name).inc()
        else:
            packed = pack(clients)
            pack_memo.store(leaves, packed)
            stats["pack_runs"] = stats.get("pack_runs", 0) + 1
            _PACK_RUNS.labels(strategy=strategy.name).inc()
        xs, ss = packed
        prev_ab = _ab_list(prev_tree) if retains else None
        run = fn_donate if (donate and retains) else fn
        outs = run(xs, ss, w, prev_ab, masks, cr)
        pairs = [{"A": o["A"], "B": o["B"], "rank": rank_leaves[i]}
                 for i, o in enumerate(outs)]
        return rebuild[0](pairs)

    return CompiledRound(strategy, spec, "packed", execute,
                         n_kernel_launches=len(buckets))


def _build_mean_distributed(strategy, spec, buckets, masks_const,
                            rank_leaves, retains) -> CompiledRound:
    """Packed shard_map: one collective round over the bucket buffers
    (clients sharded over the mesh axis, masks ride along sharded, the
    combine + prev retention computed replicated)."""
    from jax.sharding import PartitionSpec as P

    n = spec.n_clients
    mesh = spec.mesh
    ax = spec.client_axis
    if mesh is None:
        mesh = default_client_mesh(n, ax)
    cr = spec.client_ranks_array()
    norm_by = strategy.norm_by
    nb = len(buckets)

    exec_cache = strategy.__dict__.setdefault("_plan_exec_cache", {})
    key = ("mean_dist", _shape_key(spec))
    shard_fn = exec_cache.get(key)
    if shard_fn is None:
        def body(xs, ms, wt, prevs):
            outs = []
            for bi in range(nb):
                x, m = xs[bi], ms[bi]
                num = jax.lax.psum(jnp.einsum("n,nr,nrd->rd", wt, m, x),
                                   ax)
                if norm_by == "mask":
                    den = jax.lax.psum(jnp.einsum("n,nr->r", wt, m),
                                       ax)[:, None]
                    fb = prevs[bi] if retains else jnp.zeros_like(num)
                    outs.append(jnp.where(den > 0, num / (den + _EPS), fb))
                else:
                    den = jax.lax.psum(jnp.sum(wt), ax)
                    outs.append(num / (den + _EPS))
            return outs

        shard_fn = jax.jit(shard_map_no_check(
            body, mesh,
            in_specs=([P(ax)] * nb, [P(ax)] * nb, P(ax),
                      [P()] * nb if retains else []),
            out_specs=[P()] * nb))
        exec_cache[key] = shard_fn

    def round_fn(ab, wt_raw, prev_ab):
        wt = strategy.transform_weights(wt_raw, cr)
        xs = []
        for b in buckets:
            parts = [_pack_side(ab[s.pair_idx][s.side], s) for s in b.slots]
            xs.append(jnp.concatenate(parts, axis=1)
                      if len(parts) > 1 else parts[0])
        prevs = []
        if retains:
            for b in buckets:
                parts = [_pack_prev_side(prev_ab[s.pair_idx][s.side], s)
                         for s in b.slots]
                prevs.append(jnp.concatenate(parts, axis=0)
                             if len(parts) > 1 else parts[0])
        outs = shard_fn(xs, masks_const, wt, prevs)
        return [
            {s.side: _unpack_slot(outs[bi], s, spec.pairs[s.pair_idx])
             for bi, b in enumerate(buckets) for s in b.slots
             if s.pair_idx == pi}
            for pi in range(len(spec.pairs))]

    rebuild = [None]

    def execute(stacked_tree, w, prev_tree, donate):
        if rebuild[0] is None:
            rebuild[0] = _make_rebuilder(stacked_tree)
        ab = _ab_list(stacked_tree)
        prev_ab = _ab_list(prev_tree) if retains else None
        outs = round_fn(ab, w, prev_ab)
        pairs = [{"A": o["A"], "B": o["B"], "rank": rank_leaves[i]}
                 for i, o in enumerate(outs)]
        return rebuild[0](pairs)

    return CompiledRound(strategy, spec, "packed", execute,
                         n_kernel_launches=len(buckets))


# ----------------------------------------------------- packed stack plans --
def _build_stack_round(strategy, spec: CohortSpec) -> CompiledRound:
    """flora's packed plan (ref + pallas): the whole stacking round is
    copies/scales at static offsets, fused into one ``packed_stack``
    launch (or one XLA slice-update chain) per bucket.  Pairs whose
    stacked rank exceeds the cap fall back to the reference pair math
    (SVD re-projection) inside the same jitted round."""
    n = spec.n_clients

    # ---- static per-pair stacking geometry ------------------------------
    plans = []                       # one entry per pair
    for meta in spec.pairs:
        ranks = meta.rank_values()
        if ranks.ndim > 1:           # layer-stacked: flora needs uniform
            flat = ranks.reshape(n, -1)
            if not np.all(flat == flat[:, :1]):
                raise PlanUnavailable(
                    "flora packs layer-stacked pairs only with uniform "
                    "per-client ranks")
            ranks = flat[:, 0]
        ranks = ranks.reshape(-1).astype(np.int64)
        lead_a, r_st_a, _, _, _ = _side_geometry(meta, "A")
        cap = strategy.resolve_cap(spec.r_max, r_storage=r_st_a)
        strategy._validate_cap(cap, ranks, spec.r_max)
        prev_rank = 0
        prev_r_st = 0
        if spec.has_prev and meta.prev_ranks is not None:
            prev_rank = int(np.max(meta.prev_rank_values()))
            prev_r_st = int(meta.prev_a_shape[-2])
        live = [i for i in range(n) if int(ranks[i]) > 0]
        seg_ranks = ([prev_rank] if prev_rank else []) \
            + [int(ranks[i]) for i in live]
        r_total = int(sum(seg_ranks))
        plans.append(dict(ranks=ranks, cap=cap, prev_rank=prev_rank,
                          prev_r_st=prev_r_st, live=live,
                          seg_ranks=seg_ranks, r_total=r_total,
                          packable=r_total <= cap))

    def _capped_r_out(p, meta):
        # mirrors _stack_pair's over-cap branch exactly
        base = (spec.r_max if spec.r_max is not None
                else meta.a_shape[-2])
        return min(int(base), p["cap"])

    rank_leaves = _out_rank_leaves(
        spec, [p["r_total"] if p["packable"] else _capped_r_out(p, m)
               for p, m in zip(plans, spec.pairs)])

    # ---- bucket the packable pairs; out layout = lead x cap per slot ----
    buckets: dict = {}
    for pi, meta in enumerate(spec.pairs):
        if not plans[pi]["packable"]:
            continue
        for side in ("A", "B"):
            lead, r_st, rows, width, dtype = _side_geometry(meta, side)
            key = (width, dtype)
            b = buckets.setdefault(
                key, Bucket(width=width, dtype=dtype, slots=[]))
            b.slots.append(Slot(pair_idx=pi, side=side, lead=lead,
                                r_st=r_st, rows=rows, width=width,
                                dtype=dtype))
    buckets = list(buckets.values())

    # scale vector layout: entry 0 is the constant 1.0 (A rows pass
    # verbatim); then one entry per (packable pair, segment) for B
    scale_slots: list = []           # (pair_idx, seg_index) in vector order
    for pi, p in enumerate(plans):
        if p["packable"]:
            for j in range(len(p["seg_ranks"])):
                scale_slots.append((pi, j))
    scale_index = {ps: 1 + k for k, ps in enumerate(scale_slots)}

    bucket_meta = []
    for b in buckets:
        in_off = 0
        prev_off = 0
        out_off = 0
        copies_x: list = []
        copies_prev: list = []
        for s in b.slots:
            p = plans[s.pair_idx]
            nlayers = int(np.prod(s.lead, dtype=np.int64)) if s.lead else 1
            cap = p["cap"]
            prev_r_st = p["prev_r_st"]
            for l in range(nlayers):
                dst = out_off + l * cap
                seg = 0
                if p["prev_rank"]:
                    si = (scale_index[(s.pair_idx, seg)]
                          if s.side == "B" else 0)
                    copies_prev.append((prev_off + l * prev_r_st, dst,
                                        p["prev_rank"], si))
                    dst += p["prev_rank"]
                    seg += 1
                for i in p["live"]:
                    r_i = int(p["ranks"][i])
                    si = (scale_index[(s.pair_idx, seg)]
                          if s.side == "B" else 0)
                    copies_x.append((i, in_off + l * s.r_st, dst, r_i, si))
                    dst += r_i
                    seg += 1
            s.offset = out_off
            out_off += nlayers * cap
            in_off += s.rows
            prev_off += nlayers * prev_r_st
        bucket_meta.append(dict(out_rows=out_off,
                                copies_x=tuple(copies_x),
                                copies_prev=tuple(copies_prev)))

    fallback = [pi for pi, p in enumerate(plans) if not p["packable"]]
    n_scales = 1 + len(scale_slots)

    # interpreted Pallas pays per-op Python overhead on every static copy,
    # so the fused stacking loses to XLA there; the copies are static
    # slices either way, so the ref lowering is just as fused (and is the
    # only lowering the "ref" backend may use)
    from repro.kernels.runtime import auto_interpret
    use_kernel = (spec.kind == "pallas"
                  and not auto_interpret(spec.interpret))

    def round_fn(ab, wt_raw, prev_ab):
        wt = wt_raw
        mean_w = jnp.mean(wt)
        # per-(pair, segment) B-column scales: mhat_i * r_out / r_i
        scales = [jnp.float32(1.0)]
        for pi, p in enumerate(plans):
            if not p["packable"]:
                continue
            masses = []
            if p["prev_rank"]:
                masses.append(strategy.prev_weight * mean_w)
            masses.extend(wt[i] for i in p["live"])
            m = jnp.stack(masses)
            mhat = m / (jnp.sum(m) + _EPS)
            r_out = jnp.float32(p["r_total"])
            for j, rj in enumerate(p["seg_ranks"]):
                scales.append(mhat[j] * r_out / jnp.float32(rj))
        scales = jnp.stack(scales)
        assert scales.shape[0] == n_scales

        outs = []
        for bi, b in enumerate(buckets):
            from repro.kernels.rbla_agg.ops import (packed_stack_inline,
                                                    packed_stack_ref)
            x = jnp.concatenate(
                [_pack_side(ab[s.pair_idx][s.side], s) for s in b.slots],
                axis=1) if len(b.slots) > 1 else _pack_side(
                    ab[b.slots[0].pair_idx][b.slots[0].side], b.slots[0])
            prev = None
            if bucket_meta[bi]["copies_prev"]:
                parts = []
                for s in b.slots:
                    p = plans[s.pair_idx]
                    if p["prev_r_st"]:
                        parts.append(_pack_prev_side(
                            prev_ab[s.pair_idx][s.side],
                            dataclasses.replace(
                                s, r_st=p["prev_r_st"],
                                rows=(s.rows // s.r_st) * p["prev_r_st"])))
                prev = (jnp.concatenate(parts, axis=0)
                        if len(parts) > 1 else parts[0])
            stack = (functools.partial(packed_stack_inline,
                                       interpret=spec.interpret)
                     if use_kernel else packed_stack_ref)
            outs.append(stack(
                x, scales, prev,
                copies_x=bucket_meta[bi]["copies_x"],
                copies_prev=bucket_meta[bi]["copies_prev"],
                out_rows=bucket_meta[bi]["out_rows"]))

        results: dict = {}
        for bi, b in enumerate(buckets):
            for s in b.slots:
                cap = plans[s.pair_idx]["cap"]
                y = outs[bi][s.offset:s.offset
                             + (s.rows // s.r_st) * cap]
                y = y.reshape(s.lead + (cap, s.width))
                if s.side == "B":
                    y = jnp.swapaxes(y, -1, -2)
                results[(s.pair_idx, s.side)] = y.astype(s.dtype)
        # over-cap pairs: reference SVD re-projection, same jitted round
        for pi in fallback:
            meta, p = spec.pairs[pi], plans[pi]
            pA = pB = None
            if spec.has_prev and p["prev_rank"]:
                pA, pB = prev_ab[pi]["A"], prev_ab[pi]["B"]
            A_out, B_out, _ = strategy._stack_pair(
                ab[pi]["A"], ab[pi]["B"], p["ranks"], wt, pA, pB,
                p["prev_rank"] or None, spec.r_max)
            results[(pi, "A")] = A_out
            results[(pi, "B")] = B_out
        return [{"A": results[(pi, "A")], "B": results[(pi, "B")]}
                for pi in range(len(spec.pairs))]

    fn = jax.jit(round_fn)
    fn_donate = jax.jit(round_fn, donate_argnums=(2,))
    rebuild = [None]
    has_prev = spec.has_prev

    def execute(stacked_tree, w, prev_tree, donate):
        if rebuild[0] is None:
            rebuild[0] = _make_rebuilder(stacked_tree)
        ab = _ab_list(stacked_tree)
        prev_ab = _ab_list(prev_tree) if has_prev else None
        run = fn_donate if (donate and has_prev) else fn
        outs = run(ab, w, prev_ab)
        pairs = [{"A": o["A"], "B": o["B"], "rank": rank_leaves[i]}
                 for i, o in enumerate(outs)]
        return rebuild[0](pairs)

    return CompiledRound(strategy, spec, "packed", execute,
                         n_kernel_launches=len(buckets) + len(fallback),
                         n_fallback_pairs=len(fallback))


# ------------------------------------------------------ packed svd plans --
def _build_svd_round(strategy, spec: CohortSpec) -> CompiledRound:
    """svd's packed lowering: pairs bucket by (shape, dtype) and each
    bucket runs ONE batched factored SVD (``repro.core.lowrank``) inside
    a single jitted round -- same CompiledRound contract as the mean and
    stack modes.  The per-pair dense O(m*n*min(m,n)) SVDs the jit mode
    used to issue become O((m+n)*k^2 + k^3) QR/core work, vmapped across
    the bucket's same-shape pairs, with no dense delta materialized.

    Scales (``r_out / rank_i``) enter as runtime data, so -- like the
    mean mode -- one compiled executor serves every rank multiset with
    this cohort layout; a new multiset builds a cheap plan, not a fresh
    XLA compile."""
    # ---- bucket pairs by full geometry (a batched SVD needs both sides
    # of a pair, so buckets key on pair shapes, not row width) ----------
    r_outs = []
    for meta in spec.pairs:
        r_st = meta.a_shape[-2]
        r_outs.append(r_st if spec.r_max is None
                      else min(spec.r_max, r_st))
    bucket_map: dict = {}
    for pi, meta in enumerate(spec.pairs):
        key = (meta.a_shape, meta.a_dtype, meta.b_shape, meta.b_dtype,
               meta.rank_shape, r_outs[pi])
        bucket_map.setdefault(key, []).append(pi)
    svd_buckets = list(bucket_map.values())
    rank_leaves = _out_rank_leaves(spec)

    # per-pair contributor scale tensors (r_out / rank), host-built from
    # the spec's concrete ranks but passed as data for executor reuse;
    # raw (n, *rank_lead) shapes -- svd_project_stacked owns the
    # trailing-lead-dim alignment
    scale_args = []
    for idxs in svd_buckets:
        per_pair = []
        for pi in idxs:
            meta = spec.pairs[pi]
            if spec.client_ranks is not None:
                rk = np.asarray(spec.client_ranks, np.float32)
            else:
                rk = meta.rank_values().astype(np.float32)
            per_pair.append(r_outs[pi] / np.maximum(rk, 1.0))
        scale_args.append(jnp.asarray(np.stack(per_pair), jnp.float32))

    # the engine knobs are traced into round_fn via strategy._project:
    # key them so even a direct (non-with_options) attribute assignment
    # cannot serve a stale executor
    exec_cache = strategy.__dict__.setdefault("_plan_exec_cache", {})
    key = ("svd", strategy.svd_method, strategy.rsvd_oversample,
           strategy.rsvd_power_iters, _shape_key(spec),
           tuple(tuple(idxs) for idxs in svd_buckets), tuple(r_outs))
    fn = exec_cache.get(key)
    if fn is None:
        def round_fn(ab, wt, scs):
            results: dict = {}
            for g, idxs in enumerate(svd_buckets):
                meta = spec.pairs[idxs[0]]
                r_st = meta.a_shape[-2]
                r_out = r_outs[idxs[0]]
                Bs = (jnp.stack([ab[pi]["B"] for pi in idxs])
                      if len(idxs) > 1 else ab[idxs[0]]["B"][None])
                As = (jnp.stack([ab[pi]["A"] for pi in idxs])
                      if len(idxs) > 1 else ab[idxs[0]]["A"][None])

                def project(b, a, sc, _r_out=r_out):
                    return strategy._project(b, a, wt, _r_out, sc)

                Bo, Ao = jax.vmap(project)(Bs, As, scs[g])
                for j, pi in enumerate(idxs):
                    results[(pi, "A")] = pad_to_rank(
                        Ao[j], -2, r_st).astype(meta.a_dtype)
                    results[(pi, "B")] = pad_to_rank(
                        Bo[j], -1, r_st).astype(meta.b_dtype)
            return [{"A": results[(pi, "A")], "B": results[(pi, "B")]}
                    for pi in range(len(spec.pairs))]

        fn = jax.jit(round_fn)
        exec_cache[key] = fn
    rebuild = [None]

    def execute(stacked_tree, w, prev_tree, donate):
        if rebuild[0] is None:
            rebuild[0] = _make_rebuilder(stacked_tree)
        ab = _ab_list(stacked_tree)
        outs = fn(ab, w, scale_args)
        pairs = [{"A": o["A"], "B": o["B"], "rank": rank_leaves[i]}
                 for i, o in enumerate(outs)]
        return rebuild[0](pairs)

    return CompiledRound(strategy, spec, "packed", execute,
                         n_kernel_launches=len(svd_buckets))


# ----------------------------------------------------------- legacy plans --
def _build_jit_round(strategy, spec: CohortSpec) -> CompiledRound:
    """Whole-round jit over the strategy's reference tree path: ranks and
    the cohort layout are closed over as constants, so host dispatch is
    one call per round even where no packed kernel applies (svd's
    per-pair SVDs, flora's ref backend)."""
    retains = strategy.retains_prev and spec.has_prev
    cr = spec.client_ranks_array()
    rank_consts = [jnp.asarray(m.rank_values().astype(np.int32))
                   for m in spec.pairs]
    prev_rank_consts = [
        None if m.prev_ranks is None
        else jnp.asarray(m.prev_rank_values().astype(np.int32))
        for m in spec.pairs]
    rebuild = [None]

    def round_fn(ab, wt, prev_ab):
        from repro.lora import pair_masks
        pairs = [{"A": p["A"], "B": p["B"], "rank": rank_consts[i]}
                 for i, p in enumerate(ab)]
        stacked = rebuild[0](pairs)
        prev = None
        if retains:
            prev = rebuild[0](
                [{"A": p["A"], "B": p["B"], "rank": prev_rank_consts[i]}
                 for i, p in enumerate(prev_ab)])
        if spec.kind == "pallas":
            out = strategy.aggregate_tree_pallas(
                stacked, wt, cr, prev, r_max=spec.r_max,
                interpret=spec.interpret)
        else:
            masks = _map_pairs_like(pair_masks, stacked)
            out = strategy.aggregate_tree(stacked, masks, wt, prev,
                                          r_max=spec.r_max,
                                          client_ranks=cr)
        return [{"A": p["A"], "B": p["B"], "rank": p["rank"]}
                for _, p in _walk_pairs(out)]

    fn = jax.jit(round_fn)
    fn_donate = jax.jit(round_fn, donate_argnums=(2,))

    def execute(stacked_tree, w, prev_tree, donate):
        if rebuild[0] is None:
            rebuild[0] = _make_rebuilder(stacked_tree)
        ab = _ab_list(stacked_tree)
        prev_ab = _ab_list(prev_tree) if retains else None
        run = fn_donate if (donate and retains) else fn
        outs = run(ab, w, prev_ab)
        out_tree = rebuild[0](
            [{"A": o["A"], "B": o["B"], "rank": o["rank"]} for o in outs])
        return strategy.finalize_tree(out_tree, spec.r_max)

    return CompiledRound(strategy, spec, "jit", execute,
                         n_kernel_launches=1)


def _map_pairs_like(fn, tree):
    if _is_pair(tree):
        return fn(tree)
    if isinstance(tree, Mapping):
        return {k: _map_pairs_like(fn, v) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(_map_pairs_like(fn, v) for v in tree)
    return tree


def _build_eager_round(strategy, spec: CohortSpec) -> CompiledRound:
    """No-compilation wrapper: exactly the pre-plan execution (unknown
    strategies whose leaf math we cannot assume, and paths that keep
    their own caches, e.g. flora's ragged-concat distributed round)."""
    cr = spec.client_ranks_array()

    def execute(stacked_tree, w, prev_tree, donate):
        from repro.lora import pair_masks
        prev = prev_tree if strategy.retains_prev else None
        if spec.kind == "pallas":
            out = strategy.aggregate_tree_pallas(
                stacked_tree, w, cr, prev, r_max=spec.r_max,
                interpret=spec.interpret)
        elif spec.kind == "distributed":
            masks = _map_pairs_like(pair_masks, stacked_tree)
            out = strategy.aggregate_tree_distributed(
                stacked_tree, masks, w, prev, r_max=spec.r_max,
                client_ranks=cr, mesh=spec.mesh,
                client_axis=spec.client_axis)
        else:
            masks = _map_pairs_like(pair_masks, stacked_tree)
            out = strategy.aggregate_tree(stacked_tree, masks, w, prev,
                                          r_max=spec.r_max,
                                          client_ranks=cr)
        return strategy.finalize_tree(out, spec.r_max)

    return CompiledRound(strategy, spec, "eager", execute)


# -------------------------------------------------------------- dispatch --
def build_plan(strategy, spec: CohortSpec) -> CompiledRound:
    """Build the right :class:`CompiledRound` for ``strategy`` x ``spec``.

    ``strategy.plan_mode`` declares how the strategy lowers:

    * ``"mean"`` -- packed masked-mean buckets (fedavg / zeropad / rbla /
      rbla_ranked) on every backend;
    * ``"mean_norm"`` -- ditto plus rbla_norm's per-row norm restore
      (scalar-rank pairs only; ref and pallas backends);
    * ``"stack"`` -- flora: packed copy/scale stacking on ref and pallas
      (fused XLA slice-updates where Pallas would be interpreted), the
      cached ragged-concat collective when distributed;
    * ``"svd"`` -- packed batched factored SVD (``repro.core.lowrank``):
      one batched QR-core-SVD per same-shape pair bucket on ref and
      pallas; the gathered-factor collective (its own cache) when
      distributed;
    * ``"jit"`` -- whole-round jit of the reference math;
    * ``None`` -- eager legacy execution (registered strategies we know
      nothing about).
    """
    mode = getattr(strategy, "plan_mode", None)
    if spec.codecs is not None and (mode not in ("mean", "mean_norm")
                                    or spec.kind == "distributed"):
        # encoded cohorts lower through the packed mean family only; the
        # caller decodes eagerly for stack/svd/jit/eager/distributed
        raise PlanUnavailable(
            "encoded cohorts plan only on the mean family")
    try:
        if mode == "mean":
            return _build_mean_round(strategy, spec)
        if mode == "mean_norm":
            if spec.kind == "distributed" or any(
                    len(m.a_shape) != 3 for m in spec.pairs):
                if spec.codecs is not None:
                    raise PlanUnavailable(
                        "encoded mean_norm needs scalar-rank pairs")
                return _build_eager_round(strategy, spec)
            return _build_mean_round(strategy, spec, norm_restore=True)
        if mode == "stack":
            if spec.kind in ("pallas", "ref"):
                # both lower to the packed copy/scale round; the ref kind
                # (and interpret-mode pallas) uses the fused XLA stacking
                # instead of the kernel -- the whole-round jit of the
                # per-pair reference math measured *slower* than legacy
                return _build_stack_round(strategy, spec)
            return _build_eager_round(strategy, spec)
        if mode == "svd":
            if spec.kind == "distributed":
                return _build_eager_round(strategy, spec)
            return _build_svd_round(strategy, spec)
        if mode == "jit" and spec.kind == "ref":
            return _build_jit_round(strategy, spec)
    except PlanUnavailable:
        if spec.codecs is not None:
            # the eager round expects a stacked fp32 tree -- propagate so
            # the caller decodes and retries on the standard path
            raise
        return _build_eager_round(strategy, spec)
    return _build_eager_round(strategy, spec)


# ------------------------------------------------------------- fold plans --
def build_fold_plan(strategy, spec: CohortSpec):
    """Packed per-update fold executor (the async hot path).

    Reuses the cohort packing for a 1-element 'cohort': the server state
    and the arriving update pack into the same (width, dtype) buckets and
    fold in **one fused** ``axpy_fold`` **launch per bucket** -- cost
    O(state), independent of how many pairs the tree has at the Python
    level.  Returns ``fold_fn(state_ab, upd_ab, row_mass, wa, rank_leaves)
    -> (new_ab, new_row_mass)`` (jitted; ``rank_leaves`` are the arriving
    update's per-pair rank leaves, traced so one compilation serves every
    client)."""
    buckets = _make_buckets(spec, use_mask=True)

    def fold_fn(state_ab, upd_ab, row_mass, wa, rank_leaves):
        from repro.kernels.rbla_agg.ops import axpy_fold_inline
        # per-pair owned-row indicators and packed alphas
        alphas = {}
        new_mass = []
        for pi, meta in enumerate(spec.pairs):
            r_st = meta.a_shape[-2]
            rank = jnp.asarray(rank_leaves[pi], jnp.int32)
            owned = (jax.lax.iota(jnp.int32, r_st)
                     < rank[..., None]).astype(jnp.float32)
            dmass = row_mass[pi]
            alphas[pi] = jnp.where(owned > 0, wa / (dmass + wa), 0.0)
            new_mass.append(dmass + wa * owned)
        outs = []
        for b in buckets:
            y_parts = [_pack_prev_side(state_ab[s.pair_idx][s.side], s)
                       for s in b.slots]
            x_parts = [_pack_prev_side(upd_ab[s.pair_idx][s.side], s)
                       for s in b.slots]
            y = (jnp.concatenate(y_parts, axis=0)
                 if len(y_parts) > 1 else y_parts[0])
            x = (jnp.concatenate(x_parts, axis=0)
                 if len(x_parts) > 1 else x_parts[0])
            a_parts = []
            for s in b.slots:
                al = alphas[s.pair_idx]
                mid = len(s.lead) - (al.ndim - 1)
                al = jnp.broadcast_to(
                    al.reshape(al.shape[:-1] + (1,) * mid + (al.shape[-1],)),
                    s.lead + (s.r_st,))
                a_parts.append(al.reshape(s.rows))
            a = (jnp.concatenate(a_parts)
                 if len(a_parts) > 1 else a_parts[0])
            outs.append(axpy_fold_inline(y, x, a,
                                         interpret=spec.interpret))
        new_ab = [
            {s.side: _unpack_slot(outs[bi], s, spec.pairs[s.pair_idx])
             for bi, b in enumerate(buckets) for s in b.slots
             if s.pair_idx == pi}
            for pi in range(len(spec.pairs))]
        return new_ab, new_mass

    return jax.jit(fold_fn), len(buckets)


def build_state_spec(adapters: PyTree, *, interpret=None) -> CohortSpec:
    """A :class:`CohortSpec` for a *server state* tree (no client axis):
    the fold plan's cache key.  Rank values are not part of the key --
    folds take them as data so one compiled fold serves every client."""
    pairs = []
    for path, pair in _walk_pairs(adapters):
        A, B = pair["A"], pair["B"]
        if isinstance(A, jax.core.Tracer) or isinstance(B, jax.core.Tracer):
            raise PlanUnavailable("state leaves are traced")
        rk_shape = tuple(np.shape(jax.device_get(pair["rank"]))) \
            if not isinstance(pair["rank"], jax.core.Tracer) else None
        if rk_shape is None:
            raise PlanUnavailable("state rank leaf is traced")
        pairs.append(PairMeta(
            path=path, a_shape=(1,) + tuple(A.shape), a_dtype=str(A.dtype),
            b_shape=(1,) + tuple(B.shape), b_dtype=str(B.dtype),
            rank_shape=(1,) + rk_shape,
            ranks=tuple(0 for _ in range(int(np.prod(rk_shape,
                                                     dtype=np.int64))))))
    if not pairs:
        raise PlanUnavailable("no LoRA pairs in the state tree")
    return CohortSpec(n_clients=1, kind="pallas", r_max=None,
                      pairs=tuple(pairs), client_ranks=None,
                      has_prev=False, interpret=interpret)


__all__ = [
    "CohortSpec", "PairMeta", "CompiledRound", "PlanUnavailable",
    "BufferMemo",
    "build_cohort_spec", "build_encoded_cohort_spec", "build_plan",
    "build_fold_plan", "build_state_spec", "dispatch_counter",
    "DispatchCounter",
]
