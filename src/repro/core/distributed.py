"""Distributed RBLA: the paper's server loop as a TPU collective.

Alg. 1 in the paper is a Python ``for`` over clients and layers executed on
one server.  In FLaaS at pod scale, each mesh slice along a *client axis*
hosts one client (or cohort) and its adapters; aggregation becomes two
``psum``s (numerator and participating-weight-mass denominator) over that
axis -- no gather of ``n_clients`` copies ever materializes.

The method-specific math lives in ``repro.core.strategy``; everything here
is a thin, backward-compatible veneer over the registered strategies'
distributed paths.  ``rbla_allreduce`` works inside ``shard_map`` bodies;
``make_distributed_aggregator`` wraps a whole adapter pytree into a single
shard_mapped SPMD aggregation program.
"""
from __future__ import annotations

from typing import Any

import jax

from .compat import shard_map, shard_map_no_check  # noqa: F401  (re-export)
from .strategy import get_strategy

Array = jax.Array
PyTree = Any


def rbla_allreduce(local: Array, mask: Array | None, weight: Array,
                   axis_name: str, method: str = "rbla") -> Array:
    """Aggregate this shard's client adapter with all peers over ``axis_name``.

    Eq. 7 as two all-reduces:
        C = psum(w * m * x) / psum(w * m)           (rbla)
        C = psum(w * m * x) / psum(w)               (zeropad baseline)

    Dispatches on the strategy registry; any registered strategy with a
    distributed path works.
    """
    return get_strategy(method).allreduce_leaf(local, mask, weight,
                                               axis_name)


def rbla_tree_allreduce(local_tree: PyTree, mask_tree: PyTree, weight: Array,
                        axis_name: str, method: str = "rbla") -> PyTree:
    """Pytree version of :func:`rbla_allreduce` (for shard_map bodies)."""
    strategy = get_strategy(method)
    return jax.tree.map(
        lambda x, m: strategy.allreduce_leaf(
            x, None if (m is not None and m.ndim == 0) else m,
            weight, axis_name),
        local_tree, mask_tree, is_leaf=lambda v: v is None)


def make_distributed_aggregator(mesh, client_axis: str = "data",
                                method: str = "rbla"):
    """Build a jitted SPMD aggregator over ``client_axis`` of ``mesh``.

    Deprecated shim for
    ``get_strategy(method).make_distributed_aggregator(mesh, client_axis)``.
    """
    return get_strategy(method).make_distributed_aggregator(mesh,
                                                            client_axis)
