"""Distributed RBLA: the paper's server loop as a TPU collective.

Alg. 1 in the paper is a Python ``for`` over clients and layers executed on
one server.  In FLaaS at pod scale, each mesh slice along a *client axis*
hosts one client (or cohort) and its adapters; aggregation becomes two
``psum``s (numerator and participating-weight-mass denominator) over that
axis -- no gather of ``n_clients`` copies ever materializes.

``rbla_allreduce`` is written against ``jax.lax`` collectives so it can be
used inside ``shard_map`` bodies; ``make_distributed_aggregator`` wraps a
whole adapter pytree into a single shard_mapped SPMD aggregation program.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

shard_map = jax.shard_map  # jax >= 0.7: top-level API

Array = jax.Array
PyTree = Any
_EPS = 1e-12


def rbla_allreduce(local: Array, mask: Array | None, weight: Array,
                   axis_name: str, method: str = "rbla") -> Array:
    """Aggregate this shard's client adapter with all peers over ``axis_name``.

    Eq. 7 as two all-reduces:
        C = psum(w * m * x) / psum(w * m)           (rbla)
        C = psum(w * m * x) / psum(w)               (zeropad baseline)
    """
    x = local.astype(jnp.float32)
    w = jnp.asarray(weight, jnp.float32)
    m = jnp.ones_like(x) if mask is None else jnp.broadcast_to(
        mask.astype(jnp.float32), x.shape)
    num = lax.psum(w * m * x, axis_name)
    if method == "rbla":
        den = lax.psum(w * m, axis_name)
        out = jnp.where(den > 0, num / (den + _EPS), 0.0)
    elif method == "zeropad":
        den = lax.psum(w, axis_name)
        out = num / (den + _EPS)
    elif method == "fedavg":
        den = lax.psum(w, axis_name)
        out = num / (den + _EPS)
    else:
        raise ValueError(f"unknown method {method!r}")
    return out.astype(local.dtype)


def rbla_tree_allreduce(local_tree: PyTree, mask_tree: PyTree, weight: Array,
                        axis_name: str, method: str = "rbla") -> PyTree:
    """Pytree version of :func:`rbla_allreduce` (for shard_map bodies)."""
    return jax.tree.map(
        lambda x, m: rbla_allreduce(
            x, None if (m is not None and m.ndim == 0) else m,
            weight, axis_name, method),
        local_tree, mask_tree, is_leaf=lambda v: v is None)


def make_distributed_aggregator(mesh, client_axis: str = "data",
                                method: str = "rbla"):
    """Build a jitted SPMD aggregator over ``client_axis`` of ``mesh``.

    Inputs are *sharded* pytrees whose leading axis enumerates clients and
    is sharded over ``client_axis`` (one or more clients per shard).  The
    local clients are first reduced locally (masked partial sums), then
    combined globally with psum -- a two-level tree reduction.
    """
    def _local_partial(stacked, mask, weights):
        x = stacked.astype(jnp.float32)
        w = weights.astype(jnp.float32).reshape(
            weights.shape + (1,) * (x.ndim - 1))
        m = jnp.ones_like(x) if mask is None else jnp.broadcast_to(
            mask.astype(jnp.float32), x.shape)
        return jnp.sum(w * m * x, axis=0), jnp.sum(w * m, axis=0), jnp.sum(w)

    def body(stacked_tree, mask_tree, weights):
        def agg_leaf(x, m):
            m = None if (m is not None and m.ndim == 0) else m
            num, den_m, den_w = _local_partial(x, m, weights)
            num = lax.psum(num, client_axis)
            if method == "rbla":
                den = lax.psum(den_m, client_axis)
                out = jnp.where(den > 0, num / (den + _EPS), 0.0)
            else:  # zeropad / fedavg
                den = lax.psum(den_w, client_axis)
                out = num / (den + _EPS)
            return out.astype(x.dtype)
        return jax.tree.map(agg_leaf, stacked_tree, mask_tree,
                            is_leaf=lambda v: v is None)

    in_specs = (P(client_axis), P(client_axis), P(client_axis))
    out_specs = P()  # aggregated result replicated over the client axis
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    return jax.jit(fn)
