"""Batched low-rank factorization engine: SVD without the dense detour.

Every SVD the aggregation server runs -- the ``svd`` strategy's product-
space truncation, flora's over-cap re-projection, the streaming fold's
cap-crossing re-projection -- factors a matrix that is *already* a
product of low-rank factors::

    Delta = B @ A,   B: (..., m, k),  A: (..., k, n),  k = sum(r_i)

Densifying ``Delta`` and calling ``jnp.linalg.svd`` costs
``O(m * n * min(m, n))`` flops plus an ``m x n`` temporary per pair per
round -- the server bottleneck the paper flags for product-space
aggregation.  But ``rank(Delta) <= k``, so the SVD only ever lives in a
k-dimensional subspace:

* :func:`factored_svd` -- **exact** truncated SVD in factored form.  QR
  the stacked B-columns and the A-rows, SVD only the small
  ``(k x k)`` core ``R_B @ R_A^T``::

      B = Q_B R_B,  A^T = Q_A R_A
      R_B @ R_A^T = U_c S V_c^T          # (k x k) dense work only
      Delta = (Q_B U_c) S (V_c^T Q_A^T)  # never materialized

  Cost ``O((m + n) k^2 + k^3)``; no ``m x n`` intermediate exists at any
  point.  The result is the exact SVD of ``B @ A`` (it is an algebraic
  re-association, not an approximation), so truncating to ``r_out``
  matches the dense oracle whenever both would.

* :func:`randomized_svd` -- Halko-Martinsson-Tropp range-finder with
  oversampling ``p`` and ``q`` subspace (power) iterations, for inputs
  that are *genuinely dense* (no factored form exists);
  :func:`randomized_svd_product` applies the same sketch to factored
  inputs with every product associated through the factors, so it too
  never forms the dense matrix.

* :func:`truncated_svd_product` -- the dispatcher.  ``method="auto"``
  uses the factored path while ``k <= min(m, n)`` (where it is both
  exact and cheaper) and falls back to the **dense** path beyond --
  this module's dense branch is the only place in ``repro`` allowed to
  materialize ``B @ A`` for an SVD.

All entry points batch over arbitrary leading dims (``jnp.linalg.qr`` /
``svd`` batch natively) and are vmappable across same-shape pairs --
``repro.core.plan``'s svd lowering stacks a cohort's same-shape pairs
and runs ONE batched factored SVD per (shape, dtype) bucket.

Computation is float32 throughout; callers cast the factors back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .aggregation import _EPS
from .masks import pad_to_rank

Array = jax.Array


def _f32(x: Array) -> Array:
    return x.astype(jnp.float32)


def _truncate(u: Array, s: Array, vt: Array, r_out: int):
    """Keep the leading ``r_out`` triplets, zero-padding if the factored
    rank is smaller (static shapes: callers embed ``r_out`` in buffers)."""
    k = s.shape[-1]
    if k >= r_out:
        return (u[..., :, :r_out], s[..., :r_out], vt[..., :r_out, :])
    return (pad_to_rank(u, -1, r_out), pad_to_rank(s, -1, r_out),
            pad_to_rank(vt, -2, r_out))


def factored_svd(B: Array, A: Array, r_out: int | None = None
                 ) -> tuple[Array, Array, Array]:
    """Exact truncated SVD of ``B @ A`` without materializing the product.

    ``B``: (..., m, k); ``A``: (..., k, n) -> ``(U, S, Vt)`` with shapes
    (..., m, r), (..., r,), (..., r, n), ``r = r_out`` (or the full core
    rank ``min(m, n, k)`` when ``r_out`` is None).  Exact for any k; the
    cost win over the dense SVD is ``O((m+n) k^2 + k^3)`` vs
    ``O(m n min(m, n))``, so prefer it whenever ``k < min(m, n)``
    (:func:`truncated_svd_product` automates the choice).
    """
    Qb, Rb = jnp.linalg.qr(_f32(B))                    # (..., m, kb), (kb, k)
    Qa, Ra = jnp.linalg.qr(jnp.swapaxes(_f32(A), -1, -2))
    core = Rb @ jnp.swapaxes(Ra, -1, -2)               # (..., kb, ka): small
    u, s, vt = jnp.linalg.svd(core, full_matrices=False)
    if r_out is not None:
        u, s, vt = _truncate(u, s, vt, r_out)
    return Qb @ u, s, vt @ jnp.swapaxes(Qa, -1, -2)


def dense_svd(B: Array, A: Array, r_out: int | None = None
              ) -> tuple[Array, Array, Array]:
    """Dense fallback: materialize ``B @ A`` and SVD it directly.

    The ONLY place in ``repro`` that may run ``jnp.linalg.svd`` on an
    (out, in)-shaped product -- used when the combined factor rank ``k``
    exceeds ``min(m, n)`` (the factored path would do more work than the
    dense one) and by the benchmarks as the cost baseline.
    """
    delta = _f32(B) @ _f32(A)
    u, s, vt = jnp.linalg.svd(delta, full_matrices=False)
    if r_out is not None:
        u, s, vt = _truncate(u, s, vt, r_out)
    return u, s, vt


def randomized_svd(M: Array, r_out: int, *, oversample: int = 8,
                   power_iters: int = 2, key: Array | None = None
                   ) -> tuple[Array, Array, Array]:
    """Randomized range-finder SVD (Halko et al., 2011) for dense inputs.

    Samples the range with a Gaussian sketch of width
    ``min(r_out + oversample, min(m, n))``, runs ``power_iters`` rounds
    of QR-stabilized subspace iteration (sharpens the spectrum: the
    approximation error decays with the ``(2q+1)``-th power of the
    singular-value ratios), then SVDs the small projected matrix.
    Near-optimal when the spectrum tail beyond ``r_out`` is small;
    batches over leading dims.
    """
    M = _f32(M)
    m, n = M.shape[-2], M.shape[-1]
    k = min(r_out + int(oversample), min(m, n))
    if key is None:
        key = jax.random.PRNGKey(0)
    omega = jax.random.normal(key, M.shape[:-2] + (n, k), jnp.float32)
    Q, _ = jnp.linalg.qr(M @ omega)                    # (..., m, k)
    for _ in range(int(power_iters)):
        Z, _ = jnp.linalg.qr(jnp.swapaxes(M, -1, -2) @ Q)
        Q, _ = jnp.linalg.qr(M @ Z)
    small = jnp.swapaxes(Q, -1, -2) @ M                # (..., k, n)
    u, s, vt = jnp.linalg.svd(small, full_matrices=False)
    return _truncate(Q @ u, s, vt, r_out)


def randomized_svd_product(B: Array, A: Array, r_out: int, *,
                           oversample: int = 8, power_iters: int = 2,
                           key: Array | None = None
                           ) -> tuple[Array, Array, Array]:
    """Range-finder SVD of ``B @ A`` *in factored form*.

    Every sketch and projection associates through the factors --
    ``M @ Om = B @ (A @ Om)``, ``M^T @ Q = A^T @ (B^T @ Q)``,
    ``Q^T M = (Q^T B) @ A`` -- so the dense product is never formed:
    cost ``O((m + n) * k * (r + p))`` per sketch instead of the
    ``O(m * n * (r + p))`` a materialized sketch would pay.
    """
    B, A = _f32(B), _f32(A)
    m, n = B.shape[-2], A.shape[-1]
    k = min(r_out + int(oversample), min(m, n))
    if key is None:
        key = jax.random.PRNGKey(0)
    omega = jax.random.normal(key, A.shape[:-2] + (n, k), jnp.float32)
    Bt, At = jnp.swapaxes(B, -1, -2), jnp.swapaxes(A, -1, -2)
    Q, _ = jnp.linalg.qr(B @ (A @ omega))              # (..., m, k)
    for _ in range(int(power_iters)):
        Z, _ = jnp.linalg.qr(At @ (Bt @ Q))
        Q, _ = jnp.linalg.qr(B @ (A @ Z))
    small = (jnp.swapaxes(Q, -1, -2) @ B) @ A          # (..., k, n)
    u, s, vt = jnp.linalg.svd(small, full_matrices=False)
    return _truncate(Q @ u, s, vt, r_out)


def truncated_svd_product(B: Array, A: Array, r_out: int, *,
                          method: str = "auto", oversample: int = 8,
                          power_iters: int = 2, key: Array | None = None
                          ) -> tuple[Array, Array, Array]:
    """Truncated SVD of ``B @ A``, routed by ``method``:

    * ``"auto"`` -- factored while ``k <= min(m, n)`` (exact + cheaper;
      the shapes are static so the choice compiles away), dense beyond;
    * ``"factored"`` / ``"dense"`` -- force the respective exact path;
    * ``"randomized"`` -- the factored-form range-finder sketch (an
      *approximation*; useful when the spectrum decays fast).
    """
    m, k, n = B.shape[-2], B.shape[-1], A.shape[-1]
    if method == "auto":
        method = "factored" if k <= min(m, n) else "dense"
    if method == "factored":
        return factored_svd(B, A, r_out)
    if method == "dense":
        return dense_svd(B, A, r_out)
    if method == "randomized":
        return randomized_svd_product(B, A, r_out, oversample=oversample,
                                      power_iters=power_iters, key=key)
    raise ValueError(f"unknown svd method {method!r}; options: "
                     "auto | factored | dense | randomized")


def product_factors(B: Array, A: Array, r_out: int, *,
                    method: str = "auto", oversample: int = 8,
                    power_iters: int = 2, key: Array | None = None
                    ) -> tuple[Array, Array]:
    """Re-factor ``B @ A`` into a rank-``r_out`` LoRA pair.

    Returns ``(B_out, A_out)`` = ``(U sqrt(S), sqrt(S) Vt)`` -- the
    balanced square-root split every re-projection site in the repo uses
    (flora's cap handling, the svd strategy's output factors).
    """
    u, s, vt = truncated_svd_product(B, A, r_out, method=method,
                                     oversample=oversample,
                                     power_iters=power_iters, key=key)
    sq = jnp.sqrt(s)
    return u * sq[..., None, :], sq[..., :, None] * vt


def svd_project_stacked(stacked_B: Array, stacked_A: Array, weights: Array,
                        r_out: int, *, scales: Array | None = None,
                        method: str = "auto", oversample: int = 8,
                        power_iters: int = 2, key: Array | None = None
                        ) -> tuple[Array, Array]:
    """Product-space aggregation of stacked LoRA pairs, factored form.

    ``stacked_B``: (n, ..., out, r_st); ``stacked_A``: (n, ..., r_st, in)
    with the client axis leading and arbitrary layer/expert dims between.
    The weighted mean of products

        Delta = sum_i (w_i * s_i / sum(w)) * B_i @ A_i

    is *itself* a product of concatenated factors -- client ``i``'s
    scaled B columns next to everyone else's, its A rows stacked below --
    so the whole aggregation is one rank-``n*r_st`` factored SVD: no
    dense Delta, no per-client loop.  Row-masking stays implicit (padded
    rows are zero, contributing nothing to the product).  ``scales``
    broadcasts against ``weights`` over (n, *leading rank dims).
    Returns float32 ``(B_out, A_out)`` with inner dimension ``r_out``.
    """
    n, r_st = stacked_A.shape[0], stacked_A.shape[-2]
    lead_ndim = stacked_B.ndim - 3
    w = _f32(weights) / (jnp.sum(_f32(weights)) + _EPS)
    w = w.reshape((n,) + (1,) * lead_ndim)
    if scales is not None:
        sc = _f32(scales)
        # rank dims align with the *trailing* leading dims (the same
        # convention as the plan's owner masks): pad middle 1s
        mid = lead_ndim - (sc.ndim - 1)
        w = w * sc.reshape(sc.shape[:1] + (1,) * mid + sc.shape[1:])
    # fold the client weight into B, then merge (client, storage-rank)
    # into one concatenated rank axis of width n * r_st
    Bw = _f32(stacked_B) * w[..., None, None]
    Bc = jnp.moveaxis(Bw, 0, -2)                       # (..., out, n, r_st)
    Bc = Bc.reshape(Bc.shape[:-2] + (n * r_st,))
    Ac = jnp.moveaxis(_f32(stacked_A), 0, -3)          # (..., n, r_st, in)
    Ac = Ac.reshape(Ac.shape[:-3] + (n * r_st,) + Ac.shape[-1:])
    return product_factors(Bc, Ac, r_out, method=method,
                           oversample=oversample, power_iters=power_iters,
                           key=key)


__all__ = [
    "factored_svd", "dense_svd", "randomized_svd",
    "randomized_svd_product", "truncated_svd_product",
    "product_factors", "svd_project_stacked",
]
