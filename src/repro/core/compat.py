"""Version-compat shims for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to a top-level
``jax.shard_map`` (jax >= 0.7), and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way.  Everything in this repo that
builds shard_map programs goes through :func:`shard_map_no_check` so one
import site absorbs both changes.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map                     # jax >= 0.7
except AttributeError:                            # pragma: no cover - old jax
    from jax.experimental.shard_map import shard_map


def axis_size(axis_name) -> int:
    """``lax.axis_size`` (jax >= 0.4.x-late); older jax constant-folds
    ``psum(1, axis)`` to the same static size inside shard_map bodies."""
    try:
        return jax.lax.axis_size(axis_name)
    except AttributeError:                        # pragma: no cover - old jax
        return jax.lax.psum(1, axis_name)


def shard_map_no_check(body, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking disabled, any JAX version."""
    try:
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:                             # jax < 0.7 spells it check_rep
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


__all__ = ["shard_map", "shard_map_no_check"]
