"""h2o-danube-3-4b [dense] -- llama+mistral mix with sliding-window
attention. [arXiv:2401.16818]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, SWA window 4096
(mistral-style) on every layer -> qualifies for long_500k decode via the
ring-buffer window cache.
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    source="arXiv:2401.16818",
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="dense", window=4096),),
                  repeat=24),),
    rope_kind="full",
    rope_theta=10_000.0,
    mlp_act="silu",
)
