"""yi-34b [dense] -- llama-architecture GQA. [arXiv:2403.04652]

60L d_model=7168 56H (GQA kv=8, head_dim 128) d_ff=20480 vocab=64000.
Pure full attention -> long_500k is skipped (see DESIGN.md).
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="yi-34b",
    arch_type="dense",
    source="arXiv:2403.04652",
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="dense"),), repeat=60),),
    rope_kind="full",
    rope_theta=5_000_000.0,
    mlp_act="silu",
)
