"""granite-moe-3b-a800m [moe] -- 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512, vocab=49155,
MoE 40e top-8 on every layer.
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="moe"),), repeat=32),),
    rope_kind="full",
    rope_theta=10_000.0,
    n_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    mlp_act="silu",
    tie_embeddings=True,
)
