"""deepseek-v3-671b [moe] -- MLA latent attention, 1 shared + 256 routed
experts top-8, dense prefix, MTP head. [arXiv:2412.19437]

61L d_model=7168 128H (MLA) per-expert d_ff=2048 vocab=129280.
First 3 layers dense (d_ff 18432 in the real model; the assignment pins
d_ff=2048 as the routed-expert width and we use the model card's 18432 for
the dense prefix/shared expert path scaled via moe conventions).
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    source="arXiv:2412.19437",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,                 # v head dim; qk dims below (MLA)
    d_ff=18432,                   # dense-prefix MLP width (model card)
    vocab_size=129280,
    stages=(
        Stage(unit=(BlockSpec(kind="mla", ffn="dense"),), repeat=3),
        Stage(unit=(BlockSpec(kind="mla", ffn="moe"),), repeat=58),
    ),
    rope_kind="full",
    rope_theta=10_000.0,
    # MLA geometry (model card)
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    # MoE: 256 routed top-8 + 1 shared, expert width 2048 (assignment)
    n_experts=256,
    n_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    mlp_act="silu",
    mtp_depth=1,                  # one MTP module (paper's D=1 deployment)
)
