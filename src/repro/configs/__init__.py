"""Config registry: 10 assigned architectures + the paper's own models."""
from __future__ import annotations

from .base import ArchConfig, BlockSpec, InputShape, Stage, INPUT_SHAPES

from .h2o_danube_3_4b import CONFIG as h2o_danube_3_4b
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .mamba2_1_3b import CONFIG as mamba2_1_3b
from .whisper_large_v3 import CONFIG as whisper_large_v3
from .jamba_1_5_large_398b import CONFIG as jamba_1_5_large_398b
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .phi_3_vision_4_2b import CONFIG as phi_3_vision_4_2b
from .gemma2_9b import CONFIG as gemma2_9b
from .yi_34b import CONFIG as yi_34b
from .chatglm3_6b import CONFIG as chatglm3_6b

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        h2o_danube_3_4b, deepseek_v3_671b, mamba2_1_3b, whisper_large_v3,
        jamba_1_5_large_398b, granite_moe_3b_a800m, phi_3_vision_4_2b,
        gemma2_9b, yi_34b, chatglm3_6b,
    ]
}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


__all__ = ["ArchConfig", "BlockSpec", "InputShape", "Stage", "INPUT_SHAPES",
           "ARCHS", "get_config"]
