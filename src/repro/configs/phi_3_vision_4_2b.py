"""phi-3-vision-4.2b [vlm] -- phi3-mini backbone + CLIP vision stub.
[hf:microsoft/Phi-3-vision-128k-instruct]

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.  The ViT/CLIP
encoder is a STUB per the carve-out: ``input_specs()`` feeds precomputed
patch embeddings (batch, 576, 1024); the trainable projector
(1024 -> d_model, LoRA-able) and the language backbone are real.
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="dense"),), repeat=32),),
    rope_kind="full",
    rope_theta=10_000.0,
    mlp_act="silu",
    frontend="vision_patches",
    frontend_dim=1024,
    n_prefix_tokens=576,
)
