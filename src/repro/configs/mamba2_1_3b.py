"""mamba2-1.3b [ssm] -- attention-free SSD (state-space duality).
[arXiv:2405.21060]

48L d_model=2048, d_inner=4096 (expand 2), heads=64 x head_dim 64,
ssm_state=128, vocab=50280.  No MLP blocks (d_ff=0): the Mamba2 block is
the whole layer.  Sub-quadratic -> runs long_500k decode.
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    stages=(Stage(unit=(BlockSpec(kind="mamba", ffn="none"),), repeat=48),),
    rope_kind="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
