"""whisper-large-v3 [audio] -- encoder-decoder transformer backbone.
[arXiv:2212.04356]

32 encoder + 32 decoder layers, d_model=1280 20H (MHA) d_ff=5120
vocab=51866.  The mel-spectrogram + conv frontend is a STUB per the
assignment carve-out: ``input_specs()`` feeds precomputed frame embeddings
(batch, 1500, 1280).  GELU fc1/fc2 MLPs, learned positions (modeled as
sinusoidal-free: rope none + absolute embedding).
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="whisper-large-v3",
    arch_type="audio",
    source="arXiv:2212.04356",
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="dense",
                                  cross_attn=True),), repeat=32),),
    encoder_stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="dense",
                                          causal=False),), repeat=32),),
    encoder_seq=1500,
    rope_kind="none",
    qkv_bias=True,
    mlp_act="gelu_plain",
    frontend="audio_frames",
    frontend_dim=1280,
    norm_eps=1e-5,
)
