"""jamba-1.5-large-398b [hybrid] -- Mamba+attention 1:7 interleave with MoE
every other layer. [arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576, MoE 16 experts top-2,
vocab=65536, ssm_state=128 (Mamba-1-style blocks in the real model; we use
the Mamba2/SSD block per the hardware-adaptation note in DESIGN.md --
chunked SSD matmuls map to the MXU, a sequential Mamba-1 selective scan
does not).  Unit of 8 layers: attention at index 4, MoE on odd indices.
Sub-quadratic majority -> runs long_500k decode.
"""
from .base import ArchConfig, BlockSpec, Stage

_M = lambda ffn: BlockSpec(kind="mamba", ffn=ffn)
_A = lambda ffn: BlockSpec(kind="gqa", ffn=ffn)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    stages=(Stage(unit=(_M("dense"), _M("moe"), _M("dense"), _M("moe"),
                        _A("dense"), _M("moe"), _M("dense"), _M("moe")),
                  repeat=9),),
    rope_kind="none",             # jamba uses no positional encoding
    n_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    mlp_act="silu",
)
