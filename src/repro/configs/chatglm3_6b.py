"""chatglm3-6b [dense] -- 2d (half-dim) RoPE, extreme GQA (kv=2), QKV bias.
[arXiv:2406.12793]

28L d_model=4096 32H (GQA kv=2, head_dim 128) d_ff=13696 vocab=65024.
Pure full attention -> long_500k skipped (see DESIGN.md).
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="chatglm3-6b",
    arch_type="dense",
    source="arXiv:2406.12793",
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="dense"),), repeat=28),),
    rope_kind="half",             # rotary on the first half of head_dim
    rope_theta=10_000.0,
    qkv_bias=True,
    mlp_act="silu",
)
