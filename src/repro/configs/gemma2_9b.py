"""gemma2-9b [dense] -- local/global alternating attention, logit
softcapping, pre+post block norms, GeGLU. [arXiv:2408.00118]

42L d_model=3584 16H (GQA kv=8, head_dim 256) d_ff=14336 vocab=256000.
Alternation unit: (local SWA-4096, global); 21 repeats.  Half the layers
are sliding-window -> long_500k decode runs (global layers keep a
seq-sharded KV cache).
"""
from .base import ArchConfig, BlockSpec, Stage

CONFIG = ArchConfig(
    name="gemma2-9b",
    arch_type="dense",
    source="arXiv:2408.00118",
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    stages=(Stage(unit=(BlockSpec(kind="gqa", ffn="dense", window=4096),
                        BlockSpec(kind="gqa", ffn="dense")),
                  repeat=21),),
    rope_kind="full",
    rope_theta=10_000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=256 ** -0.5,      # query_pre_attn_scalar = head_dim
    post_block_norm=True,
    mlp_act="gelu",               # GeGLU
    tie_embeddings=True,
)
