"""Architecture config schema + input-shape registry.

Every assigned architecture is expressed as an ``ArchConfig`` whose layer
stack is a sequence of *stages*; each stage is a repeating *unit* of block
specs that is ``jax.lax.scan``-ned over its repeats (keeps HLO small enough
to compile 61-72-layer models for 512 SPMD partitions on one CPU host).

Heterogeneous interleaves (Jamba's 1-attn:7-mamba, gemma2's local/global
alternation, deepseek's dense prefix) are expressed as multi-block units or
multi-stage stacks -- never unrolled python loops over all layers.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

AttnKind = Literal["gqa", "mla", "mamba"]
FFNKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class BlockSpec:
    """One layer inside a repeating unit."""
    kind: AttnKind = "gqa"          # token mixer
    ffn: FFNKind = "dense"
    window: int = 0                 # 0 = global attention, >0 = SWA width
    cross_attn: bool = False        # decoder block attending to encoder
    causal: bool = True


@dataclass(frozen=True)
class Stage:
    unit: tuple[BlockSpec, ...]
    repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.unit) * self.repeat


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                        # dense|moe|ssm|audio|hybrid|vlm
    source: str                           # paper / model-card citation
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    stages: tuple[Stage, ...]
    # encoder (enc-dec archs only)
    encoder_stages: tuple[Stage, ...] = ()
    encoder_seq: int = 0                  # native encoder length (whisper 1500)
    # attention details
    rope_kind: str = "full"               # full | half | none
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    query_scale: float | None = None      # None -> 1/sqrt(head_dim)
    qkv_bias: bool = False                # chatglm3 uses qkv bias
    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    router_scale: float = 1.0
    capacity_factor: float = 1.25
    moe_mode: str = "sort"                # sort | ep_a2a (perf variant)
    moe_pad_experts: int = 0              # physical padding for EP
                                          # divisibility (SSPerf B1)
    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # modality frontend stub
    frontend: str = "none"                # none | audio_frames | vision_patches
    frontend_dim: int = 0                 # raw embedding dim fed by the stub
    n_prefix_tokens: int = 0              # vision patches prepended
    # MLP
    mlp_act: str = "silu"                 # silu (SwiGLU) | gelu (GeGLU) |
                                          # gelu_plain (fc1/fc2, whisper)
    # norms
    post_block_norm: bool = False         # gemma2 post-norms
    norm_eps: float = 1e-6
    # heads / misc
    tie_embeddings: bool = False
    mtp_depth: int = 0                    # deepseek multi-token prediction
    dtype: str = "bfloat16"
    # LoRA
    lora_targets: str = "all_dense"
    lora_r_max: int = 64

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def is_encdec(self) -> bool:
        return bool(self.encoder_stages)

    @property
    def has_full_attention(self) -> bool:
        return any(b.kind != "mamba" and b.window == 0
                   for s in self.stages for b in s.unit)

    @property
    def subquadratic(self) -> bool:
        """True if decode state does not grow linearly-unbounded with
        context for the *majority* mixer type (SSM / SWA)."""
        blocks = [b for s in self.stages for b in s.unit]
        unbounded = [b for b in blocks if b.kind != "mamba" and b.window == 0]
        return len(unbounded) < len(blocks)

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        small_stages = tuple(
            Stage(unit=s.unit, repeat=1) for s in self.stages[:2]) or \
            self.stages
        # keep at most 2 blocks total
        trimmed = []
        total = 0
        for s in small_stages:
            unit = s.unit[: max(1, 2 - total)]
            total += len(unit)
            trimmed.append(Stage(unit=unit, repeat=1))
            if total >= 2:
                break
        d = min(self.d_model, 256)
        hd = 32
        nh = max(2, min(self.n_heads, 4))
        nkv = max(1, min(self.n_kv_heads, 2))
        kw = dict(
            d_model=d, n_heads=nh, n_kv_heads=nkv, head_dim=hd,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            stages=tuple(trimmed),
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            # no token dropping in smoke/consistency tests: capacity-based
            # MoE drops depend on co-batch size, which would make decode
            # vs full-forward comparisons diverge by construction
            capacity_factor=8.0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            kv_lora_rank=(min(self.kv_lora_rank, 32)
                          if self.kv_lora_rank else 0),
            qk_nope_dim=min(self.qk_nope_dim, 32) if self.qk_nope_dim else 0,
            qk_rope_dim=min(self.qk_rope_dim, 16) if self.qk_rope_dim else 0,
            v_head_dim=min(self.v_head_dim, 32) if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 16),
            ssm_chunk=32,
            encoder_stages=tuple(Stage(unit=s.unit, repeat=1)
                                 for s in self.encoder_stages[:1]),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim
            else 0,
            n_prefix_tokens=(min(self.n_prefix_tokens, 8)
                             if self.n_prefix_tokens else 0),
            lora_r_max=8,
            mtp_depth=min(self.mtp_depth, 1),
            dtype="float32",
        )
        kw.update(overrides)
        return replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
