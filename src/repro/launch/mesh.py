"""Production meshes (TPU v5e-256 pods) + host-count test meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes)
