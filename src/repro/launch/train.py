"""Production training launcher: mesh + sharded LoRA fine-tuning loop.

On a real TPU pod this runs under `python -m repro.launch.train --arch ...`
with the production mesh; on the CPU container use --preset reduced
(single device, reduced config) to exercise the identical code path.

The loop is the pod-side of FLaaS: one client cohort's local steps.  The
FL simulator (repro.fl) drives many such loops + RBLA aggregation.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save as ckpt_save
from repro.configs import get_config, INPUT_SHAPES
from repro.core.strategy import get_strategy, list_strategies
from repro.data import make_lm_dataset
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.lora import attach_ranks, strip_ranks
from repro.models.model import make_model
from repro.optim import adam, apply_updates
from repro.sharding import rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--preset", default="reduced",
                    choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--method", default="rbla",
                    help="server aggregation strategy for the cohort "
                         f"upload: one of {list_strategies()}")
    ap.add_argument("--agg-backend", default="auto",
                    choices=["auto", "ref", "pallas", "distributed"])
    args = ap.parse_args()
    strategy = get_strategy(args.method)   # fail fast on typos

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
        mesh = make_test_mesh((1, 1))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    model = make_model(cfg, remat=args.preset == "full")
    with mesh:
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = rules.param_specs(params_shapes, mesh)
        params = jax.jit(model.init,
                         out_shardings=rules.to_shardings(pspecs, mesh))(
            jax.random.PRNGKey(0))
        adapters = model.init_adapters(jax.random.PRNGKey(1),
                                       rank=args.rank)
        factors, ranks = strip_ranks(adapters)
        opt = adam(args.lr)
        opt_state = opt.init(factors)

        data = make_lm_dataset(cfg.vocab_size, args.seq + 1,
                               n_seqs=args.batch * 32, seed=42)

        @jax.jit
        def step(factors, opt_state, tokens):
            def loss_fn(f):
                return model.loss(params, attach_ranks(f, ranks),
                                  {"tokens": tokens})
            loss, grads = jax.value_and_grad(loss_fn)(factors)
            updates, opt_state = opt.update(grads, opt_state, factors)
            return apply_updates(factors, updates), opt_state, loss

        rng = np.random.default_rng(0)
        t0 = time.time()
        for i in range(args.steps):
            ix = rng.integers(0, len(data), args.batch)
            factors, opt_state, loss = step(factors, opt_state,
                                            jnp.asarray(data[ix]))
            if i % max(1, args.steps // 10) == 0:
                print(f"step {i:4d} loss {float(loss):.4f} "
                      f"({(time.time() - t0) / (i + 1):.2f}s/step)",
                      flush=True)
        # the pod-side round ends like the FLaaS server: the cohort's
        # adapter upload goes through the registered strategy (one cohort
        # here; the FL simulator drives many).
        # r_max=args.rank keeps the live rank (and the alpha/rank forward
        # scale) identical to the model that was just trained
        trained = attach_ranks(factors, ranks)
        try:
            global_adapters = strategy.aggregate_adapters(
                [trained], jnp.ones(1), r_max=args.rank,
                client_ranks=jnp.asarray([args.rank]),
                backend=args.agg_backend)
            print(f"aggregated cohort upload via strategy={strategy.name} "
                  f"backend={args.agg_backend}")
        except NotImplementedError as e:
            # e.g. svd on layer-stacked pairs: don't lose the run --
            # checkpoint the raw trained adapters instead
            print(f"WARNING: strategy={strategy.name} cannot aggregate "
                  f"this adapter structure ({e}); saving unaggregated "
                  "adapters")
            global_adapters = trained
        if args.ckpt:
            ckpt_save(args.ckpt, global_adapters)
            print(f"saved aggregated adapters to {args.ckpt}")


if __name__ == "__main__":
    main()
