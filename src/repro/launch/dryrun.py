import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run (deliverable e): lower + compile every
# (architecture x input shape) on the production meshes, record
# memory/cost/collective analysis for the roofline (deliverable g).
#
# The two lines above MUST precede any jax import: jax locks the device
# count at first init.  Do not move them.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, INPUT_SHAPES, get_config       # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.lora import attach_ranks, strip_ranks                # noqa: E402
from repro.models.model import make_model                       # noqa: E402
from repro.optim import adam, apply_updates                     # noqa: E402
from repro.roofline.analysis import (active_params,             # noqa: E402
                                     collective_bytes_from_hlo,
                                     model_flops_estimate, Roofline,
                                     scan_correction)
from repro.sharding import rules                                # noqa: E402

DEFAULT_OUT = "benchmarks/artifacts/dryrun"


# ------------------------------------------------------------- skip rules ---
def skip_reason(cfg, shape) -> str | None:
    if shape.kind == "decode" and shape.name == "long_500k" and \
            not cfg.subquadratic:
        return ("pure full-attention architecture: long_500k requires "
                "sub-quadratic attention (DESIGN.md long_500k rule)")
    return None


# -------------------------------------------------------------- input specs -
def input_specs(cfg, shape, mesh):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.float32)
    specs = rules.batch_specs(batch, mesh)
    return rules.shaped(batch, rules.to_shardings(specs, mesh))


def decode_input_specs(cfg, shape, mesh, model, seq_shard_model=False):
    b, s = shape.global_batch, shape.seq_len
    n_prefix = cfg.n_prefix_tokens if cfg.frontend == "vision_patches" else 0
    cache_shapes = jax.eval_shape(lambda: model.init_cache(b, s + n_prefix))
    cspecs = rules.cache_specs(cache_shapes, mesh, b,
                               seq_shard_model=seq_shard_model)
    caches = rules.shaped(cache_shapes, rules.to_shardings(cspecs, mesh))
    tok_spec = rules.batch_specs(
        {"t": jax.ShapeDtypeStruct((b,), jnp.int32)}, mesh)["t"]
    token = jax.ShapeDtypeStruct(
        (b,), jnp.int32,
        sharding=rules.to_shardings(tok_spec, mesh))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, token, pos


def model_state_specs(cfg, mesh, model, fsdp=False):
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = rules.param_specs(params_shapes, mesh, fsdp=fsdp)
    params = rules.shaped(params_shapes,
                          rules.to_shardings(pspecs, mesh))
    ad_shapes = jax.eval_shape(
        lambda k: model.init_adapters(k, rank=cfg.lora_r_max),
        jax.random.PRNGKey(1))
    aspecs = rules.adapter_specs(ad_shapes, mesh)
    adapters = rules.shaped(ad_shapes, rules.to_shardings(aspecs, mesh))
    return params, adapters, pspecs, aspecs


# ------------------------------------------------------------ step builders -
def build_train_step(model, cfg):
    opt = adam(1e-4)

    def train_step(params, adapters, opt_state, batch):
        factors, ranks = strip_ranks(adapters)

        def loss_fn(f):
            return model.loss(params, attach_ranks(f, ranks), batch)

        loss, grads = jax.value_and_grad(loss_fn)(factors)
        updates, opt_state = opt.update(grads, opt_state, factors)
        factors = apply_updates(factors, updates)
        return attach_ranks(factors, ranks), opt_state, loss

    return train_step, opt


def build_prefill_step(model):
    def prefill_step(params, adapters, batch):
        return model.prefill(params, adapters, batch)
    return prefill_step


def build_decode_step(model):
    def serve_step(params, adapters, caches, token, pos):
        return model.decode_step(params, adapters, caches, token, pos)
    return serve_step


# ------------------------------------------------------------------ runner --
def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            remat: bool = True, mla_absorbed: bool = False,
            fsdp: bool = False, tag: str = "",
            cfg_overrides: dict | None = None,
            seq_shard_model: bool = False) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2" if multi_pod else "pod1"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind, "remat": remat, "fsdp": fsdp,
                 "mla_absorbed": mla_absorbed, "tag": tag,
                 "cfg_overrides": cfg_overrides or {}}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["skipped"] = reason
        _write(out_dir, rec, tag)
        print(f"[skip] {arch} x {shape_name} x {mesh_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = make_model(cfg, remat=remat, mla_absorbed=mla_absorbed)
    rec["remat"] = str(remat)
    t0 = time.time()
    with mesh:
        params, adapters, _, _ = model_state_specs(cfg, mesh, model,
                                                   fsdp=fsdp)
        if shape.kind == "train":
            step, opt = build_train_step(model, cfg)
            factors, _ = strip_ranks_shapes(adapters)
            opt_state = jax.eval_shape(opt.init, factors)
            ospecs = rules.adapter_specs(opt_state, mesh)
            opt_state = rules.shaped(
                opt_state, rules.to_shardings(ospecs, mesh))
            batch = input_specs(cfg, shape, mesh)
            lowered = jax.jit(step).lower(params, adapters, opt_state,
                                          batch)
        elif shape.kind == "prefill":
            step = build_prefill_step(model)
            batch = input_specs(cfg, shape, mesh)
            lowered = jax.jit(step).lower(params, adapters, batch)
        else:  # decode
            step = build_decode_step(model)
            caches, token, pos = decode_input_specs(
                cfg, shape, mesh, model, seq_shard_model=seq_shard_model)
            lowered = jax.jit(step).lower(params, adapters, caches, token,
                                          pos)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    cost = compiled.cost_analysis() or {}
    rec["flops_per_device"] = float(cost.get("flops", 0.0))
    rec["bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis"] = {"error": str(e)}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    rec["collectives"] = coll

    n_active = active_params(cfg)
    mf = model_flops_estimate(cfg, shape, n_active, shape.kind)
    corr = scan_correction(cfg)
    rec["scan_correction"] = corr
    roof = Roofline(flops=rec["flops_per_device"] * corr,
                    hbm_bytes=rec["bytes_per_device"] * corr,
                    collective_bytes=float(sum(coll.values())) * corr,
                    chips=chips, model_flops=mf, collectives=coll)
    rec["roofline"] = roof.as_dict()
    _write(out_dir, rec, tag)
    print(f"[ok]   {arch} x {shape_name} x {mesh_name}"
          f" lower={rec['lower_s']}s compile={rec['compile_s']}s"
          f" dominant={roof.dominant}")
    return rec


def strip_ranks_shapes(adapters):
    """strip_ranks over ShapeDtypeStruct trees (no jnp ops involved)."""
    return strip_ranks(adapters)


def _write(out_dir, rec, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (also accepts comma list)")
    ap.add_argument("--shape", default="all",
                    help="input shape or 'all' (comma list ok)")
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2",
                                                       "both"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots"])
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard frozen base over data axes too")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--set", default="", dest="overrides",
                    help="cfg overrides, e.g. capacity_factor=1.0,"
                         "n_experts=48")
    args = ap.parse_args()
    overrides = {}
    for kv in filter(None, args.overrides.split(",")):
        k, v = kv.split("=")
        overrides[k] = (float(v) if "." in v else int(v)) \
            if v.replace(".", "").lstrip("-").isdigit() else v

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = (list(INPUT_SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                try:
                    run_one(arch, shape, multi_pod, args.out,
                            remat=(False if args.no_remat
                                   else args.remat_policy),
                            mla_absorbed=args.mla_absorbed,
                            fsdp=args.fsdp, tag=args.tag,
                            cfg_overrides=overrides or None,
                            seq_shard_model=args.cache_seq_shard)
                except Exception:
                    failures.append((arch, shape, multi_pod))
                    print(f"[FAIL] {arch} x {shape} x "
                          f"{'pod2' if multi_pod else 'pod1'}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run combos failed: "
                         f"{failures}")
    print("all dry-run combos compiled")


if __name__ == "__main__":
    main()
