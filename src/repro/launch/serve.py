"""Serving launcher: batched prefill + decode with sharded KV caches.

Identical code path to the decode dry-run; --preset reduced runs it live
on the container (single device), --preset full on a pod.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models.model import make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--preset", default="reduced",
                    choices=["reduced", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--rank", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
        mesh = make_test_mesh((1, 1))
    else:
        mesh = make_production_mesh()

    model = make_model(cfg, remat=False)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        adapters = model.init_adapters(jax.random.PRNGKey(1),
                                       rank=args.rank)
        rng = np.random.default_rng(0)
        total = args.prompt_len + args.new
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size,
                         (args.batch, args.prompt_len)), jnp.int32)}
        if cfg.is_encdec:
            batch["frames"] = jnp.asarray(rng.normal(
                size=(args.batch, cfg.encoder_seq, cfg.frontend_dim)),
                jnp.float32)
        n_prefix = 0
        if cfg.frontend == "vision_patches":
            batch["patches"] = jnp.asarray(rng.normal(
                size=(args.batch, cfg.n_prefix_tokens, cfg.frontend_dim)),
                jnp.float32)
            n_prefix = cfg.n_prefix_tokens

        t0 = time.time()
        logits, caches = jax.jit(
            lambda p, a, b: model.prefill(p, a, b,
                                          capacity=total + n_prefix)
        )(params, adapters, batch)
        print(f"prefill: {time.time() - t0:.2f}s")

        decode = jax.jit(model.decode_step)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t0 = time.time()
        for i in range(args.new - 1):
            pos = jnp.asarray(args.prompt_len + n_prefix + i, jnp.int32)
            logits, caches = decode(params, adapters, caches, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"decode: {args.new - 1} steps, "
              f"{(args.new - 1) * args.batch / max(dt, 1e-9):.1f} tok/s")


if __name__ == "__main__":
    main()
