from .lora import (DEFAULT_ALPHA, adapter_masks, attach_ranks, strip_ranks, apply_pair, count_params,
                   init_adapters, init_pair, is_pair, mask_adapters,
                   mask_pair, merge_pair, pair_masks, pair_scale, set_ranks,
                   tree_map_pairs)
from .policy import POLICIES, apply_policy, filter_specs

__all__ = [
    "DEFAULT_ALPHA", "adapter_masks", "apply_pair", "count_params",
    "init_adapters", "init_pair", "is_pair", "mask_adapters", "mask_pair",
    "merge_pair", "pair_masks", "pair_scale", "set_ranks", "tree_map_pairs",
    "POLICIES", "apply_policy", "filter_specs",
]
