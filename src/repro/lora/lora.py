"""LoRA adapter substrate with heterogeneous-rank support.

Adapters are plain pytrees so they flow through jit/pjit/psum unchanged:

    pair = {"A": (r_max, fan_in), "B": (fan_out, r_max), "rank": ()} int32

Storage is always padded to ``r_max`` (static shapes for XLA); the live rank
is a scalar leaf.  Rows of ``A`` / columns of ``B`` at index >= rank are
zero, and stay zero under SGD/Adam because the gradient of a padded row is
itself gated by the (zero) opposite factor -- we additionally re-mask after
every optimizer step for belt-and-braces numerical hygiene.

Scaling follows HetLoRA/the paper: effective update is
``(alpha / rank) * B @ A``, so clients with different ranks produce updates
of comparable magnitude.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core.masks import axis_mask, pad_to_rank, rank_mask

Array = jax.Array
PyTree = Any

DEFAULT_ALPHA = 16.0


def init_pair(key: Array, fan_out: int, fan_in: int, r_max: int,
              rank: int | Array, dtype=jnp.float32,
              init_scale: float = 0.01,
              leading: tuple[int, ...] = ()) -> dict:
    """A ~ N(0, init_scale) on live rows, B = 0 (standard LoRA init).

    ``leading`` adds stacked axes (scan-over-layers repeat, MoE expert
    axis): A ``(*leading, r_max, fan_in)``, B ``(*leading, fan_out,
    r_max)``, rank ``(leading[0],)`` if stacked over layers else scalar.
    """
    a = jax.random.normal(key, leading + (r_max, fan_in), dtype) * init_scale
    rank_arr = (jnp.full((leading[0],), rank, jnp.int32) if leading
                else jnp.asarray(rank, jnp.int32))
    pair = {
        "A": a,
        "B": jnp.zeros(leading + (fan_out, r_max), dtype),
        "rank": rank_arr,
    }
    return mask_pair(pair)


def is_pair(node: Any) -> bool:
    return (isinstance(node, Mapping) and "A" in node and "B" in node
            and "rank" in node)


def pair_scale(pair: Mapping, alpha: float = DEFAULT_ALPHA) -> Array:
    r = jnp.maximum(pair["rank"].astype(jnp.float32), 1.0)
    return alpha / r


def apply_pair(x: Array, pair: Mapping, alpha: float = DEFAULT_ALPHA) -> Array:
    """``(alpha/rank) * (x @ A^T) @ B^T`` -- the LoRA path of a dense layer.

    ``x``: (..., fan_in) -> (..., fan_out).  Padded rows are structurally
    zero so no masking is needed on the forward path.
    """
    ax = jnp.einsum("...i,ri->...r", x, pair["A"].astype(x.dtype))
    y = jnp.einsum("...r,or->...o", ax, pair["B"].astype(x.dtype))
    return y * pair_scale(pair, alpha).astype(x.dtype)


def merge_pair(w: Array, pair: Mapping, alpha: float = DEFAULT_ALPHA) -> Array:
    """Return ``W + (alpha/rank) B A`` (serving-time merged weights)."""
    delta = (pair["B"].astype(jnp.float32) @ pair["A"].astype(jnp.float32))
    return (w.astype(jnp.float32)
            + pair_scale(pair, alpha) * delta).astype(w.dtype)


def _rank_vec_mask(rank: Array, r_max: int, dtype=jnp.float32) -> Array:
    """(..., r_max) mask from scalar-or-vector rank."""
    rank = jnp.asarray(rank, jnp.int32)
    iota = jax.lax.iota(jnp.int32, r_max)
    return (iota < rank[..., None]).astype(dtype) if rank.ndim else \
        (iota < rank).astype(dtype)


def _pair_row_masks(pair: Mapping, dtype=jnp.float32):
    """Broadcastable masks for A (..., r_max, fan_in) / B (..., out, r_max).

    rank may be scalar or (leading,) for layer-stacked pairs; extra middle
    axes (e.g. MoE expert axis) broadcast via singleton dims.
    """
    A, B, rank = pair["A"], pair["B"], jnp.asarray(pair["rank"], jnp.int32)
    r_max = A.shape[-2]
    m = _rank_vec_mask(rank, r_max, dtype)        # rank.shape + (r_max,)
    ndim_mid_a = A.ndim - rank.ndim - 2
    ma = m.reshape(rank.shape + (1,) * ndim_mid_a + (r_max, 1))
    ndim_mid_b = B.ndim - rank.ndim - 2
    mb = m.reshape(rank.shape + (1,) * ndim_mid_b + (1, r_max))
    return ma, mb


def mask_pair(pair: Mapping) -> dict:
    """Re-zero padded rows/cols (post-optimizer hygiene)."""
    ma, mb = _pair_row_masks(pair, pair["A"].dtype)
    return {"A": pair["A"] * ma, "B": pair["B"] * mb, "rank": pair["rank"]}


def pair_masks(pair: Mapping) -> dict:
    """delta_{i,r} masks matching the pair's structure (for aggregation).

    ``rank`` itself is marked fully-shared (0-d ones) -- the server keeps
    r_max; clients re-slice per Alg. 2.
    """
    ma, mb = _pair_row_masks(pair)
    return {"A": ma, "B": mb, "rank": jnp.ones(())}


# ------------------------------------------------------------- tree ops ----
def tree_map_pairs(fn: Callable[[Mapping], Any], tree: PyTree) -> PyTree:
    """Map ``fn`` over every LoRA pair in a nested adapter tree."""
    if is_pair(tree):
        return fn(tree)
    if isinstance(tree, Mapping):
        return {k: tree_map_pairs(fn, v) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(tree_map_pairs(fn, v) for v in tree)
    return tree


def adapter_masks(adapters: PyTree) -> PyTree:
    """Mask tree (same structure) for ``repro.core.aggregate``."""
    return tree_map_pairs(pair_masks, adapters)


def mask_adapters(adapters: PyTree) -> PyTree:
    return tree_map_pairs(mask_pair, adapters)


def set_ranks(adapters: PyTree, rank: int | Array,
              r_storage: int | None = None) -> PyTree:
    """Client-side Alg. 2 under static shapes: set the live rank and
    re-mask (equivalent to slice + re-pad).

    ``r_storage`` re-cuts the *storage* rank: rows/cols beyond it are
    sliced off, smaller storage is zero-padded up.  This is how clients
    re-slice from a rank-growing global (e.g. flora keeps the server at
    ``stack_r_cap`` storage while clients train at ``r_max``) without
    changing their compiled shapes round to round.

    The result never aliases the input buffers: every returned array is
    freshly materialized (the re-mask multiply), so a client that
    mutates its local adapters in place (numpy-backed state, in-place
    optimizers) can never corrupt ``ServerState.adapters``.
    """
    if (r_storage is not None
            and not isinstance(rank, jax.core.Tracer)
            and int(jnp.max(jnp.asarray(rank))) > r_storage):
        raise ValueError(
            f"set_ranks: live rank {int(jnp.max(jnp.asarray(rank)))} "
            f"exceeds the target storage rank {r_storage}; the pair's "
            "rank leaf would claim rows that do not physically exist")

    def f(pair):
        A, B = jnp.asarray(pair["A"]), jnp.asarray(pair["B"])
        if r_storage is not None:
            cur = A.shape[-2]
            if cur >= r_storage:
                A = A[..., :r_storage, :]
                B = B[..., :r_storage]
            else:
                A = pad_to_rank(A, -2, r_storage)
                B = pad_to_rank(B, -1, r_storage)
        out = {"A": A, "B": B,
               "rank": jnp.full_like(jnp.asarray(pair["rank"], jnp.int32),
                                     rank)}
        # mask_pair multiplies by the rank mask, which also guarantees a
        # fresh buffer (copy, not alias, of the server's storage)
        return mask_pair(out)
    return tree_map_pairs(f, adapters)


def strip_ranks(adapters: PyTree) -> tuple[PyTree, PyTree]:
    """Split pairs into differentiable factors and int rank leaves.

    jax.grad rejects int32 inputs; ranks are data, not parameters, so the
    training loop carries them separately and reattaches via
    :func:`attach_ranks`.
    """
    factors = tree_map_pairs(lambda p: {"A": p["A"], "B": p["B"]}, adapters)
    ranks = tree_map_pairs(lambda p: p["rank"], adapters)
    return factors, ranks


def attach_ranks(factors: PyTree, ranks: PyTree) -> PyTree:
    if isinstance(factors, Mapping) and "A" in factors and "B" in factors:
        return {"A": factors["A"], "B": factors["B"], "rank": ranks}
    if isinstance(factors, (tuple, list)):
        return type(factors)(attach_ranks(f, r)
                             for f, r in zip(factors, ranks))
    return {k: attach_ranks(factors[k], ranks[k]) for k in factors}


def init_adapters(key: Array, specs: Mapping[str, tuple[int, int]],
                  r_max: int, rank: int | Array,
                  dtype=jnp.float32) -> PyTree:
    """Build an adapter tree from ``{path: (fan_out, fan_in)}`` specs."""
    keys = jax.random.split(key, max(len(specs), 1))
    return {path: init_pair(k, fo, fi, r_max, rank, dtype)
            for k, (path, (fo, fi)) in zip(keys, sorted(specs.items()))}


def count_params(adapters: PyTree) -> int:
    leaves = jax.tree.leaves(adapters)
    return sum(int(x.size) for x in leaves)
