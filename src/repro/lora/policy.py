"""Which weights get LoRA adapters (the paper: "dense layers only").

Models expose ``lora_specs()``: an ordered mapping ``path -> (fan_out,
fan_in)`` describing every LoRA-able 2-D projection.  Policies filter that
mapping; the FL layer and the big-model trainer both consume the filtered
specs, so changing the target set is one line of config.
"""
from __future__ import annotations

import re
from typing import Mapping


def filter_specs(specs: Mapping[str, tuple[int, int]],
                 include: str = ".*",
                 exclude: str | None = None) -> dict[str, tuple[int, int]]:
    inc = re.compile(include)
    exc = re.compile(exclude) if exclude else None
    out = {}
    for path, shape in specs.items():
        if inc.search(path) and not (exc and exc.search(path)):
            out[path] = shape
    return out


# Named policies used by configs.
POLICIES = {
    "all_dense": dict(include=r".*"),
    "attention_only": dict(include=r"(attn|attention)"),
    "mlp_only": dict(include=r"(mlp|ffn|fc)"),
    # paper experiments: LoRA on dense (fc) layers, conv/bias full-trained
    "paper_dense": dict(include=r"fc|dense|out"),
}


def apply_policy(specs: Mapping[str, tuple[int, int]],
                 policy: str = "all_dense") -> dict[str, tuple[int, int]]:
    return filter_specs(specs, **POLICIES[policy])
