"""Multi-tenant adapter serving: the FLaaS read path.

The aggregation side (``repro.core``/``repro.fl``) produces fresh global
adapters; this package consumes them at serving scale:

* :class:`AdapterStore` -- paged per-tenant (A, B) storage over
  (fan_out, fan_in, dtype) buckets, heterogeneous ranks packed as
  rank-row segments, per-tenant offset/rank/scale as runtime data.
* :class:`ServingEngine` -- one batched-kernel launch per layer applies
  every tenant's adapter to a mixed request batch; ``publish()``
  hot-swaps a freshly aggregated global with no recompile, versioned so
  in-flight batches finish on the snapshot they started with.

See ``docs/serving.md`` for the layout, the publish semantics, and the
kernel contract; ``benchmarks/bench_serve.py`` runs the whole
aggregate -> publish -> serve loop.
"""
from .engine import ServingEngine, merged_reference
from .store import AdapterStore, SegTable, StoreSnapshot

__all__ = ["AdapterStore", "SegTable", "StoreSnapshot", "ServingEngine",
           "merged_reference"]
