"""Multi-tenant LoRA serving engine: one executable, every tenant.

Pairs the frozen base weights with an :class:`~repro.serving.AdapterStore`
and runs the batched multi-adapter kernel
(:func:`repro.kernels.batched_lora_matmul`) over mixed request batches:
each request row carries an adapter id, the kernel resolves it against the
store's runtime segment tables, and one compiled launch per layer serves
every tenant mix without retracing.

Hot swap: :meth:`ServingEngine.publish` installs a freshly aggregated
global (a sync round's output or the live state of an
:class:`~repro.fl.AsyncAggregator`, via its ``on_publish`` hook) into the
store.  A batch runs against one pinned :class:`StoreSnapshot` end to
end, so publishes never tear a batch -- in-flight requests finish on the
version they started with, the next batch picks up the new one.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping, Sequence

import jax.numpy as jnp

from repro.kernels import batched_lora_matmul
from repro.obs import get_registry as _obs_registry
from repro.obs import span
from .store import AdapterStore, StoreSnapshot

_SERVE_REQUESTS = _obs_registry().counter(
    "serving_requests_total", "request rows served (per adapted layer)")
_SERVE_BATCHES = _obs_registry().counter(
    "serving_batches_total", "batched kernel launches (one per layer)")
_PUBLISH_FAILURES = _obs_registry().counter(
    "serving_publish_failures_total",
    "hot-swap publishes that raised (readers kept the last snapshot)")
_PUBLISH_QUARANTINED = _obs_registry().gauge(
    "serving_publish_quarantined",
    "1 while the publish path is backing off after failures")

PyTree = Any


class ServingEngine:
    """Serve ``y = x @ W_path + scale_t * (x @ A_t^T) @ B_t^T`` for mixed
    tenant batches.

    Parameters
    ----------
    weights
        ``{path: W}`` frozen base weights, ``W`` of shape
        ``(fan_in, fan_out)`` matching the store's spec for ``path``.
    store
        The live :class:`AdapterStore` (shared with the write path).
    impl, interpret
        Forwarded to :func:`~repro.kernels.batched_lora_matmul`:
        ``impl="auto"`` serves the fused Pallas kernel on TPU/GPU and the
        XLA segment lowering on CPU; one executable either way.
    """

    def __init__(self, weights: Mapping[str, Any], store: AdapterStore, *,
                 impl: str = "auto", interpret: bool | None = None):
        for path, w in weights.items():
            fo, fi = store.specs[path]
            if tuple(w.shape) != (fi, fo):
                raise ValueError(
                    f"{path}: base weight shape {tuple(w.shape)} does not "
                    f"match spec (fan_in={fi}, fan_out={fo})")
        missing = set(store.specs) - set(weights)
        if missing:
            raise ValueError(f"missing base weights for {sorted(missing)}")
        self.weights = dict(weights)
        self.store = store
        self.impl = impl
        self.interpret = interpret
        # publish-failure quarantine state (see :meth:`publisher`):
        # the newest adapter tree a failed hot-swap left unpublished,
        # how many consecutive attempts have failed, and how many more
        # publish opportunities to skip before the next retry
        self._publish_pending: PyTree | None = None
        self._publish_fail_streak = 0
        self._publish_skip = 0
        self.n_publish_failures = 0

    # ------------------------------------------------------------- read --
    def snapshot(self) -> StoreSnapshot:
        """Pin the current store version for an in-flight batch."""
        return self.store.snapshot()

    def apply(self, path: str, x, adapter_ids, *,
              snapshot: StoreSnapshot | None = None):
        """One adapted layer over a mixed batch: ``x`` (..., fan_in),
        ``adapter_ids`` int32 matching x's leading dims."""
        snap = self.snapshot() if snapshot is None else snapshot
        a_rows, b_rows = snap.pair_buffers(path)
        tbl = snap.table(path)
        y = batched_lora_matmul(
            x, self.weights[path], a_rows, b_rows, adapter_ids,
            tbl.off, tbl.rank, tbl.scale, impl=self.impl,
            interpret=self.interpret)
        n_rows = 1
        for d in x.shape[:-1]:
            n_rows *= int(d)
        _SERVE_REQUESTS.inc(n_rows)
        _SERVE_BATCHES.inc()
        return y

    def forward(self, x, adapter_ids, *,
                paths: Sequence[str] | None = None,
                snapshot: StoreSnapshot | None = None):
        """Chain adapted layers (fan_out of each must feed the next's
        fan_in) under ONE pinned snapshot -- the whole batch sees exactly
        one store version even if a publish lands mid-flight."""
        snap = self.snapshot() if snapshot is None else snapshot
        # one serve span per batch, blocking once at the boundary --
        # never between layers (that would serialize the chain)
        with span("serve") as sp:
            for path in (list(self.weights) if paths is None else paths):
                x = self.apply(path, x, adapter_ids, snapshot=snap)
            sp.block(x)
        return x

    # ------------------------------------------------------------ write --
    def publish(self, tree: PyTree) -> int:
        """Hot-swap a freshly aggregated global adapter tree into the
        store (see :meth:`AdapterStore.publish`); returns the version."""
        return self.store.publish(tree)

    def publisher(self, max_backoff: int = 8) -> Callable:
        """An ``on_publish`` hook for :class:`~repro.fl.AsyncAggregator`:
        called with each advanced :class:`~repro.core.ServerState`, swaps
        its adapters into the live store.

        **Degrades gracefully** when the store rejects a swap (a flaky
        backing volume, an injected :class:`~repro.fl.chaos.FaultPlan`
        fault): the failed tree is quarantined -- readers keep serving
        the last *committed* :class:`StoreSnapshot`, which a failed
        ``AdapterStore.publish`` never tears -- and the hook retries on a
        later publish opportunity with exponential backoff (skip 1, 2,
        4, ... up to ``max_backoff`` opportunities).  Each retry carries
        the **newest** pending state, not the one that failed: serving an
        old global after several folds would re-widen the very staleness
        gap aggregation just closed.  Failures count under
        ``serving_publish_failures_total``;
        ``serving_publish_quarantined`` is 1 while backing off.
        """
        if max_backoff < 1:
            raise ValueError(
                f"max_backoff must be >= 1, got {max_backoff}")

        def _publish(state) -> None:
            if state.adapters is not None:
                # latest-wins: a newer aggregate supersedes whatever a
                # failed attempt left in quarantine
                self._publish_pending = state.adapters
            if self._publish_pending is None:
                return
            if self._publish_skip > 0:
                self._publish_skip -= 1
                return
            try:
                self.publish(self._publish_pending)
            except Exception:
                self.n_publish_failures += 1
                self._publish_fail_streak += 1
                self._publish_skip = min(
                    2 ** (self._publish_fail_streak - 1), max_backoff)
                _PUBLISH_FAILURES.inc()
                _PUBLISH_QUARANTINED.set(1)
                return              # readers stay on the last snapshot
            self._publish_pending = None
            self._publish_fail_streak = 0
            self._publish_skip = 0
            _PUBLISH_QUARANTINED.set(0)
        return _publish


def merged_reference(engine: ServingEngine, path: str, x, adapter_ids, *,
                     snapshot: StoreSnapshot | None = None):
    """Per-request dense oracle for :meth:`ServingEngine.apply` (tests):
    materializes each request's adapter via the store read-back path."""
    import numpy as np

    snap = engine.snapshot() if snapshot is None else snapshot
    a_rows, b_rows = snap.pair_buffers(path)
    tbl = snap.table(path)
    ids = np.asarray(adapter_ids).reshape(-1)
    x2 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
    w = np.asarray(engine.weights[path], np.float32)
    off = np.asarray(tbl.off)
    rank = np.asarray(tbl.rank)
    scale = np.asarray(tbl.scale)
    a_np = np.asarray(a_rows, np.float32)
    b_np = np.asarray(b_rows, np.float32)
    out = np.empty((x2.shape[0], w.shape[1]), np.float32)
    for i, t in enumerate(ids):
        seg = slice(off[t], off[t] + rank[t])
        out[i] = x2[i] @ w + scale[t] * ((x2[i] @ a_np[seg].T) @ b_np[seg])
    return jnp.asarray(out.reshape(x.shape[:-1] + (w.shape[1],)))


__all__ = ["ServingEngine", "merged_reference"]
