"""Paged multi-tenant adapter store (the FLaaS serving read path).

A FLaaS server coordinates many tenants whose LoRA adapters share a base
model but differ in **rank**.  Serving them from one executable requires
all tenants' (A, B) factors to live in a layout where "which adapter, at
which rank" is *runtime data*, never a compiled shape.  The
:class:`AdapterStore` provides that layout:

* **Buckets.**  Pairs bucket by **(fan_out, fan_in, dtype)** -- the pair
  geometry, the same keying ``repro.core.plan``'s svd lowering uses (the
  mean-path buckets key on row width alone, but the serving contraction
  must keep row p of the A buffer and row p of the B buffer as the same
  rank-one component, so both sides of a pair always share one
  allocation).  Every bucket owns two row-major buffers: ``a_rows``
  ``(R, fan_in)`` and ``b_rows`` ``(R, fan_out)`` -- B transposed so the
  packed rank axis leads both, exactly the plan-bucket row convention
  (:func:`repro.core.plan.pair_side_rows`).

* **Pages.**  Buffer rows are allocated in fixed pages of ``r_max`` rows
  from a free list; one (path, tenant) segment is one page, so segments
  are always contiguous, allocation/free is O(1), and a tenant's offset
  never moves while registered.  A tenant of rank r < r_max uses the
  first r rows of its page (the rest stay zero).  Buffer capacity grows
  by doubling when the free list empties -- the ONLY event that changes
  a compiled shape (and therefore retraces serving); tenant churn,
  rank mix, and publishes never do.

* **Runtime tables.**  Per path, three dense per-tenant-slot device
  arrays -- ``off`` (row offset), ``rank`` (live segment length),
  ``scale`` (alpha / rank) -- indexed by the adapter ids a request batch
  carries.  Slot 0 is reserved as the **null adapter** (rank 0): requests
  carrying id 0 (or any evicted slot) get the pure base matmul.

* **Snapshots & hot swap.**  Readers never touch the store directly:
  :meth:`snapshot` returns an immutable :class:`StoreSnapshot` (buffers +
  tables + version) and every write -- :meth:`put`, :meth:`publish`,
  :meth:`remove` -- installs a *new* snapshot under a bumped version.
  In-flight batches pinning the old snapshot finish on exactly the bytes
  they started with.  Writes go through one fused scatter per touched
  buffer side and **donate** the old buffer into it whenever no live
  handed-out snapshot still references it (the steady-state publish
  path: in-place bucket update, no copy, no recompile).
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import pair_side_rows
from repro.lora.lora import DEFAULT_ALPHA, is_pair
from repro.obs import get_registry as _obs_registry

_STORE_VERSION = _obs_registry().gauge(
    "serving_store_version", "current adapter-store version")
_STORE_PAGES = _obs_registry().gauge(
    "serving_store_pages", "bucket page capacity", labelnames=("bucket",))
_STORE_PAGES_USED = _obs_registry().gauge(
    "serving_store_pages_used", "bucket pages allocated to tenants",
    labelnames=("bucket",))
_STORE_PINNED = _obs_registry().gauge(
    "serving_pinned_snapshots",
    "handed-out store snapshots still alive (pinning their buffers)")
_STORE_PUBLISHES = _obs_registry().counter(
    "serving_publishes_total", "global hot-swaps installed into the store")

PyTree = Any

#: destination-row sentinel values for the fused scatter (see
#: :func:`_scatter_rows`): >= 0 gathers that source row, KEEP leaves the
#: old value, ZERO clears the row (a segment shrinking under publish).
_KEEP = -1
_ZERO = -2


def _scatter_rows(old, src, idx):
    """One fused segment write: ``out[d] = src[idx[d]]`` where
    ``idx[d] >= 0``, ``0`` where ``idx[d] == _ZERO``, else ``old[d]``.
    ``idx`` is runtime data -- one executable per (R, S, width) shape."""
    gathered = src[jnp.clip(idx, 0)]
    keep = (idx == _KEEP)[:, None]
    zero = (idx == _ZERO)[:, None]
    return jnp.where(keep, old, jnp.where(zero, 0.0, gathered))


_scatter_jit = jax.jit(_scatter_rows)
_scatter_donate = jax.jit(_scatter_rows, donate_argnums=(0,))


def _grow_rows(old, rows: int):
    # capacity growth: a new, larger buffer (donation cannot alias
    # across shapes); the ONLY serving-shape change in the store
    return jnp.pad(old, ((0, rows - old.shape[0]), (0, 0)))


@dataclasses.dataclass(frozen=True)
class SegTable:
    """Per-path tenant-slot tables (device arrays, indexed by adapter id)."""
    off: jax.Array            # (T_cap,) int32 row offset into the bucket
    rank: jax.Array           # (T_cap,) int32 live segment length
    scale: jax.Array          # (T_cap,) f32 LoRA scale (alpha / rank)


@dataclasses.dataclass(frozen=True, eq=False)
class StoreSnapshot:
    """Immutable view of the store at one version.

    Everything :func:`repro.kernels.batched_lora_matmul` needs: per-bucket
    packed factor buffers and per-path segment tables.  Holding a
    snapshot guarantees its buffers are never donated away -- an
    in-flight batch sees exactly this version regardless of concurrent
    publishes.
    """
    version: int
    buffers: Mapping[tuple, tuple]       # bucket key -> (a_rows, b_rows)
    tables: Mapping[str, SegTable]
    bucket_of: Mapping[str, tuple]       # path -> bucket key

    def pair_buffers(self, path: str):
        a_rows, b_rows = self.buffers[self.bucket_of[path]]
        return a_rows, b_rows

    def table(self, path: str) -> SegTable:
        return self.tables[path]


class _Bucket:
    """Host-side bookkeeping for one (fan_out, fan_in, dtype) bucket."""

    def __init__(self, key, page_rows: int, n_pages: int):
        self.key = key
        self.page_rows = page_rows
        self.n_pages = n_pages
        self.free = list(range(n_pages - 1, -1, -1))
        fan_out, fan_in, dtype = key
        self.a_rows = jnp.zeros((n_pages * page_rows, fan_in), dtype)
        self.b_rows = jnp.zeros((n_pages * page_rows, fan_out), dtype)

    def alloc_page(self) -> int:
        if not self.free:
            new_pages = self.n_pages * 2
            rows = new_pages * self.page_rows
            self.a_rows = _grow_rows(self.a_rows, rows)
            self.b_rows = _grow_rows(self.b_rows, rows)
            self.free = list(range(new_pages - 1, self.n_pages - 1, -1))
            self.n_pages = new_pages
        return self.free.pop()

    def free_page(self, page: int) -> None:
        self.free.append(page)


class AdapterStore:
    """Paged per-tenant (A, B) store over (fan_out, fan_in, dtype) buckets.

    Parameters
    ----------
    specs
        ``{path: (fan_out, fan_in)}`` -- the LoRA-adapted layers served.
        Paths sharing a geometry share a bucket.
    r_max
        Page size in rank rows: the largest rank any tenant may register.
    dtype
        Factor buffer dtype (all buckets).
    alpha
        Default LoRA alpha; a tenant's serve scale is ``alpha / rank``
        unless overridden per :meth:`register` / :meth:`put`.
    init_pages, init_tenant_capacity
        Initial bucket pages per path-geometry and tenant-slot table
        size; both grow by doubling (each growth changes a compiled
        shape, so size them for the expected fleet to avoid retraces).
    """

    def __init__(self, specs: Mapping[str, tuple], *, r_max: int,
                 dtype=jnp.float32, alpha: float = DEFAULT_ALPHA,
                 init_pages: int = 8, init_tenant_capacity: int = 8):
        if r_max < 1:
            raise ValueError(f"r_max must be >= 1, got {r_max}")
        self.specs = {p: (int(fo), int(fi))
                      for p, (fo, fi) in specs.items()}
        self.r_max = int(r_max)
        self.dtype = jnp.dtype(dtype)
        self.alpha = float(alpha)
        self._buckets: dict[tuple, _Bucket] = {}
        self._bucket_of: dict[str, tuple] = {}
        for path, (fo, fi) in self.specs.items():
            key = (fo, fi, str(self.dtype))
            self._bucket_of[path] = key
            if key not in self._buckets:
                self._buckets[key] = _Bucket(key, self.r_max,
                                             max(int(init_pages), 1))
        # tenant registry: slot 0 is the reserved null adapter (rank 0)
        self._t_cap = max(int(init_tenant_capacity), 2)
        self._slot_of: dict[Any, int] = {}
        self._free_slots = list(range(self._t_cap - 1, 0, -1))
        self._page_of: dict[tuple, int] = {}       # (path, slot) -> page
        self._off = {p: np.zeros(self._t_cap, np.int32) for p in specs}
        self._rank = {p: np.zeros(self._t_cap, np.int32) for p in specs}
        self._scale = {p: np.zeros(self._t_cap, np.float32)
                       for p in specs}
        self._version = 0
        self._snapshot: StoreSnapshot | None = None
        self._live: "weakref.WeakSet[StoreSnapshot]" = weakref.WeakSet()
        self._rebuild_snapshot()

    # ----------------------------------------------------------- reading --
    @property
    def version(self) -> int:
        return self._version

    @property
    def n_tenants(self) -> int:
        return len(self._slot_of)

    @property
    def pinned_snapshots(self) -> int:
        """Handed-out :class:`StoreSnapshot` objects still alive.  While
        any exist, writes to their buffers copy instead of donating."""
        return len(self._live)

    def occupancy(self) -> dict:
        """Per-bucket page occupancy: ``{bucket label: {"pages",
        "pages_used", "page_rows"}}`` -- the point-in-time view
        :class:`~repro.obs.ServiceHealth` reports (the same numbers feed
        the ``serving_store_pages*`` gauges on every version bump)."""
        out = {}
        for key, b in self._buckets.items():
            out[self._bucket_label(key)] = {
                "pages": b.n_pages,
                "pages_used": b.n_pages - len(b.free),
                "page_rows": b.page_rows,
            }
        return out

    @staticmethod
    def _bucket_label(key) -> str:
        fo, fi, dtype = key
        return f"{fo}x{fi}:{dtype}"

    def tenants(self):
        return list(self._slot_of)

    def slot(self, tenant) -> int:
        """The dense adapter id requests for ``tenant`` must carry."""
        return self._slot_of[tenant]

    def snapshot(self) -> StoreSnapshot:
        """The current immutable view; pin it for the life of a batch.

        Each call hands out a fresh (shallow) snapshot object sharing the
        version's buffers: its *lifetime* is what marks those buffers as
        pinned, so writes copy instead of donating while any handed-out
        snapshot of the current version is still alive."""
        snap = dataclasses.replace(self._snapshot)
        self._live.add(snap)
        _STORE_PINNED.set(len(self._live))
        return snap

    def _rebuild_snapshot(self) -> None:
        buffers = {k: (b.a_rows, b.b_rows)
                   for k, b in self._buckets.items()}
        tables = {p: SegTable(off=jnp.asarray(self._off[p]),
                              rank=jnp.asarray(self._rank[p]),
                              scale=jnp.asarray(self._scale[p]))
                  for p in self.specs}
        self._snapshot = StoreSnapshot(
            version=self._version, buffers=buffers, tables=tables,
            bucket_of=dict(self._bucket_of))

    def _bump(self) -> None:
        self._version += 1
        self._rebuild_snapshot()
        _STORE_VERSION.set(self._version)
        _STORE_PINNED.set(len(self._live))
        for key, b in self._buckets.items():
            label = self._bucket_label(key)
            _STORE_PAGES.labels(bucket=label).set(b.n_pages)
            _STORE_PAGES_USED.labels(bucket=label).set(
                b.n_pages - len(b.free))

    def _pinned_ids(self) -> set:
        """Identities of every buffer some live handed-out snapshot still
        references.  Donating one of these would tear the snapshot out
        from under an in-flight batch; anything else may be updated in
        place.  (Table bumps share buffers across versions, so pinning is
        by buffer identity, not version.)"""
        return {id(arr) for s in self._live
                for pair in s.buffers.values() for arr in pair}

    # ------------------------------------------------------- registration --
    def register(self, tenant, *, rank: int,
                 scale: float | None = None) -> int:
        """Allocate ``tenant`` a slot and one zeroed page per path at
        ``rank``; returns the adapter id.  Rows fill on the next
        :meth:`put` / :meth:`publish`."""
        if not 0 < rank <= self.r_max:
            raise ValueError(
                f"tenant rank must be in [1, r_max={self.r_max}], "
                f"got {rank}")
        if tenant in self._slot_of:
            slot = self._slot_of[tenant]
        else:
            slot = self._alloc_slot(tenant)
        for path in self.specs:
            key = (path, slot)
            if key not in self._page_of:
                bucket = self._buckets[self._bucket_of[path]]
                self._page_of[key] = bucket.alloc_page()
            self._off[path][slot] = (self._page_of[key]
                                     * self._buckets[
                                         self._bucket_of[path]].page_rows)
            self._rank[path][slot] = rank
            self._scale[path][slot] = (self.alpha / max(rank, 1)
                                       if scale is None else scale)
        self._bump()
        return slot

    def _alloc_slot(self, tenant) -> int:
        if not self._free_slots:
            new_cap = self._t_cap * 2
            for p in self.specs:
                self._off[p] = np.pad(self._off[p],
                                      (0, new_cap - self._t_cap))
                self._rank[p] = np.pad(self._rank[p],
                                       (0, new_cap - self._t_cap))
                self._scale[p] = np.pad(self._scale[p],
                                        (0, new_cap - self._t_cap))
            self._free_slots = list(range(new_cap - 1,
                                          self._t_cap - 1, -1))
            self._t_cap = new_cap
        slot = self._free_slots.pop()
        self._slot_of[tenant] = slot
        return slot

    def remove(self, tenant) -> None:
        """Evict a tenant: free its pages and slot.  Requests still
        carrying the stale id serve the base model (rank 0)."""
        slot = self._slot_of.pop(tenant)
        for path in self.specs:
            page = self._page_of.pop((path, slot), None)
            if page is not None:
                self._buckets[self._bucket_of[path]].free_page(page)
            self._off[path][slot] = 0
            self._rank[path][slot] = 0
            self._scale[path][slot] = 0.0
        self._free_slots.append(slot)
        self._bump()

    # -------------------------------------------------------------- writes --
    def _write(self, writes: dict) -> None:
        """Apply ``{bucket key: {'a'|'b': (src_rows, idx)}}`` -- one fused
        scatter per touched buffer side, donating the old buffer when no
        live snapshot pins it."""
        pinned = self._pinned_ids()
        for key, sides in writes.items():
            bucket = self._buckets[key]
            for side, (src, idx) in sides.items():
                old = bucket.a_rows if side == "a" else bucket.b_rows
                scatter = (_scatter_jit if id(old) in pinned
                           else _scatter_donate)
                new = scatter(old, src, jnp.asarray(idx))
                if side == "a":
                    bucket.a_rows = new
                else:
                    bucket.b_rows = new
        self._bump()

    def _pair_rows(self, path: str, pair: Mapping):
        """A pair's rank-leading packed rows, checked against the spec."""
        fo, fi = self.specs[path]
        A, B = jnp.asarray(pair["A"]), jnp.asarray(pair["B"])
        if A.ndim != 2 or B.ndim != 2:
            raise ValueError(
                f"serving packs 2-D pairs; {path} has A{A.shape} "
                f"B{B.shape} (flatten layer-stacked pairs into one path "
                "per layer)")
        if A.shape[1] != fi or B.shape[0] != fo:
            raise ValueError(
                f"{path}: pair A{A.shape}/B{B.shape} does not match "
                f"spec (fan_out={fo}, fan_in={fi})")
        rank = int(np.asarray(pair["rank"]))
        a_rows = pair_side_rows(A, "A").astype(self.dtype)
        b_rows = pair_side_rows(B, "B").astype(self.dtype)
        return a_rows, b_rows, rank

    def put(self, tenant, adapters: PyTree, *,
            scale: float | None = None) -> int:
        """Install (or replace) one tenant's personalized adapters.

        ``adapters``: ``{path: pair}`` covering every spec path.  The
        tenant's rank/scale tables follow the pairs' rank leaves; returns
        the adapter id.
        """
        pairs = {p: adapters[p] for p in self.specs}
        for p, pair in pairs.items():
            if not is_pair(pair):
                raise ValueError(f"{p}: not a LoRA pair")
        ranks = {p: int(np.asarray(pair["rank"]))
                 for p, pair in pairs.items()}
        slot = self.register(tenant, rank=max(max(ranks.values()), 1),
                             scale=scale)
        writes: dict = {}
        for path, pair in pairs.items():
            a_rows, b_rows, rank = self._pair_rows(path, pair)
            self._rank[path][slot] = rank
            self._scale[path][slot] = (self.alpha / max(rank, 1)
                                       if scale is None else scale)
            bucket = self._buckets[self._bucket_of[path]]
            off = int(self._off[path][slot])
            sides = writes.setdefault(bucket.key,
                                      {"a": ([], []), "b": ([], [])})
            for side, rows in (("a", a_rows), ("b", b_rows)):
                sides[side][0].append(rows[:rank])
                sides[side][1].append((off, rank))
        self._write(self._assemble(writes))
        return slot

    def _assemble(self, writes: dict) -> dict:
        """Concatenate per-bucket source rows and build the full-buffer
        scatter index (host-side, O(bucket rows) int32)."""
        out: dict = {}
        for key, sides in writes.items():
            bucket = self._buckets[key]
            out[key] = {}
            for side, (srcs, segs) in sides.items():
                idx = np.full(bucket.n_pages * bucket.page_rows, _KEEP,
                              np.int32)
                src_off = 0
                for rows, (off, cnt) in zip(srcs, segs):
                    idx[off:off + cnt] = np.arange(
                        src_off, src_off + cnt, dtype=np.int32)
                    # clear the rest of the page: stale rows from a
                    # higher-rank past must not survive the new segment
                    idx[off + cnt:off + bucket.page_rows] = _ZERO
                    src_off += cnt
                src = (jnp.concatenate(srcs, axis=0) if srcs
                       else jnp.zeros((1, bucket.a_rows.shape[1]
                                       if side == "a"
                                       else bucket.b_rows.shape[1]),
                                      self.dtype))
                if src.shape[0] == 0:
                    src = jnp.zeros((1, src.shape[1]), self.dtype)
                out[key][side] = (src, idx)
        return out

    def publish(self, tree: PyTree) -> int:
        """Hot-swap a freshly aggregated global into every tenant segment.

        ``tree``: ``{path: pair}`` -- the server's global adapter tree
        (e.g. ``ServerState.adapters``).  Every registered tenant's
        segment for each path is rewritten with the global's first
        ``min(tenant_rank, global_rank)`` rank rows (the paper's Alg. 2
        re-slice, materialized server-side); rows past the global rank
        are zeroed.  One fused scatter per bucket side, donated in place
        when no in-flight snapshot pins the buffer; returns the new
        version.  Never changes a compiled shape.
        """
        writes: dict = {}
        for path in self.specs:
            pair = tree[path]
            a_rows, b_rows, g_rank = self._pair_rows(path, pair)
            bucket = self._buckets[self._bucket_of[path]]
            sides = writes.setdefault(bucket.key,
                                      {"a": ([], []), "b": ([], [])})
            for slot in self._slot_of.values():
                t_rank = int(self._rank[path][slot])
                cnt = min(t_rank, g_rank)
                off = int(self._off[path][slot])
                for side, rows in (("a", a_rows), ("b", b_rows)):
                    sides[side][0].append(rows[:cnt])
                    sides[side][1].append((off, cnt))
        self._write(self._assemble(writes))
        _STORE_PUBLISHES.inc()
        return self._version

    # ------------------------------------------------------------ readback --
    def get(self, tenant) -> PyTree:
        """Read a tenant's pairs back out (tests / debugging; copies)."""
        slot = self._slot_of[tenant]
        snap = self.snapshot()
        out = {}
        for path, (fo, fi) in self.specs.items():
            a_rows, b_rows = snap.pair_buffers(path)
            off = int(self._off[path][slot])
            r = int(self._rank[path][slot])
            page = np.zeros((self.r_max, fi), self.dtype)
            page_b = np.zeros((self.r_max, fo), self.dtype)
            page[:r] = np.asarray(a_rows[off:off + r])
            page_b[:r] = np.asarray(b_rows[off:off + r])
            out[path] = {"A": jnp.asarray(page),
                         "B": pair_side_rows(jnp.asarray(page_b), "B"),
                         "rank": jnp.asarray(r, jnp.int32)}
        return out


__all__ = ["AdapterStore", "StoreSnapshot", "SegTable"]
