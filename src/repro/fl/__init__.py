from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from .async_agg import (AsyncAggregator, STALENESS_SCHEDULES,
                        make_staleness_fn)
from .chaos import FaultPlan
from .client import (LocalFitResult, make_local_fit, merge_base_params,
                     softmax_xent, split_base_params)
from .comm import BufferedUpdate, DedupWindow, RetryPolicy, UpdateBuffer
from .durability import DurableAggregator, WriteAheadLog
from .selection import ClientLatencyModel, select_clients
from .server import aggregate_adapters, aggregate_base, stack_trees
from .simulator import (AsyncFLConfig, FLConfig, FLHistory,
                        run_async_simulation, run_simulation)

__all__ = ["LocalFitResult", "make_local_fit", "merge_base_params",
           "softmax_xent", "split_base_params", "select_clients",
           "aggregate_adapters", "aggregate_base", "stack_trees",
           "FLConfig", "FLHistory", "run_simulation", "ClientUpdate",
           "ServerState", "get_strategy", "AsyncAggregator",
           "STALENESS_SCHEDULES", "make_staleness_fn", "AsyncFLConfig",
           "run_async_simulation", "ClientLatencyModel", "UpdateBuffer",
           "BufferedUpdate", "DedupWindow", "RetryPolicy",
           "DurableAggregator", "WriteAheadLog", "FaultPlan"]
