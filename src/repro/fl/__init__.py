from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from .client import (LocalFitResult, make_local_fit, merge_base_params,
                     softmax_xent, split_base_params)
from .selection import select_clients
from .server import aggregate_adapters, aggregate_base, stack_trees
from .simulator import FLConfig, FLHistory, run_simulation

__all__ = ["LocalFitResult", "make_local_fit", "merge_base_params",
           "softmax_xent", "split_base_params", "select_clients",
           "aggregate_adapters", "aggregate_base", "stack_trees",
           "FLConfig", "FLHistory", "run_simulation", "ClientUpdate",
           "ServerState", "get_strategy"]
