"""Communication-cost accounting for FL rounds (paper motivation: LoRA
cuts per-round bytes; RBLA keeps that benefit while fixing aggregation).

Counts the bytes a client uploads per round (and the server broadcast),
per aggregation method:

* lora methods (rbla / zeropad / variants): the padded adapter tree --
  but a client of rank r only needs to ship its live rows, so the honest
  per-client cost is the rank-sliced adapter (+ the non-LoRA trainables);
  we report both padded and sliced numbers.
* fft: the full parameter tree.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import numpy as np

from repro.lora import is_pair, tree_map_pairs

PyTree = Any


def _leaf_bytes(x) -> int:
    return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize


def tree_bytes(tree: PyTree) -> int:
    return sum(_leaf_bytes(x) for x in jax.tree.leaves(tree))


def adapter_upload_bytes(adapters: PyTree, rank: int | None = None) -> int:
    """Bytes a client ships for its adapters.

    ``rank=None``: padded r_max layout (what zero-padding FLaaS ships).
    ``rank=r``: rank-sliced (what a rank-r client actually needs to send
    under RBLA -- the server re-pads; Alg. 2 slicing in reverse).
    """
    total = 0

    def per_pair(pair):
        nonlocal total
        a, b = pair["A"], pair["B"]
        r_max = a.shape[-2]
        r = r_max if rank is None else min(rank, r_max)
        frac = r / r_max
        total += int(_leaf_bytes(a) * frac) + int(_leaf_bytes(b) * frac)
        total += _leaf_bytes(pair["rank"])
        return pair

    tree_map_pairs(per_pair, adapters)
    return total


def round_cost_report(params: PyTree, adapters: PyTree,
                      base_trainable: PyTree,
                      client_ranks) -> dict:
    """Per-round communication summary across methods."""
    full = tree_bytes(params)
    base_tr = tree_bytes(base_trainable)
    padded = adapter_upload_bytes(adapters)
    sliced = [adapter_upload_bytes(adapters, int(r)) for r in client_ranks]
    return {
        "fft_upload_bytes_per_client": full,
        "lora_padded_upload_bytes": padded + base_tr,
        "lora_sliced_upload_bytes_mean": int(np.mean(sliced)) + base_tr,
        "lora_sliced_upload_bytes": [s + base_tr for s in sliced],
        "broadcast_bytes": padded + base_tr,
        "reduction_vs_fft": full / max(int(np.mean(sliced)) + base_tr, 1),
    }
