"""Communication layer: per-round cost accounting and the async server's
upload buffer.

Cost accounting (paper motivation: LoRA cuts per-round bytes; RBLA keeps
that benefit while fixing aggregation) counts the bytes a client uploads
per round (and the server broadcast), per aggregation method:

* lora methods (rbla / zeropad / variants): the padded adapter tree --
  but a client of rank r only needs to ship its live rows, so the honest
  per-client cost is the rank-sliced adapter (+ the non-LoRA trainables);
  we report both padded and sliced numbers.
* fft: the full parameter tree.

:class:`UpdateBuffer` is the buffered semi-async server's intake queue:
uploads accumulate and flush as one mini-cohort on size K or deadline
(see ``repro.fl.async_agg`` / ``docs/async.md``).  The buffer itself
stays metrics-free; its owning :class:`~repro.fl.AsyncAggregator`
exports the live depth (``fl_buffer_depth``), per-upload staleness
(``fl_staleness``) and wire bytes (``fl_wire_bytes_received_total``)
through :mod:`repro.obs` -- see ``docs/observability.md``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Iterable, Mapping

import jax
import numpy as np

from repro.lora import is_pair, tree_map_pairs

PyTree = Any


# ------------------------------------------------- idempotent ingestion --
class DedupWindow:
    """Sliding window of recently seen client ``update_id`` strings.

    At-least-once delivery (client retries, WAL replay after a crash)
    means the server can receive the same logical upload twice; folding
    it twice double-counts its mass.  The window remembers the last
    ``size`` *accepted* ids so a redelivery inside the window is
    recognized and folded exactly once.  A duplicate arriving after its
    id has been evicted is indistinguishable from a new upload -- size
    the window to cover the longest plausible retry horizon (ids are
    small strings; 10k ids is a few hundred KB).

    The window is part of the durable service snapshot
    (:mod:`repro.fl.durability`): recovery restores it so WAL records
    replayed over a checkpoint that already contains them cannot
    double-fold.
    """

    def __init__(self, size: int = 1024):
        if size < 1:
            raise ValueError(f"dedup window size must be >= 1, got {size}")
        self.size = int(size)
        self._seen: OrderedDict[str, None] = OrderedDict()

    def __len__(self) -> int:
        return len(self._seen)

    def __contains__(self, update_id: str) -> bool:
        return str(update_id) in self._seen

    def add(self, update_id: str) -> None:
        """Mark one id seen (moves it to most-recent on re-add)."""
        uid = str(update_id)
        self._seen.pop(uid, None)
        self._seen[uid] = None
        while len(self._seen) > self.size:
            self._seen.popitem(last=False)

    def state_dict(self) -> list:
        """Oldest-first id list for the durable snapshot."""
        return list(self._seen)

    def load_state_dict(self, ids: Iterable[str]) -> None:
        self._seen.clear()
        for uid in ids:
            self.add(uid)


class RetryPolicy:
    """Jittered exponential backoff for client re-uploads.

    ``delay(attempt)`` is the wait before retry ``attempt`` (0-based):
    ``base * factor**attempt``, capped at ``max_delay``, times a uniform
    jitter in ``[1 - jitter, 1 + jitter]`` -- the jitter decorrelates a
    thundering herd of clients retrying a flaky server in lockstep.
    Deterministic: the jitter stream is seeded, and ``attempt`` indexes
    it, so a simulator replays identical schedules.  ``give_up(attempt)``
    is True once ``max_retries`` is exhausted.
    """

    def __init__(self, base: float = 1.0, factor: float = 2.0,
                 max_delay: float = 60.0, max_retries: int = 5,
                 jitter: float = 0.1, seed: int = 0):
        if base <= 0 or factor < 1.0 or max_delay <= 0:
            raise ValueError(
                f"need base > 0, factor >= 1, max_delay > 0; got "
                f"base={base}, factor={factor}, max_delay={max_delay}")
        if not 0.0 <= jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {jitter}")
        self.base = float(base)
        self.factor = float(factor)
        self.max_delay = float(max_delay)
        self.max_retries = int(max_retries)
        self.jitter = float(jitter)
        self.seed = int(seed)

    def give_up(self, attempt: int) -> bool:
        return attempt >= self.max_retries

    def delay(self, attempt: int, salt: int = 0) -> float:
        """Backoff before 0-based retry ``attempt`` (``salt`` decorrelates
        independent clients sharing one policy)."""
        d = min(self.base * self.factor ** max(attempt, 0), self.max_delay)
        if self.jitter:
            rng = np.random.default_rng(
                (self.seed, int(salt), int(attempt)))
            d *= 1.0 + self.jitter * float(rng.uniform(-1.0, 1.0))
        return d


# ---------------------------------------------------- semi-async buffering --
@dataclasses.dataclass
class BufferedUpdate:
    """One upload waiting in the semi-async buffer."""
    update: Any                 # repro.core.ClientUpdate
    weight: float               # effective mass (staleness already applied)
    staleness: float = 0.0      # server versions behind at arrival
    arrived: float = 0.0        # service clock at arrival
    wire_bytes: int = 0         # bytes as uploaded (post-codec, pre-decode)


class UpdateBuffer:
    """Flush-on-K-or-deadline intake queue for the async server.

    ``size=1`` means fully-async (every add is immediately due);
    ``deadline`` (same clock units the caller passes as ``now``) bounds
    how long the oldest buffered upload may wait before a flush is due
    even if the buffer is not full -- stragglers cannot stall the round,
    and quick clients cannot starve the stragglers out of it.
    """

    def __init__(self, size: int = 1, deadline: float | None = None):
        if size < 1:
            raise ValueError(f"buffer size must be >= 1, got {size}")
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
        self.size = int(size)
        self.deadline = deadline
        self._items: list[BufferedUpdate] = []

    def __len__(self) -> int:
        return len(self._items)

    def add(self, update, weight: float, staleness: float = 0.0,
            now: float = 0.0, wire_bytes: int = 0) -> None:
        self._items.append(BufferedUpdate(update=update,
                                          weight=float(weight),
                                          staleness=float(staleness),
                                          arrived=float(now),
                                          wire_bytes=int(wire_bytes)))

    def due(self, now: float = 0.0) -> bool:
        """Is a flush due -- K updates waiting, or the oldest past the
        deadline?"""
        if not self._items:
            return False
        if len(self._items) >= self.size:
            return True
        return (self.deadline is not None
                and now - self._items[0].arrived >= self.deadline)

    def next_deadline(self) -> float | None:
        """Clock time at which the oldest buffered update makes a flush
        due (None when empty or no deadline is configured) -- event loops
        schedule their deadline check here."""
        if self.deadline is None or not self._items:
            return None
        return self._items[0].arrived + self.deadline

    def total_weight(self) -> float:
        """Total effective mass currently buffered.  The flush path
        checks this before mixing: a zero-mass batch (every weight
        staleness-discounted to 0) has no convex combination and must be
        dropped, not aggregated into ``0 / 0``."""
        return float(sum(b.weight for b in self._items))

    def total_wire_bytes(self) -> int:
        """Bytes currently buffered as uploaded -- quantized payloads
        count at their wire dtype, which is the whole point of shipping
        them quantized."""
        return sum(b.wire_bytes for b in self._items)

    def pop(self) -> list[BufferedUpdate]:
        """Drain the buffer in arrival order."""
        items, self._items = self._items, []
        return items


def _leaf_bytes(x) -> int:
    return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize


def tree_bytes(tree: PyTree) -> int:
    return sum(_leaf_bytes(x) for x in jax.tree.leaves(tree))


def adapter_upload_bytes(adapters: PyTree, rank: int | None = None) -> int:
    """Bytes a client ships for its adapters.

    ``rank=None``: padded r_max layout (what zero-padding FLaaS ships).
    ``rank=r``: rank-sliced (what a rank-r client actually needs to send
    under RBLA -- the server re-pads; Alg. 2 slicing in reverse).
    """
    total = 0

    def per_pair(pair):
        nonlocal total
        a, b = pair["A"], pair["B"]
        r_max = a.shape[-2]
        r = r_max if rank is None else min(rank, r_max)
        frac = r / r_max
        total += int(_leaf_bytes(a) * frac) + int(_leaf_bytes(b) * frac)
        total += _leaf_bytes(pair["rank"])
        return pair

    tree_map_pairs(per_pair, adapters)
    return total


def round_cost_report(params: PyTree, adapters: PyTree,
                      base_trainable: PyTree,
                      client_ranks) -> dict:
    """Per-round communication summary across methods."""
    full = tree_bytes(params)
    base_tr = tree_bytes(base_trainable)
    padded = adapter_upload_bytes(adapters)
    sliced = [adapter_upload_bytes(adapters, int(r)) for r in client_ranks]
    return {
        "fft_upload_bytes_per_client": full,
        "lora_padded_upload_bytes": padded + base_tr,
        "lora_sliced_upload_bytes_mean": int(np.mean(sliced)) + base_tr,
        "lora_sliced_upload_bytes": [s + base_tr for s in sliced],
        "broadcast_bytes": padded + base_tr,
        "reduction_vs_fft": full / max(int(np.mean(sliced)) + base_tr, 1),
    }
