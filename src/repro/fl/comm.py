"""Communication layer: per-round cost accounting and the async server's
upload buffer.

Cost accounting (paper motivation: LoRA cuts per-round bytes; RBLA keeps
that benefit while fixing aggregation) counts the bytes a client uploads
per round (and the server broadcast), per aggregation method:

* lora methods (rbla / zeropad / variants): the padded adapter tree --
  but a client of rank r only needs to ship its live rows, so the honest
  per-client cost is the rank-sliced adapter (+ the non-LoRA trainables);
  we report both padded and sliced numbers.
* fft: the full parameter tree.

:class:`UpdateBuffer` is the buffered semi-async server's intake queue:
uploads accumulate and flush as one mini-cohort on size K or deadline
(see ``repro.fl.async_agg`` / ``docs/async.md``).  The buffer itself
stays metrics-free; its owning :class:`~repro.fl.AsyncAggregator`
exports the live depth (``fl_buffer_depth``), per-upload staleness
(``fl_staleness``) and wire bytes (``fl_wire_bytes_received_total``)
through :mod:`repro.obs` -- see ``docs/observability.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import numpy as np

from repro.lora import is_pair, tree_map_pairs

PyTree = Any


# ---------------------------------------------------- semi-async buffering --
@dataclasses.dataclass
class BufferedUpdate:
    """One upload waiting in the semi-async buffer."""
    update: Any                 # repro.core.ClientUpdate
    weight: float               # effective mass (staleness already applied)
    staleness: float = 0.0      # server versions behind at arrival
    arrived: float = 0.0        # service clock at arrival
    wire_bytes: int = 0         # bytes as uploaded (post-codec, pre-decode)


class UpdateBuffer:
    """Flush-on-K-or-deadline intake queue for the async server.

    ``size=1`` means fully-async (every add is immediately due);
    ``deadline`` (same clock units the caller passes as ``now``) bounds
    how long the oldest buffered upload may wait before a flush is due
    even if the buffer is not full -- stragglers cannot stall the round,
    and quick clients cannot starve the stragglers out of it.
    """

    def __init__(self, size: int = 1, deadline: float | None = None):
        if size < 1:
            raise ValueError(f"buffer size must be >= 1, got {size}")
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
        self.size = int(size)
        self.deadline = deadline
        self._items: list[BufferedUpdate] = []

    def __len__(self) -> int:
        return len(self._items)

    def add(self, update, weight: float, staleness: float = 0.0,
            now: float = 0.0, wire_bytes: int = 0) -> None:
        self._items.append(BufferedUpdate(update=update,
                                          weight=float(weight),
                                          staleness=float(staleness),
                                          arrived=float(now),
                                          wire_bytes=int(wire_bytes)))

    def due(self, now: float = 0.0) -> bool:
        """Is a flush due -- K updates waiting, or the oldest past the
        deadline?"""
        if not self._items:
            return False
        if len(self._items) >= self.size:
            return True
        return (self.deadline is not None
                and now - self._items[0].arrived >= self.deadline)

    def next_deadline(self) -> float | None:
        """Clock time at which the oldest buffered update makes a flush
        due (None when empty or no deadline is configured) -- event loops
        schedule their deadline check here."""
        if self.deadline is None or not self._items:
            return None
        return self._items[0].arrived + self.deadline

    def total_weight(self) -> float:
        """Total effective mass currently buffered.  The flush path
        checks this before mixing: a zero-mass batch (every weight
        staleness-discounted to 0) has no convex combination and must be
        dropped, not aggregated into ``0 / 0``."""
        return float(sum(b.weight for b in self._items))

    def total_wire_bytes(self) -> int:
        """Bytes currently buffered as uploaded -- quantized payloads
        count at their wire dtype, which is the whole point of shipping
        them quantized."""
        return sum(b.wire_bytes for b in self._items)

    def pop(self) -> list[BufferedUpdate]:
        """Drain the buffer in arrival order."""
        items, self._items = self._items, []
        return items


def _leaf_bytes(x) -> int:
    return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize


def tree_bytes(tree: PyTree) -> int:
    return sum(_leaf_bytes(x) for x in jax.tree.leaves(tree))


def adapter_upload_bytes(adapters: PyTree, rank: int | None = None) -> int:
    """Bytes a client ships for its adapters.

    ``rank=None``: padded r_max layout (what zero-padding FLaaS ships).
    ``rank=r``: rank-sliced (what a rank-r client actually needs to send
    under RBLA -- the server re-pads; Alg. 2 slicing in reverse).
    """
    total = 0

    def per_pair(pair):
        nonlocal total
        a, b = pair["A"], pair["B"]
        r_max = a.shape[-2]
        r = r_max if rank is None else min(rank, r_max)
        frac = r / r_max
        total += int(_leaf_bytes(a) * frac) + int(_leaf_bytes(b) * frac)
        total += _leaf_bytes(pair["rank"])
        return pair

    tree_map_pairs(per_pair, adapters)
    return total


def round_cost_report(params: PyTree, adapters: PyTree,
                      base_trainable: PyTree,
                      client_ranks) -> dict:
    """Per-round communication summary across methods."""
    full = tree_bytes(params)
    base_tr = tree_bytes(base_trainable)
    padded = adapter_upload_bytes(adapters)
    sliced = [adapter_upload_bytes(adapters, int(r)) for r in client_ranks]
    return {
        "fft_upload_bytes_per_client": full,
        "lora_padded_upload_bytes": padded + base_tr,
        "lora_sliced_upload_bytes_mean": int(np.mean(sliced)) + base_tr,
        "lora_sliced_upload_bytes": [s + base_tr for s in sliced],
        "broadcast_bytes": padded + base_tr,
        "reduction_vs_fft": full / max(int(np.mean(sliced)) + base_tr, 1),
    }
