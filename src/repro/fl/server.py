"""Server-side aggregation (paper Alg. 1) over arbitrary adapter pytrees.

Deprecated veneer: the aggregation methods themselves now live in
``repro.core.strategy`` as registered :class:`AggregationStrategy` objects
owning every backend (reference / distributed / Pallas).  These wrappers
keep the old keyword call sites working; new code should use::

    from repro.core import get_strategy
    strategy = get_strategy("rbla")
    state = strategy.aggregate(state, client_updates, weights)
"""
from __future__ import annotations

import warnings
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.strategy import get_strategy, stack_trees  # noqa: F401

Array = jax.Array
PyTree = Any

_DEPRECATION = ("repro.fl.server.%s is deprecated; use repro.core."
                "get_strategy(method).%s instead")


def aggregate_adapters(client_adapters: Sequence[PyTree], weights: Array,
                       method: str = "rbla", r_max: int | None = None,
                       client_ranks: Array | None = None,
                       prev_global: PyTree | None = None,
                       backend: str = "auto") -> PyTree:
    """Aggregate per-client adapter trees into the global adapter.

    ``method``: any registered strategy name ('rbla' | 'zeropad' |
    'fedavg' | 'rbla_ranked' | 'rbla_norm' | 'svd' | 'flora' | ...).
    Fixed-rank strategies reset the global adapter's live rank to r_max
    (the server keeps the full stack; clients re-slice per Alg. 2);
    rank-changing ones (``rank_contract="stacked"``, e.g. flora) write a
    cohort-dependent live rank instead -- read it from the output pairs.
    ``prev_global``: under partial
    participation, rank-rows owned by no participant retain the server's
    current value instead of being zeroed (strategies with
    ``retains_prev``).
    """
    warnings.warn(_DEPRECATION % ("aggregate_adapters", "aggregate_adapters"),
                  DeprecationWarning, stacklevel=2)
    return get_strategy(method).aggregate_adapters(
        client_adapters, weights, r_max=r_max, client_ranks=client_ranks,
        prev_global=prev_global, backend=backend)


def aggregate_base(client_params: Sequence[PyTree], weights: Array) -> PyTree:
    """Plain FedAvg for non-LoRA trainables (convs, biases, norms, or the
    full model in FFT mode)."""
    warnings.warn(_DEPRECATION % ("aggregate_base", "aggregate"),
                  DeprecationWarning, stacklevel=2)
    stacked = stack_trees(client_params)
    masks = jax.tree.map(lambda _: jnp.ones(()), stacked)
    return get_strategy("fedavg").aggregate_tree(stacked, masks, weights)
