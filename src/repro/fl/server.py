"""Server-side aggregation (paper Alg. 1) over arbitrary adapter pytrees."""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import aggregate
from repro.core.variants import rank_proportional_weights, rbla_norm_leaf
from repro.lora import adapter_masks, is_pair, tree_map_pairs

Array = jax.Array
PyTree = Any


def stack_trees(trees: Sequence[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def aggregate_adapters(client_adapters: Sequence[PyTree], weights: Array,
                       method: str = "rbla", r_max: int | None = None,
                       client_ranks: Array | None = None,
                       prev_global: PyTree | None = None) -> PyTree:
    """Aggregate per-client adapter trees into the global adapter.

    ``method``: 'rbla' | 'zeropad' | 'rbla_ranked' | 'rbla_norm'.
    The global adapter's live rank is reset to r_max (the server keeps the
    full stack; clients re-slice per Alg. 2).  ``prev_global``: under
    partial participation, rank-rows owned by no participant retain the
    server's current value instead of being zeroed.
    """
    stacked = stack_trees(client_adapters)
    masks = stack_trees([adapter_masks(a) for a in client_adapters])

    if method == "rbla_ranked":
        assert client_ranks is not None
        weights = rank_proportional_weights(weights, client_ranks)
        method_inner = "rbla"
    else:
        method_inner = method

    if method == "rbla_norm":
        def agg_pair(pair_stacked, pair_masks):
            return {
                "A": rbla_norm_leaf(pair_stacked["A"], pair_masks["A"],
                                    weights, row_axis=0),
                "B": rbla_norm_leaf(pair_stacked["B"], pair_masks["B"],
                                    weights, row_axis=1),
                "rank": pair_stacked["rank"][0],
            }
        out = _map_pair_trees(agg_pair, stacked, masks)
    else:
        out = aggregate(stacked, masks, weights, method=method_inner,
                        prev_tree=prev_global if method_inner == "rbla"
                        else None)

    def fix_rank(pair):
        p = dict(pair)
        rm = p["A"].shape[-2] if r_max is None else r_max
        p["rank"] = jnp.full_like(jnp.asarray(p["rank"], jnp.int32), rm)
        return p
    return tree_map_pairs(fix_rank, out)


def _map_pair_trees(fn, stacked, masks):
    if is_pair(stacked):
        return fn(stacked, masks)
    return {k: _map_pair_trees(fn, stacked[k], masks[k]) for k in stacked}


def aggregate_base(client_params: Sequence[PyTree], weights: Array) -> PyTree:
    """Plain FedAvg for non-LoRA trainables (convs, biases, norms, or the
    full model in FFT mode)."""
    stacked = stack_trees(client_params)
    masks = jax.tree.map(lambda _: jnp.ones(()), stacked)
    return aggregate(stacked, masks, weights, method="fedavg")
