"""Deterministic fault injection for the FLaaS service.

A :class:`FaultPlan` is a *seeded decision oracle*: every fault draw is
keyed by ``(seed, fault kind, event index)`` through an independent
counter-based PRNG stream, so the same plan over the same simulation
config injects exactly the same faults -- run to run, machine to
machine.  That determinism is what makes the crash-consistency gates
assertable: the chaos run is reproducible, so "recovered bit-identical"
is a hard equality, not a statistical claim.

The plan covers the failure modes an at-least-once FLaaS deployment
actually sees:

* ``drop`` -- an upload lost in transit; the client retries it (same
  ``update_id``) under a jittered :class:`~repro.fl.comm.RetryPolicy`.
* ``duplicate`` -- the transport delivers one upload twice; the server's
  :class:`~repro.fl.comm.DedupWindow` must fold it exactly once.
* ``reorder`` -- an upload delayed past its peers, arriving staler than
  it was sent.
* ``corrupt`` / ``truncate`` -- bit-flipped (NaN-poisoned) tensors and
  payloads cut short mid-pair; both must bounce off the ingestion
  front door (``nan_tensor`` / ``malformed`` rejections), never reach
  the WAL or the fold.
* ``stale_pull`` -- a client training on a long-obsolete global (its
  pull raced a publish, or it cached aggressively).
* ``publish_fail`` -- the serving store rejects a hot-swap; the engine
  must keep serving the last committed snapshot and retry with backoff
  (see :meth:`repro.serving.ServingEngine.publisher`).
* ``crash_at`` -- server crash-restart points (counts of accepted
  uploads); the simulator tears the aggregator down and recovers it
  from the WAL (:class:`~repro.fl.DurableAggregator`).

Injection points live in :func:`repro.fl.run_async_simulation`
(``fault_plan=`` argument) and the serving publish hook; the chaos
acceptance gates run in ``benchmarks/bench_async_agg.py --smoke`` and
``tests/test_durability.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategy import ClientUpdate

# stable per-kind stream ids: inserting a new kind must not shift the
# draws of existing plans (seeds are part of recorded experiment configs)
_KINDS = {"drop": 1, "duplicate": 2, "reorder": 3, "corrupt": 4,
          "truncate": 5, "stale_pull": 6, "publish_fail": 7}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, immutable fault schedule.  All probabilities are per
    event (per delivery attempt for ``drop``, per upload otherwise);
    ``crash_at`` is a tuple of accepted-upload counts at which the
    simulator crash-restarts the server."""

    seed: int = 0
    p_drop: float = 0.0
    p_duplicate: float = 0.0
    p_reorder: float = 0.0
    reorder_delay_s: float = 5.0
    p_corrupt: float = 0.0
    p_truncate: float = 0.0
    p_stale_pull: float = 0.0
    p_publish_fail: float = 0.0
    crash_at: tuple = ()

    def __post_init__(self):
        for name in ("p_drop", "p_duplicate", "p_reorder", "p_corrupt",
                     "p_truncate", "p_stale_pull", "p_publish_fail"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.reorder_delay_s < 0:
            raise ValueError(
                f"reorder_delay_s must be >= 0, got {self.reorder_delay_s}")
        object.__setattr__(self, "crash_at",
                           tuple(int(c) for c in self.crash_at))

    # ------------------------------------------------------------- draws --
    def _fires(self, kind: str, idx: int, salt: int, p: float) -> bool:
        if p <= 0.0:
            return False
        rng = np.random.default_rng(
            (self.seed, _KINDS[kind], int(idx), int(salt)))
        return bool(rng.uniform() < p)

    def drop(self, uid: int, attempt: int = 0) -> bool:
        """Is delivery ``attempt`` of upload ``uid`` lost in transit?"""
        return self._fires("drop", uid, attempt, self.p_drop)

    def duplicate(self, uid: int) -> bool:
        return self._fires("duplicate", uid, 0, self.p_duplicate)

    def reorder(self, uid: int) -> bool:
        return self._fires("reorder", uid, 0, self.p_reorder)

    def corrupt(self, uid: int) -> bool:
        return self._fires("corrupt", uid, 0, self.p_corrupt)

    def truncate(self, uid: int) -> bool:
        return self._fires("truncate", uid, 0, self.p_truncate)

    def stale_pull(self, uid: int) -> bool:
        return self._fires("stale_pull", uid, 0, self.p_stale_pull)

    def publish_fail(self, idx: int) -> bool:
        """Does the ``idx``-th publish attempt fail?  Wire this into a
        flaky store wrapper (tests) or a proxy in front of
        ``ServingEngine.publish``."""
        return self._fires("publish_fail", idx, 0, self.p_publish_fail)

    def crash_now(self, n_accepted: int) -> bool:
        return int(n_accepted) in self.crash_at

    # ---------------------------------------------------------- mutators --
    def corrupt_update(self, update: ClientUpdate) -> ClientUpdate:
        """NaN-poison one tensor (bit rot / a bad DMA on the wire).  The
        ingestion front door must reject it as ``nan_tensor``."""
        def poison(tree):
            done = [False]

            def leaf(x):
                x = jnp.asarray(x)
                if (not done[0] and jnp.issubdtype(x.dtype, jnp.floating)
                        and x.size):
                    done[0] = True
                    flat = jnp.ravel(x).at[0].set(jnp.nan)
                    return jnp.reshape(flat, x.shape)
                return x

            return jax.tree.map(leaf, tree)

        if update.adapters is not None:
            return dataclasses.replace(update,
                                       adapters=poison(update.adapters))
        return dataclasses.replace(
            update, base_trainable=poison(update.base_trainable))

    def truncate_update(self, update: ClientUpdate) -> ClientUpdate:
        """Cut the payload short mid-pair (a proxy timeout): A loses its
        last rank row, so ``A.shape[-2] != B.shape[-1]`` and the front
        door must reject it as ``malformed``.  FFT updates (no adapter
        pairs to truncate) degrade to corruption."""
        if update.adapters is None:
            return self.corrupt_update(update)
        from repro.lora import tree_map_pairs

        def chop(pair):
            out = dict(pair)
            out["A"] = jnp.asarray(pair["A"])[..., :-1, :]
            return out

        return dataclasses.replace(
            update, adapters=tree_map_pairs(chop, update.adapters))


def flaky(fn, plan: FaultPlan, kind: str = "publish_fail"):
    """Wrap a callable so its ``idx``-th invocation raises when the plan
    says that attempt fails -- the standard way to make a store's
    ``publish`` flaky in tests and the chaos smoke gate."""
    counter = {"n": 0}

    def wrapped(*a: Any, **kw: Any):
        idx = counter["n"]
        counter["n"] += 1
        if plan._fires(kind, idx, 0, getattr(plan, f"p_{kind}")):
            raise RuntimeError(f"injected {kind} fault (attempt {idx})")
        return fn(*a, **kw)

    return wrapped


__all__ = ["FaultPlan", "flaky"]
