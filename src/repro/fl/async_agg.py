"""Async staleness-aware aggregation service (the FLaaS serving loop).

In FLaaS, clients on phones, desktops, and accelerators report at wildly
different cadences; a synchronous cohort round moves at the pace of its
slowest participant.  This module makes aggregation a **long-lived
service** instead of a pure per-round function: an
:class:`AsyncAggregator` owns a live :class:`~repro.core.ServerState` and
folds individual :class:`~repro.core.ClientUpdate` objects into it as they
arrive, discounting each update by how *stale* it is -- how many server
versions were published between the global the client trained on and the
moment its update lands (``staleness_clock="version"``), or how much
service-clock time elapsed since the client pulled
(``staleness_clock="wall"``).

Staleness weighting follows FedAsync (Xie et al., 2019): the update's
mass ``n_examples`` is scaled by a schedule ``s(tau)`` in ``(0, 1]``:

* ``constant``:    ``s(tau) = 1`` (staleness ignored),
* ``polynomial``:  ``s(tau) = (1 + tau) ** -a``,
* ``hinge``:       ``s(tau) = 1`` if ``tau <= b`` else
  ``1 / (a * (tau - b) + 1)``.

The scaled mass then flows through **each strategy's own weight
semantics** -- RBLA's per-rank-row masked mean, zero-padding's dilution,
flora's stacked-contributor masses (a stale stacked contributor is
*down-weighted*, never dropped) -- via the per-update
:meth:`~repro.core.AggregationStrategy.fold` hook.

Three service modes:

* **fully async** (``buffer_size=1``): every arrival folds immediately.
  Strategies declaring ``supports_incremental=True`` stream exactly (one
  O(state) pass per update); the rest are *replayed* -- the service keeps
  the updates folded since the last anchor and recomputes the joint
  aggregate, so sequential folding reproduces the one-shot cohort result
  bit-for-bit at zero staleness for every registered strategy.
* **buffered semi-async** (``buffer_size=K`` and/or ``deadline``):
  arrivals buffer in a :class:`~repro.fl.comm.UpdateBuffer` and flush as
  one mini-cohort when K updates are waiting or the oldest has waited
  past the deadline (FedBuff-style).
* **sync** degenerates to ``buffer_size = cohort size``: one flush per
  round is exactly the classic ``strategy.aggregate``.

See ``docs/async.md`` for formulas, mode trade-offs, and a runnable
example.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.codec import (CODECS, UploadValidationError,
                              codec_of_pair, decode_update,
                              stochastic_round_tree, tree_codec,
                              validate_encoded_adapters)
from repro.core.codec import _iter_pairs as _iter_adapter_pairs
from repro.core.strategy import (ClientUpdate, FoldState, ServerState,
                                 get_strategy)
from repro.fl.comm import (BufferedUpdate, DedupWindow, UpdateBuffer,
                           tree_bytes)
from repro.obs import STALENESS_BUCKETS, get_registry, span

#: the machine-readable rejection reasons ``fl_updates_rejected_total``
#: counts (see ``docs/observability.md``); every ingestion raise, the
#: zero-mass flush drop, and the idempotency dedup map to exactly one
REJECT_REASONS = ("bad_mass", "codec_not_allowed", "bad_scale",
                  "overflow", "nan_tensor", "malformed",
                  "zero_mass_flush", "duplicate")

#: schedule name -> factory(a, b) -> s(tau); all monotone non-increasing
#: in tau with s(0) == 1 (fresh updates are never discounted)
STALENESS_SCHEDULES = {
    "constant": lambda a, b: lambda tau: 1.0,
    "polynomial": lambda a, b: lambda tau: float((1.0 + tau) ** -a),
    "hinge": lambda a, b: lambda tau: (
        1.0 if tau <= b else 1.0 / (a * (tau - b) + 1.0)),
}


def make_staleness_fn(schedule: "str | Callable[[float], float]"
                      = "polynomial", *, a: float = 0.5,
                      b: float = 4.0) -> Callable[[float], float]:
    """Resolve a staleness schedule by name (or pass a callable through).

    ``a`` is the decay strength (polynomial exponent / hinge slope), ``b``
    the hinge's grace period in server versions.
    """
    if callable(schedule):
        return schedule
    try:
        factory = STALENESS_SCHEDULES[schedule]
    except KeyError:
        raise ValueError(
            f"unknown staleness schedule {schedule!r}; options: "
            f"{sorted(STALENESS_SCHEDULES)} or a callable") from None
    if a <= 0:
        raise ValueError(f"staleness decay a must be > 0, got {a}")
    return factory(a, b)


class AsyncAggregator:
    """A long-lived aggregation service over one strategy and one state.

    Parameters
    ----------
    strategy
        Registered strategy name or instance (configured copies welcome).
    state
        Initial :class:`ServerState`; the service owns it from here on
        (read the live one from :attr:`state`).
    staleness, staleness_a, staleness_b
        Schedule for the staleness discount (see :func:`make_staleness_fn`).
    buffer_size, deadline
        Semi-async knobs: flush when ``buffer_size`` updates are waiting,
        or when the oldest buffered update has waited ``deadline`` clock
        units (checked on :meth:`submit` / :meth:`maybe_flush` -- the
        event loop supplies the clock).  ``buffer_size=1`` is fully async.
    staleness_clock
        What ``tau`` measures: ``"version"`` (default) counts server
        versions published between the client's pull and its upload
        (FedAsync's discrete clock); ``"wall"`` measures elapsed service
        clock -- ``now - pulled_at`` -- so a schedule's decay ``a`` /
        grace ``b`` are in the event loop's time units and slow *wall
        time*, not fold churn, is what discounts an update.
    backend
        Execution backend for the underlying strategy paths
        (``auto | ref | pallas | distributed``).
    replay_window
        Fully-async mode only: non-incremental strategies replay the
        updates folded since the last anchor; after this many the service
        re-anchors at the current state (bounding memory and making the
        accumulated state the new retention baseline).
    on_publish, publish_every
        The serving hot-swap hook: after every ``publish_every``-th state
        advance, ``on_publish(state)`` is called with the live
        :class:`ServerState` -- wire
        :meth:`repro.serving.ServingEngine.publisher` here to push each
        freshly folded global into the serving read path (see
        ``docs/serving.md``).  ``publish_every > 1`` batches swaps when
        folds land faster than serving wants new versions.
    server_momentum
        FedBuff/FedAvgM-style server momentum ``beta`` in ``[0, 1)`` on
        the fold path: each state advance publishes ``s_old + m`` with
        ``m <- beta * m + (s_new - s_old)`` over the adapters' float
        leaves (``beta=0`` disables, bit-exact).  The buffer
        (:attr:`FoldState.momentum <repro.core.FoldState>`) lives on
        aggregated state only, so secure-aggregation-compatible
        buffering is unaffected.  Requires a fixed-rank strategy
        (``rank_contract="fixed"``): a rank-changing live rank would
        change the buffer's meaning round to round.
    codecs
        Upload codecs this service accepts (negotiated allow-list, a
        subset of :data:`repro.core.codec.CODECS`); a single name is
        promoted to a 1-tuple.  Uploads using any other wire format are
        rejected at the ingestion front door.  Quantized uploads stay
        encoded through the buffer -- the plan layer fuses
        dequantization into the aggregation kernel -- and are decoded
        only on the incremental/replay fold paths, which operate on
        fp32 trees.
    accum_dtype
        ``None`` (default, fp32 accumulators, bit-exact) or
        ``"bfloat16"``: between folds the live accumulators -- the
        state's adapter float leaves and the server-momentum buffer --
        are stored in bf16, written back with **stochastic rounding**
        (:func:`repro.core.codec.stochastic_round`) so the accumulator
        is unbiased over folds; fold arithmetic itself stays fp32.
        ``FoldState`` masses (``mass``, ``row_mass``) stay fp32 --
        rounding the denominators would bias every subsequent mean.
    seed
        PRNG seed for the stochastic-rounding noise.  Folds are
        reproducible: a fixed seed and the same submission sequence
        yield bit-identical accumulators.
    dedup_window
        How many recently accepted client ``update_id`` strings the
        service remembers (:class:`~repro.fl.comm.DedupWindow`).  With
        at-least-once delivery (client retries, WAL replay) the same
        logical upload can arrive twice; a ``submit(...,
        update_id=...)`` whose id is inside the window is dropped as a
        ``"duplicate"`` instead of double-folding its mass.  Uploads
        without an id are never deduplicated.
    registry
        The :class:`~repro.obs.MetricsRegistry` this service reports
        into (exposed as :attr:`obs_registry`; ``None`` = the process
        default).  Feed it to :class:`~repro.obs.ServiceHealth` for the
        operator snapshot; see ``docs/observability.md``.
    """

    STALENESS_CLOCKS = ("version", "wall")

    def __init__(self, strategy, state: ServerState, *,
                 staleness="constant", staleness_a: float = 0.5,
                 staleness_b: float = 4.0, staleness_clock: str = "version",
                 buffer_size: int = 1,
                 deadline: float | None = None, backend: str = "auto",
                 replay_window: int = 64,
                 on_publish: "Callable | None" = None,
                 publish_every: int = 1,
                 server_momentum: float = 0.0,
                 codecs=CODECS,
                 accum_dtype=None,
                 seed: int = 0,
                 dedup_window: int = 1024,
                 registry=None):
        if buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        if replay_window < 1:
            raise ValueError(
                f"replay_window must be >= 1, got {replay_window}")
        if publish_every < 1:
            raise ValueError(
                f"publish_every must be >= 1, got {publish_every}")
        if staleness_clock not in self.STALENESS_CLOCKS:
            raise ValueError(
                f"unknown staleness_clock {staleness_clock!r}; options: "
                f"{self.STALENESS_CLOCKS}")
        if not 0.0 <= server_momentum < 1.0:
            raise ValueError(
                f"server_momentum must be in [0, 1), got {server_momentum}")
        if isinstance(codecs, str):
            codecs = (codecs,)
        codecs = tuple(codecs)
        unknown = [c for c in codecs if c not in CODECS]
        if unknown or not codecs:
            raise ValueError(
                f"unknown upload codec(s) {unknown or codecs}; options: "
                f"{list(CODECS)}")
        self.codecs = codecs
        if accum_dtype is not None and jnp.dtype(accum_dtype) != jnp.bfloat16:
            raise ValueError(
                "accum_dtype must be None (fp32) or bfloat16, got "
                f"{accum_dtype!r}")
        self.accum_dtype = None if accum_dtype is None else jnp.bfloat16
        self._prng_key = jax.random.PRNGKey(int(seed))
        self.strategy = get_strategy(strategy)
        if server_momentum > 0.0 and self.strategy.rank_contract != "fixed":
            raise ValueError(
                f"server momentum needs a fixed-rank strategy; "
                f"{self.strategy.name!r} declares "
                f"rank_contract={self.strategy.rank_contract!r} (the live "
                "rank -- and the momentum buffer's meaning -- would change "
                "round to round)")
        self.server_momentum = float(server_momentum)
        self.state = state
        self.backend = backend
        self.staleness_clock = staleness_clock
        self.staleness_fn = make_staleness_fn(
            staleness, a=staleness_a, b=staleness_b)
        self.buffer = UpdateBuffer(size=buffer_size, deadline=deadline)
        self.dedup = DedupWindow(dedup_window)
        self.replay_window = int(replay_window)
        self.on_publish = on_publish
        self.publish_every = int(publish_every)
        self.n_published = 0
        self._anchor = state
        self._replay: list[tuple[ClientUpdate, float]] = []
        self._fold_state: FoldState = self.strategy.init_fold(state)
        # service counters (the benchmark / simulator read these)
        self.n_received = 0
        self.n_folded = 0
        self.n_flushes = 0
        self.n_dropped = 0          # zero-mass flushes discarded whole
        self.staleness_sum = 0.0
        self.wire_bytes_received = 0   # post-codec upload bytes accepted
        # observability: cache the instrument handles once (hot path is
        # one enabled check + one add per event); pass ``registry=`` for
        # per-service isolation, default is the process registry
        reg = registry if registry is not None else get_registry()
        self.obs_registry = reg
        self._m_received = reg.counter(
            "fl_updates_received_total", "accepted client updates")
        self._m_rejected = reg.counter(
            "fl_updates_rejected_total",
            "rejected client updates, by reason", labelnames=("reason",))
        self._m_codec = reg.counter(
            "fl_uploads_by_codec_total",
            "accepted uploads, by wire codec", labelnames=("codec",))
        self._m_wire = reg.counter(
            "fl_wire_bytes_received_total",
            "post-codec upload bytes accepted")
        self._m_staleness = reg.histogram(
            "fl_staleness", "staleness of accepted updates "
            "(server versions or wall units, per staleness_clock)",
            buckets=STALENESS_BUCKETS)
        self._m_flushes = reg.counter(
            "fl_flushes_total", "buffer flushes that advanced the state")
        self._m_folds = reg.counter(
            "fl_folds_total", "client updates folded into the state")
        self._m_publishes = reg.counter(
            "fl_publishes_total", "states handed to the publish hook")
        self._m_buffer_depth = reg.gauge(
            "fl_buffer_depth", "updates currently buffered")
        self._quantize_live()          # bf16 storage from the first fold on

    # ------------------------------------------------------------- intake --
    @property
    def version(self) -> int:
        """Server model version = rounds folded into the live state."""
        return int(self.state.round)

    def staleness_weight(self, staleness: float) -> float:
        s = self.staleness_fn(max(float(staleness), 0.0))
        if not 0.0 < s <= 1.0:
            raise ValueError(
                f"staleness schedule returned {s} for tau={staleness}; "
                "schedules must map into (0, 1]")
        return s

    def _reject(self, reason: str, n: int = 1) -> None:
        """Count one rejection under its reason (the per-reason split of
        the legacy lone ``n_dropped``)."""
        self._m_rejected.labels(reason=reason).inc(n)

    def _validate_update(self, update: ClientUpdate) -> set:
        """Ingestion front door: reject malformed uploads before they can
        poison the buffer (the robust strategies bound what *well-formed*
        adversarial values can do; NaN/inf and zero/negative masses are
        rejected outright -- a NaN survives any mean, trimmed or not).

        Every raise increments ``fl_updates_rejected_total`` under
        exactly one reason.  Returns the set of wire codecs the upload
        used (for the codec-mix counters)."""
        n = float(update.n_examples)
        if not (math.isfinite(n) and n > 0.0):
            self._reject("bad_mass")
            raise ValueError(
                "rejected client update: n_examples must be positive and "
                f"finite, got {update.n_examples!r}")
        used = set()
        for path, p in _iter_adapter_pairs(update.adapters):
            used.add(codec_of_pair(p))
            # structural integrity: a truncated/garbled upload (lost
            # frames, a proxy cutting the payload short) must be rejected
            # here, not crash a fused kernel three layers down
            a, b = jnp.asarray(p["A"]), jnp.asarray(p["B"])
            if (a.ndim < 2 or b.ndim < 2
                    or a.shape[-2] != b.shape[-1]):
                self._reject("malformed")
                name = "/".join(str(s) for s in path) or "<root>"
                raise ValueError(
                    f"rejected client update: truncated or malformed "
                    f"pair {name}: A {tuple(a.shape)} / B "
                    f"{tuple(b.shape)} do not share a rank axis")
        bad = sorted(used - set(self.codecs))
        if bad:
            self._reject("codec_not_allowed")
            raise ValueError(
                f"rejected client update: upload codec {bad} not in the "
                f"negotiated set {list(self.codecs)}")
        # scale sanity first: a NaN scale should name the scale, not fall
        # through to the generic non-finite message below
        try:
            validate_encoded_adapters(update.adapters)
        except UploadValidationError as e:
            self._reject(e.reason)      # "bad_scale" | "overflow"
            raise
        for name, tree in (("adapters", update.adapters),
                           ("base_trainable", update.base_trainable)):
            for leaf in jax.tree.leaves(tree):
                x = jnp.asarray(leaf)
                if (jnp.issubdtype(x.dtype, jnp.floating)
                        and not bool(jnp.all(jnp.isfinite(x)))):
                    self._reject("nan_tensor")
                    raise ValueError(
                        "rejected client update: non-finite values in "
                        f"{name}")
        return used

    def submit(self, update: ClientUpdate, model_version: int | None = None,
               now: float = 0.0, pulled_at: float | None = None,
               update_id: str | None = None) -> bool:
        """Receive one client update; fold or buffer it.

        Staleness follows :attr:`staleness_clock`: on ``"version"`` it is
        ``version - model_version`` (the server version the client pulled
        before training; ``None`` = fresh), on ``"wall"`` it is ``now -
        pulled_at`` (the service clock when the client pulled; ``None`` =
        fresh; negative skew -- a pull timestamp ahead of the server
        clock -- clamps to 0 rather than *inflating* the weight).  ``now``
        is the service clock (any monotone unit), also used for deadline
        flushes.  Malformed updates (non-positive / non-finite
        ``n_examples``, NaN/inf tensors, truncated pairs) raise
        ``ValueError`` and leave the service untouched.

        ``update_id`` makes ingestion **idempotent** under at-least-once
        delivery: a client-supplied id already inside the
        :class:`~repro.fl.comm.DedupWindow` is dropped (counted under
        rejection reason ``"duplicate"``, returns False) so a network
        retry or a WAL replay can never fold the same upload twice.  Ids
        are remembered only for *accepted* uploads -- a retry of a
        previously rejected payload gets a fresh chance.  Returns True
        when the state advanced.
        """
        if update_id is not None and update_id in self.dedup:
            self._reject("duplicate")
            return False
        with span("submit", registry=self.obs_registry):
            used = self._validate_update(update)
            if update_id is not None:
                self.dedup.add(update_id)
            if self.staleness_clock == "wall":
                tau = (0.0 if pulled_at is None
                       else max(0.0, float(now) - float(pulled_at)))
            else:
                tau = (0.0 if model_version is None
                       else max(0.0, float(self.version - model_version)))
            weight = self.staleness_weight(tau) * float(update.n_examples)
            self.n_received += 1
            self.staleness_sum += tau
            wire = (tree_bytes(update.adapters)
                    + tree_bytes(update.base_trainable))
            self.wire_bytes_received += wire
            self._m_received.inc()
            self._m_staleness.observe(tau)
            self._m_wire.inc(wire)
            for c in (used or {"none"}):
                self._m_codec.labels(codec=c).inc()
            self.buffer.add(update, weight=weight, staleness=tau, now=now,
                            wire_bytes=wire)
            self._m_buffer_depth.set(len(self.buffer))
            due = self.buffer.due(now)
        if due:
            self.flush(now=now)
            return True
        return False

    def maybe_flush(self, now: float) -> bool:
        """Deadline check for the event loop: flush if the oldest buffered
        update has waited past the deadline."""
        if len(self.buffer) and self.buffer.due(now):
            self.flush(now=now)
            return True
        return False

    def next_deadline(self) -> float | None:
        """When the buffered remainder becomes due (see
        :meth:`UpdateBuffer.next_deadline`); drive :meth:`maybe_flush`
        at this time if no upload arrives first."""
        return self.buffer.next_deadline()

    # -------------------------------------------------------------- drain --
    def flush(self, now: float = 0.0) -> ServerState:
        """Aggregate everything buffered into the live state; push the
        advanced state through the serving publish hook (if wired).

        A batch whose total mass is zero (staleness discounts can
        underflow any positive ``n_examples`` to 0) is dropped whole and
        the state does not advance: there is no convex combination to
        take, and mixing by ``0 / 0`` would publish NaNs.
        """
        if len(self.buffer) and not self.buffer.total_weight() > 0.0:
            dropped = len(self.buffer.pop())
            self.n_dropped += dropped
            self._reject("zero_mass_flush", dropped)
            self._m_buffer_depth.set(0)
            return self.state
        batch = self.buffer.pop()
        if not batch:
            return self.state
        with span("flush", registry=self.obs_registry) as sp_flush:
            self.n_flushes += 1
            self._m_flushes.inc()
            # fold arithmetic runs in fp32; bf16 is storage between
            # advances
            self._dequantize_live()
            prev_state = self.state
            if self.buffer.size == 1 and len(batch) == 1:
                with span("fold", registry=self.obs_registry) as sp:
                    self._fold_one(batch[0].update, batch[0].weight)
                    self._apply_momentum(prev_state)
                    sp.block(self.state.adapters)
            else:
                # semi-async mini-cohort: one joint aggregate, staleness
                # already folded into the weights
                with span("fold", registry=self.obs_registry) as sp:
                    self.state = self.strategy.aggregate(
                        self.state, [b.update for b in batch],
                        weights=[b.weight for b in batch],
                        backend=self.backend)
                    self.n_folded += len(batch)
                    self._m_folds.inc(len(batch))
                    self._apply_momentum(prev_state)
                    sp.block(self.state.adapters)
                # a flush is a macro-round boundary: re-anchor the
                # per-update machinery at the new (published) state; the
                # momentum buffer is cross-round server state and
                # survives the re-anchor
                self._anchor = self.state
                self._replay.clear()
                momentum = self._fold_state.momentum
                self._fold_state = self.strategy.init_fold(self.state)
                self._fold_state.momentum = momentum
            self._quantize_live()
            self._m_buffer_depth.set(len(self.buffer))
            sp_flush.block(self.state.adapters)
        self._maybe_publish()
        return self.state

    def _apply_momentum(self, prev_state: ServerState) -> None:
        """Publish ``s_old + m`` with ``m <- beta*m + (s_new - s_old)``
        over the adapters' float leaves (rank leaves pass through)."""
        beta = self.server_momentum
        if beta <= 0.0 or prev_state.adapters is None:
            return

        def _is_float(x):
            return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)

        old, new = prev_state.adapters, self.state.adapters
        m = self._fold_state.momentum
        if m is None:
            m = jax.tree.map(
                lambda x: jnp.zeros_like(x) if _is_float(x) else x, old)
        m = jax.tree.map(
            lambda mv, o, c: beta * mv + (c - o) if _is_float(c) else c,
            m, old, new)
        self._fold_state.momentum = m
        adapters = jax.tree.map(
            lambda mv, o, c: (o + mv).astype(jnp.asarray(c).dtype)
            if _is_float(c) else c, m, old, new)
        self.state = dataclasses.replace(self.state, adapters=adapters)

    def _maybe_publish(self) -> None:
        """Hot-swap hook: every ``publish_every``-th advance hands the
        live state to ``on_publish`` (e.g. a
        :meth:`~repro.serving.ServingEngine.publisher`)."""
        if self.on_publish is None:
            return
        if self.n_flushes % self.publish_every == 0:
            with span("publish", registry=self.obs_registry):
                self.on_publish(self.state)
            self.n_published += 1
            self._m_publishes.inc()

    def _fold_one(self, update: ClientUpdate, weight: float) -> None:
        # the incremental fold kernels and the replay anchor operate on
        # fp32 trees; the fused-dequant plan path only serves mini-cohort
        # flushes, so decode here (idempotent on plain uploads)
        if tree_codec(update.adapters) != "none":
            update = decode_update(update)
        if self.strategy.supports_incremental:
            # strategies build fresh FoldStates (mass/row_mass are theirs);
            # the momentum buffer is service-level state riding in the same
            # slot, so carry it across the fold
            momentum = self._fold_state.momentum
            self.state, self._fold_state = self.strategy.fold(
                self.state, update, weight, fold_state=self._fold_state,
                backend=self.backend)
            self._fold_state.momentum = momentum
        else:
            # replay: recompute the joint aggregate of every update since
            # the anchor -- exact for any strategy (flora's stacked ranks,
            # svd's truncation, rbla_norm's rescale) at O(window) cost
            if len(self._replay) >= self.replay_window:
                self._anchor = self.state
                self._replay.clear()
            self._replay.append((update, weight))
            out = self.strategy.aggregate(
                self._anchor, [u for u, _ in self._replay],
                weights=[w for _, w in self._replay], backend=self.backend)
            self.state = dataclasses.replace(out,
                                             round=self.state.round + 1)
        self.n_folded += 1
        self._m_folds.inc()

    # ------------------------------------------------- bf16 accumulators --
    def _next_key(self):
        """Fresh SR subkey; advances the service PRNG deterministically."""
        self._prng_key, sub = jax.random.split(self._prng_key)
        return sub

    def _quantize_live(self) -> None:
        """Store the live accumulators (state adapter float leaves + the
        momentum buffer) in bf16 with stochastic rounding.  FoldState
        masses stay fp32: they are denominators, and rounding them would
        bias every later mean rather than average out."""
        if self.accum_dtype is None:
            return
        if self.state.adapters is not None:
            self.state = dataclasses.replace(
                self.state,
                adapters=stochastic_round_tree(
                    self.state.adapters, self._next_key(), self.accum_dtype))
        if self._fold_state.momentum is not None:
            self._fold_state.momentum = stochastic_round_tree(
                self._fold_state.momentum, self._next_key(),
                self.accum_dtype)

    def _dequantize_live(self) -> None:
        """Promote bf16-stored accumulators back to fp32 (exact -- every
        bf16 value is fp32-representable) before fold arithmetic."""
        if self.accum_dtype is None:
            return

        def up(x):
            x = jnp.asarray(x)
            return x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x

        if self.state.adapters is not None:
            self.state = dataclasses.replace(
                self.state, adapters=jax.tree.map(up, self.state.adapters))
        if self._fold_state.momentum is not None:
            self._fold_state.momentum = jax.tree.map(
                up, self._fold_state.momentum)

    # ------------------------------------------------ durable state (WAL) --
    #: service counters captured in (and restored from) a snapshot
    _COUNTERS = ("n_received", "n_folded", "n_flushes", "n_dropped",
                 "n_published", "staleness_sum", "wire_bytes_received")

    def state_dict(self) -> dict:
        """Everything a crash-recovery snapshot must carry to resume
        **bit-identically**: the live :class:`ServerState`, the fold
        accumulator (masses, flora's segment ledger, the momentum
        buffer), the replay anchor and window, buffered uploads, the
        stochastic-rounding PRNG key, the idempotency dedup window, and
        the service counters.  Plain dict/list/array structure, ready for
        :func:`repro.checkpoint.pack_obj`; see
        :mod:`repro.fl.durability`."""

        def st(s: ServerState) -> dict:
            return {"adapters": s.adapters,
                    "base_trainable": s.base_trainable,
                    "round": int(s.round), "r_max": s.r_max,
                    "client_ranks": s.client_ranks,
                    "current_rank": s.current_rank}

        def upd(u: ClientUpdate) -> dict:
            return {"adapters": u.adapters,
                    "base_trainable": u.base_trainable,
                    "n_examples": float(u.n_examples), "rank": u.rank}

        fs = self._fold_state
        return {
            "format": 1,
            "state": st(self.state),
            "anchor": st(self._anchor),
            "fold": {"mass": float(fs.mass), "row_mass": fs.row_mass,
                     "n_folds": int(fs.n_folds), "extra": fs.extra,
                     "momentum": fs.momentum},
            "replay": [[upd(u), float(w)] for u, w in self._replay],
            "buffer": [{"update": upd(b.update), "weight": b.weight,
                        "staleness": b.staleness, "arrived": b.arrived,
                        "wire_bytes": b.wire_bytes}
                       for b in self.buffer._items],
            "prng_key": self._prng_key,
            "dedup": self.dedup.state_dict(),
            "counters": {k: getattr(self, k) for k in self._COUNTERS},
        }

    def load_state_dict(self, sd: dict) -> None:
        """Restore a :meth:`state_dict` snapshot into this service (same
        strategy/config as the service that wrote it)."""

        def st(d: dict) -> ServerState:
            return ServerState(adapters=d["adapters"],
                               base_trainable=d["base_trainable"],
                               round=d["round"], r_max=d["r_max"],
                               client_ranks=d["client_ranks"],
                               current_rank=d["current_rank"])

        def upd(d: dict) -> ClientUpdate:
            return ClientUpdate(adapters=d["adapters"],
                                base_trainable=d["base_trainable"],
                                n_examples=d["n_examples"],
                                rank=d["rank"])

        self.state = st(sd["state"])
        self._anchor = st(sd["anchor"])
        f = sd["fold"]
        self._fold_state = FoldState(mass=f["mass"],
                                     row_mass=f["row_mass"],
                                     n_folds=f["n_folds"],
                                     extra=f["extra"],
                                     momentum=f["momentum"])
        self._replay = [(upd(u), w) for u, w in sd["replay"]]
        self.buffer._items = [
            BufferedUpdate(update=upd(b["update"]), weight=b["weight"],
                           staleness=b["staleness"], arrived=b["arrived"],
                           wire_bytes=b["wire_bytes"])
            for b in sd["buffer"]]
        self._prng_key = jnp.asarray(sd["prng_key"])
        self.dedup.load_state_dict(sd["dedup"])
        for k in self._COUNTERS:
            setattr(self, k, sd["counters"][k])
        self._m_buffer_depth.set(len(self.buffer))

    # ---------------------------------------------------------- reporting --
    def mean_staleness(self) -> float:
        return self.staleness_sum / max(self.n_received, 1)


__all__ = ["AsyncAggregator", "STALENESS_SCHEDULES", "REJECT_REASONS",
           "make_staleness_fn"]
