"""Crash-recoverable FLaaS service: write-ahead log + checkpoint/restore.

The :class:`~repro.fl.AsyncAggregator` holds every byte of accumulated
aggregation state -- the live :class:`~repro.core.ServerState`, the
:class:`~repro.core.FoldState` masses and momentum, bf16 accumulators,
the stochastic-rounding PRNG key, the semi-async buffer -- in process
memory.  One server crash would lose all of it, and at FLaaS scale the
server *will* crash mid-round.  :class:`DurableAggregator` makes the
service crash-tolerant:

* **Write-ahead log** (:class:`WriteAheadLog`): every *accepted* upload
  is journaled -- still codec-encoded, int8/bf16 wire payloads go to
  disk as-is -- before it is buffered or folded, as a crc-framed record
  in an append-only segment file.  Externally driven ``flush`` /
  ``maybe_flush`` calls are journaled too, so replay reproduces the
  exact same fold grouping.
* **Periodic checkpoints**: every ``checkpoint_every`` accepted uploads
  the full service snapshot (:meth:`AsyncAggregator.state_dict`) is
  written through the hardened :mod:`repro.checkpoint.io` blob writer
  (atomic rename-commit, checksummed); the WAL rotates and segments
  fully covered by the snapshot are pruned.
* **Recovery**: on construction over a non-empty directory the newest
  *valid* checkpoint is restored (torn/corrupt ones are skipped) and the
  WAL tail is replayed through the normal ingestion path.  Because the
  fold path is deterministic under a fixed seed, the recovered state is
  **bit-identical** to the uninterrupted run; the
  :class:`~repro.fl.comm.DedupWindow` rides in the snapshot, so a replay
  overlapping a checkpoint (or a client retry racing a crash) can never
  double-fold.

Fault injection for all of this lives in :mod:`repro.fl.chaos`;
operator docs in ``docs/durability.md``.
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from typing import Any

from repro.checkpoint.io import (CheckpointError, load_blob, pack_obj,
                                 save_blob, unpack_obj)
from repro.core.strategy import ClientUpdate, ServerState
from repro.fl.async_agg import AsyncAggregator
from repro.obs import LATENCY_BUCKETS

_WAL_PREFIX = "wal-"
_CKPT_PREFIX = "ckpt-"
_FRAME_HEAD = struct.Struct("<II")     # payload length, crc32(payload)


def _update_to_obj(u: ClientUpdate) -> dict:
    return {"adapters": u.adapters, "base_trainable": u.base_trainable,
            "n_examples": float(u.n_examples), "rank": u.rank}


def _obj_to_update(d: dict) -> ClientUpdate:
    return ClientUpdate(adapters=d["adapters"],
                        base_trainable=d["base_trainable"],
                        n_examples=d["n_examples"], rank=d["rank"])


class WriteAheadLog:
    """Append-only, crc-framed, segment-rotated journal.

    Record frame: 8-byte header (payload length, crc32) + payload
    (:func:`repro.checkpoint.pack_obj` of ``[seq, kind, body]``).  A
    crash mid-append leaves a torn tail; :meth:`records` stops at the
    first frame that fails its length or checksum -- everything before
    it is trusted, everything after is discarded (the contract the
    ingestion path relies on: an upload is acknowledged only after its
    frame is written and flushed).

    Segments are ``wal-<start_seq>.log``; :meth:`rotate` starts a fresh
    segment after a checkpoint and prunes segments whose every record
    the checkpoint already covers.
    """

    def __init__(self, dirname: str, fsync: bool = True):
        self.dir = dirname
        self.fsync = bool(fsync)
        os.makedirs(dirname, exist_ok=True)
        self._fh = None
        self._segment = None
        self.n_torn = 0                  # frames discarded as torn tails
        self.bytes_written = 0
        self.n_records = 0               # appended by THIS process
        self.last_seq = 0                # highest seq on disk (incl. prior
        for seq, _, _ in self.records():  # incarnations)
            self.last_seq = max(self.last_seq, seq)

    # ----------------------------------------------------------- segments --
    def _segments(self) -> list[str]:
        names = sorted(n for n in os.listdir(self.dir)
                       if n.startswith(_WAL_PREFIX) and n.endswith(".log"))
        return [os.path.join(self.dir, n) for n in names]

    @staticmethod
    def _seg_start(path: str) -> int:
        base = os.path.basename(path)
        try:
            return int(base[len(_WAL_PREFIX):-len(".log")])
        except ValueError:
            return 0

    def _open_segment(self, start_seq: int) -> None:
        self.close()
        self._segment = os.path.join(
            self.dir, f"{_WAL_PREFIX}{start_seq:012d}.log")
        self._fh = open(self._segment, "ab")

    # ------------------------------------------------------------- append --
    def append(self, kind: str, body: Any) -> int:
        """Journal one record; returns its sequence number.  The record
        is flushed (and fsynced when configured) before this returns --
        an acknowledged append survives a process crash."""
        if self._fh is None:
            self._open_segment(self.last_seq + 1)
        seq = self.last_seq + 1
        payload = pack_obj([seq, kind, body])
        frame = _FRAME_HEAD.pack(len(payload),
                                 zlib.crc32(payload)) + payload
        self._fh.write(frame)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.last_seq = seq
        self.n_records += 1
        self.bytes_written += len(frame)
        return seq

    # --------------------------------------------------------------- read --
    def _read_segment(self, path: str, last: bool):
        with open(path, "rb") as f:
            while True:
                head = f.read(_FRAME_HEAD.size)
                if not head:
                    return
                if len(head) < _FRAME_HEAD.size:
                    self.n_torn += 1
                    return                      # torn header at the tail
                size, crc = _FRAME_HEAD.unpack(head)
                payload = f.read(size)
                if len(payload) < size or zlib.crc32(payload) != crc:
                    self.n_torn += 1
                    if not last:
                        raise CheckpointError(
                            f"corrupt WAL frame mid-stream in {path} "
                            "(not a torn tail -- refusing to skip "
                            "journaled records)")
                    return                      # torn tail: discard rest
                seq, kind, body = unpack_obj(payload)
                yield seq, kind, body

    def records(self, min_seq: int = 0):
        """Yield ``(seq, kind, body)`` in order across all segments,
        starting at ``min_seq``; tolerates a torn tail on the final
        segment (a crash mid-append)."""
        segs = self._segments()
        for i, path in enumerate(segs):
            for seq, kind, body in self._read_segment(
                    path, last=(i == len(segs) - 1)):
                if seq >= min_seq:
                    yield seq, kind, body

    # ------------------------------------------------------------- rotate --
    def rotate(self, covered_seq: int) -> None:
        """Start a fresh segment and prune segments every one of whose
        records is ``<= covered_seq`` (i.e. already inside a durable
        checkpoint)."""
        self._open_segment(self.last_seq + 1)
        segs = self._segments()
        for i, path in enumerate(segs):
            if path == self._segment:
                continue
            nxt = (self._seg_start(segs[i + 1]) if i + 1 < len(segs)
                   else None)
            # this segment's records span [start, next_start - 1]
            if nxt is not None and nxt - 1 <= covered_seq:
                try:
                    os.remove(path)
                except OSError:
                    pass

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class DurableAggregator(AsyncAggregator):
    """An :class:`~repro.fl.AsyncAggregator` whose state survives
    crashes: WAL journal before fold, periodic atomic checkpoints,
    automatic restore-last-checkpoint + WAL-replay recovery.

    Parameters (on top of :class:`AsyncAggregator`'s)
    -------------------------------------------------
    dir
        Durability directory: WAL segments (``wal-*.log``) and
        checkpoints (``ckpt-*.bin``) live here.  Construction over a
        non-empty directory **recovers**: newest valid checkpoint +
        replay of the WAL tail, bit-identical to the uninterrupted run
        (``recover=False`` skips, for tests that stage state manually).
    checkpoint_every
        Snapshot the full service state every this many accepted
        uploads (0 disables periodic snapshots; :meth:`checkpoint` is
        always available).  The WAL rotates on every checkpoint and
        covered segments are pruned, so disk stays bounded at roughly
        one checkpoint interval of uploads plus ``keep_checkpoints``
        snapshots.
    keep_checkpoints
        How many of the newest checkpoint files to retain.  More than
        one means a checkpoint torn by a crash-during-write (already
        unlikely: the blob writer is rename-commit atomic) or bit rot
        falls back to an older snapshot plus a longer WAL replay.
    wal_fsync
        fsync every WAL append (the strict at-least-once contract
        against *machine* crashes).  ``False`` trades that for speed:
        an OS-level flush still survives process crashes, which is the
        fault model of the in-process chaos harness.

    The recovery counters (``n_recoveries``, ``n_replayed``) and the
    WAL/checkpoint metrics (``fl_wal_records_total``,
    ``fl_recoveries_total``, ``fl_replayed_updates_total``,
    ``fl_checkpoint_seconds``, ``fl_restore_seconds``) feed the
    durability section of :class:`~repro.obs.ServiceHealth`.
    """

    def __init__(self, strategy, state: ServerState, *, dir: str,
                 checkpoint_every: int = 64, keep_checkpoints: int = 2,
                 wal_fsync: bool = True, recover: bool = True, **kw):
        if checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0, got {checkpoint_every}")
        if keep_checkpoints < 1:
            raise ValueError(
                f"keep_checkpoints must be >= 1, got {keep_checkpoints}")
        super().__init__(strategy, state, **kw)
        self.dir = dir
        self.checkpoint_every = int(checkpoint_every)
        self.keep_checkpoints = int(keep_checkpoints)
        self.wal = WriteAheadLog(dir, fsync=wal_fsync)
        self._inner = 0                 # >0: inside a journaled operation
        self._replaying = False
        self._ckpt_seq = 0              # wal seq covered by newest ckpt
        self._accepts_since_ckpt = 0
        self.n_recoveries = 0
        self.n_replayed = 0
        self.n_checkpoints = 0
        reg = self.obs_registry
        self._m_wal_records = reg.counter(
            "fl_wal_records_total", "records journaled to the WAL")
        self._m_wal_bytes = reg.counter(
            "fl_wal_bytes_total", "bytes appended to the WAL")
        self._m_checkpoints = reg.counter(
            "fl_checkpoints_total", "service snapshots committed")
        self._m_recoveries = reg.counter(
            "fl_recoveries_total",
            "crash recoveries (checkpoint restore and/or WAL replay)")
        self._m_replayed = reg.counter(
            "fl_replayed_updates_total",
            "WAL records re-driven through ingestion during recovery")
        self._m_ckpt_s = reg.histogram(
            "fl_checkpoint_seconds", "checkpoint write latency",
            buckets=LATENCY_BUCKETS)
        self._m_restore_s = reg.histogram(
            "fl_restore_seconds",
            "recovery latency (restore + WAL replay)",
            buckets=LATENCY_BUCKETS)
        if recover:
            self.recover()

    # ------------------------------------------------------------ journal --
    def _journal(self, kind: str, body: Any) -> int:
        before = self.wal.bytes_written
        seq = self.wal.append(kind, body)
        self._m_wal_records.inc()
        self._m_wal_bytes.inc(self.wal.bytes_written - before)
        return seq

    def submit(self, update: ClientUpdate, model_version: int | None = None,
               now: float = 0.0, pulled_at: float | None = None,
               update_id: str | None = None) -> bool:
        """Journal-then-fold ingestion: the upload is validated (garbage
        never reaches the log), deduplicated, journaled -- codec-encoded
        payload as-is -- and only then folded/buffered.  A crash between
        journal and fold is repaired by replay; a crash before the
        journal returns no acknowledgement, so the client retries and
        the dedup window keeps the retry exactly-once."""
        if self._replaying:
            return super().submit(update, model_version=model_version,
                                  now=now, pulled_at=pulled_at,
                                  update_id=update_id)
        if update_id is not None and update_id in self.dedup:
            self._reject("duplicate")
            return False
        self._validate_update(update)
        self._journal("submit", {
            "update": _update_to_obj(update), "update_id": update_id,
            "model_version": model_version, "now": now,
            "pulled_at": pulled_at})
        self._inner += 1
        try:
            advanced = super().submit(update, model_version=model_version,
                                      now=now, pulled_at=pulled_at,
                                      update_id=update_id)
        finally:
            self._inner -= 1
        self._accepts_since_ckpt += 1
        if (self.checkpoint_every
                and self._accepts_since_ckpt >= self.checkpoint_every):
            self.checkpoint()
        return advanced

    def flush(self, now: float = 0.0) -> ServerState:
        # only *externally driven* flushes are journaled -- a flush the
        # base class triggers inside a journaled submit/maybe_flush is a
        # deterministic consequence of that record and replays for free
        if not self._replaying and self._inner == 0:
            self._journal("flush", {"now": now})
        self._inner += 1
        try:
            return super().flush(now=now)
        finally:
            self._inner -= 1

    def maybe_flush(self, now: float) -> bool:
        if not self._replaying and self._inner == 0:
            self._journal("maybe_flush", {"now": now})
        self._inner += 1
        try:
            return super().maybe_flush(now=now)
        finally:
            self._inner -= 1

    # --------------------------------------------------------- checkpoint --
    def _ckpt_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"{_CKPT_PREFIX}{seq:012d}.bin")

    def _checkpoints(self) -> list[str]:
        names = sorted(n for n in os.listdir(self.dir)
                       if n.startswith(_CKPT_PREFIX) and n.endswith(".bin"))
        return [os.path.join(self.dir, n) for n in names]

    def checkpoint(self) -> str:
        """Commit one atomic full-service snapshot; rotate + prune the
        WAL; prune old checkpoints.  Returns the checkpoint path."""
        t0 = time.perf_counter()
        sd = self.state_dict()
        sd["wal_seq"] = self.wal.last_seq
        sd["durable"] = {"n_recoveries": self.n_recoveries,
                         "n_replayed": self.n_replayed}
        path = self._ckpt_path(self.wal.last_seq)
        save_blob(path, sd, fsync=self.wal.fsync)
        self._ckpt_seq = self.wal.last_seq
        self._accepts_since_ckpt = 0
        self.n_checkpoints += 1
        self._m_checkpoints.inc()
        for old in self._checkpoints()[:-self.keep_checkpoints]:
            try:
                os.remove(old)
            except OSError:
                pass
        # prune WAL segments covered by the OLDEST retained checkpoint,
        # not the newest: if the newest snapshot turns out torn/corrupt,
        # recovery falls back an epoch and must still find the records
        # between the two snapshots on disk
        retained = self._checkpoints()
        oldest = os.path.basename(retained[0])[len(_CKPT_PREFIX):-len(".bin")]
        self.wal.rotate(int(oldest))
        self._m_ckpt_s.observe(time.perf_counter() - t0)
        return path

    # ------------------------------------------------------------ recover --
    def recover(self) -> int:
        """Restore the newest valid checkpoint (skipping torn/corrupt
        ones) and replay the WAL tail through normal ingestion.  Returns
        the number of replayed records; 0 on a fresh directory.  The
        recovered service is bit-identical to one that never crashed:
        the snapshot carries the PRNG key, masses, momentum, buffer, and
        dedup window, and the WAL replay re-drives the exact submission
        sequence (duplicates are impossible -- records at or before the
        snapshot's ``wal_seq`` are skipped by sequence number, client
        retries by the restored dedup window)."""
        t0 = time.perf_counter()
        restored = False
        start_seq = 0
        for path in reversed(self._checkpoints()):
            try:
                sd = load_blob(path)
            except (CheckpointError, OSError):
                continue                 # torn/corrupt: fall back older
            self.load_state_dict(sd)
            dur = sd.get("durable", {})
            self.n_recoveries = dur.get("n_recoveries", 0)
            self.n_replayed = dur.get("n_replayed", 0)
            start_seq = sd.get("wal_seq", 0)
            self._ckpt_seq = start_seq
            restored = True
            break
        n = 0
        self._replaying = True
        try:
            for seq, kind, body in self.wal.records(min_seq=start_seq + 1):
                if kind == "submit":
                    try:
                        self.submit(_obj_to_update(body["update"]),
                                    model_version=body["model_version"],
                                    now=body["now"],
                                    pulled_at=body["pulled_at"],
                                    update_id=body["update_id"])
                    except ValueError:
                        # journaled records were validated before the
                        # append; a raise here means the negotiation
                        # config changed between incarnations -- skip,
                        # the rejection counters already recorded it
                        pass
                elif kind == "flush":
                    self.flush(now=body["now"])
                elif kind == "maybe_flush":
                    self.maybe_flush(now=body["now"])
                n += 1
        finally:
            self._replaying = False
        self._accepts_since_ckpt = 0
        if restored or n:
            self.n_recoveries += 1
            self.n_replayed += n
            self._m_recoveries.inc()
            self._m_replayed.inc(n)
            self._m_restore_s.observe(time.perf_counter() - t0)
        return n

    def close(self) -> None:
        """Release the WAL file handle (the log itself stays)."""
        self.wal.close()


__all__ = ["DurableAggregator", "WriteAheadLog"]
