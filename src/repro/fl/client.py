"""Client-side local fine-tuning (paper Alg. 2).

One jitted ``local_fit`` is compiled per (model, optimizer, shapes) and
reused across every client and round: client datasets are padded to a
common length and batches are index-sampled below the true count, so rank
and data size are *values*, not shapes.

Two modes:
* ``lora`` -- base dense kernels frozen; trainable = LoRA adapters + all
  non-LoRA'd base params (biases, convs, norms).  This is the paper's
  ZP/RBLA client.
* ``fft``  -- full fine-tune of every parameter (the FFT baseline).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.data.pipeline import sample_batch_indices
from repro.lora import attach_ranks, mask_adapters, strip_ranks
from repro.optim import Optimizer, apply_updates

Array = jax.Array
PyTree = Any


def split_base_params(params: dict, lora_specs) -> tuple[dict, dict]:
    """-> (frozen, trainable).  Freeze the 'w' of every LoRA'd dense."""
    frozen, trainable = {}, {}
    for k, v in params.items():
        if k in lora_specs:
            frozen[k] = {"w": v["w"]}
            rest = {kk: vv for kk, vv in v.items() if kk != "w"}
            if rest:
                trainable[k] = rest
        else:
            trainable[k] = v
    return frozen, trainable


def merge_base_params(frozen: dict, trainable: dict) -> dict:
    out = {}
    for k in set(frozen) | set(trainable):
        sub = {}
        sub.update(frozen.get(k, {}))
        sub.update(trainable.get(k, {}))
        out[k] = sub
    return out


def softmax_xent(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None],
                                         axis=-1))


class LocalFitResult(NamedTuple):
    adapters: PyTree           # updated adapters (lora mode) or None-like
    base_trainable: PyTree     # updated trainable base params
    loss: Array                # mean loss over local steps


def make_local_fit(model, optimizer: Optimizer, batch_size: int,
                   n_steps: int, mode: str = "lora",
                   alpha: float = 16.0) -> Callable[..., LocalFitResult]:
    """Compile the client update. Signature of the returned fn:

        local_fit(frozen_base, base_trainable, adapters, x, y, n_true, key)
    """
    if mode not in ("lora", "fft"):
        raise ValueError(mode)

    def loss_fn(trainable, ranks, frozen_base, xb, yb, rng):
        base_tr, factors = trainable
        params = merge_base_params(frozen_base, base_tr)
        adapters = attach_ranks(factors, ranks) if mode == "lora" else None
        logits = model.apply(params, adapters, xb, train=True, rng=rng)
        return softmax_xent(logits, yb)

    @jax.jit
    def local_fit(frozen_base, base_trainable, adapters, x, y, n_true, key):
        idx_key, step_key = jax.random.split(key)
        idx = sample_batch_indices(idx_key, n_true, batch_size, n_steps)
        factors, ranks = strip_ranks(adapters)
        opt_state = optimizer.init((base_trainable, factors))

        def step(carry, batch_ix):
            trainable, opt_state, rng = carry
            rng, sub = jax.random.split(rng)
            xb, yb = x[batch_ix], y[batch_ix]
            loss, grads = jax.value_and_grad(loss_fn)(
                trainable, ranks, frozen_base, xb, yb, sub)
            updates, opt_state = optimizer.update(grads, opt_state,
                                                  trainable)
            trainable = apply_updates(trainable, updates)
            if mode == "lora":
                base_tr, fac = trainable
                fac, _ = strip_ranks(mask_adapters(attach_ranks(fac, ranks)))
                trainable = (base_tr, fac)
            return (trainable, opt_state, rng), loss

        (trainable, _, _), losses = jax.lax.scan(
            step, ((base_trainable, factors), opt_state, step_key), idx)
        base_tr, fac = trainable
        ad = attach_ranks(fac, ranks) if mode == "lora" else adapters
        return LocalFitResult(ad, base_tr, jnp.mean(losses))

    return local_fit
