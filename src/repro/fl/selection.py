"""Client participation and timing policies.

Sync rounds use :func:`select_clients` (paper: full, and random 20%).
The async/event-driven modes add :class:`ClientLatencyModel`: per-client
report latencies with a heavy straggler tail, the distribution that makes
synchronous cohorts slow and staleness weighting necessary.
"""
from __future__ import annotations

import numpy as np


def select_clients(n_clients: int, round_ix: int, fraction: float = 1.0,
                   seed: int = 42) -> list[int]:
    if fraction >= 1.0:
        return list(range(n_clients))
    rng = np.random.default_rng(np.random.SeedSequence([seed, round_ix]))
    k = max(1, int(round(fraction * n_clients)))
    return sorted(rng.choice(n_clients, size=k, replace=False).tolist())


class ClientLatencyModel:
    """Two-level log-normal report latencies.

    Device heterogeneity: client ``i`` gets a persistent median latency
    ``median_s * exp(straggler_sigma * z_i)`` (log-normal across clients
    -- a few devices are *much* slower than the rest).  Per-upload
    jitter: each report multiplies that median by ``exp(sigma * z)``.

    Each client draws from its own seeded substream, so a simulation's
    latency sequence is deterministic per (seed, client) regardless of
    how server-side events interleave.
    """

    def __init__(self, n_clients: int, median_s: float = 1.0,
                 sigma: float = 0.25, straggler_sigma: float = 1.0,
                 seed: int = 42):
        if n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {n_clients}")
        if median_s <= 0:
            raise ValueError(f"median_s must be > 0, got {median_s}")
        self.n_clients = int(n_clients)
        head = np.random.default_rng(np.random.SeedSequence([seed, 0]))
        self.client_median_s = median_s * np.exp(
            straggler_sigma * head.standard_normal(self.n_clients))
        self.sigma = float(sigma)
        self._rngs = [np.random.default_rng(
            np.random.SeedSequence([seed, 1 + i]))
            for i in range(self.n_clients)]

    def sample(self, client: int) -> float:
        """Next report latency (seconds) for ``client``."""
        rng = self._rngs[client]
        return float(self.client_median_s[client]
                     * np.exp(self.sigma * rng.standard_normal()))
