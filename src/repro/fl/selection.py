"""Client participation policies (paper: full, and random 20%)."""
from __future__ import annotations

import numpy as np


def select_clients(n_clients: int, round_ix: int, fraction: float = 1.0,
                   seed: int = 42) -> list[int]:
    if fraction >= 1.0:
        return list(range(n_clients))
    rng = np.random.default_rng(np.random.SeedSequence([seed, round_ix]))
    k = max(1, int(round(fraction * n_clients)))
    return sorted(rng.choice(n_clients, size=k, replace=False).tolist())
