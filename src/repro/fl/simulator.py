"""In-process FLaaS simulator: the paper's experiment loop, end to end.

One simulation = (dataset, model, aggregation method, participation) ->
per-round global-model test accuracy.  Seeded (42, like the paper) and
deterministic.  The same simulator backs the unit tests, the paper-repro
benchmarks (Table 1, Figs. 5-10) and the examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from repro.data import ClientData, make_dataset, staircase_partition
from repro.fl.client import (make_local_fit, merge_base_params,
                             split_base_params)
from repro.fl.selection import select_clients
from repro.lora import init_adapters, set_ranks
from repro.models.paper_nets import PAPER_MODELS
from repro.optim import adam, sgd

PyTree = Any


@dataclass
class FLConfig:
    dataset: str = "mnist"
    model: str = "mlp"
    method: str = "rbla"           # any registered strategy: rbla |
                                   # zeropad | fedavg | rbla_ranked |
                                   # rbla_norm | svd | flora -- or "fft"
                                   # (full fine-tune, FedAvg on params)
    agg_backend: str = "auto"      # auto | ref | pallas | distributed
    stack_r_cap: int | None = None  # rank-changing strategies (flora):
                                    # stacked-rank cap / server storage
                                    # rank (None = the strategy default)
    n_clients: int = 10
    rounds: int = 50
    local_epochs: int = 1
    batch_size: int = 64
    lr: float = 0.01
    optimizer: str = "sgd"         # sgd (mnist/fmnist) | adam (cifar/cinic)
    r_max: int = 64
    ratio_step: float = 0.1
    alpha: float = 16.0
    participation: float = 1.0     # 1.0 = full, 0.2 = paper's random 20%
    n_per_class: int = 400
    n_test_per_class: int = 100
    seed: int = 42
    eval_batch: int = 256


@dataclass
class FLHistory:
    test_acc: list[float] = field(default_factory=list)
    train_loss: list[float] = field(default_factory=list)
    round_time_s: list[float] = field(default_factory=list)

    def rounds_to_target(self, target: float) -> int | None:
        for i, a in enumerate(self.test_acc):
            if a >= target:
                return i + 1
        return None


def run_simulation(cfg: FLConfig, verbose: bool = False) -> FLHistory:
    # "fft" resolves to the fedavg strategy (full-parameter FedAvg); every
    # other method name resolves through the registry, so a
    # register_strategy'd class is immediately runnable from FLConfig.
    # Resolve first: a typo'd method must fail before data/model setup.
    strategy = get_strategy(cfg.method)
    if cfg.stack_r_cap is not None:
        # configured copy -- registered instances are shared singletons;
        # strategies without the knob reject it loudly here
        strategy = strategy.with_options(stack_r_cap=cfg.stack_r_cap)
    key = jax.random.PRNGKey(cfg.seed)
    model = PAPER_MODELS[cfg.model]() if cfg.model != "cnn_cifar" else \
        PAPER_MODELS[cfg.model](n_dense=2 if cfg.dataset == "cifar" else 4)

    train = make_dataset(cfg.dataset, cfg.n_per_class, cfg.seed, "train")
    test = make_dataset(cfg.dataset, cfg.n_test_per_class, cfg.seed, "test")
    clients = staircase_partition(train, cfg.n_clients, cfg.r_max,
                                  cfg.ratio_step, cfg.seed)

    key, pkey, akey = jax.random.split(key, 3)
    params = model.init(pkey)
    mode = "fft" if cfg.method == "fft" else "lora"
    if mode == "lora":
        frozen_base, base_trainable = split_base_params(params,
                                                        model.lora_specs)
    else:                       # FFT trains every parameter
        frozen_base, base_trainable = {}, params
    # rank-changing strategies (flora) keep the global at a larger static
    # storage rank (the stack cap); the live rank then varies per round
    r_storage = strategy.server_storage_rank(cfg.r_max) or cfg.r_max
    global_adapters = init_adapters(akey, model.lora_specs, r_storage,
                                    cfg.r_max)
    state = ServerState(
        adapters=global_adapters if mode == "lora" else None,
        base_trainable=base_trainable, round=0, r_max=cfg.r_max)

    opt = (sgd(cfg.lr) if cfg.optimizer == "sgd" else adam(cfg.lr))
    max_n = max(len(c.x) for c in clients)
    steps = max(1, (max_n * cfg.local_epochs) // cfg.batch_size)
    local_fit = make_local_fit(model, opt, cfg.batch_size, steps, mode,
                               cfg.alpha)

    client_x = [jnp.asarray(c.x) for c in clients]
    client_y = [jnp.asarray(c.y.astype(np.int32)) for c in clients]

    @jax.jit
    def eval_logits(frozen_b, base_tr, adapters, xb):
        p = merge_base_params(frozen_b, base_tr)
        return model.apply(p, adapters if mode == "lora" else None, xb,
                           train=False)

    test_x, test_y = jnp.asarray(test.x), jnp.asarray(test.y)

    def evaluate():
        correct = 0
        for i in range(0, len(test_x), cfg.eval_batch):
            logits = eval_logits(frozen_base, base_trainable,
                                 global_adapters, test_x[i:i + cfg.eval_batch])
            correct += int(jnp.sum(jnp.argmax(logits, -1) ==
                                   test_y[i:i + cfg.eval_batch]))
        return correct / len(test_x)

    hist = FLHistory()
    rng = np.random.default_rng(cfg.seed)
    for rnd in range(cfg.rounds):
        t0 = time.time()
        part = select_clients(cfg.n_clients, rnd, cfg.participation,
                              cfg.seed)
        updates, losses = [], []
        for ci in part:
            c = clients[ci]
            fit_key = jax.random.PRNGKey(
                int(rng.integers(0, 2 ** 31)) )
            # re-slice from the (possibly round-varying, rank-grown)
            # global down to the client's rank at r_max storage: one
            # compiled local_fit serves every round, and set_ranks copies
            # -- a client must never alias the server's adapter storage
            local_ad = set_ranks(global_adapters, c.rank,
                                 r_storage=cfg.r_max)
            res = local_fit(frozen_base, base_trainable, local_ad,
                            client_x[ci], client_y[ci],
                            jnp.asarray(c.n, jnp.int32), fit_key)
            updates.append(ClientUpdate(
                adapters=res.adapters if mode == "lora" else None,
                base_trainable=res.base_trainable,
                n_examples=float(max(c.n, 1)), rank=c.rank))
            losses.append(float(res.loss))

        state = strategy.aggregate(state, updates,
                                   backend=cfg.agg_backend)
        base_trainable = state.base_trainable
        if mode == "lora":
            global_adapters = state.adapters
        acc = evaluate()
        hist.test_acc.append(acc)
        hist.train_loss.append(float(np.mean(losses)))
        hist.round_time_s.append(time.time() - t0)
        if verbose:
            print(f"[{cfg.method:>11s}] round {rnd + 1:3d} "
                  f"acc={acc:.4f} loss={hist.train_loss[-1]:.4f}")
    return hist
