"""In-process FLaaS simulator: the paper's experiment loop, end to end.

One simulation = (dataset, model, aggregation method, participation) ->
per-round global-model test accuracy.  Seeded (42, like the paper) and
deterministic.  The same simulator backs the unit tests, the paper-repro
benchmarks (Table 1, Figs. 5-10) and the examples.

Two drivers share one rig:

* :func:`run_simulation` -- synchronous cohort rounds (paper Alg. 1): the
  server waits for every selected client, aggregates once per round.
* :func:`run_async_simulation` -- event-driven FLaaS mode: each client
  reports on its own clock (log-normal latencies with a straggler tail,
  :class:`~repro.fl.selection.ClientLatencyModel`) and the server folds
  updates as they arrive through an
  :class:`~repro.fl.async_agg.AsyncAggregator`, discounting stale ones.
  The staleness clock is the server *version* (folds published), not
  wall time.  See ``docs/async.md``.

The aggregate's live rank follows the strategy's declared
``rank_contract``: fixed-rank methods serve at ``r_max`` every round,
while rank-changing ones (flora) grow and shrink it round to round --
clients always re-slice to their own rank at ``r_max`` storage, so one
compiled ``local_fit`` serves every round either way.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from repro.data import make_dataset, staircase_partition
from repro.fl.async_agg import AsyncAggregator
from repro.fl.chaos import FaultPlan
from repro.fl.client import (make_local_fit, merge_base_params,
                             split_base_params)
from repro.fl.comm import RetryPolicy
from repro.fl.durability import DurableAggregator
from repro.fl.selection import ClientLatencyModel, select_clients
from repro.lora import init_adapters, set_ranks
from repro.models.paper_nets import PAPER_MODELS
from repro.optim import adam, sgd

PyTree = Any


@dataclass
class FLConfig:
    dataset: str = "mnist"
    model: str = "mlp"
    method: str = "rbla"           # any registered strategy: rbla |
                                   # zeropad | fedavg | rbla_ranked |
                                   # rbla_norm | svd | flora -- or "fft"
                                   # (full fine-tune, FedAvg on params)
    agg_backend: str = "auto"      # auto | ref | pallas | distributed
    stack_r_cap: int | None = None  # rank-changing strategies (flora):
                                    # stacked-rank cap / server storage
                                    # rank (None = the strategy default)
    n_clients: int = 10
    rounds: int = 50
    local_epochs: int = 1
    batch_size: int = 64
    lr: float = 0.01
    optimizer: str = "sgd"         # sgd (mnist/fmnist) | adam (cifar/cinic)
    r_max: int = 64
    ratio_step: float = 0.1
    alpha: float = 16.0
    participation: float = 1.0     # 1.0 = full, 0.2 = paper's random 20%
    n_per_class: int = 400
    n_test_per_class: int = 100
    seed: int = 42
    eval_batch: int = 256


@dataclass
class AsyncFLConfig(FLConfig):
    """Event-driven FLaaS simulation (see ``docs/async.md``).

    ``buffer_size=1`` is fully async (every arrival folds immediately);
    ``buffer_size=K > 1`` and/or ``buffer_deadline_s`` is buffered
    semi-async (flush a mini-cohort on K or deadline).  Latencies are the
    two-level log-normal of :class:`~repro.fl.selection.ClientLatencyModel`;
    staleness is measured in server versions.
    """
    staleness: str = "polynomial"      # constant | polynomial | hinge
    staleness_a: float = 0.5           # decay strength (exponent / slope)
    staleness_b: float = 4.0           # hinge grace period (versions / s)
    staleness_clock: str = "version"   # version (folds behind) | wall
                                       # (simulated seconds since pull)
    buffer_size: int = 1               # semi-async: flush at K updates
    buffer_deadline_s: float | None = None   # ... or on deadline (sim s)
    latency_median_s: float = 1.0      # fleet-median report latency
    latency_sigma: float = 0.25        # per-upload jitter (log-normal)
    straggler_sigma: float = 1.0       # device heterogeneity (log-normal)
    total_updates: int | None = None   # stop after this many uploads
                                       # (None -> rounds * n_clients)
    eval_every: int | None = None      # eval cadence in uploads
                                       # (None -> n_clients)
    # -- durability (docs/durability.md): a wal_dir makes the server a
    # DurableAggregator (journal + periodic checkpoints); crash-restart
    # faults require it.  fsync is off in simulation: the fault model is
    # process crashes, and the event loop is hot.
    wal_dir: str | None = None
    checkpoint_every: int = 64         # accepted uploads per snapshot
    dedup_window: int = 1024           # update_id memory (idempotency)
    retry_base_s: float = 0.5          # client re-upload backoff (see
    retry_max: int = 4                 # repro.fl.comm.RetryPolicy)


@dataclass
class FLHistory:
    test_acc: list[float] = field(default_factory=list)
    train_loss: list[float] = field(default_factory=list)
    round_time_s: list[float] = field(default_factory=list)
    # async-mode extras (empty for sync runs): simulated service clock at
    # each eval point, and the mean staleness of the interval's uploads
    sim_time_s: list[float] = field(default_factory=list)
    mean_staleness: list[float] = field(default_factory=list)

    def rounds_to_target(self, target: float) -> int | None:
        for i, a in enumerate(self.test_acc):
            if a >= target:
                return i + 1
        return None


def _build_sim(cfg: FLConfig) -> SimpleNamespace:
    """Everything both drivers share: strategy, data, model, server
    state, the compiled local fit, and the eval closure."""
    # "fft" resolves to the fedavg strategy (full-parameter FedAvg); every
    # other method name resolves through the registry, so a
    # register_strategy'd class is immediately runnable from FLConfig.
    # Resolve first: a typo'd method must fail before data/model setup.
    strategy = get_strategy(cfg.method)
    if cfg.stack_r_cap is not None:
        # configured copy -- registered instances are shared singletons;
        # strategies without the knob reject it loudly here
        strategy = strategy.with_options(stack_r_cap=cfg.stack_r_cap)
    key = jax.random.PRNGKey(cfg.seed)
    model = PAPER_MODELS[cfg.model]() if cfg.model != "cnn_cifar" else \
        PAPER_MODELS[cfg.model](n_dense=2 if cfg.dataset == "cifar" else 4)

    train = make_dataset(cfg.dataset, cfg.n_per_class, cfg.seed, "train")
    test = make_dataset(cfg.dataset, cfg.n_test_per_class, cfg.seed, "test")
    clients = staircase_partition(train, cfg.n_clients, cfg.r_max,
                                  cfg.ratio_step, cfg.seed)

    key, pkey, akey = jax.random.split(key, 3)
    params = model.init(pkey)
    mode = "fft" if cfg.method == "fft" else "lora"
    if mode == "lora":
        frozen_base, base_trainable = split_base_params(params,
                                                        model.lora_specs)
    else:                       # FFT trains every parameter
        frozen_base, base_trainable = {}, params
    # rank-changing strategies (flora) keep the global at a larger static
    # storage rank (the stack cap); the live rank then varies per round
    r_storage = strategy.server_storage_rank(cfg.r_max) or cfg.r_max
    global_adapters = init_adapters(akey, model.lora_specs, r_storage,
                                    cfg.r_max)
    state = ServerState(
        adapters=global_adapters if mode == "lora" else None,
        base_trainable=base_trainable, round=0, r_max=cfg.r_max)

    opt = (sgd(cfg.lr) if cfg.optimizer == "sgd" else adam(cfg.lr))
    max_n = max(len(c.x) for c in clients)
    steps = max(1, (max_n * cfg.local_epochs) // cfg.batch_size)
    local_fit = make_local_fit(model, opt, cfg.batch_size, steps, mode,
                               cfg.alpha)

    client_x = [jnp.asarray(c.x) for c in clients]
    client_y = [jnp.asarray(c.y.astype(np.int32)) for c in clients]

    @jax.jit
    def eval_logits(frozen_b, base_tr, adapters, xb):
        p = merge_base_params(frozen_b, base_tr)
        return model.apply(p, adapters if mode == "lora" else None, xb,
                           train=False)

    test_x, test_y = jnp.asarray(test.x), jnp.asarray(test.y)

    def evaluate(base_trainable, adapters):
        correct = 0
        for i in range(0, len(test_x), cfg.eval_batch):
            logits = eval_logits(frozen_base, base_trainable, adapters,
                                 test_x[i:i + cfg.eval_batch])
            correct += int(jnp.sum(jnp.argmax(logits, -1) ==
                                   test_y[i:i + cfg.eval_batch]))
        return correct / len(test_x)

    return SimpleNamespace(strategy=strategy, model=model, mode=mode,
                           clients=clients, frozen_base=frozen_base,
                           state=state, local_fit=local_fit,
                           client_x=client_x, client_y=client_y,
                           evaluate=evaluate)


def run_simulation(cfg: FLConfig, verbose: bool = False) -> FLHistory:
    rig = _build_sim(cfg)
    strategy, clients = rig.strategy, rig.clients
    state = rig.state
    base_trainable, global_adapters = state.base_trainable, state.adapters

    hist = FLHistory()
    rng = np.random.default_rng(cfg.seed)
    for rnd in range(cfg.rounds):
        t0 = time.time()
        part = select_clients(cfg.n_clients, rnd, cfg.participation,
                              cfg.seed)
        updates, losses = [], []
        for ci in part:
            c = clients[ci]
            fit_key = jax.random.PRNGKey(
                int(rng.integers(0, 2 ** 31)) )
            # re-slice from the (possibly round-varying, rank-grown)
            # global down to the client's rank at r_max storage: one
            # compiled local_fit serves every round, and set_ranks copies
            # -- a client must never alias the server's adapter storage
            local_ad = set_ranks(global_adapters, c.rank,
                                 r_storage=cfg.r_max)
            res = rig.local_fit(rig.frozen_base, base_trainable, local_ad,
                                rig.client_x[ci], rig.client_y[ci],
                                jnp.asarray(c.n, jnp.int32), fit_key)
            updates.append(ClientUpdate(
                adapters=res.adapters if rig.mode == "lora" else None,
                base_trainable=res.base_trainable,
                n_examples=float(max(c.n, 1)), rank=c.rank))
            losses.append(float(res.loss))

        # donate the old global's buffers to the round: the loop only
        # ever reads the *returned* state (clients re-slice from the new
        # global, eval runs on it), so the server holds one copy of the
        # adapters instead of two -- jax hard-errors if anything were to
        # touch the donated buffers again (PR 4's no-use-after-donate
        # guard)
        state = strategy.aggregate(state, updates,
                                   backend=cfg.agg_backend, donate=True)
        base_trainable = state.base_trainable
        if rig.mode == "lora":
            global_adapters = state.adapters
        acc = rig.evaluate(base_trainable, global_adapters)
        hist.test_acc.append(acc)
        hist.train_loss.append(float(np.mean(losses)))
        hist.round_time_s.append(time.time() - t0)
        if verbose:
            print(f"[{cfg.method:>11s}] round {rnd + 1:3d} "
                  f"acc={acc:.4f} loss={hist.train_loss[-1]:.4f}")
    return hist


def run_async_simulation(cfg: AsyncFLConfig, verbose: bool = False,
                         fault_plan: FaultPlan | None = None) -> FLHistory:
    """Event-driven FLaaS loop: clients report on their own clocks.

    Each client perpetually (pull global -> local fit -> upload); the
    upload lands ``latency`` simulated seconds after dispatch and is
    folded (or buffered) by an :class:`AsyncAggregator` with its
    staleness discount.  Stops after ``total_updates`` uploads; evaluates
    every ``eval_every`` uploads, logging the simulated clock and the
    interval's mean staleness alongside accuracy.

    With ``cfg.wal_dir`` set the server is a :class:`DurableAggregator`
    (journal + periodic checkpoints); every upload carries a client
    ``update_id``, so redeliveries fold exactly once.  ``fault_plan``
    injects the :mod:`repro.fl.chaos` fault set: dropped uploads are
    retried under the config's :class:`~repro.fl.comm.RetryPolicy` with
    the same id, duplicates/corruption/truncation bounce off the dedup
    window and the ingestion front door, stale pulls train on obsolete
    globals, and ``crash_at`` points tear the server down mid-stream and
    recover it from the WAL -- the run completes either way.
    """
    rig = _build_sim(cfg)
    clients = rig.clients
    agg_kw = dict(
        staleness=cfg.staleness, staleness_a=cfg.staleness_a,
        staleness_b=cfg.staleness_b, staleness_clock=cfg.staleness_clock,
        buffer_size=cfg.buffer_size, deadline=cfg.buffer_deadline_s,
        backend=cfg.agg_backend, dedup_window=cfg.dedup_window)

    def make_agg():
        if cfg.wal_dir is not None:
            return DurableAggregator(
                rig.strategy, rig.state, dir=cfg.wal_dir,
                checkpoint_every=cfg.checkpoint_every, wal_fsync=False,
                **agg_kw)
        return AsyncAggregator(rig.strategy, rig.state, **agg_kw)

    plan = fault_plan
    if plan is not None and plan.crash_at and cfg.wal_dir is None:
        raise ValueError(
            "FaultPlan.crash_at needs cfg.wal_dir: crash-restart recovery "
            "only exists for a DurableAggregator")
    agg = make_agg()
    retry = RetryPolicy(base=cfg.retry_base_s, max_retries=cfg.retry_max,
                        seed=cfg.seed)
    latency = ClientLatencyModel(
        cfg.n_clients, median_s=cfg.latency_median_s,
        sigma=cfg.latency_sigma, straggler_sigma=cfg.straggler_sigma,
        seed=cfg.seed)

    total = cfg.total_updates or cfg.rounds * cfg.n_clients
    eval_every = cfg.eval_every or cfg.n_clients
    rng = np.random.default_rng(cfg.seed)
    # (done_time, tiebreak, client, version, pull_time, payload, uid,
    #  attempt) -- payload is the pulled snapshot on attempt 0 and the
    # already-trained ClientUpdate on retries (the client retransmits the
    # same upload, it does not retrain)
    heap: list = []
    seq = 0
    n_uploads = 0                  # upload ids handed out (-> update_id)
    past: list = []                # recent pulls for stale_pull faults
    crashed: set[int] = set()

    def dispatch(ci: int, now: float) -> None:
        nonlocal seq, n_uploads
        # the client trains on the global it pulls NOW; by the time its
        # update lands the server may have moved on -- that gap is the
        # staleness the aggregator discounts (in versions or sim-seconds,
        # per cfg.staleness_clock)
        uid = n_uploads
        n_uploads += 1
        version = agg.version
        adapters, base = agg.state.adapters, agg.state.base_trainable
        if plan is not None:
            past.append((version, adapters, base))
            del past[:-8]
            if plan.stale_pull(uid):
                version, adapters, base = past[0]   # oldest retained pull
        local_ad = None
        if rig.mode == "lora":
            local_ad = set_ranks(adapters, clients[ci].rank,
                                 r_storage=cfg.r_max)
        delay = latency.sample(ci)
        if plan is not None and plan.reorder(uid):
            delay += plan.reorder_delay_s
        heapq.heappush(heap, (now + delay, seq, ci, version, now,
                              (local_ad, base), uid, 0))
        seq += 1

    def deliver(upd, version, now, pulled_at, uid) -> None:
        """One delivery attempt through the ingestion front door; a
        rejection (poisoned tensors, truncated pairs, duplicate id) is
        counted by the aggregator and otherwise final."""
        try:
            agg.submit(upd, model_version=version, now=now,
                       pulled_at=pulled_at, update_id=f"u{uid}")
        except ValueError:
            pass

    for ci in range(cfg.n_clients):
        dispatch(ci, 0.0)

    hist = FLHistory()
    losses: list[float] = []
    stale_mark = 0.0
    eval_mark = 0                  # uploads already covered by an eval
    received = 0
    t_wall = time.time()
    while received < total:
        (now, _, ci, version, pulled_at, payload, uid,
         attempt) = heapq.heappop(heap)
        # a buffered deadline may fall before this arrival: honor it at
        # its own simulated time, not piggy-backed on the next upload
        due_t = agg.next_deadline()
        if due_t is not None and due_t < now:
            agg.maybe_flush(now=due_t)
        if attempt == 0:
            local_ad, base_snap = payload
            c = clients[ci]
            fit_key = jax.random.PRNGKey(int(rng.integers(0, 2 ** 31)))
            res = rig.local_fit(rig.frozen_base, base_snap, local_ad,
                                rig.client_x[ci], rig.client_y[ci],
                                jnp.asarray(c.n, jnp.int32), fit_key)
            losses.append(float(res.loss))
            upd = ClientUpdate(
                adapters=res.adapters if rig.mode == "lora" else None,
                base_trainable=res.base_trainable,
                n_examples=float(max(c.n, 1)), rank=c.rank)
            if plan is not None:
                if plan.corrupt(uid):
                    upd = plan.corrupt_update(upd)
                elif plan.truncate(uid):
                    upd = plan.truncate_update(upd)
        else:
            upd = payload           # retransmission of the same upload
        if plan is not None and plan.drop(uid, attempt):
            if not retry.give_up(attempt):
                # lost in transit: the client re-uploads the SAME update
                # (same id) after a jittered backoff
                heapq.heappush(heap, (now + retry.delay(attempt, salt=uid),
                                      seq, ci, version, pulled_at, upd,
                                      uid, attempt + 1))
                seq += 1
                continue            # nothing reached the server yet
            # retries exhausted: the upload is lost for good; the client
            # moves on to its next round (counts toward total so chaos
            # runs still terminate)
        else:
            deliver(upd, version, now, pulled_at, uid)
            if plan is not None and plan.duplicate(uid):
                # transport redelivery: the dedup window must fold it
                # exactly once (rejected as "duplicate")
                deliver(upd, version, now, pulled_at, uid)
        received += 1
        dispatch(ci, now)
        if (plan is not None and cfg.wal_dir is not None
                and plan.crash_now(received) and received not in crashed):
            # server crash-restart: drop the in-memory aggregator on the
            # floor and recover from checkpoint + WAL.  In-flight client
            # uploads (the heap) survive -- clients are other machines.
            crashed.add(received)
            agg.close()
            agg = make_agg()

        if received % eval_every == 0 or received == total:
            if received == total:
                agg.flush(now=now)      # drain any semi-async remainder
            acc = rig.evaluate(agg.state.base_trainable,
                               agg.state.adapters)
            interval = received - eval_mark   # the final one may be short
            hist.test_acc.append(acc)
            hist.train_loss.append(float(np.mean(losses[eval_mark:])))
            hist.round_time_s.append(time.time() - t_wall)
            hist.sim_time_s.append(now)
            hist.mean_staleness.append(
                (agg.staleness_sum - stale_mark) / max(interval, 1))
            stale_mark = agg.staleness_sum
            eval_mark = received
            t_wall = time.time()
            if verbose:
                print(f"[{cfg.method:>11s}/async] upload {received:4d} "
                      f"t={now:8.1f}s acc={acc:.4f} "
                      f"stale={hist.mean_staleness[-1]:.2f}")
    return hist
