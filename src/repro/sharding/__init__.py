from . import rules

__all__ = ["rules"]
