"""Logical sharding rules: pytree paths -> PartitionSpec.

Scheme (megatron-style TP x DP, FLaaS pod axis on top):
  batch               -> ("pod", "data") when present, else ("data",)
  fused head dims     -> "model"    (q/k/v/up projections: column parallel)
  contracting dims    -> "model"    (o/down projections: row parallel)
  vocab               -> "model" when divisible, else replicated
  MoE expert axis     -> "model"    (expert parallelism)
  LoRA adapters       -> replicated (tiny; psum'd grads)
  KV cache time axis  -> data axes when the batch axis is unshardable
                         (long_500k, global_batch=1)

Every rule degrades to replication when the dimension does not divide the
mesh axis (e.g. whisper's 51866 vocab) -- recorded via ``maybe()``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def maybe(mesh: Mesh, dim: int, axes):
    """axes if dim divides the mesh axes product, else None (replicate)."""
    return axes if dim % axis_size(mesh, axes) == 0 else None


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


# --------------------------------------------------------------- params ----
_COL = ("q", "k", "v", "xq", "xk", "xv", "gate", "up", "fc1", "q_b",
        "kv_b", "in_proj")
_ROW = ("o", "xo", "down", "fc2", "out_proj")
_REPL = ("q_a", "kv_a", "router", "proj")


def param_spec_for(path: str, leaf, mesh: Mesh, fsdp: bool = False) -> P:
    """``fsdp=True`` additionally shards the *contracting* dim of big 2-D
    kernels over the data axes.  Legitimate here even for training because
    the base is FROZEN in LoRA fine-tuning -- there are no base gradients
    to all-reduce, so zero-redundancy sharding costs only the forward
    all-gather (SSPerf iteration A1)."""
    parts = path.split("/")
    name = parts[-2] if parts[-1] in ("w", "b") else parts[-1]
    ndim = leaf.ndim
    m = "model"
    da = data_axes(mesh)

    def lead(n_extra: int, *last) -> P:
        return P(*([None] * (ndim - len(last))), *last)

    def fs(dim: int):
        return maybe(mesh, dim, da) if fsdp else None

    if parts[-1] == "b":                      # biases: shard like fan-out
        if name in _COL:
            return lead(0, maybe(mesh, leaf.shape[-1], m))
        return lead(0, None)
    if "experts" in parts:                    # (L, E, in, out): expert axis
        e_axis = ndim - 3
        spec = [None] * ndim
        if leaf.shape[e_axis] % axis_size(mesh, m) == 0:
            spec[e_axis] = m
        if fsdp and leaf.shape[-2] % axis_size(mesh, da) == 0:
            spec[ndim - 2] = da
        return P(*spec)
    if name == "table":                       # embedding (V, d)
        return P(maybe(mesh, leaf.shape[0], m), fs(leaf.shape[1]))
    if name == "lm_head" or (len(parts) >= 2 and parts[-2] == "lm_head"):
        return lead(0, fs(leaf.shape[-2]), maybe(mesh, leaf.shape[-1], m))
    if name in _COL:
        return lead(0, fs(leaf.shape[-2]), maybe(mesh, leaf.shape[-1], m))
    if name in _ROW:
        return lead(0, maybe(mesh, leaf.shape[-2], m), fs(leaf.shape[-1]))
    if name == "pos":                         # whisper learned positions
        return P(*([None] * ndim))
    return P(*([None] * ndim))                # norms, scalars, conv, misc


def param_specs(params_shapes: PyTree, mesh: Mesh,
                fsdp: bool = False) -> PyTree:
    def f(path, leaf):
        return param_spec_for(_path_str(path), leaf, mesh, fsdp)
    return jax.tree_util.tree_map_with_path(f, params_shapes)


def replicated_specs(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda leaf: P(*([None] * leaf.ndim)), tree)


def adapter_specs(ad_shapes: PyTree, mesh: Mesh) -> PyTree:
    """LoRA adapters are tiny and replicated -- EXCEPT per-expert adapters
    (A (L, E, r, d), B (L, E, out, r)) whose expert axis is sharded over
    'model' exactly like the expert weights they adapt.  Their grads then
    stay shard-local instead of being all-reduced at adapter size x E."""
    def f(path, leaf):
        path_s = _path_str(path)
        if "experts" in path_s and leaf.ndim == 4:
            e = leaf.shape[1]
            spec = [None] * leaf.ndim
            if e % axis_size(mesh, "model") == 0:
                spec[1] = "model"
            return P(*spec)
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(f, ad_shapes)


# ---------------------------------------------------------------- batch ----
def batch_specs(batch_shapes: PyTree, mesh: Mesh) -> PyTree:
    da = data_axes(mesh)

    def f(leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        ax = da if b % axis_size(mesh, da) == 0 else (
            ("data",) if b % mesh.shape["data"] == 0 else None)
        return P(ax, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(f, batch_shapes)


# ---------------------------------------------------------------- cache ----
def cache_specs(cache_shapes: PyTree, mesh: Mesh, global_batch: int,
                seq_shard_model: bool = False) -> PyTree:
    """Caches are stacked (L, B, T, ...) kv / (L, B, T, R) latent /
    (L, B, ...) mamba states.  Shard batch over data axes when divisible;
    otherwise (long_500k) shard the *time* axis of attention caches.

    ``seq_shard_model=True`` additionally shards the time axis over the
    'model' axis (SSPerf C3): decode attention has one query, so the
    partial-softmax all-reduce it induces is tiny, while the per-device
    cache shrinks by the model-axis size.  Only applied to caches without
    a model-sharded head axis (MLA latent)."""
    da = data_axes(mesh)
    batch_shardable = global_batch % axis_size(mesh, da) == 0
    if not batch_shardable and global_batch % mesh.shape["data"] == 0:
        da = ("data",)
        batch_shardable = True
    m = "model"

    def f(path, leaf):
        name = _path_str(path).split("/")[-1]
        spec = [None] * leaf.ndim
        if batch_shardable:
            spec[1] = da
        if name in ("k", "v", "xk", "xv"):        # (L,B,T,KV,hd)
            spec[3] = maybe(mesh, leaf.shape[3], m)
            if not batch_shardable:
                spec[2] = da if leaf.shape[2] % axis_size(mesh, da) == 0 \
                    else maybe(mesh, leaf.shape[2], ("data",))
        elif name in ("ckv", "kr"):               # (L,B,T,R)
            if not batch_shardable:
                spec[2] = da if leaf.shape[2] % axis_size(mesh, da) == 0 \
                    else maybe(mesh, leaf.shape[2], ("data",))
            elif seq_shard_model:
                spec[2] = maybe(mesh, leaf.shape[2], m)
        elif name == "ssm":                       # (L,B,H,P,N)
            spec[2] = maybe(mesh, leaf.shape[2], m)
        elif name == "conv":                      # (L,B,K-1,C)
            spec[3] = maybe(mesh, leaf.shape[3], m)
        return P(*spec)
    return jax.tree_util.tree_map_with_path(f, cache_shapes)


# ------------------------------------------------------------- sharding ----
def to_shardings(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shaped(shapes: PyTree, shardings: PyTree) -> PyTree:
    """Attach shardings to ShapeDtypeStructs (dry-run inputs)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
