"""Shared runtime policy for the Pallas kernel wrappers."""
from __future__ import annotations

import jax


def auto_interpret(interpret: bool | None) -> bool:
    """Resolve the ``interpret`` tri-state of a kernel wrapper.

    ``None`` (the default) auto-detects: compiled Pallas on TPU/GPU,
    interpreter mode on CPU (where Pallas cannot lower).  Explicit
    ``True`` / ``False`` pass through -- tests force ``True``; TPU callers
    that want a hard failure on accidental interpretation force ``False``.
    """
    if interpret is None:
        return jax.default_backend() not in ("tpu", "gpu")
    return interpret


def bench_env() -> dict:
    """The environment header every machine-readable benchmark emits
    (``BENCH_agg.json``, ``BENCH_serve.json``): enough to tell whether
    two committed runs are comparable -- jax version, device kind, and
    whether Pallas kernels ran compiled or in interpreter mode."""
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": len(jax.devices()),
        "pallas_interpret": auto_interpret(None),
    }
