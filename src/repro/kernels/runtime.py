"""Shared runtime policy for the Pallas kernel wrappers."""
from __future__ import annotations

import jax


def auto_interpret(interpret: bool | None) -> bool:
    """Resolve the ``interpret`` tri-state of a kernel wrapper.

    ``None`` (the default) auto-detects: compiled Pallas on TPU/GPU,
    interpreter mode on CPU (where Pallas cannot lower).  Explicit
    ``True`` / ``False`` pass through -- tests force ``True``; TPU callers
    that want a hard failure on accidental interpretation force ``False``.
    """
    if interpret is None:
        return jax.default_backend() not in ("tpu", "gpu")
    return interpret
