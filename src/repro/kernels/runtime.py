"""Shared runtime policy + counters for the Pallas kernel wrappers.

Both kernel packages (``rbla_agg``, ``lora_matmul``) used to carry their
own copy of the dispatch / trace accounting; it lives here once now,
backed by the :mod:`repro.obs` metrics registry:

* :func:`count_dispatch` -- one call per public kernel-entry dispatch,
  mirrored into the legacy ``repro.core.plan.dispatch_counter`` window so
  existing ``reset()``-based probes keep working;
* :func:`note_trace` -- called from *inside* a jitted wrapper body, so it
  fires exactly once per (re)trace; the per-entry counts are readable as
  the dict-like :data:`trace_counts` (the surface
  ``lora_matmul.ops.trace_counts`` re-exports).
"""
from __future__ import annotations

from typing import Iterator, Mapping

import jax

from repro.obs import get_registry

_KERNEL_DISPATCHES = get_registry().counter(
    "kernel_dispatches_total",
    "public kernel-entry dispatches, by entry point",
    labelnames=("entry",))
_KERNEL_TRACES = get_registry().counter(
    "kernel_traces_total",
    "jit (re)traces of kernel wrapper bodies, by entry point",
    labelnames=("entry",))


def count_dispatch(n: int = 1, kernel: str = "unknown") -> None:
    """Count ``n`` dispatches of a public kernel entry point.

    Feeds the labelled ``kernel_dispatches_total`` series and the legacy
    windowed ``plan.dispatch_counter`` (imported lazily -- plan imports
    the kernel packages, not the other way around).
    """
    from repro.core.plan import dispatch_counter
    dispatch_counter.inc(n)
    _KERNEL_DISPATCHES.labels(entry=kernel).inc(n)


def note_trace(name: str) -> None:
    """Record one jit trace of the wrapper body ``name``.  Call this from
    inside the traced function: it then runs once per (re)trace and never
    on cached-executable dispatch, which is exactly the retrace signal the
    zero-retrace CI gates watch."""
    _KERNEL_TRACES.labels(entry=name).inc()


class TraceCounts(Mapping):
    """Read-only dict view over ``kernel_traces_total`` -- the legacy
    ``lora_matmul.ops.trace_counts`` surface.  Keys appear once an entry
    has traced at least once; ``clear()`` zeroes the counts (the
    pre-registry dict supported it, so tests may rely on it)."""

    def _items(self) -> dict[str, int]:
        return {key.partition("=")[2]: int(v)
                for key, v in _KERNEL_TRACES.samples().items()}

    def __getitem__(self, name: str) -> int:
        return self._items()[name]

    def get(self, name: str, default=None):
        return self._items().get(name, default)

    def __iter__(self) -> Iterator[str]:
        return iter(self._items())

    def __len__(self) -> int:
        return len(self._items())

    def __repr__(self) -> str:
        return f"TraceCounts({self._items()!r})"

    def clear(self) -> None:
        _KERNEL_TRACES._reset()


#: the process-wide per-entry trace counts (dict-like, live)
trace_counts = TraceCounts()


def auto_interpret(interpret: bool | None) -> bool:
    """Resolve the ``interpret`` tri-state of a kernel wrapper.

    ``None`` (the default) auto-detects: compiled Pallas on TPU/GPU,
    interpreter mode on CPU (where Pallas cannot lower).  Explicit
    ``True`` / ``False`` pass through -- tests force ``True``; TPU callers
    that want a hard failure on accidental interpretation force ``False``.
    """
    if interpret is None:
        return jax.default_backend() not in ("tpu", "gpu")
    return interpret


def bench_env() -> dict:
    """The environment header every machine-readable benchmark emits
    (``BENCH_agg.json``, ``BENCH_serve.json``): enough to tell whether
    two committed runs are comparable -- jax version, device kind, and
    whether Pallas kernels ran compiled or in interpreter mode."""
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": len(jax.devices()),
        "pallas_interpret": auto_interpret(None),
    }
