"""Pure-jnp oracle for the fused LoRA matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ w + scale * (x @ a^T) @ b^T, f32 accumulation."""
    xf = x.astype(jnp.float32)
    base = xf @ w.astype(jnp.float32)
    lora = (xf @ a.astype(jnp.float32).T) @ b.astype(jnp.float32).T
    return (base + jnp.asarray(scale, jnp.float32).reshape(()) *
            lora).astype(x.dtype)
