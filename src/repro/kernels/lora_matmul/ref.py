"""Oracles and the XLA fallback for the fused LoRA matmul kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ w + scale * (x @ a^T) @ b^T, f32 accumulation."""
    xf = x.astype(jnp.float32)
    base = xf @ w.astype(jnp.float32)
    lora = (xf @ a.astype(jnp.float32).T) @ b.astype(jnp.float32).T
    return (base + jnp.asarray(scale, jnp.float32).reshape(()) *
            lora).astype(x.dtype)


def batched_lora_matmul_ref(x, w, a_rows, b_rows, off, cnt, scale):
    """Per-request python-loop oracle for the multi-adapter kernel.

    Each request i slices its own (A, B) segment out of the packed row
    buffers -- ``a_rows[off_i : off_i + cnt_i]`` / same rows of
    ``b_rows`` -- and runs the single-adapter reference on it.  Host-side
    numpy (concrete inputs only); this is the parity oracle the batched
    executables are checked against.
    """
    x = np.asarray(x)
    wf = np.asarray(w, np.float32)
    af = np.asarray(a_rows, np.float32)
    bf = np.asarray(b_rows, np.float32)
    off = np.asarray(off, np.int64).reshape(-1)
    cnt = np.asarray(cnt, np.int64).reshape(-1)
    scale = np.asarray(scale, np.float32).reshape(-1)
    out = np.empty((x.shape[0], wf.shape[1]), np.float32)
    for i in range(x.shape[0]):
        xi = x[i].astype(np.float32)
        seg = slice(off[i], off[i] + cnt[i])
        lora = (xi @ af[seg].T) @ bf[seg]
        out[i] = xi @ wf + scale[i] * lora
    return jnp.asarray(out.astype(x.dtype))


def batched_lora_matmul_segments(x, w, a_rows, b_rows, off, cnt, scale):
    """Jittable XLA segment fallback for the multi-adapter matmul.

    Same contract as :func:`batched_lora_matmul_pallas` but lowered as
    two plain matmuls with a per-request segment mask in between:

        xa   = x @ a_rows^T                       (M, R_total)
        mask = off_i <= p < off_i + cnt_i         (M, R_total)
        y    = x @ w + (scale_i * mask * xa) @ b_rows

    Offsets/counts/scales are runtime data, so one XLA executable serves
    every tenant mix; this is the CPU/GPU serving path (and the in-jit
    fallback everywhere).
    """
    xf = x.astype(jnp.float32)
    base = xf @ w.astype(jnp.float32)
    xa = xf @ a_rows.astype(jnp.float32).T            # (M, R_total)
    p = jnp.arange(a_rows.shape[0], dtype=jnp.int32)[None, :]
    off = jnp.asarray(off, jnp.int32).reshape(-1, 1)
    cnt = jnp.asarray(cnt, jnp.int32).reshape(-1, 1)
    seg = (p >= off) & (p < off + cnt)
    sc = jnp.asarray(scale, jnp.float32).reshape(-1, 1)
    lora = jnp.where(seg, xa, 0.0) @ b_rows.astype(jnp.float32)
    return (base + sc * lora).astype(x.dtype)
