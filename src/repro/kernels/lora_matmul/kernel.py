"""Fused LoRA matmul Pallas TPU kernel.

Computes  y = x @ W + scale * (x @ A^T) @ B^T  in ONE pass over x:
the low-rank path shares x's VMEM residency with the frozen-weight matmul
instead of streaming x from HBM twice (the usual two-matmul lowering).

Grid (i, j, k) over (M/bm, N/bn, K/bk); k innermost.  Accumulators live in
VMEM scratch:
  acc (bm, bn) f32 -- frozen-path partial sums
  axr (bm, r)  f32 -- x @ A^T partial sums (r <= 128 fits VMEM)
At the last k step the low-rank correction axr @ B_j^T is added and the
tile is written out.  Matmul dims should be multiples of 128 for MXU
alignment (ops.py pads otherwise).  VMEM working set per step:
bm*bk + bk*bn + r*bk + bn*r + bm*bn + bm*r floats -- defaults (256, 256,
512) with r<=128 stay under ~2 MB, well inside the ~16 MB v5e VMEM budget
with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _kernel(x_ref, w_ref, a_ref, b_ref, scale_ref, o_ref, acc_ref, axr_ref,
            *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        axr_ref[...] = jnp.zeros_like(axr_ref)

    x = x_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    axr_ref[...] += jax.lax.dot_general(
        x, a_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        lora = jax.lax.dot_general(
            axr_ref[...], b_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        y = acc_ref[...] + scale_ref[0, 0] * lora
        o_ref[...] = y.astype(o_ref.dtype)


def lora_matmul_pallas(x, w, a, b, scale, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       bk=DEFAULT_BK, interpret=True):
    """x (M,K) @ w (K,N) + scale * ((x @ a^T) @ b^T).  a: (r,K), b: (N,r).

    scale: (1,1) f32.  Shapes must tile evenly (ops.py pads).
    """
    m, k = x.shape
    _, n = w.shape
    r = a.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    n_k = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((r, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bn, r), lambda i, j, kk: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b, scale)
