"""Fused LoRA matmul Pallas TPU kernels (single- and multi-adapter).

``lora_matmul_pallas`` computes  y = x @ W + scale * (x @ A^T) @ B^T  in
ONE pass over x: the low-rank path shares x's VMEM residency with the
frozen-weight matmul instead of streaming x from HBM twice (the usual
two-matmul lowering).

Grid (i, j, k) over (M/bm, N/bn, K/bk); k innermost.  Accumulators live in
VMEM scratch:
  acc (bm, bn) f32 -- frozen-path partial sums
  axr (bm, r)  f32 -- x @ A^T partial sums (r <= 128 fits VMEM)
At the last k step the low-rank correction axr @ B_j^T is added and the
tile is written out.  Matmul dims should be multiples of 128 for MXU
alignment (ops.py pads otherwise).  VMEM working set per step:
bm*bk + bk*bn + r*bk + bn*r + bm*bn + bm*r floats -- defaults (256, 256,
512) with r<=128 stay under ~2 MB, well inside the ~16 MB v5e VMEM budget
with double buffering.

``batched_lora_matmul_pallas`` is the multi-tenant extension (the FLaaS
serving hot path): many (A, B) pairs of *heterogeneous rank* live packed
as rank-row segments of two row-major buffers, and each request row of x
selects its own segment via per-request (offset, count, scale) **data**:

  y_i = x_i @ W + scale_i * sum_p in seg_i (x_i . a_rows[p]) * b_rows[p]

Row p of ``a_rows`` and row p of ``b_rows`` belong to the same rank-one
component, so the contraction is the masked product
``(x @ a_rows^T) * seg_mask @ b_rows`` with ``seg_mask[i, p] =
off_i <= p < off_i + cnt_i`` built from a lane iota -- no gather, no
per-tenant shapes, and therefore ONE executable for every tenant mix.
The packed rank axis R_total rides whole through the grid like the
single-adapter r does; VMEM adds bm*R + 2*R*max(bk, bn) floats, so keep
R_total <= ~2048 at the default blocks (ops.py shrinks bk/bn as R
grows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..runtime import auto_interpret

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _kernel(x_ref, w_ref, a_ref, b_ref, scale_ref, o_ref, acc_ref, axr_ref,
            *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        axr_ref[...] = jnp.zeros_like(axr_ref)

    x = x_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    axr_ref[...] += jax.lax.dot_general(
        x, a_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        lora = jax.lax.dot_general(
            axr_ref[...], b_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        y = acc_ref[...] + scale_ref[0, 0] * lora
        o_ref[...] = y.astype(o_ref.dtype)


def lora_matmul_pallas(x, w, a, b, scale, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       bk=DEFAULT_BK, interpret=None):
    """x (M,K) @ w (K,N) + scale * ((x @ a^T) @ b^T).  a: (r,K), b: (N,r).

    scale: (1,1) f32.  Shapes must tile evenly (ops.py pads).
    ``interpret=None`` auto-detects (compiled on TPU/GPU, interpreter on
    CPU), matching the rbla_agg wrapper convention.
    """
    interpret = auto_interpret(interpret)
    m, k = x.shape
    _, n = w.shape
    r = a.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    n_k = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((r, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bn, r), lambda i, j, kk: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b, scale)


def _batched_kernel(x_ref, w_ref, a_ref, b_ref, off_ref, cnt_ref,
                    scale_ref, o_ref, acc_ref, axr_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        axr_ref[...] = jnp.zeros_like(axr_ref)

    x = x_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    axr_ref[...] += jax.lax.dot_general(
        x, a_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        # per-request segment mask over the packed rank axis: request i
        # owns rows [off_i, off_i + cnt_i) of a_rows/b_rows -- runtime
        # data, so one trace serves every tenant mix
        bm, r_tot = axr_ref.shape
        p = jax.lax.broadcasted_iota(jnp.int32, (bm, r_tot), 1)
        off = off_ref[...]                        # (bm, 1) int32
        cnt = cnt_ref[...]
        seg = (p >= off) & (p < off + cnt)
        axr = jnp.where(seg, axr_ref[...], 0.0) * scale_ref[...]
        lora = jax.lax.dot_general(
            axr, b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + lora).astype(o_ref.dtype)


def batched_lora_matmul_pallas(x, w, a_rows, b_rows, off, cnt, scale, *,
                               bm=DEFAULT_BM, bn=DEFAULT_BN,
                               bk=DEFAULT_BK, interpret=None):
    """Multi-adapter fused LoRA matmul over packed rank-row segments.

    x: (M, K); w: (K, N); a_rows: (R, K); b_rows: (R, N) -- B transposed
    so the packed rank axis leads both factor buffers (row p of each is
    the same rank-one component).  off/cnt: (M, 1) int32 per-request
    segment bounds into R; scale: (M, 1) f32 per-request LoRA scale.
    Shapes must tile evenly (ops.py pads; R to lane alignment with
    cnt=0 padding segments).
    """
    interpret = auto_interpret(interpret)
    m, k = x.shape
    _, n = w.shape
    r_tot = a_rows.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    n_k = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), n_k)

    return pl.pallas_call(
        functools.partial(_batched_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((r_tot, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((r_tot, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r_tot), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a_rows, b_rows, off, cnt, scale)
