"""jit'd public wrappers for the fused LoRA matmul kernels.

Follows the ``rbla_agg`` ops conventions: the public entry points
(``lora_matmul``, ``batched_lora_matmul``) are jitted and **count as one
tracked dispatch each** (``repro.core.plan.dispatch_counter``); the
``*_inline`` variants run un-jitted for use *inside* an already compiled
computation (the serving engine's fused forward, compiled plan rounds);
``interpret=None`` auto-detects (compiled Pallas on TPU/GPU, interpreter
mode on CPU where Pallas cannot lower).

``batched_lora_matmul`` is the multi-tenant serving entry: one launch
applies many packed (A, B) segments of heterogeneous rank to a mixed
request batch, with per-request adapter ids resolved against per-tenant
(offset, rank, scale) tables *inside* the jitted computation -- ids and
ranks are data, so one executable serves every tenant mix.
``trace_counts`` records how many times each public entry was traced
(the serving no-retrace guard reads it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..runtime import (auto_interpret, count_dispatch, note_trace,
                       trace_counts as runtime_trace_counts)
from .kernel import batched_lora_matmul_pallas, lora_matmul_pallas
from .ref import (batched_lora_matmul_ref, batched_lora_matmul_segments,
                  lora_matmul_ref)

#: public-entry trace counts: name -> times jax retraced it.  A retrace
#: means a new executable (new shapes/dtypes/static args); serving across
#: changing tenant mixes must not move these (tests/test_serving.py).
#: Now a live dict view over the shared ``kernel_traces_total`` metric
#: (see :mod:`repro.kernels.runtime`); ``[]`` / ``.get`` keep working.
trace_counts = runtime_trace_counts


def _pad_to(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


def lora_matmul_inline(x, w, a, b, scale, *, interpret=None, bm=256,
                       bn=256, bk=512):
    """Un-jitted :func:`lora_matmul` body (for use inside compiled
    computations)."""
    interpret = auto_interpret(interpret)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    r = a.shape[0]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    mp, np_, kp = _pad_to(m, 128), _pad_to(n, 128), _pad_to(k, 128)
    rp = _pad_to(r, 128)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    ap = jnp.pad(a, ((0, rp - r), (0, kp - k)))
    bp = jnp.pad(b, ((0, np_ - n), (0, rp - r)))
    sc = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    y = lora_matmul_pallas(x2, wp, ap, bp, sc,
                           bm=min(bm, mp), bn=min(bn, np_),
                           bk=min(bk, kp), interpret=interpret)
    return y[:m, :n].reshape(lead + (n,))


@functools.partial(jax.jit, static_argnames=("interpret", "bm", "bn", "bk"))
def _lora_matmul_jit(x, w, a, b, scale, *, interpret, bm, bn, bk):
    note_trace("lora_matmul")
    return lora_matmul_inline(x, w, a, b, scale, interpret=interpret,
                              bm=bm, bn=bn, bk=bk)


def lora_matmul(x, w, a, b, scale, *, interpret=None, bm=256, bn=256,
                bk=512):
    """x (..., K) @ w (K, N) + scale * (x @ a^T) @ b^T  via the Pallas
    kernel.  a: (r, K), b: (N, r), scale scalar."""
    count_dispatch(kernel="lora_matmul")
    return _lora_matmul_jit(x, w, a, b, scale, interpret=interpret,
                            bm=bm, bn=bn, bk=bk)


# ----------------------------------------------------- batched multi-adapter
def resolve_impl(impl: str | None) -> str:
    """Resolve the batched entry's ``impl`` tri-state: ``"auto"`` picks
    the fused Pallas kernel where it compiles (TPU/GPU) and the XLA
    segment lowering on CPU (interpreted Pallas is a debugging mode, not
    a serving path)."""
    if impl in (None, "auto"):
        return "xla" if auto_interpret(None) else "pallas"
    if impl not in ("pallas", "xla"):
        raise ValueError(
            f"unknown batched lora_matmul impl {impl!r}; options: "
            "auto | pallas | xla")
    return impl


def batched_lora_matmul_inline(x, w, a_rows, b_rows, adapter_ids, seg_off,
                               seg_rank, seg_scale, *, impl="auto",
                               interpret=None, bm=256, bn=256, bk=512):
    """Un-jitted :func:`batched_lora_matmul` body."""
    impl = resolve_impl(impl)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    ids = jnp.asarray(adapter_ids, jnp.int32).reshape(-1)
    # per-request segment metadata: a gather over runtime tables, traced
    # once -- changing ids / offsets / ranks never retraces
    off = jnp.asarray(seg_off, jnp.int32)[ids]
    cnt = jnp.asarray(seg_rank, jnp.int32)[ids]
    sc = jnp.asarray(seg_scale, jnp.float32)[ids]

    if impl == "xla":
        y = batched_lora_matmul_segments(x2, w, a_rows, b_rows, off, cnt,
                                         sc)
        return y.reshape(lead + (n,))

    r_tot = a_rows.shape[0]
    interpret = auto_interpret(interpret)
    mp, np_, kp = _pad_to(m, 128), _pad_to(n, 128), _pad_to(k, 128)
    rp = _pad_to(r_tot, 128)
    # keep the (bm, R) + 2 * (R, max(bk, bn)) VMEM residency bounded as
    # the packed rank axis grows
    while rp * max(bk, bn) > 2 ** 20 and max(bk, bn) > 128:
        bk, bn = max(bk // 2, 128), max(bn // 2, 128)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    ap = jnp.pad(a_rows, ((0, rp - r_tot), (0, kp - k)))
    bp = jnp.pad(b_rows, ((0, rp - r_tot), (0, np_ - n)))
    # padded requests carry an empty segment (cnt = 0): pure zero rows
    off = jnp.pad(off, (0, mp - m)).reshape(-1, 1)
    cnt = jnp.pad(cnt, (0, mp - m)).reshape(-1, 1)
    sc = jnp.pad(sc, (0, mp - m)).reshape(-1, 1)
    y = batched_lora_matmul_pallas(x2, wp, ap, bp, off, cnt, sc,
                                   bm=min(bm, mp), bn=min(bn, np_),
                                   bk=min(bk, kp), interpret=interpret)
    return y[:m, :n].reshape(lead + (n,))


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "bm",
                                             "bn", "bk"))
def _batched_lora_matmul_jit(x, w, a_rows, b_rows, adapter_ids, seg_off,
                             seg_rank, seg_scale, *, impl, interpret, bm,
                             bn, bk):
    note_trace("batched_lora_matmul")
    return batched_lora_matmul_inline(
        x, w, a_rows, b_rows, adapter_ids, seg_off, seg_rank, seg_scale,
        impl=impl, interpret=interpret, bm=bm, bn=bn, bk=bk)


def batched_lora_matmul(x, w, a_rows, b_rows, adapter_ids, seg_off,
                        seg_rank, seg_scale, *, impl="auto",
                        interpret=None, bm=256, bn=256, bk=512):
    """One launch, many adapters:  for every request row i of x,

        y_i = x_i @ w + seg_scale[t] * (x_i @ A_t^T) @ B_t^T,
        t = adapter_ids[i]

    where tenant t's factors live as rank-row segment
    ``[seg_off[t], seg_off[t] + seg_rank[t])`` of the packed buffers
    ``a_rows`` (R_total, K) and ``b_rows`` (R_total, N) (B transposed so
    row p of both is the same rank-one component -- the
    :class:`~repro.serving.AdapterStore` layout).  ``adapter_ids``
    (matching x's leading dims) and all three per-tenant tables are
    runtime data: one compiled executable serves every tenant mix, rank
    multiset, and table content.  A tenant with ``seg_rank[t] == 0``
    (unregistered / evicted) gets the pure base matmul.
    """
    count_dispatch(kernel="batched_lora_matmul")
    return _batched_lora_matmul_jit(
        x, w, a_rows, b_rows, adapter_ids, seg_off, seg_rank, seg_scale,
        impl=impl, interpret=interpret, bm=bm, bn=bn, bk=bk)


def lora_dense_apply(p, x, pair, alpha: float = 16.0, interpret=None):
    """Drop-in replacement for models.common.dense on 2-D kernels with a
    LoRA pair: uses the fused kernel for the matmul + low-rank path."""
    scale = alpha / jnp.maximum(pair["rank"].astype(jnp.float32), 1.0)
    y = lora_matmul(x, p["w"], pair["A"], pair["B"], scale,
                    interpret=interpret)
    if "b" in p:
        y = y + p["b"]
    return y


__all__ = ["lora_matmul", "lora_matmul_inline", "lora_dense_apply",
           "lora_matmul_ref", "batched_lora_matmul",
           "batched_lora_matmul_inline", "batched_lora_matmul_ref",
           "batched_lora_matmul_segments", "resolve_impl",
           "trace_counts"]
