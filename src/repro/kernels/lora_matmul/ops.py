"""jit'd public wrapper for the fused LoRA matmul kernel.

Handles: leading batch dims, non-aligned shape padding (to 128 multiples),
LoRA-pair plumbing (alpha/rank scale), and the interpret switch
(``None`` = auto-detect: compiled Pallas on TPU/GPU, interpreter mode on
CPU where Pallas cannot lower).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..runtime import auto_interpret
from .kernel import lora_matmul_pallas
from .ref import lora_matmul_ref


def _pad_to(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


@functools.partial(jax.jit, static_argnames=("interpret", "bm", "bn", "bk"))
def lora_matmul(x, w, a, b, scale, *, interpret=None, bm=256, bn=256,
                bk=512):
    """x (..., K) @ w (K, N) + scale * (x @ a^T) @ b^T  via the Pallas
    kernel.  a: (r, K), b: (N, r), scale scalar."""
    interpret = auto_interpret(interpret)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    r = a.shape[0]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    mp, np_, kp = _pad_to(m, 128), _pad_to(n, 128), _pad_to(k, 128)
    rp = _pad_to(r, 128)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    ap = jnp.pad(a, ((0, rp - r), (0, kp - k)))
    bp = jnp.pad(b, ((0, np_ - n), (0, rp - r)))
    sc = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    y = lora_matmul_pallas(x2, wp, ap, bp, sc,
                           bm=min(bm, mp), bn=min(bn, np_),
                           bk=min(bk, kp), interpret=interpret)
    return y[:m, :n].reshape(lead + (n,))


def lora_dense_apply(p, x, pair, alpha: float = 16.0, interpret=None):
    """Drop-in replacement for models.common.dense on 2-D kernels with a
    LoRA pair: uses the fused kernel for the matmul + low-rank path."""
    scale = alpha / jnp.maximum(pair["rank"].astype(jnp.float32), 1.0)
    y = lora_matmul(x, p["w"], pair["A"], pair["B"], scale,
                    interpret=interpret)
    if "b" in p:
        y = y + p["b"]
    return y


__all__ = ["lora_matmul", "lora_dense_apply", "lora_matmul_ref"]
