"""Oracle for the SSD scan kernel: the model-zoo chunked implementation
(itself property-tested against the sequential recurrence in
tests/test_model_properties.py)."""
from __future__ import annotations

from repro.models.mamba import ssd_chunked


def ssd_scan_ref(xdt, dta, bm, cm, chunk: int):
    """Returns (y (B,L,H,P), h_final (B,H,P,N))."""
    return ssd_chunked(xdt, dta, bm, cm, chunk)
