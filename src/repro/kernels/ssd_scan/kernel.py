"""Chunked SSD (Mamba2 state-space duality) Pallas TPU kernel.

One grid step processes one (batch, head, chunk) tile:

  intra-chunk:  Y_diag = (C B^T  *  L) @ (dt*x)       -- MXU matmuls
  inter-chunk:  Y_off  = (C h_prev^T) * exp(A_cs)
  state update: h      = h_prev * exp(A_tot) + (B * decay)^T (dt*x)

The chunk axis is the LAST grid dimension, which Pallas TPU executes
sequentially per (b, h) tile -- the running state h lives in VMEM scratch
and persists across chunk iterations (the standard sequential-grid carry
trick), so the recurrence never round-trips HBM.

Cumulative sums are computed as lower-triangular matmuls (MXU-friendly;
avoids 1-D scan lowering inside the kernel).

VMEM working set per step (Q=chunk, N=state, P=head_dim, f32):
Q*P + 2*Q*N + 3*Q*Q + P*N + Q  floats -- for (256, 128, 64):
~0.9 MB, comfortably double-bufferable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, dta_ref, b_ref, c_ref, o_ref, hout_ref, h_ref, *,
            n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    q = xdt_ref.shape[2]
    xdt = xdt_ref[0, 0].astype(jnp.float32)         # (Q, P)
    a = dta_ref[0, 0].astype(jnp.float32)           # (Q, 1)
    bm = b_ref[0].astype(jnp.float32)               # (Q, N)
    cm = c_ref[0].astype(jnp.float32)               # (Q, N)

    # cumulative sum via lower-triangular (inclusive) matmul
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril_inc = (cols <= rows).astype(jnp.float32)   # (Q, Q)
    a_cs = jax.lax.dot_general(tril_inc, a, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (Q,1)

    # L[i, j] = exp(a_cs[i] - a_cs[j]) for j <= i (segment sums include
    # steps j+1..i: subtract a[j] back out of the exclusive form)
    seg = a_cs - a_cs.T                              # (Q, Q) inclusive diff
    L = jnp.where(cols <= rows, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(scores * L, xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    h_prev = h_ref[...]                              # (P, N)
    y_off = jax.lax.dot_general(cm, h_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(a_cs)                    # (Q, P)

    a_tot = a_cs[q - 1, 0]
    decay = jnp.exp(a_tot - a_cs)                    # (Q, 1)
    state_c = jax.lax.dot_general(xdt, bm * decay,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_new = h_prev * jnp.exp(a_tot) + state_c        # (P, N)
    h_ref[...] = h_new

    o_ref[0, 0] = (y_diag + y_off).astype(o_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan_pallas(xdt, dta, bm, cm, chunk: int, *, interpret=True):
    """xdt: (B, L, H, P) pre-scaled inputs; dta: (B, L, H); bm/cm: (B, L, N).

    Returns (y (B, L, H, P), h_final (B, H, P, N)).
    """
    b, l, h, p = xdt.shape
    n = bm.shape[-1]
    q = min(chunk, l)
    while l % q:
        q -= 1
    nc = l // q
    grid = (b, h, nc)

    # layouts: chunk-major so each grid step sees contiguous (Q, *) blocks
    xdt_r = xdt.transpose(0, 2, 1, 3)                # (B, H, L, P)
    dta_r = dta.transpose(0, 2, 1)[..., None]        # (B, H, L, 1)

    y, h_out = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, p), xdt.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), xdt.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdt_r, dta_r, bm, cm)
    return y.transpose(0, 2, 1, 3), h_out
