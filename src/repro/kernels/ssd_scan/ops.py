"""jit'd wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from ..runtime import auto_interpret
from .kernel import ssd_scan_pallas
from .ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt, dta, bm, cm, chunk: int = 256, *, interpret=None):
    """Chunked SSD: xdt (B,L,H,P) pre-scaled by dt; dta (B,L,H);
    bm/cm (B,L,N).  Returns (y, h_final).  ``interpret=None`` auto-detects
    (compiled on TPU/GPU, interpreter on CPU)."""
    return ssd_scan_pallas(xdt, dta, bm, cm, chunk,
                           interpret=auto_interpret(interpret))


__all__ = ["ssd_scan", "ssd_scan_ref"]
