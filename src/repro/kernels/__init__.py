"""Pallas TPU kernels for the LoRA-FL hot spots.

Wrappers default to ``interpret=None`` (auto-detect): real Pallas lowering
on TPU/GPU, interpreter mode on CPU.  Validated in interpreter mode on CPU
against the ref.py oracles; pass ``interpret=False`` to force compilation.
"""
from .lora_matmul.ops import (batched_lora_matmul,
                              batched_lora_matmul_inline, lora_dense_apply,
                              lora_matmul, lora_matmul_inline)
from .lora_matmul.ref import (batched_lora_matmul_ref,
                              batched_lora_matmul_segments, lora_matmul_ref)
from .rbla_agg.ops import (axpy_fold, flora_stack, packed_agg,
                           packed_robust, packed_stack, rbla_agg)
from .rbla_agg.ref import (axpy_fold_ref, flora_stack_ref, packed_agg_ref,
                           packed_robust_ref, packed_robust_xla,
                           packed_stack_ref, rbla_agg_ref)
from .ssd_scan.ops import ssd_scan
from .ssd_scan.ref import ssd_scan_ref

__all__ = ["lora_dense_apply", "lora_matmul", "lora_matmul_inline",
           "lora_matmul_ref", "batched_lora_matmul",
           "batched_lora_matmul_inline", "batched_lora_matmul_ref",
           "batched_lora_matmul_segments",
           "axpy_fold", "axpy_fold_ref", "flora_stack", "flora_stack_ref",
           "packed_agg", "packed_agg_ref", "packed_robust",
           "packed_robust_ref", "packed_robust_xla",
           "packed_stack", "packed_stack_ref",
           "rbla_agg", "rbla_agg_ref", "ssd_scan", "ssd_scan_ref"]
