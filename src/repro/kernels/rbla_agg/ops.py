"""jit'd wrapper: pad to tile alignment, flatten trailing dims, dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..runtime import auto_interpret
from .kernel import rbla_agg_pallas
from .ref import rbla_agg_ref


def _pad_to(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


#: legacy method names -> the kernel's two normalization modes.  FedAvg at
#: kernel level is zeropad with full-rank masks (see FedAvgStrategy).
_NORM_BY = {"rbla": "mask", "zeropad": "weight"}


@functools.partial(jax.jit, static_argnames=("method", "interpret"))
def rbla_agg(x, ranks, weights, *, method: str = "rbla", interpret=None):
    """Aggregate stacked client tensors (N, R, *dims) with rank-row masks.

    Trailing dims are flattened into D; padding rows/cols are masked out of
    the result.  Matches ``repro.core.rbla_leaf`` semantics.
    ``interpret=None`` auto-detects: compiled on TPU/GPU, interpreter on
    CPU.
    """
    interpret = auto_interpret(interpret)
    try:
        norm_by = _NORM_BY[method]
    except KeyError:
        raise ValueError(f"unknown kernel method {method!r}; options: "
                         f"{sorted(_NORM_BY)}") from None
    n, r = x.shape[:2]
    lead = x.shape[2:]
    d = 1
    for v in lead:
        d *= v
    x2 = x.reshape(n, r, d)
    rp, dp = _pad_to(r, 8), _pad_to(d, 128)
    x2 = jnp.pad(x2, ((0, 0), (0, rp - r), (0, dp - d)))
    out = rbla_agg_pallas(x2, jnp.asarray(ranks, jnp.int32),
                          jnp.asarray(weights, jnp.float32),
                          norm_by=norm_by, interpret=interpret)
    return out[:r, :d].reshape((r,) + lead)


__all__ = ["rbla_agg", "rbla_agg_ref"]
