"""jit'd wrapper: pad to tile alignment, flatten trailing dims, dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..runtime import auto_interpret
from .kernel import axpy_fold_pallas, flora_stack_pallas, rbla_agg_pallas
from .ref import axpy_fold_ref, flora_stack_ref, rbla_agg_ref


def _pad_to(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


#: legacy method names -> the kernel's two normalization modes.  FedAvg at
#: kernel level is zeropad with full-rank masks (see FedAvgStrategy).
_NORM_BY = {"rbla": "mask", "zeropad": "weight"}


@functools.partial(jax.jit, static_argnames=("method", "interpret"))
def rbla_agg(x, ranks, weights, *, method: str = "rbla", interpret=None):
    """Aggregate stacked client tensors (N, R, *dims) with rank-row masks.

    Trailing dims are flattened into D; padding rows/cols are masked out of
    the result.  Matches ``repro.core.rbla_leaf`` semantics.
    ``interpret=None`` auto-detects: compiled on TPU/GPU, interpreter on
    CPU.
    """
    interpret = auto_interpret(interpret)
    try:
        norm_by = _NORM_BY[method]
    except KeyError:
        raise ValueError(f"unknown kernel method {method!r}; options: "
                         f"{sorted(_NORM_BY)}") from None
    n, r = x.shape[:2]
    lead = x.shape[2:]
    d = 1
    for v in lead:
        d *= v
    x2 = x.reshape(n, r, d)
    rp, dp = _pad_to(r, 8), _pad_to(d, 128)
    x2 = jnp.pad(x2, ((0, 0), (0, rp - r), (0, dp - d)))
    out = rbla_agg_pallas(x2, jnp.asarray(ranks, jnp.int32),
                          jnp.asarray(weights, jnp.float32),
                          norm_by=norm_by, interpret=interpret)
    return out[:r, :d].reshape((r,) + lead)


@functools.partial(jax.jit, static_argnames=("segs", "out_rows",
                                             "interpret"))
def flora_stack(x, scales, *, segs: tuple[int, ...], out_rows: int,
                interpret=None):
    """Stack contributors' leading rank rows (FLoRA aggregation):

        out[off_i : off_i + segs[i]] = scales[i] * x[i, :segs[i]]

    with ``off_i`` the running sum of ``segs`` -- a pure copy/scale, no
    reduction.  x: (N, R, *dims); trailing dims are flattened into D and
    restored; lane/sublane padding is stripped from the result.  ``segs``
    must be static (the output layout depends on them); recompiles per
    distinct cohort rank multiset.
    """
    interpret = auto_interpret(interpret)
    n, r = x.shape[:2]
    lead = x.shape[2:]
    d = 1
    for v in lead:
        d *= v
    x2 = x.reshape(n, r, d)
    rp, dp = _pad_to(max(r, 1), 8), _pad_to(d, 128)
    op = _pad_to(max(out_rows, 1), 8)
    x2 = jnp.pad(x2, ((0, 0), (0, rp - r), (0, dp - d)))
    out = flora_stack_pallas(x2, jnp.asarray(scales, jnp.float32),
                             segs=segs, out_rows=op, interpret=interpret)
    return out[:out_rows, :d].reshape((out_rows,) + lead)


@functools.partial(jax.jit, static_argnames=("interpret",))
def axpy_fold(y, x, alpha, *, interpret=None):
    """Fold one update into the live state: ``y + alpha * (x - y)``.

    y, x: (R, *dims) with the rank-row axis leading; ``alpha`` is a scalar
    (uniform server mixing, FedAsync-style) or an (R,) vector (per-row
    mixing -- RBLA's running masked mean folds only the rows the arriving
    client owns).  Trailing dims are flattened into D; sublane/lane
    padding is stripped from the result.  This is the async aggregation
    service's per-update hot path: cost is O(R*D) regardless of how many
    clients ever reported.
    """
    interpret = auto_interpret(interpret)
    r = y.shape[0]
    lead = y.shape[1:]
    d = 1
    for v in lead:
        d *= v
    a = jnp.broadcast_to(jnp.asarray(alpha, jnp.float32), (r,))
    y2 = y.reshape(r, d)
    x2 = x.reshape(r, d)
    rp, dp = _pad_to(max(r, 1), 8), _pad_to(max(d, 1), 128)
    y2 = jnp.pad(y2, ((0, rp - r), (0, dp - d)))
    x2 = jnp.pad(x2, ((0, rp - r), (0, dp - d)))
    a = jnp.pad(a, (0, rp - r))
    out = axpy_fold_pallas(y2, x2, a, interpret=interpret)
    return out[:r, :d].reshape((r,) + lead)


__all__ = ["rbla_agg", "rbla_agg_ref", "flora_stack", "flora_stack_ref",
           "axpy_fold", "axpy_fold_ref"]
