"""jit'd wrappers: pad to tile alignment, flatten trailing dims, dispatch.

Two tiers:

* the public entry points (``rbla_agg``, ``flora_stack``, ``axpy_fold``,
  ``packed_agg``, ``packed_stack``) are jitted and **count as one tracked
  dispatch each** (``repro.core.plan.dispatch_counter``) -- they are the
  per-pair legacy path the aggregation benchmarks compare against;
* the ``*_inline`` variants run un-jitted for use *inside* an already
  compiled plan round (``repro.core.plan``), where a whole FL round is a
  single traced function and extra jit layers would only add overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..runtime import auto_interpret, count_dispatch, note_trace
from .kernel import (axpy_fold_pallas, flora_stack_pallas,
                     packed_agg_pallas, packed_robust_pallas,
                     packed_stack_pallas, rbla_agg_pallas)
from .ref import (axpy_fold_ref, flora_stack_ref, packed_agg_ref,
                  packed_robust_ref, packed_stack_ref, rbla_agg_ref)


def _pad_to(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


#: legacy method names -> the kernel's two normalization modes.  FedAvg at
#: kernel level is zeropad with full-rank masks (see FedAvgStrategy).
_NORM_BY = {"rbla": "mask", "zeropad": "weight"}


def rbla_agg_inline(x, ranks, weights, *, method: str = "rbla",
                    interpret=None):
    """Un-jitted :func:`rbla_agg` body (for use inside compiled plans)."""
    interpret = auto_interpret(interpret)
    try:
        norm_by = _NORM_BY[method]
    except KeyError:
        raise ValueError(f"unknown kernel method {method!r}; options: "
                         f"{sorted(_NORM_BY)}") from None
    n, r = x.shape[:2]
    lead = x.shape[2:]
    d = 1
    for v in lead:
        d *= v
    x2 = x.reshape(n, r, d)
    rp, dp = _pad_to(r, 8), _pad_to(d, 128)
    x2 = jnp.pad(x2, ((0, 0), (0, rp - r), (0, dp - d)))
    out = rbla_agg_pallas(x2, jnp.asarray(ranks, jnp.int32),
                          jnp.asarray(weights, jnp.float32),
                          norm_by=norm_by, interpret=interpret)
    return out[:r, :d].reshape((r,) + lead)


@functools.partial(jax.jit, static_argnames=("method", "interpret"))
def _rbla_agg_jit(x, ranks, weights, *, method, interpret):
    note_trace("rbla_agg")
    return rbla_agg_inline(x, ranks, weights, method=method,
                           interpret=interpret)


def rbla_agg(x, ranks, weights, *, method: str = "rbla", interpret=None):
    """Aggregate stacked client tensors (N, R, *dims) with rank-row masks.

    Trailing dims are flattened into D; padding rows/cols are masked out of
    the result.  Matches ``repro.core.rbla_leaf`` semantics.
    ``interpret=None`` auto-detects: compiled on TPU/GPU, interpreter on
    CPU.
    """
    count_dispatch(kernel="rbla_agg")
    return _rbla_agg_jit(x, ranks, weights, method=method,
                         interpret=interpret)


def packed_agg_inline(x, masks, weights, prev=None, *,
                      norm_by: str = "mask", norm_restore: bool = False,
                      scales=None, out_dtype=None, interpret=None):
    """Un-jitted fused-bucket aggregation (the compiled plan's hot op).

    ``x``: (N, R, *dims) packed rows spanning many pairs; ``masks``:
    (N, R) per-row owner indicators; ``prev``: (R, *dims) packed previous
    global retained where no participant owns a row (``norm_by="mask"``
    only).  ``norm_restore`` fuses rbla_norm's per-row norm restoration
    (zero padding is norm-neutral).  Trailing dims flatten into D;
    padding is stripped.

    ``scales``: optional (N, R) f32 per-row dequantization scales fused
    on the load (int8 transport); padded rows get scale 1 (they have no
    owner either way).  ``out_dtype`` sets the output dtype -- required
    when ``x`` is a wire dtype; ``prev`` is staged in the *output* dtype,
    never the wire dtype.
    """
    interpret = auto_interpret(interpret)
    n, r = x.shape[:2]
    lead = x.shape[2:]
    d = 1
    for v in lead:
        d *= v
    x2 = x.reshape(n, r, d)
    rp, dp = _pad_to(max(r, 1), 8), _pad_to(max(d, 1), 128)
    x2 = jnp.pad(x2, ((0, 0), (0, rp - r), (0, dp - d)))
    m2 = jnp.pad(jnp.asarray(masks, jnp.float32), ((0, 0), (0, rp - r)))
    s2 = None
    if scales is not None:
        s2 = jnp.pad(jnp.asarray(scales, jnp.float32),
                     ((0, 0), (0, rp - r)), constant_values=1.0)
    pv = None
    if prev is not None:
        pv = jnp.pad(prev.reshape(r, d).astype(out_dtype or x2.dtype),
                     ((0, rp - r), (0, dp - d)))
    out = packed_agg_pallas(x2, m2, jnp.asarray(weights, jnp.float32), pv,
                            norm_by=norm_by, norm_restore=norm_restore,
                            scales=s2, out_dtype=out_dtype,
                            interpret=interpret)
    return out[:r, :d].reshape((r,) + lead)


@functools.partial(jax.jit, static_argnames=("norm_by", "norm_restore",
                                             "out_dtype", "interpret"))
def _packed_agg_jit(x, masks, weights, prev, scales, *, norm_by,
                    norm_restore, out_dtype, interpret):
    note_trace("packed_agg")
    return packed_agg_inline(x, masks, weights, prev, norm_by=norm_by,
                             norm_restore=norm_restore, scales=scales,
                             out_dtype=out_dtype, interpret=interpret)


def packed_agg(x, masks, weights, prev=None, *, norm_by: str = "mask",
               norm_restore: bool = False, scales=None, out_dtype=None,
               interpret=None):
    """Jitted :func:`packed_agg_inline` (standalone use and tests)."""
    count_dispatch(kernel="packed_agg")
    return _packed_agg_jit(x, masks, weights, prev, scales, norm_by=norm_by,
                           norm_restore=norm_restore, out_dtype=out_dtype,
                           interpret=interpret)


def packed_robust_inline(x, masks, weights, prev=None, *, mode: str,
                         clip_norm: float = 0.0, trim_frac: float = 0.0,
                         scales=None, out_dtype=None, interpret=None):
    """Un-jitted Byzantine-robust bucket aggregation (the compiled plan's
    hot op for the ``robustness != "none"`` strategies).

    Same packed layout as :func:`packed_agg_inline`; ``mode`` selects
    norm clipping, per-coordinate trimmed mean, or coordinate-wise
    median (see ``kernel.packed_robust_pallas``).  Padding is harmless:
    padded rows have no owner (they retain the zero-padded prev), padded
    columns are zero for every owner and cannot shift a row norm or an
    order statistic off the stripped region.  ``scales``/``out_dtype``
    as in :func:`packed_agg_inline` (dequant applied before clip/sort).
    """
    interpret = auto_interpret(interpret)
    n, r = x.shape[:2]
    lead = x.shape[2:]
    d = 1
    for v in lead:
        d *= v
    x2 = x.reshape(n, r, d)
    rp, dp = _pad_to(max(r, 1), 8), _pad_to(max(d, 1), 128)
    x2 = jnp.pad(x2, ((0, 0), (0, rp - r), (0, dp - d)))
    m2 = jnp.pad(jnp.asarray(masks, jnp.float32), ((0, 0), (0, rp - r)))
    s2 = None
    if scales is not None:
        s2 = jnp.pad(jnp.asarray(scales, jnp.float32),
                     ((0, 0), (0, rp - r)), constant_values=1.0)
    pv = None
    if prev is not None:
        pv = jnp.pad(prev.reshape(r, d).astype(out_dtype or x2.dtype),
                     ((0, rp - r), (0, dp - d)))
    out = packed_robust_pallas(x2, m2, jnp.asarray(weights, jnp.float32),
                               pv, mode=mode, clip_norm=clip_norm,
                               trim_frac=trim_frac, scales=s2,
                               out_dtype=out_dtype, interpret=interpret)
    return out[:r, :d].reshape((r,) + lead)


@functools.partial(jax.jit, static_argnames=("mode", "clip_norm",
                                             "trim_frac", "out_dtype",
                                             "interpret"))
def _packed_robust_jit(x, masks, weights, prev, scales, *, mode, clip_norm,
                       trim_frac, out_dtype, interpret):
    note_trace("packed_robust")
    return packed_robust_inline(x, masks, weights, prev, mode=mode,
                                clip_norm=clip_norm, trim_frac=trim_frac,
                                scales=scales, out_dtype=out_dtype,
                                interpret=interpret)


def packed_robust(x, masks, weights, prev=None, *, mode: str,
                  clip_norm: float = 0.0, trim_frac: float = 0.0,
                  scales=None, out_dtype=None, interpret=None):
    """Jitted :func:`packed_robust_inline` (standalone use and tests)."""
    count_dispatch(kernel="packed_robust")
    return _packed_robust_jit(x, masks, weights, prev, scales, mode=mode,
                              clip_norm=float(clip_norm),
                              trim_frac=float(trim_frac),
                              out_dtype=out_dtype, interpret=interpret)


def packed_stack_inline(x, scales, prev=None, *, copies_x=(),
                        copies_prev=(), out_rows: int, interpret=None):
    """Un-jitted fused stacking over a packed bucket (flora plan path).

    ``x``: (N, R_in, D); ``scales``: (S,); ``prev``: (R_prev, D) or None;
    the static ``copies_*`` describe every (pair, layer, contributor)
    placement (see ``packed_stack_pallas``).  D is padded to lane
    alignment and stripped; row padding never collides with copies.
    """
    interpret = auto_interpret(interpret)
    n, r_in, d = x.shape
    rp, dp = _pad_to(max(r_in, 1), 8), _pad_to(max(d, 1), 128)
    op = _pad_to(max(out_rows, 1), 8)
    x2 = jnp.pad(x, ((0, 0), (0, rp - r_in), (0, dp - d)))
    pv = None
    if prev is not None:
        r_prev = prev.shape[0]
        pv = jnp.pad(prev, ((0, _pad_to(max(r_prev, 1), 8) - r_prev),
                            (0, dp - d)))
    out = packed_stack_pallas(x2, jnp.asarray(scales, jnp.float32), pv,
                              copies_x=tuple(copies_x),
                              copies_prev=tuple(copies_prev),
                              out_rows=op, interpret=interpret)
    return out[:out_rows, :d]


@functools.partial(jax.jit, static_argnames=("copies_x", "copies_prev",
                                             "out_rows", "interpret"))
def _packed_stack_jit(x, scales, prev, *, copies_x, copies_prev, out_rows,
                      interpret):
    note_trace("packed_stack")
    return packed_stack_inline(x, scales, prev, copies_x=copies_x,
                               copies_prev=copies_prev, out_rows=out_rows,
                               interpret=interpret)


def packed_stack(x, scales, prev=None, *, copies_x=(), copies_prev=(),
                 out_rows: int, interpret=None):
    """Jitted :func:`packed_stack_inline` (standalone use and tests)."""
    count_dispatch(kernel="packed_stack")
    return _packed_stack_jit(x, scales, prev, copies_x=tuple(copies_x),
                             copies_prev=tuple(copies_prev),
                             out_rows=out_rows, interpret=interpret)


def flora_stack_inline(x, scales, *, segs: tuple[int, ...], out_rows: int,
                       interpret=None):
    """Un-jitted :func:`flora_stack` body."""
    interpret = auto_interpret(interpret)
    n, r = x.shape[:2]
    lead = x.shape[2:]
    d = 1
    for v in lead:
        d *= v
    x2 = x.reshape(n, r, d)
    rp, dp = _pad_to(max(r, 1), 8), _pad_to(d, 128)
    op = _pad_to(max(out_rows, 1), 8)
    x2 = jnp.pad(x2, ((0, 0), (0, rp - r), (0, dp - d)))
    out = flora_stack_pallas(x2, jnp.asarray(scales, jnp.float32),
                             segs=segs, out_rows=op, interpret=interpret)
    return out[:out_rows, :d].reshape((out_rows,) + lead)


@functools.partial(jax.jit, static_argnames=("segs", "out_rows",
                                             "interpret"))
def _flora_stack_jit(x, scales, *, segs, out_rows, interpret):
    note_trace("flora_stack")
    return flora_stack_inline(x, scales, segs=segs, out_rows=out_rows,
                              interpret=interpret)


def flora_stack(x, scales, *, segs: tuple[int, ...], out_rows: int,
                interpret=None):
    """Stack contributors' leading rank rows (FLoRA aggregation):

        out[off_i : off_i + segs[i]] = scales[i] * x[i, :segs[i]]

    with ``off_i`` the running sum of ``segs`` -- a pure copy/scale, no
    reduction.  x: (N, R, *dims); trailing dims are flattened into D and
    restored; lane/sublane padding is stripped from the result.  ``segs``
    must be static (the output layout depends on them); recompiles per
    distinct cohort rank multiset.
    """
    count_dispatch(kernel="flora_stack")
    return _flora_stack_jit(x, scales, segs=segs, out_rows=out_rows,
                            interpret=interpret)


def axpy_fold_inline(y, x, alpha, *, interpret=None, sr_key=None):
    """Un-jitted :func:`axpy_fold` body (for use inside compiled plans --
    the packed per-update fold runs one of these per bucket).

    ``sr_key``: optional PRNG key for *quantized accumulators* -- the
    fold runs on an fp32 view of ``y`` and the result is stochastically
    rounded back to ``y``'s storage dtype (bf16), keeping a long stream
    of low-precision folds unbiased (see
    :func:`repro.core.codec.stochastic_round`).  With ``sr_key=None``
    the fold is bit-identical to before."""
    interpret = auto_interpret(interpret)
    out_dt = y.dtype
    if sr_key is not None:
        y = y.astype(jnp.float32)
        x = x.astype(jnp.float32)
    r = y.shape[0]
    lead = y.shape[1:]
    d = 1
    for v in lead:
        d *= v
    a = jnp.broadcast_to(jnp.asarray(alpha, jnp.float32), (r,))
    y2 = y.reshape(r, d)
    x2 = x.reshape(r, d)
    rp, dp = _pad_to(max(r, 1), 8), _pad_to(max(d, 1), 128)
    y2 = jnp.pad(y2, ((0, rp - r), (0, dp - d)))
    x2 = jnp.pad(x2, ((0, rp - r), (0, dp - d)))
    a = jnp.pad(a, (0, rp - r))
    out = axpy_fold_pallas(y2, x2, a, interpret=interpret)
    out = out[:r, :d].reshape((r,) + lead)
    if sr_key is not None and out.dtype != out_dt:
        if out_dt == jnp.bfloat16:
            from repro.core.codec import stochastic_round
            out = stochastic_round(out, sr_key, out_dt)
        else:
            out = out.astype(out_dt)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def _axpy_fold_jit(y, x, alpha, sr_key, *, interpret):
    note_trace("axpy_fold")
    return axpy_fold_inline(y, x, alpha, interpret=interpret, sr_key=sr_key)


def axpy_fold(y, x, alpha, *, interpret=None, sr_key=None):
    """Fold one update into the live state: ``y + alpha * (x - y)``.

    y, x: (R, *dims) with the rank-row axis leading; ``alpha`` is a scalar
    (uniform server mixing, FedAsync-style) or an (R,) vector (per-row
    mixing -- RBLA's running masked mean folds only the rows the arriving
    client owns).  Trailing dims are flattened into D; sublane/lane
    padding is stripped from the result.  This is the async aggregation
    service's per-update hot path: cost is O(R*D) regardless of how many
    clients ever reported.  ``sr_key`` enables stochastic rounding back
    to a bf16 ``y`` (quantized accumulators; see
    :func:`axpy_fold_inline`).
    """
    count_dispatch(kernel="axpy_fold")
    return _axpy_fold_jit(y, x, alpha, sr_key, interpret=interpret)


__all__ = ["rbla_agg", "rbla_agg_ref", "flora_stack", "flora_stack_ref",
           "axpy_fold", "axpy_fold_ref", "packed_agg", "packed_agg_ref",
           "packed_robust", "packed_robust_ref", "packed_stack",
           "packed_stack_ref", "rbla_agg_inline", "packed_agg_inline",
           "packed_robust_inline", "packed_stack_inline",
           "flora_stack_inline", "axpy_fold_inline"]
