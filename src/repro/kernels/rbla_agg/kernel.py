"""RBLA masked rank-row aggregation Pallas TPU kernel (paper Eq. 7).

Given stacked client adapters x (N, R, D), ranks (N,), weights (N,):

    out[r, d] = sum_n w_n * [r < rank_n] * x[n, r, d]
              / sum_n w_n * [r < rank_n]          (0 where no owner)

This is the server's hot loop: bandwidth-bound (reads N*R*D, writes R*D,
O(1) flops per element).  One pass, fused mask generation from the rank
vector (delta is never materialized in HBM -- the jnp reference builds an
(N, R, 1) mask tensor; the kernel derives it from a VMEM iota).

Grid (R/br, D/bd); the client axis is an in-kernel fori_loop over VMEM
blocks (N is small: the cohort size).  Block (N, br, bd) of x streams
through VMEM; ranks/weights ride along as (N,) f32 vectors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BR = 128
DEFAULT_BD = 512


def _kernel(ranks_ref, weights_ref, x_ref, o_ref, *, n_clients: int,
            norm_by: str):
    br = x_ref.shape[1]
    r0 = pl.program_id(0) * br
    rows = r0 + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)

    num = jnp.zeros(o_ref.shape, jnp.float32)
    den = jnp.zeros((br, 1), jnp.float32)
    wtot = jnp.zeros((), jnp.float32)
    for nix in range(n_clients):                     # static unroll
        m = (rows < ranks_ref[nix]).astype(jnp.float32)       # (br, 1)
        w = weights_ref[nix]
        num = num + (w * m) * x_ref[nix].astype(jnp.float32)
        den = den + w * m
        wtot = wtot + w
    if norm_by == "mask":       # rbla: owner weight-mass denominator
        out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    else:                       # zeropad baseline: total weight mass
        out = num / wtot
    o_ref[...] = out.astype(o_ref.dtype)


def _packed_kernel(weights_ref, masks_ref, x_ref, *rest, n_clients: int,
                   norm_by: str, has_prev: bool, norm_restore: bool = False,
                   has_scales: bool = False):
    """Fused whole-round aggregation over a packed bucket (plan path).

    ``x``: (N, R, D) packed rows from *every* pair of the cohort that
    shares this bucket's (width, dtype); ``masks``: (N, R) per-row owner
    indicators precomputed on the host from the cohort's rank multiset
    (delta_{i,r} in packed-row form -- layer-stacked pairs just occupy
    more rows); optional ``prev``: (R, D) packed previous global, the
    fallback for rows no participant owns.  One launch aggregates what
    the per-pair path spread over 2 x n_pairs launches.

    ``norm_restore`` fuses rbla_norm's per-row norm restoration into the
    same pass: each output row is rescaled so its L2 norm matches the
    owners' weighted-mean row norm (the wrapper keeps the whole row in
    one block -- the reduction runs over the full width).

    ``has_scales`` adds a (N, R) per-row dequantization-scale operand
    (after ``x``, before ``prev``): each client row is multiplied by its
    scale on load, fusing int8 upload decoding into the same pass -- the
    fp32 view of the payload never hits HBM.
    """
    rest = list(rest)
    scales_ref = rest.pop(0) if has_scales else None
    if has_prev:
        prev_ref, o_ref = rest
    else:
        (o_ref,) = rest
    br = x_ref.shape[1]
    num = jnp.zeros(o_ref.shape, jnp.float32)
    den = jnp.zeros((br, 1), jnp.float32)
    wtot = jnp.zeros((), jnp.float32)
    tnum = jnp.zeros((br, 1), jnp.float32)           # w-mass-weighted norms
    town = jnp.zeros((br, 1), jnp.float32)           # owner weight mass
    for nix in range(n_clients):                     # static unroll
        m = masks_ref[nix][:, None]                  # (br, 1)
        w = weights_ref[nix]
        xn = x_ref[nix].astype(jnp.float32)
        if has_scales:
            xn = scales_ref[nix][:, None] * xn       # fused dequant
        num = num + (w * m) * xn
        den = den + w * m
        wtot = wtot + w
        if norm_restore:
            xm = m * xn
            rn = jnp.sqrt(jnp.sum(xm * xm, axis=1, keepdims=True))
            own = (m > 0).astype(jnp.float32) * w
            tnum = tnum + own * rn
            town = town + own
    if norm_by == "mask":
        fb = (prev_ref[...].astype(jnp.float32) if has_prev
              else jnp.zeros_like(num))
        out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), fb)
    else:
        out = num / wtot
    if norm_restore:
        target = tnum / (town + 1e-12)
        agg = jnp.sqrt(jnp.sum(out * out, axis=1, keepdims=True))
        out = out * jnp.where(agg > 1e-12, target / (agg + 1e-12), 1.0)
    o_ref[...] = out.astype(o_ref.dtype)


def packed_agg_pallas(x, masks, weights, prev=None, *,
                      norm_by: str = "mask", norm_restore: bool = False,
                      scales=None, out_dtype=None,
                      br=DEFAULT_BR, bd=DEFAULT_BD, interpret=True):
    """x: (N, R, D); masks: (N, R) f32; weights: (N,) f32; prev: (R, D)
    or None -> (R, D).  The plan path's fused bucket reduction: like
    :func:`rbla_agg_pallas` but with an explicit per-row owner-mask
    matrix (packed rows span many pairs, so a single rank vector cannot
    describe them) and prev-global retention fused in.  ``norm_restore``
    adds rbla_norm's per-row norm restoration (full-width blocks: the
    row-norm reduction cannot cross column tiles).  ``scales``: optional
    (N, R) f32 per-row dequantization scales fused on the load (int8
    transport); ``out_dtype`` overrides the output dtype when ``x`` is a
    wire dtype."""
    n, r, d = x.shape
    if masks.shape != (n, r):
        raise ValueError(f"packed_agg: masks {masks.shape} != ({n}, {r})")
    if scales is not None and scales.shape != (n, r):
        raise ValueError(f"packed_agg: scales {scales.shape} != ({n}, {r})")
    if prev is not None and prev.shape != (r, d):
        raise ValueError(f"packed_agg: prev {prev.shape} != ({r}, {d})")
    br, bd = min(br, r), (d if norm_restore else min(bd, d))
    if norm_restore:
        # full-width blocks (the row-norm reduction cannot cross column
        # tiles): bound VMEM by shrinking the row block as the bucket
        # widens -- the (n, br, d) f32 x block must fit on-chip.  A
        # two-pass scheme is the follow-on if even br=8 overflows.
        budget = 4 * 1024 * 1024
        br = min(br, max(8, (budget // max(n * d * 4, 1)) // 8 * 8))
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    in_specs = [
        pl.BlockSpec((n,), lambda i, j: (0,)),
        pl.BlockSpec((n, br), lambda i, j: (0, i)),
        pl.BlockSpec((n, br, bd), lambda i, j: (0, i, j)),
    ]
    args = [weights.astype(jnp.float32), masks.astype(jnp.float32), x]
    if scales is not None:
        in_specs.append(pl.BlockSpec((n, br), lambda i, j: (0, i)))
        args.append(scales.astype(jnp.float32))
    if prev is not None:
        in_specs.append(pl.BlockSpec((br, bd), lambda i, j: (i, j)))
        args.append(prev)
    return pl.pallas_call(
        functools.partial(_packed_kernel, n_clients=n, norm_by=norm_by,
                          has_prev=prev is not None,
                          norm_restore=norm_restore,
                          has_scales=scales is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, d), out_dtype or x.dtype),
        interpret=interpret,
    )(*args)


#: sentinel for unowned slots in the order-statistic kernels (matches
#: ref._SENTINEL): above any sane upload, finite under f32 averaging.
_SENTINEL = 1e30


def _packed_robust_kernel(weights_ref, masks_ref, x_ref, *rest,
                          n_clients: int, mode: str, clip_norm: float,
                          trim_frac: float, has_prev: bool,
                          has_scales: bool = False):
    """Byzantine-robust fused bucket reduction (plan path).

    Same packed layout as :func:`_packed_kernel`.  ``mode="clipped"``
    rescales each client row to at most ``clip_norm`` L2 (full-width
    blocks -- the norm reduction cannot cross column tiles) before the
    standard masked weighted mean.  ``mode="trimmed"``/``"median"`` run
    per-coordinate order statistics over the owners: unowned slots get a
    large sentinel, a static odd-even transposition network sorts the
    client axis (``jnp.sort`` does not lower in Mosaic; n is the cohort
    size, so the O(n^2) compare-exchange unroll stays small), and a
    per-row owner count selects the retained positions.  Rows nobody
    owns retain ``prev``.

    ``has_scales`` fuses int8 dequantization on the load exactly as in
    :func:`_packed_kernel` -- *before* any clip or order statistic, so
    quantized uploads cannot widen the robustness bounds.
    """
    rest = list(rest)
    scales_ref = rest.pop(0) if has_scales else None
    if has_prev:
        prev_ref, o_ref = rest
    else:
        (o_ref,) = rest
    br = x_ref.shape[1]
    fb = (prev_ref[...].astype(jnp.float32) if has_prev
          else jnp.zeros(o_ref.shape, jnp.float32))
    if mode == "clipped":
        num = jnp.zeros(o_ref.shape, jnp.float32)
        den = jnp.zeros((br, 1), jnp.float32)
        for nix in range(n_clients):                 # static unroll
            m = masks_ref[nix][:, None]              # (br, 1)
            w = weights_ref[nix]
            xn = x_ref[nix].astype(jnp.float32)
            if has_scales:
                xn = scales_ref[nix][:, None] * xn   # fused dequant
            rn = jnp.sqrt(jnp.sum(xn * xn, axis=1, keepdims=True))
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(rn, 1e-12))
            num = num + (w * m) * (scale * xn)
            den = den + w * m
        out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), fb)
        o_ref[...] = out.astype(o_ref.dtype)
        return
    vals = []
    cnt = jnp.zeros((br, 1), jnp.int32)
    for nix in range(n_clients):
        m = masks_ref[nix][:, None]                  # (br, 1)
        xn = x_ref[nix].astype(jnp.float32)
        if has_scales:
            xn = scales_ref[nix][:, None] * xn       # fused dequant
        vals.append(jnp.where(m > 0, xn, _SENTINEL))
        cnt = cnt + (m > 0).astype(jnp.int32)
    for rnd in range(n_clients):                     # odd-even sort
        for i in range(rnd % 2, n_clients - 1, 2):
            lo = jnp.minimum(vals[i], vals[i + 1])
            vals[i + 1] = jnp.maximum(vals[i], vals[i + 1])
            vals[i] = lo
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    if mode == "median":
        lo_ix = jnp.maximum((cnt - 1) // 2, 0)
        hi_ix = cnt // 2
        for j in range(n_clients):
            sel = 0.5 * ((lo_ix == j).astype(jnp.float32)
                         + (hi_ix == j).astype(jnp.float32))
            acc = acc + sel * vals[j]
        out = acc
    else:                                            # trimmed
        k = jnp.minimum(
            jnp.floor(trim_frac * cnt.astype(jnp.float32)).astype(jnp.int32),
            jnp.maximum((cnt - 1) // 2, 0))
        for j in range(n_clients):
            inc = ((j >= k) & (j < cnt - k)).astype(jnp.float32)
            acc = acc + inc * vals[j]
        keep = (cnt - 2 * k).astype(jnp.float32)
        out = acc / jnp.maximum(keep, 1.0)
    o_ref[...] = jnp.where(cnt > 0, out, fb).astype(o_ref.dtype)


def packed_robust_pallas(x, masks, weights, prev=None, *, mode: str,
                         clip_norm: float = 0.0, trim_frac: float = 0.0,
                         scales=None, out_dtype=None,
                         br=DEFAULT_BR, bd=DEFAULT_BD, interpret=True):
    """x: (N, R, D); masks: (N, R) f32; weights: (N,) f32; prev: (R, D)
    or None -> (R, D).  Byzantine-robust sibling of
    :func:`packed_agg_pallas`: one fused launch per packed bucket, with
    per-client norm clipping (``mode="clipped"``), per-coordinate trimmed
    mean (``"trimmed"``), or coordinate-wise median (``"median"``) in
    place of the weighted mean.  Numerics match
    ``ref.packed_robust_ref``.  ``scales``/``out_dtype`` as in
    :func:`packed_agg_pallas` (dequant applied before clip/sort)."""
    n, r, d = x.shape
    if masks.shape != (n, r):
        raise ValueError(f"packed_robust: masks {masks.shape} != ({n}, {r})")
    if scales is not None and scales.shape != (n, r):
        raise ValueError(f"packed_robust: scales {scales.shape} != "
                         f"({n}, {r})")
    if prev is not None and prev.shape != (r, d):
        raise ValueError(f"packed_robust: prev {prev.shape} != ({r}, {d})")
    if mode not in ("clipped", "trimmed", "median"):
        raise ValueError(f"unknown robust mode {mode!r}; options: "
                         f"['clipped', 'median', 'trimmed']")
    br = min(br, r)
    # clipped needs the full row in one block (L2 norm over D); the sort
    # network keeps n f32 blocks live -- either way, bound VMEM by
    # shrinking the row block as n*width grows
    bd = d if mode == "clipped" else min(bd, d)
    budget = 4 * 1024 * 1024
    br = min(br, max(8, (budget // max(n * bd * 4, 1)) // 8 * 8))
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    in_specs = [
        pl.BlockSpec((n,), lambda i, j: (0,)),
        pl.BlockSpec((n, br), lambda i, j: (0, i)),
        pl.BlockSpec((n, br, bd), lambda i, j: (0, i, j)),
    ]
    args = [weights.astype(jnp.float32), masks.astype(jnp.float32), x]
    if scales is not None:
        in_specs.append(pl.BlockSpec((n, br), lambda i, j: (0, i)))
        args.append(scales.astype(jnp.float32))
    if prev is not None:
        in_specs.append(pl.BlockSpec((br, bd), lambda i, j: (i, j)))
        args.append(prev)
    return pl.pallas_call(
        functools.partial(_packed_robust_kernel, n_clients=n, mode=mode,
                          clip_norm=float(clip_norm),
                          trim_frac=float(trim_frac),
                          has_prev=prev is not None,
                          has_scales=scales is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, d), out_dtype or x.dtype),
        interpret=interpret,
    )(*args)


def _packed_stack_kernel(scales_ref, x_ref, *rest, copies_x, copies_prev,
                         has_prev: bool):
    """Fused FLoRA stacking over a packed bucket: every (pair, layer,
    contributor) placement is one static sliced copy/scale.  ``copies_x``
    entries are ``(client, src_row, dst_row, rows, scale_idx)``;
    ``copies_prev`` drop the client index and read the packed previous
    global.  Rows no copy touches stay zero (the cap padding)."""
    if has_prev:
        prev_ref, o_ref = rest
    else:
        (o_ref,) = rest
    o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)
    for (src, s0, d0, nr, si) in copies_x:
        o_ref[d0:d0 + nr, :] = (
            scales_ref[si] * x_ref[src, s0:s0 + nr, :].astype(jnp.float32)
        ).astype(o_ref.dtype)
    for (s0, d0, nr, si) in copies_prev:
        o_ref[d0:d0 + nr, :] = (
            scales_ref[si] * prev_ref[s0:s0 + nr, :].astype(jnp.float32)
        ).astype(o_ref.dtype)


def packed_stack_pallas(x, scales, prev=None, *, copies_x=(),
                        copies_prev=(), out_rows: int, bd=DEFAULT_BD,
                        interpret=True):
    """x: (N, R_in, D); scales: (S,) f32; prev: (R_prev, D) or None ->
    (out_rows, D).  One launch stacks every packable pair of the cohort
    (the plan path's flora bucket); :func:`flora_stack_pallas` remains
    the single-pair form."""
    n, r_in, d = x.shape
    for (src, s0, d0, nr, si) in copies_x:
        if not (0 <= src < n and 0 <= s0 and s0 + nr <= r_in
                and 0 <= d0 and d0 + nr <= out_rows and 0 <= si):
            raise ValueError(f"packed_stack: bad copy {(src, s0, d0, nr, si)}")
    if copies_prev and prev is None:
        raise ValueError("packed_stack: prev copies but no prev buffer")
    for (s0, d0, nr, si) in copies_prev:
        if not (0 <= s0 and s0 + nr <= prev.shape[0]
                and 0 <= d0 and d0 + nr <= out_rows):
            raise ValueError(f"packed_stack: bad prev copy {(s0, d0, nr, si)}")
    bd = min(bd, d)
    grid = (pl.cdiv(d, bd),)
    in_specs = [
        pl.BlockSpec((scales.shape[0],), lambda j: (0,)),
        pl.BlockSpec((n, r_in, bd), lambda j: (0, 0, j)),
    ]
    args = [scales.astype(jnp.float32), x]
    if prev is not None:
        in_specs.append(pl.BlockSpec((prev.shape[0], bd), lambda j: (0, j)))
        args.append(prev)
    return pl.pallas_call(
        functools.partial(_packed_stack_kernel,
                          copies_x=tuple(copies_x),
                          copies_prev=tuple(copies_prev),
                          has_prev=prev is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((out_rows, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((out_rows, d), x.dtype),
        interpret=interpret,
    )(*args)


def _stack_kernel(scales_ref, x_ref, o_ref, *, segs, offs):
    """FLoRA stacking: pure copy/scale, no reduction.

    Each contributor ``i`` owns output rows [offs[i], offs[i]+segs[i]);
    the segment layout is static (host-known ranks), so every placement
    is a plain sliced store.  Rows beyond the stacked total stay zero.
    """
    o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)
    for i, (r_i, off) in enumerate(zip(segs, offs)):
        o_ref[off:off + r_i, :] = (
            scales_ref[i] * x_ref[i, :r_i, :].astype(jnp.float32)
        ).astype(o_ref.dtype)


def flora_stack_pallas(x, scales, *, segs: tuple[int, ...], out_rows: int,
                       bd=DEFAULT_BD, interpret=True):
    """x: (N, R, D); scales: (N,) f32; segs: static per-contributor live
    row counts -> (out_rows, D) with contributor i's rows at the running
    offset, scaled.  ``out_rows >= sum(segs)`` (extra rows are zero).

    Bandwidth-optimal for the stacking server: reads sum(segs)*D, writes
    out_rows*D, zero flops beyond the scale multiply -- the rbla_agg
    reduction kernel would burn N*R*D reads on what is a placement.
    """
    n, r, d = x.shape
    if len(segs) != n:
        raise ValueError(f"{len(segs)} segments for {n} contributors")
    if any(s < 0 or s > r for s in segs):
        raise ValueError(f"segment sizes {segs} outside [0, {r}]")
    offs = []
    tot = 0
    for s in segs:
        offs.append(tot)
        tot += int(s)
    if tot > out_rows:
        raise ValueError(f"stacked rows {tot} exceed out_rows={out_rows}")
    bd = min(bd, d)
    grid = (pl.cdiv(d, bd),)
    return pl.pallas_call(
        functools.partial(_stack_kernel, segs=tuple(int(s) for s in segs),
                          offs=tuple(offs)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((n, r, bd), lambda j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((out_rows, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((out_rows, d), x.dtype),
        interpret=interpret,
    )(scales.astype(jnp.float32), x)


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    """Staleness-weighted fold: o = y + alpha_row * (x - y).

    ``alpha`` rides along as a per-row (br,) f32 vector so the same kernel
    serves both the scalar server-mixing fold (uniform alpha) and RBLA's
    per-rank-row running masked mean (row-dependent alpha: rows the client
    does not own get alpha 0 and pass ``y`` through untouched).
    """
    a = alpha_ref[...][:, None]                              # (br, 1)
    y = y_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (y + a * (x - y)).astype(o_ref.dtype)


def axpy_fold_pallas(y, x, alpha, *, br=DEFAULT_BR, bd=DEFAULT_BD,
                     interpret=True):
    """y, x: (R, D); alpha: (R,) f32 -> (R, D) = y + alpha[:, None]*(x-y).

    The async server's hot loop: one arriving client update folded into
    the live global in a single pass.  Bandwidth-bound like ``rbla_agg``
    but reads 2*R*D and writes R*D with no client axis at all -- the
    per-update cost of fully-async aggregation is independent of the
    cohort size.
    """
    r, d = y.shape
    if x.shape != y.shape:
        raise ValueError(f"axpy_fold: x {x.shape} vs y {y.shape}")
    if alpha.shape != (r,):
        raise ValueError(f"axpy_fold: alpha {alpha.shape} != ({r},)")
    br, bd = min(br, r), min(bd, d)
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    return pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br, bd), lambda i, j: (i, j)),
            pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, d), y.dtype),
        interpret=interpret,
    )(alpha.astype(jnp.float32), x, y)


def rbla_agg_pallas(x, ranks, weights, *, norm_by: str = "mask",
                    br=DEFAULT_BR, bd=DEFAULT_BD, interpret=True):
    """x: (N, R, D); ranks: (N,) int32; weights: (N,) f32 -> (R, D).

    ``norm_by``: "mask" divides by the owners' weight mass (RBLA Eq. 7);
    "weight" divides by the total mass (zero-padding dilution / FedAvg).
    """
    n, r, d = x.shape
    br, bd = min(br, r), min(bd, d)
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    return pl.pallas_call(
        functools.partial(_kernel, n_clients=n, norm_by=norm_by),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n, br, bd), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(ranks, weights.astype(jnp.float32), x)
