"""RBLA masked rank-row aggregation Pallas TPU kernel (paper Eq. 7).

Given stacked client adapters x (N, R, D), ranks (N,), weights (N,):

    out[r, d] = sum_n w_n * [r < rank_n] * x[n, r, d]
              / sum_n w_n * [r < rank_n]          (0 where no owner)

This is the server's hot loop: bandwidth-bound (reads N*R*D, writes R*D,
O(1) flops per element).  One pass, fused mask generation from the rank
vector (delta is never materialized in HBM -- the jnp reference builds an
(N, R, 1) mask tensor; the kernel derives it from a VMEM iota).

Grid (R/br, D/bd); the client axis is an in-kernel fori_loop over VMEM
blocks (N is small: the cohort size).  Block (N, br, bd) of x streams
through VMEM; ranks/weights ride along as (N,) f32 vectors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BR = 128
DEFAULT_BD = 512


def _kernel(ranks_ref, weights_ref, x_ref, o_ref, *, n_clients: int,
            norm_by: str):
    br = x_ref.shape[1]
    r0 = pl.program_id(0) * br
    rows = r0 + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)

    num = jnp.zeros(o_ref.shape, jnp.float32)
    den = jnp.zeros((br, 1), jnp.float32)
    wtot = jnp.zeros((), jnp.float32)
    for nix in range(n_clients):                     # static unroll
        m = (rows < ranks_ref[nix]).astype(jnp.float32)       # (br, 1)
        w = weights_ref[nix]
        num = num + (w * m) * x_ref[nix].astype(jnp.float32)
        den = den + w * m
        wtot = wtot + w
    if norm_by == "mask":       # rbla: owner weight-mass denominator
        out = jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
    else:                       # zeropad baseline: total weight mass
        out = num / wtot
    o_ref[...] = out.astype(o_ref.dtype)


def rbla_agg_pallas(x, ranks, weights, *, norm_by: str = "mask",
                    br=DEFAULT_BR, bd=DEFAULT_BD, interpret=True):
    """x: (N, R, D); ranks: (N,) int32; weights: (N,) f32 -> (R, D).

    ``norm_by``: "mask" divides by the owners' weight mass (RBLA Eq. 7);
    "weight" divides by the total mass (zero-padding dilution / FedAvg).
    """
    n, r, d = x.shape
    br, bd = min(br, r), min(bd, d)
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    return pl.pallas_call(
        functools.partial(_kernel, n_clients=n, norm_by=norm_by),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n, br, bd), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(ranks, weights.astype(jnp.float32), x)
