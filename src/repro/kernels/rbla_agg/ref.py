"""Pure-jnp oracle for the RBLA aggregation kernel (reuses the core
implementation -- the kernel must agree with the paper's Eq. 7 exactly)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rbla_leaf, stacked_rank_masks, zeropad_leaf


def rbla_agg_ref(x, ranks, weights, method: str = "rbla"):
    """x: (N, R, D); ranks: (N,); weights: (N,) -> (R, D)."""
    masks = stacked_rank_masks(x.shape[1], ranks)[:, :, None]
    if method == "rbla":
        return rbla_leaf(x, masks, weights)
    return zeropad_leaf(x, masks, weights)
