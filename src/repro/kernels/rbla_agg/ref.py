"""Pure-jnp oracle for the RBLA aggregation kernel (reuses the core
implementation -- the kernel must agree with the paper's Eq. 7 exactly)."""
from __future__ import annotations

from repro.core import rbla_leaf, stacked_rank_masks, zeropad_leaf

_REF_FNS = {"rbla": rbla_leaf, "zeropad": zeropad_leaf}


def rbla_agg_ref(x, ranks, weights, method: str = "rbla"):
    """x: (N, R, D); ranks: (N,); weights: (N,) -> (R, D)."""
    try:
        fn = _REF_FNS[method]
    except KeyError:
        raise ValueError(f"unknown kernel method {method!r}; options: "
                         f"{sorted(_REF_FNS)}") from None
    masks = stacked_rank_masks(x.shape[1], ranks)[:, :, None]
    return fn(x, masks, weights)
