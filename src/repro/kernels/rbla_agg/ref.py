"""Pure-jnp oracle for the RBLA aggregation kernel (reuses the core
implementation -- the kernel must agree with the paper's Eq. 7 exactly)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rbla_leaf, stacked_rank_masks, zeropad_leaf

_REF_FNS = {"rbla": rbla_leaf, "zeropad": zeropad_leaf}


def axpy_fold_ref(y, x, alpha):
    """Oracle for the async fold kernel: y, x (R, *dims); alpha scalar or
    (R,) -> y + alpha*(x-y) with alpha broadcast over trailing dims."""
    a = jnp.asarray(alpha, jnp.float32)
    if a.ndim == 1:
        a = a.reshape((y.shape[0],) + (1,) * (y.ndim - 1))
    yf = y.astype(jnp.float32)
    return (yf + a * (x.astype(jnp.float32) - yf)).astype(y.dtype)


def flora_stack_ref(x, scales, segs, out_rows: int):
    """Oracle for the FLoRA stacking kernel: x (N, R, D), scales (N,),
    static segs -> (out_rows, D) ragged concat of scaled leading rows."""
    parts = [scales[i] * x[i, :int(s)].astype(jnp.float32)
             for i, s in enumerate(segs)]
    stacked = jnp.concatenate(parts, axis=0)
    pad = out_rows - stacked.shape[0]
    return jnp.pad(stacked, ((0, pad), (0, 0))).astype(x.dtype)


def packed_agg_ref(x, masks, weights, prev=None, norm_by: str = "mask",
                   norm_restore: bool = False):
    """Oracle for the fused-bucket kernel: x (N, R, D), masks (N, R),
    weights (N,), prev (R, D) or None -> (R, D).  Matches the packed-row
    form of rbla_leaf (``norm_by="mask"``: per-row owner-mass mean with
    prev retention) / zeropad_leaf (``norm_by="weight"``: total-mass
    dilution).  ``norm_restore`` adds rbla_norm's per-row norm
    restoration (rescale each output row to the owners' weighted-mean
    row norm)."""
    xf = x.astype(jnp.float32)
    m = masks.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    num = jnp.einsum("n,nr,nrd->rd", w, m, xf)
    if norm_by == "mask":
        den = jnp.einsum("n,nr->r", w, m)[:, None]
        fb = (jnp.zeros_like(num) if prev is None
              else prev.astype(jnp.float32))
        out = jnp.where(den > 0, num / (den + 1e-12), fb)
    else:
        out = num / (jnp.sum(w) + 1e-12)
    if norm_restore:
        xm = m[:, :, None] * xf
        row_norms = jnp.sqrt(jnp.einsum("nrd,nrd->nr", xm, xm))
        w_rows = (m > 0).astype(jnp.float32) * w[:, None]
        target = (jnp.sum(w_rows * row_norms, axis=0)
                  / (jnp.sum(w_rows, axis=0) + 1e-12))
        agg = jnp.sqrt(jnp.sum(out ** 2, axis=1))
        out = out * jnp.where(agg > 1e-12, target / (agg + 1e-12),
                              1.0)[:, None]
    return out.astype(x.dtype)


def rbla_agg_ref(x, ranks, weights, method: str = "rbla"):
    """x: (N, R, D); ranks: (N,); weights: (N,) -> (R, D)."""
    try:
        fn = _REF_FNS[method]
    except KeyError:
        raise ValueError(f"unknown kernel method {method!r}; options: "
                         f"{sorted(_REF_FNS)}") from None
    masks = stacked_rank_masks(x.shape[1], ranks)[:, :, None]
    return fn(x, masks, weights)
