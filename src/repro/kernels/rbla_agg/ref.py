"""Pure-jnp oracle for the RBLA aggregation kernel (reuses the core
implementation -- the kernel must agree with the paper's Eq. 7 exactly)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rbla_leaf, stacked_rank_masks, zeropad_leaf

_REF_FNS = {"rbla": rbla_leaf, "zeropad": zeropad_leaf}


def axpy_fold_ref(y, x, alpha):
    """Oracle for the async fold kernel: y, x (R, *dims); alpha scalar or
    (R,) -> y + alpha*(x-y) with alpha broadcast over trailing dims."""
    a = jnp.asarray(alpha, jnp.float32)
    if a.ndim == 1:
        a = a.reshape((y.shape[0],) + (1,) * (y.ndim - 1))
    yf = y.astype(jnp.float32)
    return (yf + a * (x.astype(jnp.float32) - yf)).astype(y.dtype)


def flora_stack_ref(x, scales, segs, out_rows: int):
    """Oracle for the FLoRA stacking kernel: x (N, R, D), scales (N,),
    static segs -> (out_rows, D) ragged concat of scaled leading rows."""
    parts = [scales[i] * x[i, :int(s)].astype(jnp.float32)
             for i, s in enumerate(segs)]
    stacked = jnp.concatenate(parts, axis=0)
    pad = out_rows - stacked.shape[0]
    return jnp.pad(stacked, ((0, pad), (0, 0))).astype(x.dtype)


def packed_agg_ref(x, masks, weights, prev=None, norm_by: str = "mask",
                   norm_restore: bool = False, scales=None, out_dtype=None):
    """Oracle for the fused-bucket kernel: x (N, R, D), masks (N, R),
    weights (N,), prev (R, D) or None -> (R, D).  Matches the packed-row
    form of rbla_leaf (``norm_by="mask"``: per-row owner-mass mean with
    prev retention) / zeropad_leaf (``norm_by="weight"``: total-mass
    dilution).  ``norm_restore`` adds rbla_norm's per-row norm
    restoration (rescale each output row to the owners' weighted-mean
    row norm).

    ``scales`` (N, R) fuses int8 dequantization as an epilogue on the
    load: each client row is multiplied by its per-row scale before any
    reduction, so quantized uploads never materialize an fp32 staging
    buffer.  The mask-mass denominator stays scale-free (scales rescale
    values, not ownership).  ``out_dtype`` overrides the output dtype --
    required when ``x`` is a wire dtype (int8/bf16) but the aggregate is
    fp32."""
    xf = x.astype(jnp.float32)
    if scales is not None:
        xf = scales.astype(jnp.float32)[:, :, None] * xf
    m = masks.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    num = jnp.einsum("n,nr,nrd->rd", w, m, xf)
    if norm_by == "mask":
        den = jnp.einsum("n,nr->r", w, m)[:, None]
        fb = (jnp.zeros_like(num) if prev is None
              else prev.astype(jnp.float32))
        out = jnp.where(den > 0, num / (den + 1e-12), fb)
    else:
        out = num / (jnp.sum(w) + 1e-12)
    if norm_restore:
        xm = m[:, :, None] * xf
        row_norms = jnp.sqrt(jnp.einsum("nrd,nrd->nr", xm, xm))
        w_rows = (m > 0).astype(jnp.float32) * w[:, None]
        target = (jnp.sum(w_rows * row_norms, axis=0)
                  / (jnp.sum(w_rows, axis=0) + 1e-12))
        agg = jnp.sqrt(jnp.sum(out ** 2, axis=1))
        out = out * jnp.where(agg > 1e-12, target / (agg + 1e-12),
                              1.0)[:, None]
    return out.astype(out_dtype or x.dtype)


def packed_stack_ref(x, scales, prev=None, *, copies_x=(), copies_prev=(),
                     out_rows: int):
    """Oracle for the fused FLoRA stacking kernel (plan path): x
    (N, R_in, D), scales (S,), prev (R_prev, D) or None, static
    ``copies_x`` entries ``(client, src_row, dst_row, rows, scale_idx)``
    (``copies_prev`` drop the client index and read ``prev``) ->
    (out_rows, D).  Rows no copy touches stay zero.  Because every copy
    is a static slice, this *is* a fused XLA lowering, not just a test
    oracle -- the plan layer uses it where interpreted Pallas would pay
    per-op Python overhead."""
    sc = jnp.asarray(scales, jnp.float32)
    out = jnp.zeros((out_rows, x.shape[-1]), x.dtype)
    for (src, s0, d0, nr, si) in copies_x:
        out = out.at[d0:d0 + nr, :].set(
            (sc[si] * x[src, s0:s0 + nr, :].astype(jnp.float32)
             ).astype(x.dtype))
    for (s0, d0, nr, si) in copies_prev:
        out = out.at[d0:d0 + nr, :].set(
            (sc[si] * prev[s0:s0 + nr, :].astype(jnp.float32)
             ).astype(x.dtype))
    return out


#: sentinel pushed into unowned slots before the per-coordinate sort --
#: strictly above any sane upload (breakdown tests go to ~1e6 norms) yet
#: small enough that averaging two sentinels stays finite in f32.
_SENTINEL = 1e30


def packed_robust_ref(x, masks, weights, prev=None, *, mode: str,
                      clip_norm: float = 0.0, trim_frac: float = 0.0,
                      scales=None, out_dtype=None):
    """Byzantine-robust oracle on the packed bucket layout: x (N, R, D),
    masks (N, R), weights (N,), prev (R, D) or None -> (R, D).

    ``mode="clipped"``: each client's packed row is L2-clipped to
    ``clip_norm`` (scale = min(1, clip/||row||)) and then aggregated with
    the standard masked weighted mean -- identical to ``packed_agg_ref``
    when every row norm is under the clip.

    ``mode="trimmed"`` / ``"median"``: per-coordinate order statistics
    over the row's owners, *unweighted* (example counts are
    client-reported and therefore adversary-controlled; order statistics
    on values, not masses, is what bounds the breakdown point).  Unowned
    slots sort to the top via a large sentinel, so owners occupy sorted
    positions ``[0, c)``; trimming drops ``k = min(floor(trim_frac*c),
    (c-1)//2)`` from each end, the median averages sorted positions
    ``(c-1)//2`` and ``c//2``.  Rows with no owner retain ``prev``.

    ``scales`` (N, R) dequantizes int8 uploads *before* any clip or
    order statistic -- robustness bounds apply to decoded values, so
    quantization cannot widen them.  ``out_dtype`` as in
    :func:`packed_agg_ref`."""
    xf = x.astype(jnp.float32)
    if scales is not None:
        xf = scales.astype(jnp.float32)[:, :, None] * xf
    m = masks.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    fb = (jnp.zeros(x.shape[1:], jnp.float32) if prev is None
          else prev.astype(jnp.float32))
    if mode == "clipped":
        norms = jnp.sqrt(jnp.einsum("nrd,nrd->nr", xf, xf))
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
        num = jnp.einsum("n,nr,nrd->rd", w, m, scale[:, :, None] * xf)
        den = jnp.einsum("n,nr->r", w, m)[:, None]
        out = jnp.where(den > 0, num / (den + 1e-12), fb)
        return out.astype(out_dtype or x.dtype)
    if mode not in ("trimmed", "median"):
        raise ValueError(f"unknown robust mode {mode!r}; options: "
                         f"['clipped', 'median', 'trimmed']")
    n = x.shape[0]
    owned = m > 0
    s = jnp.sort(jnp.where(owned[:, :, None], xf, _SENTINEL), axis=0)
    c = jnp.sum(owned, axis=0).astype(jnp.int32)             # (R,)
    idx = jnp.arange(n, dtype=jnp.int32)[:, None]            # (N, 1)
    if mode == "median":
        lo = jnp.maximum((c - 1) // 2, 0)[None, :]
        hi = (c // 2)[None, :]
        sel = 0.5 * ((idx == lo).astype(jnp.float32)
                     + (idx == hi).astype(jnp.float32))      # (N, R)
        out = jnp.einsum("nr,nrd->rd", sel, s)
    else:
        k = jnp.minimum(
            jnp.floor(trim_frac * c.astype(jnp.float32)).astype(jnp.int32),
            jnp.maximum((c - 1) // 2, 0))[None, :]
        inc = ((idx >= k) & (idx < c[None, :] - k)).astype(jnp.float32)
        cnt = jnp.sum(inc, axis=0)[:, None]                  # = c - 2k
        out = jnp.einsum("nr,nrd->rd", inc, s) / jnp.maximum(cnt, 1.0)
    out = jnp.where((c > 0)[:, None], out, fb)
    return out.astype(out_dtype or x.dtype)


def packed_robust_xla(x, masks, weights, prev=None, *, mode: str,
                      clip_norm: float = 0.0, trim_frac: float = 0.0,
                      scales=None, out_dtype=None):
    """Fused XLA lowering of :func:`packed_robust_ref` for the order
    statistics: identical contract and semantics, but the per-coordinate
    sort runs a static odd-even transposition network (the same network
    the Pallas kernel uses) instead of ``jnp.sort`` -- on CPU, XLA's
    variadic sort is a serial per-lane comparison sort while the network
    is ~n^2/2 vectorized min/max sweeps over the whole bucket, ~10x
    faster at cohort sizes.  The plan layer uses this for interpret-mode
    pallas plans, where per-tile grid emulation overhead also rules out
    the real kernel; ``jnp.sort`` in ``packed_robust_ref`` stays the
    independent oracle."""
    if mode == "clipped":            # einsum path is already one fusion
        return packed_robust_ref(x, masks, weights, prev, mode=mode,
                                 clip_norm=clip_norm, trim_frac=trim_frac,
                                 scales=scales, out_dtype=out_dtype)
    if mode not in ("trimmed", "median"):
        raise ValueError(f"unknown robust mode {mode!r}; options: "
                         f"['clipped', 'median', 'trimmed']")
    n = x.shape[0]
    xf = x.astype(jnp.float32)
    if scales is not None:
        xf = scales.astype(jnp.float32)[:, :, None] * xf
    owned = masks.astype(jnp.float32) > 0                    # (N, R)
    fb = (jnp.zeros(x.shape[1:], jnp.float32) if prev is None
          else prev.astype(jnp.float32))
    vals = [jnp.where(owned[i][:, None], xf[i], _SENTINEL)
            for i in range(n)]
    c = jnp.sum(owned, axis=0).astype(jnp.int32)[:, None]    # (R, 1)
    for rnd in range(n):
        for i in range(rnd % 2, n - 1, 2):
            lo = jnp.minimum(vals[i], vals[i + 1])
            vals[i + 1] = jnp.maximum(vals[i], vals[i + 1])
            vals[i] = lo
    if mode == "median":
        lo_ix = jnp.maximum((c - 1) // 2, 0)
        hi_ix = c // 2
        out = sum(0.5 * ((lo_ix == j).astype(jnp.float32)
                         + (hi_ix == j).astype(jnp.float32)) * vals[j]
                  for j in range(n))
    else:
        k = jnp.minimum(
            jnp.floor(trim_frac * c.astype(jnp.float32)).astype(jnp.int32),
            jnp.maximum((c - 1) // 2, 0))
        cnt = jnp.maximum((c - 2 * k).astype(jnp.float32), 1.0)
        out = sum(((j >= k) & (j < c - k)).astype(jnp.float32) * vals[j]
                  for j in range(n)) / cnt
    out = jnp.where(c > 0, out, fb)
    return out.astype(out_dtype or x.dtype)


def rbla_agg_ref(x, ranks, weights, method: str = "rbla"):
    """x: (N, R, D); ranks: (N,); weights: (N,) -> (R, D)."""
    try:
        fn = _REF_FNS[method]
    except KeyError:
        raise ValueError(f"unknown kernel method {method!r}; options: "
                         f"{sorted(_REF_FNS)}") from None
    masks = stacked_rank_masks(x.shape[1], ranks)[:, :, None]
    return fn(x, masks, weights)
