"""The paper's experiment models (Section 5.1) in functional JAX.

* ``mlp``       -- 784-200-200-10 ReLU MLP (MNIST/FMNIST).
* ``cnn_mnist`` -- conv32-pool-conv64-pool-fc512-fc10 (MNIST/FMNIST).
* ``cnn_cifar`` -- 2x(conv-conv-pool-drop) + n_dense x fc512 + fc10
                   (CIFAR: n_dense=2, CINIC: n_dense=4 per the paper).

LoRA attaches to dense ("fc*", "out") layers only, matching the paper
("LoRA is applied only to dense layers"); conv kernels, biases and norms
remain fully trainable and are aggregated with plain FedAvg in every method.

Deviation noted in DESIGN.md: the paper's CIFAR net uses BatchNorm with
running statistics; we use batch-statistics normalization (no running
state), the common choice in FL research where client BN state is
problematic to aggregate.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.lora import apply_pair

Array = jax.Array
PyTree = Any


# ------------------------------------------------------------ layer ops ----
def dense_init(key, fan_out: int, fan_in: int, dtype=jnp.float32) -> dict:
    wkey, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / fan_in)
    return {"w": jax.random.normal(wkey, (fan_out, fan_in), dtype) * scale,
            "b": jnp.zeros((fan_out,), dtype)}


def dense_apply(p: dict, x: Array, lora_pair=None, alpha: float = 16.0):
    y = jnp.einsum("...i,oi->...o", x, p["w"]) + p["b"]
    if lora_pair is not None:
        y = y + apply_pair(x, lora_pair, alpha)
    return y


def conv_init(key, out_c: int, in_c: int, k: int = 3, dtype=jnp.float32):
    scale = jnp.sqrt(2.0 / (in_c * k * k))
    return {"w": jax.random.normal(key, (k, k, in_c, out_c), dtype) * scale,
            "b": jnp.zeros((out_c,), dtype)}


def conv_apply(p: dict, x: Array) -> Array:
    """NHWC conv, SAME padding, stride 1."""
    y = lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def maxpool2(x: Array) -> Array:
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                             (1, 2, 2, 1), "VALID")


def batch_stat_norm(x: Array, scale: Array, bias: Array,
                    eps: float = 1e-5) -> Array:
    mean = jnp.mean(x, axis=tuple(range(x.ndim - 1)), keepdims=True)
    var = jnp.var(x, axis=tuple(range(x.ndim - 1)), keepdims=True)
    return (x - mean) * lax.rsqrt(var + eps) * scale + bias


def dropout(key, x: Array, rate: float, train: bool) -> Array:
    if not train or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


# ---------------------------------------------------------------- models ----
class PaperModel(NamedTuple):
    name: str
    init: Callable[[Array], PyTree]
    apply: Callable[..., Array]        # (params, lora, x, train, rng)
    lora_specs: dict[str, tuple[int, int]]


def mlp(input_dim: int = 784, hidden: int = 200,
        n_classes: int = 10) -> PaperModel:
    specs = {"fc1": (hidden, input_dim), "fc2": (hidden, hidden),
             "out": (n_classes, hidden)}

    def init(key):
        ks = jax.random.split(key, 3)
        return {"fc1": dense_init(ks[0], hidden, input_dim),
                "fc2": dense_init(ks[1], hidden, hidden),
                "out": dense_init(ks[2], n_classes, hidden)}

    def apply(params, lora, x, train: bool = False, rng=None):
        del train, rng
        lora = lora or {}
        h = x.reshape(x.shape[0], -1)
        h = jax.nn.relu(dense_apply(params["fc1"], h, lora.get("fc1")))
        h = jax.nn.relu(dense_apply(params["fc2"], h, lora.get("fc2")))
        return dense_apply(params["out"], h, lora.get("out"))

    return PaperModel("mlp", init, apply, specs)


def cnn_mnist(n_classes: int = 10) -> PaperModel:
    fc_in = 7 * 7 * 64
    specs = {"fc1": (512, fc_in), "out": (n_classes, 512)}

    def init(key):
        ks = jax.random.split(key, 4)
        return {"conv1": conv_init(ks[0], 32, 1),
                "conv2": conv_init(ks[1], 64, 32),
                "fc1": dense_init(ks[2], 512, fc_in),
                "out": dense_init(ks[3], n_classes, 512)}

    def apply(params, lora, x, train: bool = False, rng=None):
        del train, rng
        lora = lora or {}
        h = jax.nn.relu(conv_apply(params["conv1"], x))
        h = maxpool2(h)
        h = jax.nn.relu(conv_apply(params["conv2"], h))
        h = maxpool2(h)
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(dense_apply(params["fc1"], h, lora.get("fc1")))
        return dense_apply(params["out"], h, lora.get("out"))

    return PaperModel("cnn_mnist", init, apply, specs)


def cnn_cifar(n_classes: int = 10, n_dense: int = 2,
              in_hw: int = 32, in_c: int = 3,
              drop: float = 0.25) -> PaperModel:
    fc_in = (in_hw // 4) * (in_hw // 4) * 64
    specs = {}
    dims = [fc_in] + [512] * n_dense
    for i in range(n_dense):
        specs[f"fc{i + 1}"] = (512, dims[i])
    specs["out"] = (n_classes, 512)

    def init(key):
        ks = jax.random.split(key, 8 + n_dense)
        params = {
            "conv1a": conv_init(ks[0], 32, in_c),
            "conv1b": conv_init(ks[1], 32, 32),
            "norm1": {"scale": jnp.ones((32,)), "bias": jnp.zeros((32,))},
            "conv2a": conv_init(ks[2], 64, 32),
            "conv2b": conv_init(ks[3], 64, 64),
            "norm2": {"scale": jnp.ones((64,)), "bias": jnp.zeros((64,))},
        }
        for i in range(n_dense):
            params[f"fc{i + 1}"] = dense_init(ks[4 + i], 512, dims[i])
        params["out"] = dense_init(ks[4 + n_dense], n_classes, 512)
        return params

    def apply(params, lora, x, train: bool = False, rng=None):
        lora = lora or {}
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        r = jax.random.split(rng, 2 + n_dense)
        h = jax.nn.relu(conv_apply(params["conv1a"], x))
        h = jax.nn.relu(conv_apply(params["conv1b"], h))
        h = batch_stat_norm(h, params["norm1"]["scale"],
                            params["norm1"]["bias"])
        h = maxpool2(h)
        h = dropout(r[0], h, drop, train)
        h = jax.nn.relu(conv_apply(params["conv2a"], h))
        h = jax.nn.relu(conv_apply(params["conv2b"], h))
        h = batch_stat_norm(h, params["norm2"]["scale"],
                            params["norm2"]["bias"])
        h = maxpool2(h)
        h = dropout(r[1], h, drop, train)
        h = h.reshape(h.shape[0], -1)
        for i in range(n_dense):
            h = jax.nn.relu(dense_apply(params[f"fc{i + 1}"], h,
                                        lora.get(f"fc{i + 1}")))
            h = dropout(r[2 + i], h, drop, train)
        return dense_apply(params["out"], h, lora.get("out"))

    return PaperModel("cnn_cifar", init, apply, specs)


PAPER_MODELS = {
    "mlp": mlp,
    "cnn_mnist": cnn_mnist,
    "cnn_cifar": cnn_cifar,
}
