"""Attention token mixers: GQA (with SWA windows, softcaps, QKV bias),
MLA (deepseek latent attention), and encoder-decoder cross attention.

Three execution modes share one parameter set:
* full   -- training / encoder forward over a whole sequence.
* prefill -- full + returns the KV cache for subsequent decode.
* decode -- one new token against the cache (ring buffer for SWA layers;
            latent cache for MLA).

Full-sequence attention is query-chunked (scan over query blocks) so the
score matrix never materializes at (S, S) -- the TPU-native flash-style
formulation (the Pallas kernel in ``repro.kernels`` covers the fused LoRA
matmul; chunked attention here stays in jnp for XLA fusion).
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax import lax

from .common import apply_rope, dense, dense_init, norm, norm_init, softcap

Array = jax.Array

NEG_INF = -2.0 ** 30  # large-negative in f32, safe under bf16 casts


def _choose_q_chunk(s: int, target: int = 1024) -> int:
    if s <= target:
        return s
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return s


# =================================================================== GQA ====
def gqa_init(key, cfg, block, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "ln": norm_init(cfg, d),
        "q": dense_init(ks[0], d, h * hd, dt, bias=cfg.qkv_bias),
        "k": dense_init(ks[1], d, kv * hd, dt, bias=cfg.qkv_bias),
        "v": dense_init(ks[2], d, kv * hd, dt, bias=cfg.qkv_bias),
        "o": dense_init(ks[3], h * hd, d, dt),
    }
    if block.cross_attn:
        p["xk"] = dense_init(ks[4], d, kv * hd, dt, bias=cfg.qkv_bias)
        p["xv"] = dense_init(ks[5], d, kv * hd, dt, bias=cfg.qkv_bias)
        p["xq"] = dense_init(ks[4], d, h * hd, dt, bias=cfg.qkv_bias)
        p["xo"] = dense_init(ks[5], h * hd, d, dt)
        p["xln"] = norm_init(cfg, d)
    if cfg.post_block_norm:
        p["post_ln"] = norm_init(cfg, d)
    return p


def gqa_lora_targets(block) -> tuple[str, ...]:
    t = ("q", "k", "v", "o")
    return t + ("xq", "xk", "xv", "xo") if block.cross_attn else t


def _split_heads(x: Array, n: int) -> Array:
    return x.reshape(x.shape[:-1] + (n, x.shape[-1] // n))


def _attend_chunked(q: Array, k: Array, v: Array, *, causal: bool,
                    window: int, q_positions: Array, k_positions: Array,
                    scale: float, cap: float) -> Array:
    """q: (B,S,K,G,D); k/v: (B,T,K,D); positions give absolute indices.

    Scans over query chunks; masks built from absolute positions so the
    same path serves training (q_pos == k_pos) and chunked prefill.
    """
    b, s, kh, g, d = q.shape
    qc = _choose_q_chunk(s)
    nq = s // qc
    q = q.reshape(b, nq, qc, kh, g, d)
    qpos = q_positions.reshape(nq, qc)

    def one_chunk(carry, inp):
        qi, qp = inp                               # (B,qc,K,G,D), (qc,)
        scores = jnp.einsum("bqkgd,btkd->bkgqt", qi, k) * scale
        scores = softcap(scores, cap)
        mask = jnp.ones((qc, k.shape[1]), bool)
        if causal:
            mask &= k_positions[None, :] <= qp[:, None]
        if window > 0:
            mask &= k_positions[None, :] > (qp[:, None] - window)
        scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32),
                           NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqt,btkd->bqkgd", probs, v)
        return carry, out

    _, outs = lax.scan(one_chunk, None, (jnp.moveaxis(q, 1, 0), qpos))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, kh, g, v.shape[-1])
    return out


def _attend_decode(q: Array, k: Array, v: Array, valid: Array,
                   scale: float, cap: float) -> Array:
    """q: (B,1,K,G,D); k/v: (B,T,K,D); valid: (T,) bool."""
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q, k) * scale
    scores = softcap(scores, cap)
    scores = jnp.where(valid[None, None, None, None],
                       scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqt,btkd->bqkgd", probs, v)


def gqa_forward(p: Mapping, lora: Mapping | None, x: Array, cfg, block, *,
                mode: str, positions: Array | None = None,
                cache: Mapping | None = None, pos: Array | None = None,
                enc_out: Array | None = None, alpha: float = 16.0,
                capacity: int | None = None):
    """Returns (y, new_cache or None).

    mode: 'full' | 'prefill' | 'decode'.  ``positions``: (S,) absolute
    positions for full/prefill.  ``pos``: scalar current index for decode.
    ``capacity``: prefill cache buffer length (>= S) so decode can continue
    in place.
    """
    lora = lora or {}
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5
    hx = norm(p["ln"], x, cfg.norm_eps)

    def proj(name, inp):
        return dense(p[name], inp, lora.get(name), alpha)

    new_cache = {}
    if mode in ("full", "prefill"):
        s = x.shape[1]
        positions = (jnp.arange(s) if positions is None else positions)
        q = _split_heads(proj("q", hx), h)
        kk = _split_heads(proj("k", hx), kv)
        vv = _split_heads(proj("v", hx), kv)
        q = apply_rope(q, positions[None], cfg.rope_theta, cfg.rope_kind)
        kk = apply_rope(kk, positions[None], cfg.rope_theta, cfg.rope_kind)
        qg = q.reshape(q.shape[:2] + (kv, g, hd))
        out = _attend_chunked(qg, kk, vv, causal=block.causal,
                              window=block.window, q_positions=positions,
                              k_positions=positions, scale=scale,
                              cap=cfg.attn_softcap)
        out = out.reshape(x.shape[:2] + (h * hd,))
        y = dense(p["o"], out, lora.get("o"), alpha)
        if mode == "prefill":
            t_cap = capacity or s
            if block.window > 0:
                w = min(block.window, t_cap)
                # keep the last `w` positions in ring order slot = pos % w
                tail_k, tail_v, _ = _ring_from_tail(kk, vv, positions, w)
                new_cache = {"k": tail_k, "v": tail_v}
            else:
                pad = [(0, 0), (0, t_cap - s), (0, 0), (0, 0)]
                new_cache = {"k": jnp.pad(kk, pad), "v": jnp.pad(vv, pad)}
    else:  # decode
        q = _split_heads(proj("q", hx), h)
        kk = _split_heads(proj("k", hx), kv)
        vv = _split_heads(proj("v", hx), kv)
        posb = jnp.full((1, 1), pos)
        q = apply_rope(q, posb, cfg.rope_theta, cfg.rope_kind)
        kk = apply_rope(kk, posb, cfg.rope_theta, cfg.rope_kind)
        t = cache["k"].shape[1]
        # ring buffer slot; cache may be smaller than the window when the
        # serving context itself is shorter (t == min(window, seq_len))
        slot = (pos % t) if block.window > 0 else pos
        ck = lax.dynamic_update_slice_in_dim(cache["k"], kk, slot, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], vv, slot, axis=1)
        iota = jnp.arange(t)
        if block.window > 0:
            valid = iota < jnp.minimum(pos + 1, t)
        else:
            valid = iota <= pos
        qg = q.reshape(q.shape[:2] + (kv, g, hd))
        out = _attend_decode(qg, ck, cv, valid, scale, cfg.attn_softcap)
        out = out.reshape(x.shape[:2] + (h * hd,))
        y = dense(p["o"], out, lora.get("o"), alpha)
        new_cache = {"k": ck, "v": cv}

    # ---------------- cross attention (encoder-decoder) ----------------
    if block.cross_attn:
        if mode in ("full", "prefill"):
            assert enc_out is not None, "cross_attn requires encoder output"
            xk = _split_heads(proj("xk", enc_out), kv)
            xv = _split_heads(proj("xv", enc_out), kv)
            if mode == "prefill":
                new_cache["xk"], new_cache["xv"] = xk, xv
        else:
            xk, xv = cache["xk"], cache["xv"]
            new_cache["xk"], new_cache["xv"] = xk, xv
        hx2 = norm(p["xln"], x + y, cfg.norm_eps)
        xq = _split_heads(proj("xq", hx2), h)
        xqg = xq.reshape(xq.shape[:2] + (kv, g, hd))
        enc_t = xk.shape[1]
        xout = _attend_decode(xqg, xk, xv, jnp.ones((enc_t,), bool), scale,
                              cfg.attn_softcap)
        xout = xout.reshape(x.shape[:2] + (h * hd,))
        y = y + dense(p["xo"], xout, lora.get("xo"), alpha)

    if cfg.post_block_norm:
        y = norm(p["post_ln"], y, cfg.norm_eps)
    return y, (new_cache or None)


def _ring_from_tail(kk, vv, positions, w):
    """Arrange the last ``w`` timesteps of (B,T,KV,D) into ring order."""
    t = kk.shape[1]
    if t <= w:
        pad = [(0, 0), (0, w - t), (0, 0), (0, 0)]
        return (jnp.pad(kk, pad), jnp.pad(vv, pad), positions)
    last_pos = positions[-1]
    # positions kept: last_pos-w+1 .. last_pos ; slot = pos % w
    kept_k, kept_v = kk[:, -w:], vv[:, -w:]
    kept_pos = positions[-w:]
    slots = kept_pos % w
    order = jnp.argsort(slots)
    return kept_k[:, order], kept_v[:, order], kept_pos


def gqa_init_cache(cfg, block, batch: int, seq_len: int, dtype) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    t = min(block.window, seq_len) if block.window > 0 else seq_len
    c = {"k": jnp.zeros((batch, t, kv, hd), dtype),
         "v": jnp.zeros((batch, t, kv, hd), dtype)}
    if block.cross_attn:
        c["xk"] = jnp.zeros((batch, cfg.encoder_seq, kv, hd), dtype)
        c["xv"] = jnp.zeros((batch, cfg.encoder_seq, kv, hd), dtype)
    return c


# =================================================================== MLA ====
def mla_init(key, cfg, block) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        "ln": norm_init(cfg, d),
        "q_a": dense_init(ks[0], d, cfg.q_lora_rank, dt),
        "q_ln": norm_init(cfg, cfg.q_lora_rank),
        "q_b": dense_init(ks[1], cfg.q_lora_rank, h * qk_dim, dt),
        "kv_a": dense_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dt),
        "kv_ln": norm_init(cfg, cfg.kv_lora_rank),
        "kv_b": dense_init(ks[3], cfg.kv_lora_rank,
                           h * (cfg.qk_nope_dim + cfg.v_head_dim), dt),
        "o": dense_init(ks[4], h * cfg.v_head_dim, d, dt),
    }
    return p


MLA_LORA_TARGETS = ("q_a", "q_b", "kv_a", "kv_b", "o")


def mla_forward(p: Mapping, lora: Mapping | None, x: Array, cfg, block, *,
                mode: str, positions: Array | None = None,
                cache: Mapping | None = None, pos: Array | None = None,
                enc_out=None, alpha: float = 16.0,
                absorbed: bool = False, capacity: int | None = None):
    """DeepSeek-V3 Multi-head Latent Attention.

    Baseline decode re-expands K/V from the latent cache each step
    (paper-faithful to the reference implementation); ``absorbed=True``
    switches to the absorbed formulation (q projected into latent space) --
    a beyond-paper perf iteration, see EXPERIMENTS.md SSPerf.
    """
    del enc_out
    lora = lora or {}
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qk_dim = nope + rope_d
    scale = qk_dim ** -0.5
    hx = norm(p["ln"], x, cfg.norm_eps)

    def proj(name, inp):
        return dense(p[name], inp, lora.get(name), alpha)

    # query path
    cq = norm(p["q_ln"], proj("q_a", hx), cfg.norm_eps)
    q = proj("q_b", cq).reshape(hx.shape[:2] + (h, qk_dim))
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    # latent kv path
    ckv_full = proj("kv_a", hx)
    ckv, k_rope = ckv_full[..., :cfg.kv_lora_rank], \
        ckv_full[..., cfg.kv_lora_rank:]
    ckv = norm(p["kv_ln"], ckv, cfg.norm_eps)

    if mode in ("full", "prefill"):
        s = x.shape[1]
        positions = jnp.arange(s) if positions is None else positions
        q_rope = apply_rope(q_rope, positions[None], cfg.rope_theta, "full")
        k_rope_r = apply_rope(k_rope[..., None, :], positions[None],
                              cfg.rope_theta, "full")[..., 0, :]
        kv = proj("kv_b", ckv).reshape(hx.shape[:2] + (h, nope + vd))
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_r[..., None, :],
                                      k_nope.shape[:-1] + (rope_d,))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        qg = qq.reshape(qq.shape[:2] + (h, 1, qk_dim))
        out = _attend_chunked(qg, k, v, causal=block.causal, window=0,
                              q_positions=positions, k_positions=positions,
                              scale=scale, cap=0.0)
        out = out.reshape(x.shape[:2] + (h * vd,))
        y = dense(p["o"], out, lora.get("o"), alpha)
        new_cache = None
        if mode == "prefill":
            t_cap = capacity or s
            pad = [(0, 0), (0, t_cap - s), (0, 0)]
            new_cache = {"ckv": jnp.pad(ckv, pad),
                         "kr": jnp.pad(k_rope_r, pad)}
        return y, new_cache

    # ---------------------------- decode --------------------------------
    posb = jnp.full((1, 1), pos)
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta, "full")
    k_rope_new = apply_rope(k_rope[..., None, :], posb, cfg.rope_theta,
                            "full")[..., 0, :]
    ckv_c = lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, axis=1)
    kr_c = lax.dynamic_update_slice_in_dim(cache["kr"], k_rope_new, pos,
                                           axis=1)
    t = ckv_c.shape[1]
    valid = jnp.arange(t) <= pos
    if absorbed:
        # fold kv_b's K-half into the query: q_lat = q_nope @ W_bk^T
        wkb = p["kv_b"]["w"].reshape(cfg.kv_lora_rank, h, nope + vd)
        wk, wv = wkb[..., :nope], wkb[..., nope:]
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk)       # (B,1,H,R)
        s_lat = jnp.einsum("bqhr,btr->bhqt", q_lat, ckv_c)
        s_rope = jnp.einsum("bqhd,btd->bhqt", q_rope, kr_c)
        scores = (s_lat + s_rope) * scale
        scores = jnp.where(valid[None, None, None],
                           scores.astype(jnp.float32), NEG_INF)
        probs = jax.nn.softmax(scores, -1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhqt,btr->bqhr", probs, ckv_c)   # (B,1,H,R)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx_lat, wv)
    else:
        kv = proj("kv_b", ckv_c).reshape(ckv_c.shape[:2] + (h, nope + vd))
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_c[..., None, :],
                                      k_nope.shape[:-1] + (rope_d,))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        qg = qq.reshape(qq.shape[:2] + (h, 1, qk_dim))
        out = _attend_decode(qg, k, v, valid, scale, 0.0)
    out = out.reshape(x.shape[:2] + (h * vd,))
    y = dense(p["o"], out, lora.get("o"), alpha)
    return y, {"ckv": ckv_c, "kr": kr_c}


def mla_init_cache(cfg, block, batch: int, seq_len: int, dtype) -> dict:
    return {"ckv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, seq_len, cfg.qk_rope_dim), dtype)}
