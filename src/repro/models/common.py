"""Shared building blocks for the big-model zoo.

Conventions (differ from the small paper nets, chosen for TPU einsums):
* dense kernels are stored ``(..., fan_in, fan_out)`` and applied with
  ``einsum('...i,io->...o')`` -- leading dims are scan/stack axes.
* LoRA pairs keep the ``repro.lora`` layout: A ``(..., r_max, fan_in)``,
  B ``(..., fan_out, r_max)``.
* activations/matmuls run in the config dtype (bf16), softmax/norms in f32.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax import lax

from repro.lora import DEFAULT_ALPHA

Array = jax.Array
PyTree = Any


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- dense ----
def dense_init(key, fan_in: int, fan_out: int, dtype, *, bias: bool = False,
               scale: float | None = None) -> dict:
    s = (1.0 / fan_in) ** 0.5 if scale is None else scale
    p = {"w": jax.random.normal(key, (fan_in, fan_out), dtype) * s}
    if bias:
        p["b"] = jnp.zeros((fan_out,), dtype)
    return p


def dense(p: Mapping, x: Array, lora_pair: Mapping | None = None,
          alpha: float = DEFAULT_ALPHA) -> Array:
    y = jnp.einsum("...i,io->...o", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    if lora_pair is not None:
        scale = alpha / jnp.maximum(
            lora_pair["rank"].astype(jnp.float32), 1.0)
        ax = jnp.einsum("...i,ri->...r", x, lora_pair["A"].astype(x.dtype))
        y = y + jnp.einsum("...r,or->...o", ax,
                           lora_pair["B"].astype(x.dtype)) * scale.astype(
                               x.dtype)
    return y


# ----------------------------------------------------------------- norms ----
def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Mapping, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,),
                                                                dtype)}


def norm_init(cfg, dim: int | None = None) -> dict:
    dim = dim or cfg.d_model
    if cfg.mlp_act == "gelu_plain":      # whisper family uses LayerNorm
        return layernorm_init(dim)
    return rmsnorm_init(dim)


def norm(p: Mapping, x: Array, eps: float = 1e-6) -> Array:
    if "bias" in p:                      # LayerNorm
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * lax.rsqrt(var + eps) * p["scale"].astype(
            jnp.float32) + p["bias"].astype(jnp.float32)
        return out.astype(x.dtype)
    return rmsnorm(p, x, eps)


def softcap(x: Array, cap: float) -> Array:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ------------------------------------------------------------------ rope ----
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float,
               kind: str = "full") -> Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable (..., seq)."""
    if kind == "none":
        return x
    hd = x.shape[-1]
    rot = hd if kind == "full" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)                           # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., s, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if kind == "half" else out


# ------------------------------------------------------------- embedding ----
def embed_init(key, vocab: int, dim: int, dtype) -> dict:
    return {"table": jax.random.normal(key, (vocab, dim), dtype) * 0.02}


def embed(p: Mapping, ids: Array) -> Array:
    return jnp.take(p["table"], ids, axis=0)


def unembed(p: Mapping, x: Array) -> Array:
    return jnp.einsum("...d,vd->...v", x, p["table"])
