"""Mamba2 (SSD, state-space duality) block -- jnp chunked implementation.

Hardware adaptation (see DESIGN.md): the chunked SSD form turns the
selective-scan recurrence into block matmuls (intra-chunk quadratic term +
inter-chunk state recurrence), which is exactly the MXU-friendly layout;
a sequential Mamba-1 scan would leave the systolic array idle.  Jamba's
mamba layers reuse this block (G=1 groups).

Shapes (n_groups fixed to 1):
  d_inner = expand * d_model;  H = d_inner // ssm_head_dim;  N = ssm_state
  in_proj : d_model -> 2*d_inner + 2*N + H      (z, x, B, C, dt)
  conv    : depthwise causal width-4 over [x, B, C]
  out_proj: d_inner -> d_model

Decode carries (conv_state (B, conv_w-1, d_conv_ch), ssm_state
(B, H, P, N)) -- O(1) in context length, which is what makes long_500k
decode run for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp
from jax import lax

from .common import dense, dense_init, norm_init, rmsnorm

Array = jax.Array


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    return d_in, h, cfg.ssm_state, cfg.ssm_head_dim


def mamba_init(key, cfg) -> dict:
    d = cfg.d_model
    d_in, h, n, p_dim = _dims(cfg)
    conv_ch = d_in + 2 * n
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "ln": norm_init(cfg),
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * n + h, dt),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), dt)
        * (1.0 / cfg.ssm_conv) ** 0.5,
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gn": {"scale": jnp.ones((d_in,), jnp.float32)},
        "out_proj": dense_init(ks[2], d_in, d, dt),
    }


MAMBA_LORA_TARGETS = ("in_proj", "out_proj")


def _segsum(x: Array) -> Array:
    """x: (..., T) -> (..., T, T) lower-triangular segment sums."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv.  x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # unfold: sum_j w[j] * x[t-k+1+j]
    out = sum(xp[:, j:j + x.shape[1], :] * w[j][None, None, :]
              for j in range(k))
    return out + b[None, None, :]


def ssd_chunked(xdt: Array, dtA: Array, Bm: Array, Cm: Array, chunk: int,
                h_init: Array | None = None):
    """Chunked SSD.  xdt: (B,L,H,P) (inputs pre-scaled by dt);
    dtA: (B,L,H); Bm/Cm: (B,L,N).  Returns (y (B,L,H,P), h_final
    (B,H,P,N))."""
    b, l, h, p = xdt.shape
    n = Bm.shape[-1]
    q = min(chunk, l)
    while l % q:
        q -= 1
    nc = l // q
    xc = xdt.reshape(b, nc, q, h, p)
    Bc = Bm.reshape(b, nc, q, n)
    Cc = Cm.reshape(b, nc, q, n)
    Ac = jnp.moveaxis(dtA.reshape(b, nc, q, h), -1, 1)     # (B,H,NC,Q)
    A_cs = jnp.cumsum(Ac, -1)                              # (B,H,NC,Q)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(Ac))                               # (B,H,NC,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)         # (B,NC,Q,Q)
    y_diag = jnp.einsum("bcqs,bhcqs,bcshp->bcqhp", scores,
                        L.astype(scores.dtype),
                        xc)

    # 2) per-chunk output states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)          # (B,H,NC,Q)
    states = jnp.einsum("bcqn,bhcq,bcqhp->bchpn", Bc,
                        decay_states.astype(Bc.dtype), xc)  # (B,NC,H,P,N)

    # 3) inter-chunk recurrence (carry h across chunks)
    A_tot = A_cs[..., -1]                                  # (B,H,NC)

    def step(hprev, inp):
        st, at = inp                                       # (B,H,P,N),(B,H)
        hnew = hprev * jnp.exp(at)[..., None, None].astype(hprev.dtype) + st
        return hnew, hprev

    h0 = (jnp.zeros((b, h, p, n), xdt.dtype) if h_init is None else h_init)
    h_last, h_prevs = lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(A_tot, -1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,NC,H,P,N)

    # 4) inter-chunk contribution to outputs
    state_decay = jnp.exp(A_cs)                            # (B,H,NC,Q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", Cc, h_prevs,
                       state_decay.astype(Cc.dtype))
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, h_last


def mamba_forward(p: Mapping, lora: Mapping | None, x: Array, cfg, *,
                  mode: str, cache: Mapping | None = None,
                  pos: Array | None = None, alpha: float = 16.0):
    """Returns (y, new_cache or None).  x: (B, S, d)."""
    lora = lora or {}
    d_in, h, n, pd = _dims(cfg)
    hx = rmsnorm(p["ln"], x, cfg.norm_eps)
    zxbcdt = dense(p["in_proj"], hx, lora.get("in_proj"), alpha)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)

    if mode in ("full", "prefill"):
        conv_in = jnp.concatenate([xin, Bm, Cm], -1)
        conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"],
                                            p["conv_b"]))
        xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
        xh = xc.reshape(xc.shape[:2] + (h, pd))
        xdt = xh * dt[..., None].astype(xh.dtype)
        dtA = dt * A[None, None, :]
        y, h_last = ssd_chunked(xdt, dtA, Bc, Cc, cfg.ssm_chunk)
        y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
        y = y.reshape(x.shape[:2] + (d_in,))
        y = rmsnorm(p["gn"], y * jax.nn.silu(z), cfg.norm_eps)
        out = dense(p["out_proj"], y, lora.get("out_proj"), alpha)
        new_cache = None
        if mode == "prefill":
            k = cfg.ssm_conv
            tail = jnp.concatenate([xin, Bm, Cm], -1)[:, -(k - 1):, :]
            new_cache = {"conv": tail, "ssm": h_last}
        return out, new_cache

    # ------------------------------ decode ------------------------------
    # x: (B,1,d); cache: conv (B,K-1,C), ssm (B,H,P,N)
    k = cfg.ssm_conv
    conv_in = jnp.concatenate([xin, Bm, Cm], -1)             # (B,1,C)
    hist = jnp.concatenate([cache["conv"], conv_in], 1)      # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]             # (B,1,C)
    xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    xh = xc.reshape(xc.shape[0], h, pd)                      # (B,H,P)
    dt1 = dt[:, 0]                                           # (B,H)
    dA = jnp.exp(dt1 * A[None, :])                           # (B,H)
    Bv = Bc[:, 0]                                            # (B,N)
    Cv = Cc[:, 0]                                            # (B,N)
    dBx = jnp.einsum("bhp,bn->bhpn", xh * dt1[..., None].astype(xh.dtype),
                     Bv)
    h_new = cache["ssm"] * dA[..., None, None].astype(xh.dtype) + dBx
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cv)
    y = y + p["D"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(x.shape[0], 1, d_in)
    y = rmsnorm(p["gn"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y, lora.get("out_proj"), alpha)
    return out, {"conv": hist[:, 1:], "ssm": h_new}


def mamba_init_cache(cfg, batch: int, dtype) -> dict:
    d_in, h, n, pd = _dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * n),
                              dtype),
            "ssm": jnp.zeros((batch, h, pd, n), dtype)}
