"""Expert-parallel MoE dispatch with explicit all-to-all (mode 'ep_a2a').

The pjit 'sort' baseline leaves collective choice to XLA, which tends to
all-gather the (g, E, C, d) dispatch tensor across the model axis.  This
module instead expresses the GShard-style expert parallelism explicitly
inside ``shard_map``:

  1. each data shard routes its tokens locally into per-expert capacity
     slots (E experts, C_local capacity each),
  2. ``all_to_all`` over the model axis swaps the expert dimension for the
     shard dimension: each model shard receives the slots destined for
     ITS E/ep experts from every data peer,
  3. local expert matmuls,
  4. the inverse all_to_all returns outputs to token owners.

Per-device a2a volume = 2 * C_local * E * d * bytes -- independent of the
expert count replication that the all-gather pays.  Used as the SSPerf
iteration A6 for deepseek-v3 (``ArchConfig.moe_mode = 'ep_a2a'``).

Restrictions (asserted): n_experts divisible by the model-axis size,
tokens divisible by the data sharding; LoRA per-expert adapters must be
sharded over 'model' (rules.adapter_specs does this).
"""
from __future__ import annotations

import math
from typing import Mapping

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import axis_size, shard_map_no_check

from .common import dense, norm
from .moe import _route, expert_dense

Array = jax.Array


def moe_forward_ep_wrapped(p: Mapping, lora: Mapping | None, x: Array,
                           cfg, alpha: float = 16.0) -> Array:
    """pjit-callable wrapper: nests a shard_map over the ambient mesh.

    Tokens are resharded over (data..., model) for the dispatch (that
    reshard is part of the measured cost), expert weights stay on their
    'model' shards, everything else is replicated inside the region.
    """
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    axes = tuple(mesh.axis_names)
    da = tuple(a for a in axes if a != "model")
    tok_axes = da + ("model",)

    def spec_for(path_leaf):
        return P()

    pspec = jax.tree.map(lambda _: P(), p)
    pspec["experts"] = {k: {"w": P("model", None, None)}
                        for k in ("gate", "up", "down")}
    lspec = None
    if lora:
        lspec = {}
        for k, v in lora.items():
            if k.startswith("experts/"):
                lspec[k] = {"A": P("model", None, None),
                            "B": P("model", None, None), "rank": P()}
            else:
                lspec[k] = jax.tree.map(lambda _: P(), v)

    def body(p_l, lora_l, x_l):
        return moe_forward_ep(p_l, lora_l, x_l, cfg, model_axis="model",
                              alpha=alpha)

    fn = shard_map_no_check(body, mesh,
                            in_specs=(pspec, lspec,
                                      P(tok_axes, None, None)),
                            out_specs=P(tok_axes, None, None))
    return fn(p, lora, x)


def moe_forward_ep(p: Mapping, lora: Mapping | None, x: Array, cfg, *,
                   model_axis: str = "model", alpha: float = 16.0) -> Array:
    """shard_map body: x is the LOCAL shard (b_local, s, d); expert weights
    in ``p`` are the LOCAL expert slice (E/ep, d, f).  Must run inside a
    shard_map over (data..., model) with tokens sharded on data and
    experts on model."""
    lora = lora or {}
    ep = axis_size(model_axis)
    e = cfg.n_experts + cfg.moe_pad_experts
    e_local = e // ep
    k = cfg.experts_per_token
    b, s, d = x.shape
    n = b * s
    cap = int(math.ceil(n * k / e * cfg.capacity_factor))

    h = norm(p["ln"], x, cfg.norm_eps)
    flat = h.reshape(n, d)
    # router weights are replicated; logits over ALL experts
    logits = jnp.einsum("nd,de->ne", flat.astype(jnp.float32),
                        p["router"]["w"])
    w, ix = _route(cfg, logits)                       # (n, k)

    # local capacity dispatch (same sort trick as the pjit path)
    ae = ix.reshape(-1)
    order = jnp.argsort(ae)
    ae_sorted = ae[order]
    pos_in_expert = jnp.arange(n * k) - jnp.searchsorted(
        ae_sorted, ae_sorted, side="left")
    keep = pos_in_expert < cap
    token_of = order // k
    rows = jnp.where(keep, ae_sorted, e - 1)
    cols = jnp.where(keep, pos_in_expert, cap - 1)
    vals = flat[token_of] * keep[:, None].astype(flat.dtype)
    einp = jnp.zeros((e, cap, d), flat.dtype).at[rows, cols].add(vals)

    # a2a over the model axis: each peer receives the slots destined for
    # ITS local experts from every peer.  tiled semantics:
    # (e, cap, d) --split ax0 / concat ax1--> (e_local, ep*cap, d)
    einp = lax.all_to_all(einp, model_axis, split_axis=0, concat_axis=1,
                          tiled=True)
    einp = einp[None]                                 # group dim of 1

    eg = expert_dense(p["experts"]["gate"]["w"], einp,
                      lora.get("experts/gate"), alpha)
    eu = expert_dense(p["experts"]["up"]["w"], einp,
                      lora.get("experts/up"), alpha)
    eh = jax.nn.silu(eg) * eu
    eo = expert_dense(p["experts"]["down"]["w"], eh,
                      lora.get("experts/down"), alpha)  # (1,e_local,ep*cap,d)

    # inverse a2a back to token owners:
    # (e_local, ep*cap, d) --split ax1 / concat ax0--> (e, cap, d)
    eo = lax.all_to_all(eo[0], model_axis, split_axis=1, concat_axis=0,
                        tiled=True)
    gathered = eo[rows, cols] * keep[:, None].astype(eo.dtype)
    wflat = w.reshape(-1)[order]
    y = jnp.zeros((n, d), eo.dtype).at[token_of].add(
        gathered * wflat[:, None].astype(eo.dtype))

    if "shared" in p:
        sh = p["shared"]
        y = y + dense(sh["down"],
                      jax.nn.silu(dense(sh["gate"], flat,
                                        lora.get("shared/gate"), alpha)) *
                      dense(sh["up"], flat, lora.get("shared/up"), alpha),
                      lora.get("shared/down"), alpha)
    y = y.reshape(b, s, d)
    if cfg.post_block_norm:
        y = norm(p["post_ln"], y, cfg.norm_eps)
    return y
