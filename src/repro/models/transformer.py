"""Model assembly: embedding -> scanned stages of blocks -> head.

Every repeating unit is a ``jax.lax.scan`` over stacked block params (and
stacked LoRA adapters, and stacked KV caches), keeping the HLO size
independent of depth.  Heterogeneous units (jamba 7:1, gemma2
local/global) are a static python loop *inside* the scanned body.

Entry points (all pure):
  forward_train(params, adapters, batch)          -> logits
  loss(params, adapters, batch)                   -> scalar CE (+ MTP term)
  prefill(params, adapters, batch)                -> (last_logits, cache)
  decode_step(params, adapters, cache, token, pos)-> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax import lax

from repro.lora import init_pair
from .attention import (gqa_forward, gqa_init, gqa_init_cache,
                        gqa_lora_targets, mla_forward, mla_init,
                        mla_init_cache, MLA_LORA_TARGETS)
from .common import (dense, dense_init, embed, embed_init, norm, norm_init,
                     softcap, unembed)
from .mamba import (mamba_forward, mamba_init, mamba_init_cache,
                    MAMBA_LORA_TARGETS)
from .mlp import mlp_forward, mlp_init, mlp_lora_targets
from .moe import moe_forward, moe_init, MOE_LORA_TARGETS

Array = jax.Array
PyTree = Any


# ============================================================ block level ====
def block_init(key, cfg, spec) -> dict:
    k1, k2 = jax.random.split(key)
    if spec.kind == "mamba":
        p = {"mix": mamba_init(k1, cfg)}
    elif spec.kind == "mla":
        p = {"mix": mla_init(k1, cfg, spec)}
    else:
        p = {"mix": gqa_init(k1, cfg, spec)}
    if spec.ffn == "dense":
        p["ffn"] = mlp_init(k2, cfg)
    elif spec.ffn == "moe":
        p["ffn"] = moe_init(k2, cfg)
    return p


def block_forward(bp, blora, x, cfg, spec, *, mode, positions=None,
                  cache=None, pos=None, enc_out=None, alpha=16.0,
                  mla_absorbed=False, capacity=None):
    blora = blora or {}
    if spec.kind == "mamba":
        y, c = mamba_forward(bp["mix"], blora.get("mix"), x, cfg, mode=mode,
                             cache=cache, pos=pos, alpha=alpha)
    elif spec.kind == "mla":
        y, c = mla_forward(bp["mix"], blora.get("mix"), x, cfg, spec,
                           mode=mode, positions=positions, cache=cache,
                           pos=pos, alpha=alpha, absorbed=mla_absorbed,
                           capacity=capacity)
    else:
        y, c = gqa_forward(bp["mix"], blora.get("mix"), x, cfg, spec,
                           mode=mode, positions=positions, cache=cache,
                           pos=pos, enc_out=enc_out, alpha=alpha,
                           capacity=capacity)
    x = x + y
    if spec.ffn == "dense":
        x = x + mlp_forward(bp["ffn"], blora.get("ffn"), x, cfg, alpha)
    elif spec.ffn == "moe":
        if cfg.moe_mode == "ep_a2a":
            from .moe_ep import moe_forward_ep_wrapped
            x = x + moe_forward_ep_wrapped(bp["ffn"], blora.get("ffn"), x,
                                           cfg, alpha)
        else:
            x = x + moe_forward(bp["ffn"], blora.get("ffn"), x, cfg, alpha)
    return x, c


def block_init_cache(cfg, spec, batch: int, seq_len: int, dtype) -> dict:
    if spec.kind == "mamba":
        return mamba_init_cache(cfg, batch, dtype)
    if spec.kind == "mla":
        return mla_init_cache(cfg, spec, batch, seq_len, dtype)
    return gqa_init_cache(cfg, spec, batch, seq_len, dtype)


def block_lora_specs(cfg, spec) -> dict[str, tuple]:
    """{relpath: (fan_out, fan_in, extra_leading)} for one block."""
    d = cfg.d_model
    out: dict[str, tuple] = {}
    if spec.kind == "mamba":
        d_in = cfg.ssm_expand * d
        h = d_in // cfg.ssm_head_dim
        n = cfg.ssm_state
        dims = {"in_proj": (2 * d_in + 2 * n + h, d),
                "out_proj": (d, d_in)}
        for t in MAMBA_LORA_TARGETS:
            out[f"mix/{t}"] = dims[t] + ((),)
    elif spec.kind == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        dims = {
            "q_a": (cfg.q_lora_rank, d),
            "q_b": (cfg.n_heads * qk, cfg.q_lora_rank),
            "kv_a": (cfg.kv_lora_rank + cfg.qk_rope_dim, d),
            "kv_b": (cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim),
                     cfg.kv_lora_rank),
            "o": (d, cfg.n_heads * cfg.v_head_dim),
        }
        for t in MLA_LORA_TARGETS:
            out[f"mix/{t}"] = dims[t] + ((),)
    else:
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        dims = {"q": (h * hd, d), "k": (kv * hd, d), "v": (kv * hd, d),
                "o": (d, h * hd), "xq": (h * hd, d), "xk": (kv * hd, d),
                "xv": (kv * hd, d), "xo": (d, h * hd)}
        for t in gqa_lora_targets(spec):
            out[f"mix/{t}"] = dims[t] + ((),)
    if spec.ffn == "dense":
        f = cfg.d_ff
        if cfg.mlp_act == "gelu_plain":
            dims = {"fc1": (f, d), "fc2": (d, f)}
        else:
            dims = {"gate": (f, d), "up": (f, d), "down": (d, f)}
        for t, v in dims.items():
            out[f"ffn/{t}"] = v + ((),)
    elif spec.ffn == "moe":
        f = cfg.moe_d_ff or cfg.d_ff
        e = cfg.n_experts + cfg.moe_pad_experts
        for t in MOE_LORA_TARGETS:
            fo, fi = (d, f) if t.endswith("down") else (f, d)
            out[f"ffn/{t}"] = (fo, fi, (e,))
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            out["ffn/shared/gate"] = (fs, d, ())
            out["ffn/shared/up"] = (fs, d, ())
            out["ffn/shared/down"] = (d, fs, ())
    return out


def _get_lora(blora: Mapping | None, prefix: str):
    """Project 'mix/q'-style flat keys into the sub-dict for one module."""
    if not blora:
        return None
    sub = {}
    for k, v in blora.items():
        if k.startswith(prefix + "/"):
            sub[k[len(prefix) + 1:]] = v
    return sub or None


# ============================================================ stage level ====
def stage_init(key, cfg, stage) -> dict:
    def unit_init(k):
        ks = jax.random.split(k, len(stage.unit))
        return {f"b{i}": block_init(ks[i], cfg, spec)
                for i, spec in enumerate(stage.unit)}
    keys = jax.random.split(key, stage.repeat)
    return jax.vmap(unit_init)(keys)


def stage_lora_init(key, cfg, stage, r_max: int, rank) -> dict:
    out = {}
    for i, spec in enumerate(stage.unit):
        specs = block_lora_specs(cfg, spec)
        ks = jax.random.split(jax.random.fold_in(key, i), len(specs))
        out[f"b{i}"] = {
            path: init_pair(kk, fo, fi, r_max, rank,
                            leading=(stage.repeat,) + extra)
            for kk, (path, (fo, fi, extra)) in zip(ks,
                                                   sorted(specs.items()))
        }
    return out


REMAT_POLICIES = {
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def stage_forward(sp, slora, x, cfg, stage, *, mode, positions=None,
                  caches=None, pos=None, enc_out=None, alpha=16.0,
                  remat=False, mla_absorbed=False, capacity=None):
    """Scan over the stage's repeats. Returns (x, new_caches or None).

    ``remat``: False | True ("full") | "full" | "dots" -- the checkpoint
    policy applied to each scanned block during training."""

    def body(x, xs):
        bp_unit, bl_unit, cache_unit = xs
        new_caches = {}
        for i, spec in enumerate(stage.unit):
            bp = bp_unit[f"b{i}"]
            bl = None
            if bl_unit is not None:
                flat = bl_unit.get(f"b{i}")
                bl = {"mix": _get_lora(flat, "mix"),
                      "ffn": _get_lora(flat, "ffn")} if flat else None
            c = cache_unit[f"b{i}"] if cache_unit is not None else None
            x, cnew = block_forward(
                bp, bl, x, cfg, spec, mode=mode, positions=positions,
                cache=c, pos=pos, enc_out=enc_out, alpha=alpha,
                mla_absorbed=mla_absorbed, capacity=capacity)
            if cnew is not None:
                new_caches[f"b{i}"] = cnew
        return x, (new_caches or None)

    if remat and mode == "full":
        policy_name = "full" if remat is True else remat
        body = jax.checkpoint(body, policy=REMAT_POLICIES[policy_name]())

    xs = (sp, slora, caches)
    # lax.scan needs xs leaves with a leading `repeat` axis; None subtrees
    # are threaded through untouched.
    x, ys = lax.scan(lambda carry, xs_: body(carry, xs_), x, xs)
    return x, ys
