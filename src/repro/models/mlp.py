"""Dense MLP blocks: SwiGLU (llama/yi/etc.), GeGLU (gemma2), and plain
GELU fc1/fc2 (whisper)."""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from .common import dense, dense_init, norm, norm_init

Array = jax.Array


def mlp_init(key, cfg, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"ln": norm_init(cfg)}
    if cfg.mlp_act == "gelu_plain":
        p["fc1"] = dense_init(ks[0], d, f, dt, bias=True)
        p["fc2"] = dense_init(ks[1], f, d, dt, bias=True)
    else:
        p["gate"] = dense_init(ks[0], d, f, dt)
        p["up"] = dense_init(ks[1], d, f, dt)
        p["down"] = dense_init(ks[2], f, d, dt)
    if cfg.post_block_norm:
        p["post_ln"] = norm_init(cfg)
    return p


def mlp_lora_targets(cfg) -> tuple[str, ...]:
    return (("fc1", "fc2") if cfg.mlp_act == "gelu_plain"
            else ("gate", "up", "down"))


def _act(cfg, x: Array) -> Array:
    if cfg.mlp_act == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def mlp_forward(p: Mapping, lora: Mapping | None, x: Array, cfg,
                alpha: float = 16.0) -> Array:
    lora = lora or {}
    h = norm(p["ln"], x, cfg.norm_eps)
    if cfg.mlp_act == "gelu_plain":
        y = dense(p["fc2"], jax.nn.gelu(
            dense(p["fc1"], h, lora.get("fc1"), alpha), approximate=True),
            lora.get("fc2"), alpha)
    else:
        y = dense(p["down"],
                  _act(cfg, dense(p["gate"], h, lora.get("gate"), alpha)) *
                  dense(p["up"], h, lora.get("up"), alpha),
                  lora.get("down"), alpha)
    if cfg.post_block_norm:
        y = norm(p["post_ln"], y, cfg.norm_eps)
    return y
