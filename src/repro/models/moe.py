"""Mixture-of-Experts FFN with two dispatch strategies.

* ``sort``   -- capacity-based sort/scatter routing under plain pjit
             (global semantics; XLA SPMD inserts the collectives).  The
             baseline for every MoE arch.
* ``ep_a2a`` -- explicit expert-parallel all-to-all dispatch inside
             shard_map (tokens sharded on the data axis, experts on the
             model axis).  The SSPerf hillclimb variant for deepseek-v3;
             see ``repro/models/moe_ep.py``.

Per-expert LoRA: each expert's gate/up/down kernels (E, d, f) carry an
adapter with a leading expert axis -- A (E, r, d), B (E, f, r).  RBLA
masks broadcast over the expert axis unchanged.
"""
from __future__ import annotations

import math
from typing import Mapping

import jax
import jax.numpy as jnp

from .common import dense, dense_init, norm, norm_init

Array = jax.Array


def moe_init(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    # physical expert count may be padded so it divides the model axis
    # (padded experts are never routed to -- dead weights, EP-shardable)
    e = cfg.n_experts + cfg.moe_pad_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    s = (1.0 / d) ** 0.5
    p = {
        "ln": norm_init(cfg),
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * s},
        "experts": {
            "gate": {"w": jax.random.normal(ks[1], (e, d, f), dt) * s},
            "up": {"w": jax.random.normal(ks[2], (e, d, f), dt) * s},
            "down": {"w": jax.random.normal(ks[3], (e, f, d), dt) *
                     (1.0 / f) ** 0.5},
        },
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = {
            "gate": dense_init(ks[4], d, fs, dt),
            "up": dense_init(ks[5], d, fs, dt),
            "down": dense_init(ks[4], fs, d, dt),
        }
    if cfg.post_block_norm:
        p["post_ln"] = norm_init(cfg)
    return p


MOE_LORA_TARGETS = ("experts/gate", "experts/up", "experts/down")


def expert_dense(w: Array, x: Array, lora_pair: Mapping | None = None,
                 alpha: float = 16.0) -> Array:
    """x: (G, E, C, in), w: (E, in, out) -> (G, E, C, out) with per-expert
    LoRA (A (E, r, in), B (E, out, r))."""
    y = jnp.einsum("geci,eio->geco", x, w)
    if lora_pair is not None:
        scale = alpha / jnp.maximum(lora_pair["rank"].astype(jnp.float32),
                                    1.0)
        ax = jnp.einsum("geci,eri->gecr", x, lora_pair["A"].astype(x.dtype))
        y = y + jnp.einsum("gecr,eor->geco", ax,
                           lora_pair["B"].astype(x.dtype)) * scale.astype(
                               x.dtype)
    return y


def _route(cfg, logits: Array):
    """Top-k routing. Returns (weights (N,K), experts (N,K)) over flat
    tokens."""
    k = cfg.experts_per_token
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ix = jax.lax.top_k(probs, k)
    w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)   # renormalize over top-k
    return w, ix


def moe_forward(p: Mapping, lora: Mapping | None, x: Array, cfg,
                alpha: float = 16.0, n_groups: int = 32) -> Array:
    """Capacity-based sort routing with group-local dispatch.

    Tokens are split into ``n_groups`` routing groups (GShard-style); each
    group routes/scatters independently, so under pjit the scatter stays
    local to the data shard holding the group -- the (g, E, C, d) dispatch
    tensor is sharded on g (data axes) and sliced on E (model axis) by the
    expert matmul.  x: (B, S, d).
    """
    lora = lora or {}
    b, s, d = x.shape
    e, k = cfg.n_experts + cfg.moe_pad_experts, cfg.experts_per_token
    n = b * s
    g = max(1, min(n_groups, n))
    while n % g:
        g -= 1
    ng = n // g
    cap = int(math.ceil(ng * k / e * cfg.capacity_factor))

    h = norm(p["ln"], x, cfg.norm_eps)
    flat = h.reshape(g, ng, d)
    logits = jnp.einsum("gnd,de->gne", flat.astype(jnp.float32),
                        p["router"]["w"])
    w, ix = _route(cfg, logits)                       # (g,ng,K)

    def dispatch(flat_g, ix_g):
        """One group's scatter into (E, C, d) expert slots."""
        ae = ix_g.reshape(-1)                         # (ng*K,)
        order = jnp.argsort(ae)
        ae_sorted = ae[order]
        pos_in_expert = jnp.arange(ng * k) - jnp.searchsorted(
            ae_sorted, ae_sorted, side="left")
        keep = pos_in_expert < cap
        token_of = order // k
        rows = jnp.where(keep, ae_sorted, e - 1)
        cols = jnp.where(keep, pos_in_expert, cap - 1)
        vals = flat_g[token_of] * keep[:, None].astype(flat_g.dtype)
        einp = jnp.zeros((e, cap, d), flat_g.dtype).at[rows, cols].add(vals)
        return einp, rows, cols, keep, token_of, order

    einp, rows, cols, keep, token_of, order = jax.vmap(dispatch)(flat, ix)

    if cfg.moe_mode == "ep_hint":
        # expert-parallel hint: pin the dispatch tensor's expert axis to
        # the 'model' mesh axis.  XLA SPMD then moves slots to their
        # expert owners with all-to-all instead of all-gathering the
        # whole (g, E, C, d) tensor (SSPerf iteration A6).
        from jax.sharding import PartitionSpec as P
        U = P.UNCONSTRAINED
        einp = jax.lax.with_sharding_constraint(
            einp, P(U, "model", U, U))

    # expert computation (SwiGLU) over (g, E, C, *)
    eg = expert_dense(p["experts"]["gate"]["w"], einp,
                      lora.get("experts/gate"), alpha)
    eu = expert_dense(p["experts"]["up"]["w"], einp,
                      lora.get("experts/up"), alpha)
    eh = jax.nn.silu(eg) * eu
    eo = expert_dense(p["experts"]["down"]["w"], eh,
                      lora.get("experts/down"), alpha)   # (g,E,C,d)

    def combine(eo_g, rows_g, cols_g, keep_g, token_of_g, w_g, order_g):
        gathered = eo_g[rows_g, cols_g] * keep_g[:, None].astype(eo_g.dtype)
        wflat = w_g.reshape(-1)[order_g]
        contrib = gathered * wflat[:, None].astype(eo_g.dtype)
        return jnp.zeros((ng, d), eo_g.dtype).at[token_of_g].add(contrib)

    y = jax.vmap(combine)(eo, rows, cols, keep, token_of, w, order)

    flat = flat.reshape(n, d)
    y = y.reshape(n, d)
    if "shared" in p:
        sh = p["shared"]
        y = y + dense(sh["down"],
                      jax.nn.silu(dense(sh["gate"], flat,
                                        lora.get("shared/gate"), alpha)) *
                      dense(sh["up"], flat, lora.get("shared/up"), alpha),
                      lora.get("shared/down"), alpha)

    y = y.reshape(b, s, d)
    if cfg.post_block_norm:
        y = norm(p["post_ln"], y, cfg.norm_eps)
    return y
