"""Top-level Model: config -> params/adapters/caches + train & serve fns.

The one class every launcher, test, benchmark and dry-run goes through.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax import lax

from repro.fl.client import softmax_xent  # reuse CE impl
from repro.lora import init_pair
from .common import (dense, dense_init, dtype_of, embed, embed_init, norm,
                     norm_init, softcap, unembed)
from .transformer import (block_init_cache, stage_forward, stage_init,
                          stage_lora_init)

Array = jax.Array
PyTree = Any


@dataclass(frozen=True)
class Model:
    cfg: Any
    remat: Any = True            # False | True/"full" | "dots"
    mla_absorbed: bool = False   # perf variant (EXPERIMENTS.md SSPerf)
    alpha: float = 16.0

    # ------------------------------------------------------------ params ----
    def init(self, key: Array) -> PyTree:
        cfg = self.cfg
        dt = dtype_of(cfg)
        keys = jax.random.split(key, 8 + len(cfg.stages)
                                + len(cfg.encoder_stages))
        p: dict = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                       dt)}
        kix = 1
        p["stages"] = tuple(
            stage_init(keys[kix + i], cfg, s)
            for i, s in enumerate(cfg.stages))
        kix += len(cfg.stages)
        p["final_ln"] = norm_init(cfg)
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(keys[kix], cfg.d_model,
                                      cfg.vocab_size, dt)
        kix += 1
        if cfg.is_encdec:
            p["enc"] = {
                "stages": tuple(stage_init(keys[kix + i], cfg, s)
                                for i, s in enumerate(cfg.encoder_stages)),
                "final_ln": norm_init(cfg),
                "pos": jax.random.normal(
                    keys[kix + len(cfg.encoder_stages)],
                    (cfg.encoder_seq, cfg.d_model), dt) * 0.02,
            }
            kix += len(cfg.encoder_stages)
        if cfg.frontend != "none":
            p["frontend"] = {"proj": dense_init(
                jax.random.fold_in(keys[-1], 1), cfg.frontend_dim,
                cfg.d_model, dt)}
        if cfg.mtp_depth:
            from .transformer import block_init
            spec = cfg.stages[-1].unit[-1]
            p["mtp"] = {
                "proj": dense_init(jax.random.fold_in(keys[-1], 2),
                                   2 * cfg.d_model, cfg.d_model, dt),
                "block": block_init(jax.random.fold_in(keys[-1], 3), cfg,
                                    spec),
                "ln": norm_init(cfg),
            }
        return p

    # ---------------------------------------------------------- adapters ----
    def init_adapters(self, key: Array, r_max: int | None = None,
                      rank: int | None = None) -> PyTree:
        cfg = self.cfg
        r_max = r_max or cfg.lora_r_max
        rank = rank if rank is not None else r_max
        ad: dict = {"stages": tuple(
            stage_lora_init(jax.random.fold_in(key, i), cfg, s, r_max, rank)
            for i, s in enumerate(cfg.stages))}
        if cfg.is_encdec:
            ad["enc"] = {"stages": tuple(
                stage_lora_init(jax.random.fold_in(key, 100 + i), cfg, s,
                                r_max, rank)
                for i, s in enumerate(cfg.encoder_stages))}
        if cfg.frontend != "none":
            ad["frontend"] = {"proj": init_pair(
                jax.random.fold_in(key, 200), cfg.d_model, cfg.frontend_dim,
                r_max, rank)}
        return ad

    # ----------------------------------------------------------- encoder ----
    def _encode(self, params, adapters, frames):
        cfg = self.cfg
        enc = params["enc"]
        x = dense(params["frontend"]["proj"],
                  frames.astype(dtype_of(cfg)),
                  (adapters or {}).get("frontend", {}).get("proj"),
                  self.alpha)
        s = x.shape[1]
        x = x + enc["pos"][:s][None]
        enc_lora = (adapters or {}).get("enc")
        for i, stage in enumerate(cfg.encoder_stages):
            slora = enc_lora["stages"][i] if enc_lora else None
            x, _ = stage_forward(enc["stages"][i], slora, x, cfg, stage,
                                 mode="full", positions=jnp.arange(s),
                                 alpha=self.alpha, remat=self.remat)
        return norm(enc["final_ln"], x, cfg.norm_eps)

    # ----------------------------------------------------------- forward ----
    def _embed_inputs(self, params, adapters, batch):
        """Token embeddings (+ VLM patch prefix). Returns (x, n_prefix)."""
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"])
        n_prefix = 0
        if cfg.frontend == "vision_patches":
            proj = dense(params["frontend"]["proj"],
                         batch["patches"].astype(dtype_of(cfg)),
                         (adapters or {}).get("frontend", {}).get("proj"),
                         self.alpha)
            x = jnp.concatenate([proj.astype(x.dtype), x], axis=1)
            n_prefix = proj.shape[1]
        return x, n_prefix

    def forward(self, params, adapters, batch, mode: str = "full",
                capacity: int | None = None):
        """Full-sequence forward.  Returns (logits, caches or None)."""
        cfg = self.cfg
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encode(params, adapters, batch["frames"])
        x, n_prefix = self._embed_inputs(params, adapters, batch)
        s = x.shape[1]
        positions = jnp.arange(s)
        caches = [] if mode == "prefill" else None
        for i, stage in enumerate(cfg.stages):
            slora = adapters.get("stages")[i] if adapters else None
            x, c = stage_forward(params["stages"][i], slora, x, cfg, stage,
                                 mode=mode, positions=positions,
                                 enc_out=enc_out, alpha=self.alpha,
                                 remat=self.remat,
                                 mla_absorbed=self.mla_absorbed,
                                 capacity=capacity)
            if mode == "prefill":
                caches.append(c)
        x = norm(params["final_ln"], x, cfg.norm_eps)
        if n_prefix:
            x = x[:, n_prefix:]
        logits = (unembed(params["embed"], x) if cfg.tie_embeddings
                  else dense(params["lm_head"], x))
        logits = softcap(logits, cfg.final_softcap)
        return logits, (tuple(caches) if caches is not None else None)

    # -------------------------------------------------------------- loss ----
    def loss(self, params, adapters, batch) -> Array:
        cfg = self.cfg
        logits, _ = self.forward(params, adapters, batch, mode="full")
        tok = batch["tokens"]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, tok[:, 1:, None], axis=-1)[..., 0]
        main = jnp.mean(nll)
        if cfg.mtp_depth:
            main = main + 0.3 * self._mtp_loss(params, adapters, batch,
                                               logits)
        return main

    def _mtp_loss(self, params, adapters, batch, logits) -> Array:
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
        hidden(t) combined with embedding(t+1)."""
        cfg = self.cfg
        from .transformer import block_forward
        tok = batch["tokens"]
        h = embed(params["embed"], tok)          # cheap re-embed (stop-grad)
        nxt = embed(params["embed"], tok[:, 1:])
        cat = jnp.concatenate([norm(params["mtp"]["ln"], h[:, :-1],
                                    cfg.norm_eps), nxt], -1)
        x = dense(params["mtp"]["proj"], cat)
        spec = cfg.stages[-1].unit[-1]
        x, _ = block_forward(params["mtp"]["block"], None, x, cfg, spec,
                             mode="full",
                             positions=jnp.arange(x.shape[1]))
        mlogits = (unembed(params["embed"], x) if cfg.tie_embeddings
                   else dense(params["lm_head"], x))
        lp = jax.nn.log_softmax(mlogits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, tok[:, 2:, None], axis=-1)[..., 0]
        return jnp.mean(nll)

    # ------------------------------------------------------------- serve ----
    def init_cache(self, batch_size: int, seq_len: int) -> PyTree:
        cfg = self.cfg
        dt = dtype_of(cfg)
        caches = []
        for stage in cfg.stages:
            unit = {}
            for i, spec in enumerate(stage.unit):
                c1 = block_init_cache(cfg, spec, batch_size, seq_len, dt)
                unit[f"b{i}"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (stage.repeat,) + x.shape), c1)
            caches.append(unit)
        return tuple(caches)

    def prefill(self, params, adapters, batch,
                capacity: int | None = None):
        logits, caches = self.forward(params, adapters, batch,
                                      mode="prefill", capacity=capacity)
        return logits[:, -1], caches

    def decode_step(self, params, adapters, caches, token: Array,
                    pos: Array):
        """token: (B,) int32; pos: scalar int32 (absolute position)."""
        cfg = self.cfg
        x = embed(params["embed"], token[:, None])
        new_caches = []
        for i, stage in enumerate(cfg.stages):
            slora = adapters.get("stages")[i] if adapters else None
            x, c = stage_forward(params["stages"][i], slora, x, cfg, stage,
                                 mode="decode", caches=caches[i], pos=pos,
                                 alpha=self.alpha, remat=False,
                                 mla_absorbed=self.mla_absorbed)
            new_caches.append(c)
        x = norm(params["final_ln"], x, cfg.norm_eps)
        logits = (unembed(params["embed"], x) if cfg.tie_embeddings
                  else dense(params["lm_head"], x))
        logits = softcap(logits, cfg.final_softcap)
        return logits[:, 0], tuple(new_caches)


def make_model(cfg, remat=True, mla_absorbed: bool = False) -> Model:
    return Model(cfg=cfg, remat=remat, mla_absorbed=mla_absorbed)
