from .io import restore, save

__all__ = ["restore", "save"]
