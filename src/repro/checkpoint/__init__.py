from .io import (CheckpointError, atomic_write_bytes, load_blob, pack_obj,
                 restore, save, save_blob, unpack_obj)

__all__ = ["restore", "save", "CheckpointError", "pack_obj", "unpack_obj",
           "save_blob", "load_blob", "atomic_write_bytes"]
