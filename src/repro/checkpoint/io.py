"""Pytree checkpointing: msgpack index + raw .npy payloads.

No orbax in the container; this is a compact, dependency-light format that
round-trips nested dicts/tuples/lists of jax/numpy arrays and python
scalars, with optional sharding-aware restore (arrays are placed with
``jax.device_put`` against a provided sharding tree).
"""
from __future__ import annotations

import io
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

_KIND_ARRAY = 0
_KIND_SCALAR = 1


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(path: str, tree: PyTree) -> None:
    os.makedirs(path, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    index = []
    with open(os.path.join(path, "data.bin"), "wb") as f:
        for p, leaf in zip(paths, leaves):
            arr = np.asarray(leaf)
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            raw = buf.getvalue()
            index.append({"path": p, "offset": f.tell(), "size": len(raw),
                          "kind": _KIND_ARRAY})
            f.write(raw)
    with open(os.path.join(path, "index.msgpack"), "wb") as f:
        f.write(msgpack.packb({"leaves": index}))


def restore(path: str, like: PyTree, shardings: PyTree | None = None
            ) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(os.path.join(path, "index.msgpack"), "rb") as f:
        index = msgpack.unpackb(f.read())["leaves"]
    by_path = {e["path"]: e for e in index}
    paths, leaves, treedef = _flatten_with_paths(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    with open(os.path.join(path, "data.bin"), "rb") as f:
        for p, leaf, shard in zip(paths, leaves, shard_leaves):
            e = by_path[p]
            f.seek(e["offset"])
            arr = np.load(io.BytesIO(f.read(e["size"])),
                          allow_pickle=False)
            want = np.asarray(leaf)
            if arr.shape != want.shape:
                raise ValueError(f"{p}: shape {arr.shape} != {want.shape}")
            arr = arr.astype(want.dtype)
            out.append(jax.device_put(arr, shard) if shard is not None
                       else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
