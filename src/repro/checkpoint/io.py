"""Pytree checkpointing: msgpack index + raw .npy payloads.

No orbax in the container; this is a compact, dependency-light format
that round-trips nested dicts/tuples/lists of jax/numpy arrays and
python scalars, with optional sharding-aware restore (arrays are placed
with ``jax.device_put`` against a provided sharding tree).

Durability contract (the async service's crash-recovery layer,
``repro.fl.durability``, checkpoints through this module -- see
``docs/durability.md``):

* :func:`save` is **atomic**: payloads go to a uniquely named data file,
  everything is fsynced, and the index -- the commit point -- is
  installed with an atomic rename.  A crash at any instant leaves either
  the previous checkpoint or the new one, never a torn,
  loadable-looking hybrid.
* :func:`restore` **validates** before it trusts: every leaf's recorded
  shape, dtype, kind, and payload checksum must match; a missing path, a
  size mismatch, or a corrupt payload raises :class:`CheckpointError`
  naming the leaf instead of silently misreading offsets.
* The leaf codec round-trips what the service actually holds: bfloat16
  arrays (numpy's ``.npy`` cannot carry them raw -- stored as a uint16
  view plus a dtype tag), python ``int`` / ``float`` / ``bool`` scalars
  (the ``_KIND_SCALAR`` path -- they come back as scalars, not 0-d
  arrays), and JAX PRNG keys (typed keys via
  ``jax.random.key_data`` + impl tag; legacy ``uint32`` keys are plain
  arrays already).

:func:`pack_obj` / :func:`unpack_obj` serialize *self-describing*
objects (no ``like`` tree needed) -- nested dict / list / tuple /
scalars / strings / arrays -- which is what the write-ahead log and the
service snapshots use for variable-structure state (replay windows,
buffered uploads, flora's segment ledger).  :func:`atomic_write_bytes`
is the shared rename-commit primitive.
"""
from __future__ import annotations

import io
import os
import uuid
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

_KIND_ARRAY = 0
_KIND_SCALAR = 1
_KIND_KEY = 2

_INDEX = "index.msgpack"
_FORMAT = 2


class CheckpointError(ValueError):
    """A checkpoint that cannot be trusted: missing/extra leaves, shape or
    dtype mismatches against the restore target, checksum failures, torn
    or unparseable files.  Subclasses ``ValueError`` so existing
    ``except ValueError`` call sites keep working."""


# ------------------------------------------------------------ atomic I/O --
def _fsync_dir(dirname: str) -> None:
    """Flush directory metadata so a rename survives a crash (no-op on
    platforms whose dirs cannot be opened)."""
    try:
        fd = os.open(dirname, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes, fsync: bool = True) -> None:
    """Write ``data`` to ``path`` atomically: temp file in the same
    directory, flush + fsync, then rename-commit.  Readers see the old
    contents or the new contents, never a prefix."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    tmp = os.path.join(d, f".tmp-{uuid.uuid4().hex}")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        _fsync_dir(d)


# ------------------------------------------------------------ leaf codec --
def _is_typed_key(x) -> bool:
    """New-style jax PRNG key (extended dtype)?"""
    try:
        return jnp.issubdtype(jnp.asarray(x).dtype, jax.dtypes.prng_key)
    except (AttributeError, TypeError):
        return False


def _encode_leaf(leaf) -> tuple[dict, bytes]:
    """One leaf -> (index entry sans offset, payload bytes)."""
    if isinstance(leaf, (bool, int, float, str)) and not isinstance(
            leaf, np.generic):
        # the _KIND_SCALAR path: python scalars round-trip as python
        # scalars (state.round, FoldState.mass, service counters), not as
        # 0-d arrays that would poison ``round + 1`` style arithmetic
        # with device transfers
        return {"kind": _KIND_SCALAR, "value": leaf,
                "pykind": type(leaf).__name__}, b""
    if _is_typed_key(leaf):
        impl = str(jax.random.key_impl(leaf))
        data = np.asarray(jax.random.key_data(leaf))
        buf = io.BytesIO()
        np.save(buf, data, allow_pickle=False)
        raw = buf.getvalue()
        return {"kind": _KIND_KEY, "impl": impl,
                "crc": zlib.crc32(raw)}, raw
    arr = np.asarray(jax.device_get(leaf))
    logical = str(arr.dtype)
    if logical == "bfloat16":
        # np.save writes the dtype descr by name; np.load in a process
        # that has not registered ml_dtypes would then fail (or worse,
        # guess).  Store the raw bits as uint16 plus a tag instead.
        arr = arr.view(np.uint16)
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    raw = buf.getvalue()
    return {"kind": _KIND_ARRAY, "dtype": logical,
            "shape": list(arr.shape), "crc": zlib.crc32(raw)}, raw


def _decode_leaf(entry: dict, raw: bytes, where: str):
    """Inverse of :func:`_encode_leaf`; validates the checksum."""
    kind = entry["kind"]
    if kind == _KIND_SCALAR:
        value = entry["value"]
        py = {"bool": bool, "int": int, "float": float,
              "str": str}.get(entry.get("pykind", ""), None)
        return py(value) if py is not None else value
    crc = entry.get("crc")
    if crc is not None and zlib.crc32(raw) != crc:
        raise CheckpointError(
            f"{where}: payload checksum mismatch (corrupt or torn "
            "checkpoint data)")
    try:
        arr = np.load(io.BytesIO(raw), allow_pickle=False)
    except Exception as e:
        raise CheckpointError(f"{where}: unreadable payload ({e})") from e
    if kind == _KIND_KEY:
        key = jax.random.wrap_key_data(jnp.asarray(arr))
        if str(jax.random.key_impl(key)) != entry["impl"]:
            key = jax.random.wrap_key_data(jnp.asarray(arr),
                                           impl=entry["impl"])
        return key
    if entry.get("dtype") == "bfloat16":
        return jax.lax.bitcast_convert_type(jnp.asarray(arr), jnp.bfloat16)
    return jnp.asarray(arr)


# ------------------------------------------------------- path-index save --
def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(path: str, tree: PyTree) -> None:
    """Atomically checkpoint ``tree`` under directory ``path``.

    Payloads land in a fresh ``data-<token>.bin``; the index rename is
    the commit point, after which stale data files are pruned.  A crash
    anywhere in between leaves the previous checkpoint fully loadable.
    """
    os.makedirs(path, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    token = uuid.uuid4().hex[:12]
    data_name = f"data-{token}.bin"
    index = []
    with open(os.path.join(path, data_name), "wb") as f:
        for p, leaf in zip(paths, leaves):
            entry, raw = _encode_leaf(leaf)
            entry.update(path=p, offset=f.tell(), size=len(raw))
            index.append(entry)
            f.write(raw)
        f.flush()
        os.fsync(f.fileno())
    atomic_write_bytes(
        os.path.join(path, _INDEX),
        msgpack.packb({"format": _FORMAT, "data": data_name,
                       "leaves": index}))
    for name in os.listdir(path):          # prune superseded data files
        if name.startswith("data-") and name != data_name:
            try:
                os.remove(os.path.join(path, name))
            except OSError:
                pass


def _load_index(path: str) -> dict:
    index_path = os.path.join(path, _INDEX)
    if not os.path.exists(index_path):
        raise CheckpointError(f"no checkpoint index at {index_path}")
    try:
        with open(index_path, "rb") as f:
            index = msgpack.unpackb(f.read())
    except Exception as e:
        raise CheckpointError(
            f"unreadable checkpoint index {index_path} ({e})") from e
    if not isinstance(index, dict) or "leaves" not in index:
        raise CheckpointError(f"malformed checkpoint index {index_path}")
    return index


def restore(path: str, like: PyTree, shardings: PyTree | None = None
            ) -> PyTree:
    """Restore into the structure of ``like``.

    Every leaf is validated before it is trusted: the stored entry must
    exist for each of ``like``'s paths, array shapes and dtypes must
    match exactly (no silent cast), python scalars come back through the
    ``_KIND_SCALAR`` path as scalars, and payload checksums must verify.
    Any mismatch raises :class:`CheckpointError` naming the leaf.
    """
    index = _load_index(path)
    by_path = {e["path"]: e for e in index["leaves"]}
    data_name = index.get("data", "data.bin")
    paths, leaves, treedef = _flatten_with_paths(like)
    missing = [p for p in paths if p not in by_path]
    if missing:
        raise CheckpointError(
            f"checkpoint {path} is missing leaves {missing[:5]}"
            f"{'...' if len(missing) > 5 else ''} (have "
            f"{len(by_path)}, want {len(paths)})")
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    data_path = os.path.join(path, data_name)
    if not os.path.exists(data_path):
        raise CheckpointError(
            f"checkpoint {path}: index references missing payload file "
            f"{data_name}")
    out = []
    with open(data_path, "rb") as f:
        size = os.fstat(f.fileno()).st_size
        for p, leaf, shard in zip(paths, leaves, shard_leaves):
            e = by_path[p]
            if e["offset"] + e["size"] > size:
                raise CheckpointError(
                    f"{p}: payload extends past end of {data_name} "
                    "(truncated checkpoint)")
            f.seek(e["offset"])
            raw = f.read(e["size"])
            value = _decode_leaf(e, raw, where=p)
            want = leaf
            if e["kind"] == _KIND_ARRAY:
                if isinstance(want, (bool, int, float)) and not isinstance(
                        want, np.generic):
                    raise CheckpointError(
                        f"{p}: stored an array but restore target is a "
                        f"python {type(want).__name__}")
                want_arr = np.asarray(want)
                got_shape = tuple(value.shape)
                if got_shape != want_arr.shape:
                    raise CheckpointError(
                        f"{p}: shape {got_shape} != {want_arr.shape}")
                if str(e.get("dtype")) != str(want_arr.dtype):
                    raise CheckpointError(
                        f"{p}: dtype {e.get('dtype')} != "
                        f"{want_arr.dtype} (restore never casts "
                        "silently)")
            out.append(jax.device_put(value, shard)
                       if shard is not None else value)
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------- self-describing blob codec --
_T_NONE, _T_PY, _T_STR, _T_BYTES = "n", "p", "s", "y"
_T_DICT, _T_LIST, _T_TUPLE, _T_ARR = "d", "l", "t", "a"


def _enc(obj):
    if obj is None:
        return (_T_NONE,)
    if isinstance(obj, (bool, int, float)) and not isinstance(
            obj, np.generic):
        return (_T_PY, obj, type(obj).__name__)
    if isinstance(obj, str):
        return (_T_STR, obj)
    if isinstance(obj, bytes):
        return (_T_BYTES, obj)
    if isinstance(obj, dict):
        return (_T_DICT, [[k, _enc(v)] for k, v in obj.items()])
    if isinstance(obj, tuple):
        return (_T_TUPLE, [_enc(v) for v in obj])
    if isinstance(obj, list):
        return (_T_LIST, [_enc(v) for v in obj])
    entry, raw = _encode_leaf(obj)          # arrays, np scalars, PRNG keys
    return (_T_ARR, entry, raw)


def _dec(node):
    tag = node[0]
    if tag == _T_NONE:
        return None
    if tag == _T_PY:
        py = {"bool": bool, "int": int, "float": float}[node[2]]
        return py(node[1])
    if tag in (_T_STR, _T_BYTES):
        return node[1]
    if tag == _T_DICT:
        return {k: _dec(v) for k, v in node[1]}
    if tag == _T_TUPLE:
        return tuple(_dec(v) for v in node[1])
    if tag == _T_LIST:
        return [_dec(v) for v in node[1]]
    if tag == _T_ARR:
        return _decode_leaf(node[1], node[2], where="<blob>")
    raise CheckpointError(f"unknown blob node tag {tag!r}")


def pack_obj(obj) -> bytes:
    """Serialize a self-describing object graph (dict / list / tuple /
    None / bool / int / float / str / bytes / arrays incl. bfloat16 and
    PRNG keys) to bytes.  Deterministic for a given object (dict
    insertion order is preserved)."""
    return msgpack.packb(_enc(obj), use_bin_type=True)


def unpack_obj(data: bytes):
    """Inverse of :func:`pack_obj` (checksum-validated array payloads)."""
    try:
        node = msgpack.unpackb(data, use_list=True, strict_map_key=False)
    except Exception as e:
        raise CheckpointError(f"unreadable blob ({e})") from e
    return _dec(node)


def save_blob(path: str, obj, fsync: bool = True) -> int:
    """Atomically write one :func:`pack_obj` blob with a crc32 trailer;
    returns the byte size written.  The rename is the commit point."""
    payload = pack_obj(obj)
    framed = (len(payload).to_bytes(8, "little")
              + zlib.crc32(payload).to_bytes(4, "little") + payload)
    atomic_write_bytes(path, framed, fsync=fsync)
    return len(framed)


def load_blob(path: str):
    """Read back a :func:`save_blob` file; raises
    :class:`CheckpointError` on truncation or checksum mismatch."""
    with open(path, "rb") as f:
        head = f.read(12)
        if len(head) < 12:
            raise CheckpointError(f"{path}: truncated blob header")
        size = int.from_bytes(head[:8], "little")
        crc = int.from_bytes(head[8:12], "little")
        payload = f.read(size)
    if len(payload) != size:
        raise CheckpointError(f"{path}: truncated blob payload "
                              f"({len(payload)} of {size} bytes)")
    if zlib.crc32(payload) != crc:
        raise CheckpointError(f"{path}: blob checksum mismatch")
    return unpack_obj(payload)
