"""Service-level health view over the async aggregation + serving stack.

Operators of a long-lived FLaaS deployment need one call that answers
"is the service healthy *right now*": how stale are arriving updates,
what is being rejected and why, which wire codecs the fleet actually
uses, what a fold / publish costs, whether the plan cache is absorbing
cohort churn, and how full the serving store is.  :class:`ServiceHealth`
assembles exactly that from the metrics registry plus the live objects
(the registry holds the streams; the objects hold the point-in-time
state a gauge cannot keep honest, like page free lists and pinned
snapshots).

``ServiceHealth(aggregator=..., engine=...).snapshot()`` is the payload
a ``/healthz`` endpoint would serve; everything in it is plain JSON.
See ``docs/observability.md`` for the field catalog.
"""
from __future__ import annotations

from typing import Any

from .metrics import MetricsRegistry, get_registry

#: the latency percentiles every *_latency block reports
PERCENTILES = (0.5, 0.9, 0.99)


def _hist_view(hist_child) -> dict | None:
    if hist_child is None or hist_child.count == 0:
        return None
    view = {"count": int(hist_child.count),
            "mean": hist_child.sum / hist_child.count}
    for q in PERCENTILES:
        view[f"p{int(q * 100)}"] = hist_child.percentile(q)
    return view


def _labelled_values(metric, label: str) -> dict:
    """``{label value: count}`` for a single-label counter family."""
    if metric is None:
        return {}
    out = {}
    for key, value in metric.samples().items():
        # key is "name=value" (single labelname)
        out[key.partition("=")[2]] = value
    return out


class ServiceHealth:
    """One view over an :class:`~repro.fl.AsyncAggregator`, a
    :class:`~repro.serving.ServingEngine` and/or
    :class:`~repro.serving.AdapterStore`, and the metrics registry they
    report into.  Any component may be ``None``; its section is omitted.
    """

    def __init__(self, aggregator=None, engine=None, store=None,
                 registry: MetricsRegistry | None = None):
        self.aggregator = aggregator
        self.engine = engine
        self.store = store if store is not None else (
            engine.store if engine is not None else None)
        if registry is None and aggregator is not None:
            registry = getattr(aggregator, "obs_registry", None)
        self.registry = registry or get_registry()

    # ------------------------------------------------------------ pieces --
    def _span_latency(self, stage: str) -> dict | None:
        hist = self.registry.get("obs_span_seconds")
        if hist is None:
            return None
        child = hist._children.get((stage,))
        return _hist_view(child)

    def staleness(self) -> dict | None:
        """The staleness distribution of accepted updates (histogram
        buckets in the aggregator's clock units) plus its percentiles."""
        hist = self.registry.get("fl_staleness")
        if hist is None or not hist._children:
            return None
        child = hist._children.get(())
        if child is None or child.count == 0:
            return None
        view = child._sample()
        view.update(_hist_view(child))
        return view

    def rejections(self) -> dict:
        """Per-reason rejection counts (see ``docs/observability.md``
        for the reason catalog)."""
        return _labelled_values(
            self.registry.get("fl_updates_rejected_total"), "reason")

    def codec_mix(self) -> dict:
        """Accepted uploads per wire codec."""
        return _labelled_values(
            self.registry.get("fl_uploads_by_codec_total"), "codec")

    def plan_cache(self) -> dict | None:
        """The aggregator strategy's plan-cache hit rate (the live
        per-instance ``plan_stats``, the shimmed public surface)."""
        if self.aggregator is None:
            return None
        stats = dict(self.aggregator.strategy.__dict__.get(
            "plan_stats", {}))
        hits = stats.get("hits", 0)
        misses = stats.get("misses", 0)
        stats["hit_rate"] = hits / (hits + misses) if hits + misses else None
        return stats

    def durability(self) -> dict | None:
        """Crash-recovery posture (only when the aggregator is a
        :class:`~repro.fl.DurableAggregator`): WAL volume, checkpoint
        cadence and latency, recoveries/replays so far, and the serving
        publish quarantine.  The one operator question this answers:
        *if the server died right now, how much would replay cost?*"""
        agg = self.aggregator
        if agg is None or not hasattr(agg, "wal"):
            return None
        view = {
            "wal_last_seq": agg.wal.last_seq,
            "wal_records_appended": agg.wal.n_records,
            "wal_bytes_appended": agg.wal.bytes_written,
            "wal_torn_frames": agg.wal.n_torn,
            "checkpoint_every": agg.checkpoint_every,
            "n_checkpoints": agg.n_checkpoints,
            "n_recoveries": agg.n_recoveries,
            "n_replayed_updates": agg.n_replayed,
            # replay exposure: records journaled past the newest snapshot
            "replay_backlog": max(agg.wal.last_seq - agg._ckpt_seq, 0),
            "checkpoint_latency": _hist_view(
                self._hist_child("fl_checkpoint_seconds")),
            "restore_latency": _hist_view(
                self._hist_child("fl_restore_seconds")),
        }
        eng = self.engine
        if eng is not None and hasattr(eng, "n_publish_failures"):
            view["publish_failures"] = eng.n_publish_failures
            view["publish_quarantined"] = eng._publish_pending is not None
        return view

    def _hist_child(self, name: str):
        hist = self.registry.get(name)
        if hist is None:
            return None
        return hist._children.get(())

    def store_health(self) -> dict | None:
        """Page occupancy per bucket and the pinned-snapshot count --
        read live off the store (free lists and snapshot liveness are
        point-in-time state, not streams)."""
        store = self.store
        if store is None:
            return None
        return {
            "version": store.version,
            "n_tenants": store.n_tenants,
            "pinned_snapshots": store.pinned_snapshots,
            "page_occupancy": store.occupancy(),
        }

    # ----------------------------------------------------------- the view --
    def snapshot(self) -> dict:
        """The health payload: staleness histogram, per-reason
        rejections, codec mix, fold/publish latency percentiles,
        plan-cache hit rate, buffer state, store occupancy."""
        out: dict[str, Any] = {}
        agg = self.aggregator
        if agg is not None:
            out["service"] = {
                "version": agg.version,
                "n_received": agg.n_received,
                "n_folded": agg.n_folded,
                "n_flushes": agg.n_flushes,
                "n_dropped": agg.n_dropped,
                "n_published": agg.n_published,
                "mean_staleness": agg.mean_staleness(),
                "wire_bytes_received": agg.wire_bytes_received,
                "buffer_depth": len(agg.buffer),
                "buffer_wire_bytes": agg.buffer.total_wire_bytes(),
            }
            out["plan_cache"] = self.plan_cache()
        out["staleness"] = self.staleness()
        out["rejections"] = self.rejections()
        out["codec_mix"] = self.codec_mix()
        out["latency"] = {
            stage: self._span_latency(stage)
            for stage in ("submit", "flush", "fold", "publish", "serve")}
        store_view = self.store_health()
        if store_view is not None:
            out["store"] = store_view
        dur_view = self.durability()
        if dur_view is not None:
            out["durability"] = dur_view
        return out


__all__ = ["ServiceHealth", "PERCENTILES"]
