"""Exporters: Prometheus text format, JSON-lines, in-memory snapshot.

Three ways out of a :class:`~repro.obs.MetricsRegistry`:

* :meth:`MetricsRegistry.snapshot` -- the in-memory dict view (embedded
  verbatim in every benchmark's ``--json`` payload);
* :func:`to_prometheus` -- the Prometheus text exposition format
  (counters get a ``_total``-as-written name, histograms expand into
  cumulative ``_bucket{le=...}`` series plus ``_sum`` / ``_count``);
* :func:`write_jsonl_snapshot` -- one JSON line per call, for an
  append-only metrics log next to the span :class:`~repro.obs.EventLog`.

:func:`parse_prometheus` parses the text format back into flat samples
-- the round-trip property (export -> parse == the registry's own
samples) is gated in ``tests/test_obs.py``.
"""
from __future__ import annotations

import json
import time

from .metrics import MetricsRegistry, get_registry


def _fmt_labels(label_key: str, extra: str = "") -> str:
    parts = []
    if label_key:
        for item in label_key.split(","):
            name, value = item.split("=", 1)
            parts.append(f'{name}="{value}"')
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_prometheus(registry: MetricsRegistry | None = None) -> str:
    """The registry in Prometheus text exposition format."""
    reg = registry or get_registry()
    lines = []
    for inst in reg.collect():
        samples = inst.samples()
        if not samples:
            continue
        if inst.help:
            lines.append(f"# HELP {inst.name} {inst.help}")
        lines.append(f"# TYPE {inst.name} {inst.kind}")
        for key, val in samples.items():
            if inst.kind == "histogram":
                cum = 0
                for edge, count in val["buckets"]:
                    cum += count
                    le = 'le="%g"' % edge
                    lines.append(
                        f"{inst.name}_bucket{_fmt_labels(key, le)} {cum}")
                cum += val["overflow"]
                inf = 'le="+Inf"'
                lines.append(
                    f"{inst.name}_bucket{_fmt_labels(key, inf)} {cum}")
                lines.append(
                    f"{inst.name}_sum{_fmt_labels(key)} "
                    f"{_fmt_value(val['sum'])}")
                lines.append(
                    f"{inst.name}_count{_fmt_labels(key)} {val['count']}")
            else:
                lines.append(
                    f"{inst.name}{_fmt_labels(key)} {_fmt_value(val)}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus text format into
    ``{series_name: {frozenset(label pairs): value}}`` -- enough to
    verify the export round-trips (``tests/test_obs.py``)."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_part, _, value_part = line.rpartition(" ")
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            label_str = rest.rstrip("}")
            labels = []
            for item in label_str.split(","):
                if not item:
                    continue
                k, _, v = item.partition("=")
                labels.append((k, v.strip('"')))
            key = frozenset(labels)
        else:
            name, key = name_part, frozenset()
        value = float(value_part)
        out.setdefault(name, {})[key] = value
    return out


def write_jsonl_snapshot(path, registry: MetricsRegistry | None = None,
                         **meta) -> dict:
    """Append one JSON line holding a full registry snapshot (plus a
    timestamp and any ``meta``); returns the record written."""
    reg = registry or get_registry()
    record = {"ts": time.time(), **meta, "metrics": reg.snapshot()}
    with open(path, "a") as f:
        f.write(json.dumps(record) + "\n")
    return record


__all__ = ["to_prometheus", "parse_prometheus", "write_jsonl_snapshot"]
