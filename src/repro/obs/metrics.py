"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

The FLaaS server is a long-lived process whose operational signals used to
live in scattered ad-hoc state (``plan.dispatch_counter``, per-strategy
``plan_stats`` dicts, ``lora_matmul.trace_counts``, the async service's
hand-counted attributes).  This module gives them one home:

* a :class:`MetricsRegistry` holds named instruments; modules create them
  once at import / construction time and cache the handle -- the hot path
  is one ``enabled`` check, one lock, one float add;
* instruments are **Prometheus-shaped**: monotone :class:`Counter`,
  settable :class:`Gauge`, and :class:`Histogram` with *fixed* bucket
  upper edges (``observe`` is O(log buckets), percentiles read back off
  the edges) -- no unbounded per-sample storage, safe for a server that
  never restarts;
* labels follow the Prometheus child model: ``metric.labels(reason=...)``
  returns a cached child; callers on hot paths hold the child, not the
  parent;
* everything is lock-safe (one ``threading.Lock`` per instrument family)
  and **cheap when disabled**: :func:`set_enabled` (or
  ``MetricsRegistry(enabled=False)``) turns every record call into a
  single attribute read and return;
* tests get :meth:`MetricsRegistry.reset` (zero every value, keep the
  instruments -- cached handles stay valid) and
  :meth:`MetricsRegistry.scoped` (save values, zero, restore on exit --
  concurrent-safe snapshots of a shared process registry).

Exporters live in :mod:`repro.obs.export`; span timing in
:mod:`repro.obs.trace`; the service-level view in
:mod:`repro.obs.health`.  See ``docs/observability.md`` for the metric
catalog and the overhead guarantees.
"""
from __future__ import annotations

import bisect
import contextlib
import math
import re
import threading
from typing import Iterable, Mapping, Sequence

_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default histogram edges for latency-in-seconds instruments: ~100us to
#: 30s, geometric -- wide enough for a CPU interpreter fold and a TPU
#: kernel alike; the overflow (+Inf) bucket is implicit.
LATENCY_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1,
                   1.0, 3.0, 10.0, 30.0)

#: default edges for staleness (server versions or wall seconds behind):
#: fine near fresh, coarse in the straggler tail.
STALENESS_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


def _label_key(labelnames: Sequence[str], labels: Mapping) -> tuple:
    try:
        return tuple(str(labels[n]) for n in labelnames)
    except KeyError:
        missing = [n for n in labelnames if n not in labels]
        raise ValueError(
            f"missing label(s) {missing}; declared labelnames "
            f"{list(labelnames)}") from None


class _Instrument:
    """Base: one named instrument family with optional labels.

    A family with ``labelnames=()`` has exactly one child (itself, label
    key ``()``); labelled families create children on first
    :meth:`labels` call and cache them forever (label cardinality is
    bounded by construction: reasons, codecs, kernel entry names).
    """

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str,
                 help: str = "", labelnames: Sequence[str] = ()):
        self._registry = registry
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        # reentrant: family-level state walks (reset/scoped) hold the
        # lock while touching children, which lock their own updates
        self._lock = threading.RLock()
        self._children: dict[tuple, "_Child"] = {}
        if not self.labelnames:
            self._default = self._make_child(())
            self._children[()] = self._default
        else:
            self._default = None

    # -- child management ------------------------------------------------
    def _make_child(self, key: tuple) -> "_Child":
        raise NotImplementedError

    def labels(self, **labels) -> "_Child":
        key = _label_key(self.labelnames, labels)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child(key))
        return child

    # -- introspection ---------------------------------------------------
    def samples(self) -> dict:
        """``{label_key_string: value-ish}`` for every live child."""
        with self._lock:
            items = list(self._children.items())
        return {",".join(f"{n}={v}" for n, v in zip(self.labelnames, key)):
                child._sample() for key, child in items}

    def _state(self):
        with self._lock:
            return {k: c._get_state() for k, c in self._children.items()}

    def _restore(self, state) -> None:
        with self._lock:
            for k, c in self._children.items():
                c._set_state(state.get(k))

    def _reset(self) -> None:
        with self._lock:
            for c in self._children.values():
                c._set_state(None)


class _Child:
    """One (instrument, label values) time series."""

    def __init__(self, family: _Instrument, key: tuple):
        self._family = family
        self._key = key
        self._lock = family._lock

    @property
    def _enabled(self) -> bool:
        return self._family._registry.enabled

    def _sample(self):
        raise NotImplementedError

    def _get_state(self):
        raise NotImplementedError

    def _set_state(self, state) -> None:
        """``None`` means zero."""
        raise NotImplementedError


class _CounterChild(_Child):
    def __init__(self, family, key):
        super().__init__(family, key)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if not self._enabled:
            return
        if amount < 0:
            raise ValueError(f"counters are monotone; inc({amount})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def _sample(self):
        return self._value

    def _get_state(self):
        return self._value

    def _set_state(self, state):
        self._value = 0.0 if state is None else state


class Counter(_Instrument):
    """Monotone counter family.  ``counter.inc()`` on the unlabelled
    default child; ``counter.labels(reason="x").inc()`` on a labelled
    one."""

    kind = "counter"

    def _make_child(self, key):
        return _CounterChild(self, key)

    def inc(self, amount: float = 1.0) -> None:
        if self._default is None:
            raise ValueError(
                f"{self.name} declares labels {self.labelnames}; use "
                ".labels(...)")
        self._default.inc(amount)

    @property
    def value(self) -> float:
        if self._default is None:
            raise ValueError(f"{self.name} is labelled; read .samples()")
        return self._default.value


class _GaugeChild(_Child):
    def __init__(self, family, key):
        super().__init__(family, key)
        self._value = 0.0

    def set(self, value: float) -> None:
        if not self._enabled:
            return
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if not self._enabled:
            return
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value

    def _sample(self):
        return self._value

    def _get_state(self):
        return self._value

    def _set_state(self, state):
        self._value = 0.0 if state is None else state


class Gauge(_Instrument):
    """Point-in-time value family (buffer depth, page occupancy, store
    version)."""

    kind = "gauge"

    def _make_child(self, key):
        return _GaugeChild(self, key)

    def set(self, value: float) -> None:
        self._default.set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default.inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default.dec(amount)

    @property
    def value(self) -> float:
        return self._default.value


class _HistogramChild(_Child):
    def __init__(self, family, key):
        super().__init__(family, key)
        n = len(family.buckets)
        self._counts = [0] * (n + 1)        # + overflow (+Inf) bucket
        self._sum = 0.0
        self._count = 0
        self._max = None

    def observe(self, value: float) -> None:
        if not self._enabled:
            return
        value = float(value)
        # bucket semantics are Prometheus ``le``: value v lands in the
        # first bucket whose upper edge e satisfies v <= e
        i = bisect.bisect_left(self._family.buckets, value)
        with self._lock:
            self._counts[i] += 1
            self._sum += value
            self._count += 1
            if self._max is None or value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float | None:
        """Bucket-resolution quantile: the upper edge of the bucket in
        which the q-quantile observation falls (the overflow bucket
        reports the max observed value).  ``None`` with no observations.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            total = self._count
            if total == 0:
                return None
            target = q * total
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= target and c:
                    if i < len(self._family.buckets):
                        return float(self._family.buckets[i])
                    return float(self._max)
            return float(self._max)

    def _sample(self):
        with self._lock:
            return {
                "buckets": [[float(e), int(c)] for e, c in
                            zip(self._family.buckets, self._counts)],
                "overflow": int(self._counts[-1]),
                "sum": self._sum, "count": self._count,
                "max": self._max,
            }

    def _get_state(self):
        with self._lock:
            return (list(self._counts), self._sum, self._count, self._max)

    def _set_state(self, state):
        with self._lock:
            if state is None:
                self._counts = [0] * len(self._counts)
                self._sum, self._count, self._max = 0.0, 0, None
            else:
                self._counts, self._sum, self._count, self._max = \
                    list(state[0]), state[1], state[2], state[3]


class Histogram(_Instrument):
    """Fixed-bucket histogram family.  ``buckets`` are the finite upper
    edges (strictly increasing); an overflow (+Inf) bucket is implicit.
    """

    kind = "histogram"

    def __init__(self, registry, name, help="", labelnames=(),
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        buckets = tuple(float(b) for b in buckets)
        if not buckets:
            raise ValueError("histogram needs at least one bucket edge")
        if any(b2 <= b1 for b1, b2 in zip(buckets, buckets[1:])):
            raise ValueError(
                f"bucket edges must be strictly increasing: {buckets}")
        if any(math.isnan(b) or math.isinf(b) for b in buckets):
            raise ValueError(f"bucket edges must be finite: {buckets}")
        self.buckets = buckets
        super().__init__(registry, name, help, labelnames)

    def _make_child(self, key):
        return _HistogramChild(self, key)

    def observe(self, value: float) -> None:
        self._default.observe(value)

    @property
    def count(self) -> int:
        return self._default.count

    @property
    def sum(self) -> float:
        return self._default.sum

    def percentile(self, q: float) -> float | None:
        return self._default.percentile(q)


class MetricsRegistry:
    """Named instruments, get-or-create, process-lifetime.

    ``counter`` / ``gauge`` / ``histogram`` return the existing
    instrument when the name is already registered (re-registration with
    a conflicting kind, labelnames, or buckets raises -- a name means one
    thing).  Instruments are cheap to look up but callers on hot paths
    should cache the handle (and the labelled child) once.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: dict[str, _Instrument] = {}

    # -- construction ----------------------------------------------------
    def _register(self, cls, name, help, labelnames, **kw) -> _Instrument:
        if not _NAME_RE.match(name):
            raise ValueError(
                f"bad metric name {name!r}: must match {_NAME_RE.pattern}")
        with self._lock:
            got = self._metrics.get(name)
            if got is not None:
                if not isinstance(got, cls):
                    raise ValueError(
                        f"{name} already registered as {got.kind}")
                if got.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"{name} already registered with labels "
                        f"{got.labelnames}, not {tuple(labelnames)}")
                if kw.get("buckets") is not None and \
                        tuple(kw["buckets"]) != got.buckets:
                    raise ValueError(
                        f"{name} already registered with buckets "
                        f"{got.buckets}")
                return got
            inst = (cls(self, name, help, labelnames, **{
                k: v for k, v in kw.items() if v is not None})
                if cls is Histogram
                else cls(self, name, help, labelnames))
            self._metrics[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] | None = None) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    # -- introspection ---------------------------------------------------
    def get(self, name: str) -> _Instrument | None:
        return self._metrics.get(name)

    def collect(self) -> Iterable[_Instrument]:
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> dict:
        """One consistent, JSON-serializable view of every instrument:
        ``{"counters": {name: {label_key: v}}, "gauges": ...,
        "histograms": {name: {label_key: {buckets, sum, count, max}}}}``.
        Safe under concurrent writers: each child is read under its
        family lock.
        """
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for inst in self.collect():
            out[inst.kind + "s"][inst.name] = inst.samples()
        return out

    # -- lifecycle (tests) -----------------------------------------------
    def reset(self) -> None:
        """Zero every value; instruments and cached children survive."""
        for inst in self.collect():
            inst._reset()

    @contextlib.contextmanager
    def scoped(self):
        """Save all values, zero them, restore on exit -- an isolated
        measurement window over a shared registry.  Cached instrument
        handles keep working inside and after the scope."""
        saved = [(inst, inst._state()) for inst in self.collect()]
        was_enabled = self.enabled
        for inst, _ in saved:
            inst._reset()
        self.enabled = True
        try:
            yield self
        finally:
            self.enabled = was_enabled
            for inst, state in saved:
                inst._restore(state)


#: the process-default registry every repro module instruments against;
#: pass an explicit registry to services that need isolation.
REGISTRY = MetricsRegistry(enabled=True)


def get_registry() -> MetricsRegistry:
    return REGISTRY


def set_enabled(enabled: bool) -> bool:
    """Flip metric recording on the default registry; returns the
    previous state.  Disabled recording is a single attribute check per
    call -- the documented overhead guarantee (``docs/observability.md``)
    is gated in CI against this switch."""
    prev = REGISTRY.enabled
    REGISTRY.enabled = bool(enabled)
    return prev


def metrics_enabled() -> bool:
    return REGISTRY.enabled


__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram",
           "REGISTRY", "get_registry", "set_enabled", "metrics_enabled",
           "LATENCY_BUCKETS", "STALENESS_BUCKETS"]
