"""Shared benchmark timing utilities (JAX-aware, registry-integrated).

Every benchmark used to hand-roll the same three things: a
``block_until_ready``-on-leaves helper, a warm-up-then-time loop, and a
``--json`` payload with the :func:`repro.kernels.runtime.bench_env`
header.  They live here now, next to the metrics they feed:

* :func:`block` -- block on a pytree's array leaves (the only correct
  way to time lazy JAX dispatch);
* :func:`time_fn` -- warm up (compile) once, then time ``iters`` calls
  and reduce with ``min`` (default; on a 1-vCPU CI box a co-scheduled
  process steals the whole core, so the minimum is the real cost -- the
  PR 7 lesson) or ``mean``;
* :func:`bench_payload` -- the standard machine-readable payload
  (``BENCH_*.json``): bench name, backend, environment header, and a
  full metrics-registry :func:`~repro.obs.MetricsRegistry.snapshot`, so
  every committed benchmark run carries its own observability record.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax

from .metrics import MetricsRegistry, get_registry


def block(tree: Any) -> Any:
    """``jax.block_until_ready`` on every array leaf; returns ``tree``."""
    jax.block_until_ready(
        [x for x in jax.tree.leaves(tree)
         if hasattr(x, "block_until_ready")])
    return tree


def time_fn(fn: Callable[[], Any], iters: int = 3,
            reduce: str = "min") -> float:
    """Seconds per call of ``fn`` (which must return a pytree of arrays
    -- we block on every leaf).  The first call warms up / compiles and
    is not timed.  ``reduce="min"`` (timeit-style, default) or
    ``"mean"``.
    """
    if reduce not in ("min", "mean"):
        raise ValueError(f"reduce must be min|mean, got {reduce!r}")
    block(fn())                               # warm up / compile
    times = []
    for _ in range(max(int(iters), 1)):
        t0 = time.perf_counter()
        block(fn())
        times.append(time.perf_counter() - t0)
    return min(times) if reduce == "min" else sum(times) / len(times)


def bench_payload(bench: str, *, smoke: bool, case: dict, results: Any,
                  registry: MetricsRegistry | None = None,
                  **extra) -> dict:
    """The standard ``--json`` payload every benchmark writes: the
    shared environment header plus a metrics snapshot under ``"obs"``."""
    from repro.kernels.runtime import bench_env     # deferred: no cycle
    reg = registry or get_registry()
    payload = {
        "bench": bench,
        "backend": jax.default_backend(),
        "env": bench_env(),
        "smoke": bool(smoke),
        "case": case,
        "results": results,
        "obs": reg.snapshot(),
    }
    payload.update(extra)
    return payload


__all__ = ["block", "time_fn", "bench_payload"]
