"""Span-based round-lifecycle tracing with JAX-aware timers.

The aggregation service's round lifecycle is::

    submit -> buffer -> flush/replay -> fold -> publish -> serve

Each stage is wrapped in a :func:`span`: a context manager that measures
wall time into the ``obs_span_seconds{stage=...}`` histogram and
(optionally) appends a JSON-serializable event to an :class:`EventLog`.

Two JAX rules, both hard requirements (``tests/test_obs.py`` gates
them):

* **Block only at span boundaries.**  JAX dispatch is asynchronous; a
  naive timer measures enqueue cost, not compute.  A span caller hands
  the stage's *result* to :meth:`Span.block` (or passes ``block_on=``)
  and the span calls ``jax.block_until_ready`` on its array leaves
  exactly once, at the boundary -- never inside the computation.
* **Never trace Python into jitted code.**  Spans are host-side pure
  Python; if one is (incorrectly) entered while JAX is tracing, it
  degrades to a complete no-op -- no timing call, no callback, nothing
  staged into the jaxpr -- so instrumentation can never add a trace or a
  retrace to a compiled path (the zero-retrace guarantee).
"""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any

import jax

from .metrics import LATENCY_BUCKETS, get_registry

#: the canonical round-lifecycle stages (free-form stage names are
#: allowed; these are the ones the service emits)
ROUND_STAGES = ("submit", "buffer", "flush", "replay", "fold", "publish",
                "serve")


def _trace_clean() -> bool:
    """True when JAX is *not* currently tracing (spans may run)."""
    try:
        return bool(jax.core.trace_state_clean())
    except AttributeError:      # very old / very new jax: fail open as
        return True             # "not tracing" (spans are host-called)


class EventLog:
    """Bounded in-memory event ring with an optional JSON-lines sink.

    ``log(event)`` appends a dict; with :meth:`attach_jsonl` every event
    is also written as one JSON line (the exporter format operators tail
    into their log pipeline).  Thread-safe.
    """

    def __init__(self, maxlen: int = 4096):
        self._events: collections.deque = collections.deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self._sink = None
        self._sink_path = None

    def attach_jsonl(self, path) -> None:
        """Start appending every event as a JSON line to ``path``."""
        with self._lock:
            if self._sink is not None:
                self._sink.close()
            self._sink = open(path, "a")
            self._sink_path = path

    def detach(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.close()
            self._sink = None
            self._sink_path = None

    def log(self, event: dict) -> None:
        with self._lock:
            self._events.append(event)
            if self._sink is not None:
                self._sink.write(json.dumps(event) + "\n")
                self._sink.flush()

    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


#: process-default event log; spans write here when ``log=True``
EVENT_LOG = EventLog()


class Span:
    """One timed stage.  Use via :func:`span`."""

    __slots__ = ("stage", "meta", "_t0", "_active", "_registry", "_log",
                 "duration_s")

    def __init__(self, stage: str, registry, log, meta):
        self.stage = stage
        self.meta = meta
        self._registry = registry
        self._log = log
        self._active = False
        self._t0 = 0.0
        self.duration_s = None

    def block(self, tree: Any) -> Any:
        """Wait for ``tree``'s array leaves (the stage's result) so the
        span measures compute, not enqueue; returns ``tree``.  No-op on
        an inactive span (disabled metrics / under jit)."""
        if self._active:
            jax.block_until_ready(
                [x for x in jax.tree.leaves(tree)
                 if hasattr(x, "block_until_ready")])
        return tree

    def __enter__(self) -> "Span":
        reg = self._registry
        self._active = reg.enabled and _trace_clean()
        if self._active:
            self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._active:
            return
        self.duration_s = time.perf_counter() - self._t0
        _span_hist(self._registry).labels(stage=self.stage).observe(
            self.duration_s)
        if self._log:
            event = {"event": "span", "stage": self.stage,
                     "duration_s": self.duration_s,
                     "t_end": time.time()}
            if exc_type is not None:
                event["error"] = exc_type.__name__
            if self.meta:
                event.update(self.meta)
            EVENT_LOG.log(event)


def _span_hist(registry):
    # get-or-create is idempotent and cheap (one lock, one dict hit);
    # keying off the registry itself avoids any id-reuse bookkeeping
    return registry.histogram(
        "obs_span_seconds", "wall seconds per lifecycle stage",
        labelnames=("stage",), buckets=LATENCY_BUCKETS)


def span(stage: str, *, registry=None, block_on: Any = None,
         log: bool = False, **meta) -> Span:
    """A timed lifecycle stage::

        with span("fold") as sp:
            out = strategy.aggregate(...)
            sp.block(out)          # JAX-aware: block at the boundary

    ``block_on`` blocks on a pytree at *entry* (isolating this stage
    from still-in-flight predecessors).  ``log=True`` also appends the
    span to :data:`EVENT_LOG` (and its JSON-lines sink, when attached).
    Extra keyword arguments ride along as event metadata.  When metrics
    are disabled -- or JAX is tracing -- the span is a no-op.
    """
    sp = Span(stage, registry or get_registry(), log, meta)
    if block_on is not None and sp._registry.enabled and _trace_clean():
        jax.block_until_ready(
            [x for x in jax.tree.leaves(block_on)
             if hasattr(x, "block_until_ready")])
    return sp


__all__ = ["span", "Span", "EventLog", "EVENT_LOG", "ROUND_STAGES"]
