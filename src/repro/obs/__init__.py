"""repro.obs -- unified observability: metrics, tracing, health, export.

The operational substrate for the FLaaS server (see
``docs/observability.md``):

* :mod:`repro.obs.metrics` -- the process :class:`MetricsRegistry`
  (counters / gauges / fixed-bucket histograms, lock-safe, cheap no-op
  when disabled, ``reset()`` / ``scoped()`` for tests);
* :mod:`repro.obs.trace` -- span-based round-lifecycle tracing
  (``submit -> buffer -> flush/replay -> fold -> publish -> serve``)
  with JAX-aware timers that block only at span boundaries and degrade
  to no-ops under jit (the zero-retrace guarantee);
* :mod:`repro.obs.export` -- Prometheus text format, JSON-lines, and
  the in-memory :meth:`MetricsRegistry.snapshot`;
* :mod:`repro.obs.health` -- :class:`ServiceHealth`, the one-call
  operator view over the async aggregation service and the serving
  store;
* :mod:`repro.obs.timing` -- the shared benchmark timing helpers.
"""
from .metrics import (LATENCY_BUCKETS, REGISTRY, STALENESS_BUCKETS,
                      Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, metrics_enabled, set_enabled)
from .trace import EVENT_LOG, ROUND_STAGES, EventLog, Span, span
from .export import parse_prometheus, to_prometheus, write_jsonl_snapshot
from .health import ServiceHealth
from .timing import bench_payload, block, time_fn

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "REGISTRY",
    "get_registry", "set_enabled", "metrics_enabled",
    "LATENCY_BUCKETS", "STALENESS_BUCKETS",
    "span", "Span", "EventLog", "EVENT_LOG", "ROUND_STAGES",
    "to_prometheus", "parse_prometheus", "write_jsonl_snapshot",
    "ServiceHealth",
    "block", "time_fn", "bench_payload",
]
