"""Learning-rate schedules (callables of the int32 step count)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def cosine(peak: float, total_steps: int, warmup: int = 0,
           floor: float = 0.0):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak * c / jnp.maximum(warmup, 1)
        t = jnp.clip((c - warmup) / jnp.maximum(total_steps - warmup, 1),
                     0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(c < warmup, warm, cos)
    return fn


def exponential(init: float, decay: float, every: int):
    def fn(count):
        return jnp.asarray(init, jnp.float32) * decay ** (
            count.astype(jnp.float32) / every)
    return fn
