from .optimizers import (Optimizer, adam, adamw, apply_updates,
                         clip_by_global_norm, sgd)
from .schedules import constant, cosine, exponential

__all__ = ["Optimizer", "adam", "adamw", "apply_updates",
           "clip_by_global_norm", "sgd", "constant", "cosine", "exponential"]
