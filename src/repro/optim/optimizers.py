"""Minimal optax-style optimizers in pure JAX (optax is not installed).

An optimizer is a pair of pure functions:

    init(params)                  -> state
    update(grads, state, params)  -> (updates, state)      # updates are
                                                           # *added* to params

plus :func:`apply_updates`.  All states are pytrees, so they shard/jit
exactly like params.  ``masked`` freezes a sub-tree (used for LoRA-only
fine-tuning: base weights get zero updates and **no optimizer state**, which
is what makes 100B+ fine-tuning fit on a pod).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates, is_leaf=lambda x: x is None)


def _resolve_lr(lr, count):
    return lr(count) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        mu = (jax.tree.map(jnp.zeros_like, params) if momentum else None)
        return {"count": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        del params
        count = state["count"] + 1
        step = _resolve_lr(lr, count)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g,
                              state["mu"], grads)
            eff = (jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
                   if nesterov else mu)
        else:
            mu, eff = None, grads
        updates = jax.tree.map(lambda g: -step * g, eff)
        return updates, {"count": count, "mu": mu}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW (decoupled decay when ``weight_decay > 0``)."""
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              params),
        }

    def update(grads, state, params=None):
        count = state["count"] + 1
        step = _resolve_lr(lr, count)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -step * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay and p is not None:
                u = u - step * weight_decay * p.astype(jnp.float32)
            return u
        if params is None:
            updates = jax.tree.map(lambda m_, v_: upd(m_, v_, None), m, v)
        else:
            updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    def init(params):
        return opt.init(params)

    def update(grads, state, params=None):
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.update(grads, state, params)

    return Optimizer(init, update)
