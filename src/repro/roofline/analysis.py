"""Roofline terms from a compiled dry-run artifact.

  compute_s    = HLO_FLOPs / (chips * peak)
  memory_s     = HLO_bytes / (chips * hbm_bw)
  collective_s = collective_bytes / (chips * ici_bw)

``cost_analysis`` FLOPs/bytes from XLA are for the *per-device* partitioned
module; we treat them as per-chip and normalize accordingly (chips factor
already applied by SPMD partitioning).  Collective bytes are not in
cost_analysis -- ``collective_bytes_from_hlo`` parses the post-SPMD HLO
text and sums the output-shape bytes of every collective op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from . import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[2,512,128]{2,1,0} all-gather(%x) or
#       (f32[8,16]{1,0}, f32[8,16]{1,0}) all-reduce-start(...)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<out>\([^)]*\)|[\w\[\],{}: ]+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(?P<dt>\w+?)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Per-collective-op output bytes (per device), summed by op kind."""
    out: dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue  # counted at -start
        op = m.group("op")
        out[op] = out.get(op, 0) + _shape_bytes(m.group("out"))
    return out


@dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    collective_bytes: float      # per-device collective bytes
    chips: int
    model_flops: float = 0.0     # 6*N*D useful flops (global)
    collectives: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / hw.ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops across all chips)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "collectives": self.collectives,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops_estimate(cfg, shape, n_params_active: float,
                         kind: str) -> float:
    """6*N*D for training, 2*N*D for inference forward (per step)."""
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch


def scan_correction(cfg) -> float:
    """Trip-count correction for XLA CPU cost_analysis.

    The CPU backend counts a ``while``-lowered ``lax.scan`` body ONCE
    (verified empirically: scan-of-10 matmuls reports exactly 1/10 the
    flops of the unrolled version).  Our models scan over layer stacks, so
    raw cost_analysis numbers undercount by roughly the layer count.  We
    correct with a parameter-weighted trip-count multiplier:

        c = sum_s R_s * W_s / sum_s W_s

    over stages s (repeat R_s, per-unit params W_s) plus a non-scanned
    pseudo-stage (embedding/head, R=1).  Exact when per-param cost is
    uniform; applied to flops, bytes and collective bytes alike.
    """
    units = []
    d = cfg.d_model
    embed_w = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    units.append((1, embed_w))
    for stage in cfg.stages + cfg.encoder_stages:
        w = sum(_block_params(cfg, spec) for spec in stage.unit)
        units.append((stage.repeat, w))
    num = sum(r * w for r, w in units)
    den = sum(w for _, w in units)
    return num / den if den else 1.0


def _block_params(cfg, spec) -> float:
    d = cfg.d_model
    n = 0.0
    if spec.kind == "mamba":
        d_in = cfg.ssm_expand * d
        h = d_in // cfg.ssm_head_dim
        n += d * (2 * d_in + 2 * cfg.ssm_state + h) + d_in * d
    elif spec.kind == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        n += d * cfg.q_lora_rank
        n += cfg.q_lora_rank * cfg.n_heads * qk
        n += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        n += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim +
                                               cfg.v_head_dim)
        n += cfg.n_heads * cfg.v_head_dim * d
    else:
        hd = cfg.head_dim
        n += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
        n += cfg.n_heads * hd * d
        if spec.cross_attn:
            n *= 2
    if spec.ffn == "dense":
        mult = 2 if cfg.mlp_act == "gelu_plain" else 3
        n += mult * d * cfg.d_ff
    elif spec.ffn == "moe":
        f = cfg.moe_d_ff or cfg.d_ff
        # dispatched compute ~ active experts x capacity factor
        n += (3 * d * f * cfg.experts_per_token * cfg.capacity_factor
              + 3 * d * f * cfg.n_shared_experts + d * cfg.n_experts)
    return n


def active_params(cfg) -> float:
    """Approximate active (per-token) parameter count from the config."""
    d = cfg.d_model
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    for stage in cfg.stages + cfg.encoder_stages:
        for spec in stage.unit:
            n = 0.0
            if spec.kind == "mamba":
                d_in = cfg.ssm_expand * d
                h = d_in // cfg.ssm_head_dim
                n += d * (2 * d_in + 2 * cfg.ssm_state + h) + d_in * d
            elif spec.kind == "mla":
                qk = cfg.qk_nope_dim + cfg.qk_rope_dim
                n += d * cfg.q_lora_rank
                n += cfg.q_lora_rank * cfg.n_heads * qk
                n += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                n += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim +
                                                       cfg.v_head_dim)
                n += cfg.n_heads * cfg.v_head_dim * d
            else:
                hd = cfg.head_dim
                n += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
                n += cfg.n_heads * hd * d
                if spec.cross_attn:
                    n *= 2
            if spec.ffn == "dense":
                mult = 2 if cfg.mlp_act == "gelu_plain" else 3
                n += mult * d * cfg.d_ff
            elif spec.ffn == "moe":
                f = cfg.moe_d_ff or cfg.d_ff
                n += 3 * d * f * cfg.experts_per_token      # active experts
                n += 3 * d * f * cfg.n_shared_experts
                n += d * cfg.n_experts                      # router
            total += n * stage.repeat
    return total
