from . import hw
from .analysis import (Roofline, active_params, collective_bytes_from_hlo,
                       model_flops_estimate)

__all__ = ["hw", "Roofline", "active_params", "collective_bytes_from_hlo",
           "model_flops_estimate"]
