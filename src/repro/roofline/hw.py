"""TPU v5e hardware constants (per chip) for the roofline model."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~ per-chip injection)

CHIPS_PER_POD = 256
