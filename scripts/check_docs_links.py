#!/usr/bin/env python
"""Fail on broken intra-repo links in README.md and docs/*.md.

Scans every markdown link target; external URLs and pure anchors are
skipped, everything else must resolve to a file or directory — relative
to the containing file, or to the repo root (both styles appear in the
docs). Run from anywhere: ``python scripts/check_docs_links.py``.
Exit code 0 = all links resolve; 1 = broken links (listed on stderr).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def broken_links(md: Path) -> list[str]:
    bad = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]      # strip section anchors
        if not path:
            continue
        if not ((md.parent / path).exists() or (REPO / path).exists()):
            bad.append(target)
    return bad


def main() -> int:
    missing_docs = [p for p in ("README.md", "docs/async.md",
                                "docs/strategies.md")
                    if not (REPO / p).exists()]
    failures = {str(md.relative_to(REPO)): broken_links(md)
                for md in doc_files()}
    failures = {k: v for k, v in failures.items() if v}
    if missing_docs:
        print(f"missing required docs: {missing_docs}", file=sys.stderr)
    for doc, links in failures.items():
        print(f"{doc}: broken links {links}", file=sys.stderr)
    if missing_docs or failures:
        return 1
    n = sum(len(LINK_RE.findall(md.read_text())) for md in doc_files())
    print(f"docs links OK ({len(doc_files())} files, {n} links)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
