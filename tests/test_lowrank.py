"""The factored low-rank SVD engine (`repro.core.lowrank`).

Exactness gates: the factored QR-core SVD is an algebraic re-association
of the dense SVD, so it must match the `jnp.linalg.svd` oracle on the
materialized product -- across ranks, dtypes, and batched (layer-stacked)
inputs.  The randomized range-finder is an approximation and is gated
against the optimal truncation error (the spectrum tail) instead.

Also enforces the repo-wide invariant this engine exists for: no call
site in `src/repro` materializes a dense (out, in) delta for an SVD --
`jnp.linalg.svd` appears only inside `repro.core.lowrank`.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lowrank import (dense_svd, factored_svd, product_factors,
                                randomized_svd, svd_project_stacked,
                                truncated_svd_product)

jax.config.update("jax_platform_name", "cpu")

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


def random_factors(rng, m, n, k, lead=(), dtype=jnp.float32):
    B = jnp.asarray(rng.normal(size=lead + (m, k)), dtype)
    A = jnp.asarray(rng.normal(size=lead + (k, n)), dtype)
    return B, A


def svd_close(got, want, rtol=1e-4, atol=1e-5):
    """Compare two truncated SVDs by their invariants: the singular
    values and the reconstructed product (individual factors are only
    unique up to sign/rotation in degenerate spectra)."""
    (u1, s1, vt1), (u2, s2, vt2) = got, want
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=rtol, atol=atol)
    r1 = np.asarray(u1 * s1[..., None, :] @ vt1)
    r2 = np.asarray(u2 * s2[..., None, :] @ vt2)
    np.testing.assert_allclose(r1, r2, rtol=rtol, atol=atol)


# ------------------------------------------------------- exactness gates --
@pytest.mark.parametrize("m,n,k,r_out", [
    (20, 15, 4, 4),          # low rank, lossless truncation
    (20, 15, 8, 4),          # low rank, lossy truncation
    (15, 20, 6, 6),          # wide
    (9, 7, 3, 7),            # r_out beyond the factored rank (zero pad)
    (12, 12, 12, 8),         # k == min(m, n): the exactness boundary
])
def test_factored_svd_matches_dense_oracle(m, n, k, r_out):
    rng = np.random.default_rng(m * 100 + n + k)
    B, A = random_factors(rng, m, n, k)
    svd_close(factored_svd(B, A, r_out), dense_svd(B, A, r_out))


def test_factored_svd_is_exact_reconstruction_when_lossless():
    """Sum(r) <= min(m, n) and r_out >= k: the truncation loses nothing,
    so U S Vt must reproduce B @ A itself (the binding-oracle case the
    acceptance criteria name)."""
    rng = np.random.default_rng(0)
    B, A = random_factors(rng, 24, 18, 5)
    U, S, Vt = factored_svd(B, A, 5)
    np.testing.assert_allclose(np.asarray(U * S[None, :] @ Vt),
                               np.asarray(B @ A), rtol=1e-4, atol=1e-5)


def test_factored_svd_batches_over_leading_dims():
    """Layer-stacked pairs: the engine batches like jnp.linalg does, and
    every batch element matches its own unbatched run."""
    rng = np.random.default_rng(1)
    B, A = random_factors(rng, 11, 13, 4, lead=(3, 2))
    U, S, Vt = factored_svd(B, A, 4)
    assert U.shape == (3, 2, 11, 4) and S.shape == (3, 2, 4)
    for i in range(3):
        for j in range(2):
            svd_close((U[i, j], S[i, j], Vt[i, j]),
                      dense_svd(B[i, j], A[i, j], 4))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_factored_svd_dtypes(dtype):
    rng = np.random.default_rng(2)
    B, A = random_factors(rng, 16, 12, 4, dtype=dtype)
    tol = dict(rtol=1e-4, atol=1e-5) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-2)      # bf16 inputs: ~8-bit mantissa
    svd_close(factored_svd(B, A, 4), dense_svd(B, A, 4), **tol)


def test_truncated_svd_product_auto_routes_overcomplete_to_dense():
    """k > min(m, n): the factored path would do more work than the
    dense one, so auto falls back -- and stays exact."""
    rng = np.random.default_rng(3)
    B, A = random_factors(rng, 9, 7, 30)
    svd_close(truncated_svd_product(B, A, 6, method="auto"),
              dense_svd(B, A, 6))
    with pytest.raises(ValueError, match="unknown svd method"):
        truncated_svd_product(B, A, 6, method="qr")


def test_product_factors_split_is_balanced_and_faithful():
    rng = np.random.default_rng(4)
    B, A = random_factors(rng, 18, 14, 4)
    Bo, Ao = product_factors(B, A, 4)
    np.testing.assert_allclose(np.asarray(Bo @ Ao), np.asarray(B @ A),
                               rtol=1e-4, atol=1e-5)
    # balanced square-root split: both factors carry sqrt(S)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(Bo), axis=0),
        np.linalg.norm(np.asarray(Ao), axis=1), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------- randomized SVD --
def test_randomized_svd_error_bounded_by_spectrum_tail():
    """Range-finder gate: on a decaying spectrum, the rank-r
    approximation error must sit within a small factor of the optimal
    (exact truncated SVD) error -- the Frobenius tail."""
    rng = np.random.default_rng(5)
    m, n, r = 60, 40, 8
    u, _ = np.linalg.qr(rng.normal(size=(m, n)))
    v, _ = np.linalg.qr(rng.normal(size=(n, n)))
    spectrum = 0.7 ** np.arange(n)
    M = (u * spectrum) @ v.T
    U, S, Vt = randomized_svd(jnp.asarray(M, jnp.float32), r,
                              oversample=8, power_iters=2,
                              key=jax.random.PRNGKey(7))
    err = np.linalg.norm(M - np.asarray(U * S[None, :] @ Vt))
    opt = np.linalg.norm(spectrum[r:])          # optimal Frobenius tail
    assert err <= 1.5 * opt + 1e-4, (err, opt)


def test_randomized_product_sketch_stays_factored_and_accurate():
    """method="randomized" must sketch through the factors (no dense
    B @ A anywhere) and still recover a low-rank product exactly."""
    from repro.core.lowrank import randomized_svd_product
    rng = np.random.default_rng(11)
    B, A = random_factors(rng, 40, 35, 4)
    U, S, Vt = randomized_svd_product(B, A, 4, key=jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(U * S[None, :] @ Vt),
                               np.asarray(B @ A), rtol=1e-3, atol=1e-3)
    # routed through the dispatcher too
    U2, S2, Vt2 = truncated_svd_product(B, A, 4, method="randomized",
                                        key=jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S),
                               rtol=1e-5, atol=1e-6)


def test_randomized_svd_recovers_exactly_low_rank_input():
    rng = np.random.default_rng(6)
    B, A = random_factors(rng, 30, 25, 4)
    M = B @ A
    U, S, Vt = randomized_svd(M, 4, key=jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(U * S[None, :] @ Vt),
                               np.asarray(M), rtol=1e-3, atol=1e-3)


# -------------------------------------------------- aggregation plumbing --
def test_svd_project_stacked_matches_dense_weighted_mean():
    """The strategy-facing entry: weighted product mean == the factored
    projection's product, scales folded in (scalar-rank pairs)."""
    rng = np.random.default_rng(7)
    n, out, r_st, fin, r_out = 4, 14, 6, 10, 5
    B = jnp.asarray(rng.normal(size=(n, out, r_st)), jnp.float32)
    A = jnp.asarray(rng.normal(size=(n, r_st, fin)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    sc = jnp.asarray(rng.uniform(0.5, 1.5, n), jnp.float32)
    Bo, Ao = svd_project_stacked(B, A, w, r_out, scales=sc)
    wn = np.asarray(w) / np.asarray(w).sum()
    delta = sum(wn[i] * float(sc[i])
                * np.asarray(B[i]) @ np.asarray(A[i]) for i in range(n))
    u, s, vt = np.linalg.svd(delta)
    want = (u[:, :r_out] * s[:r_out]) @ vt[:r_out]
    np.testing.assert_allclose(np.asarray(Bo @ Ao), want,
                               rtol=1e-4, atol=1e-5)


def test_svd_project_stacked_layer_stacked_matches_per_layer_loop():
    """Layer-stacked pairs batch through the engine; each layer must
    match its own per-layer dense truncation (the loop the old code said
    it would need)."""
    rng = np.random.default_rng(8)
    n, L, out, r_st, fin, r_out = 3, 4, 12, 5, 9, 4
    B = jnp.asarray(rng.normal(size=(n, L, out, r_st)), jnp.float32)
    A = jnp.asarray(rng.normal(size=(n, L, r_st, fin)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    Bo, Ao = svd_project_stacked(B, A, w, r_out)
    assert Bo.shape == (L, out, r_out) and Ao.shape == (L, r_out, fin)
    wn = np.asarray(w) / np.asarray(w).sum()
    for l in range(L):
        delta = sum(wn[i] * np.asarray(B[i, l]) @ np.asarray(A[i, l])
                    for i in range(n))
        u, s, vt = np.linalg.svd(delta)
        want = (u[:, :r_out] * s[:r_out]) @ vt[:r_out]
        np.testing.assert_allclose(np.asarray(Bo[l] @ Ao[l]), want,
                                   rtol=1e-4, atol=1e-4, err_msg=f"l={l}")


def test_svd_strategy_aggregates_layer_stacked_pairs():
    """The svd strategy no longer refuses layer-stacked pairs: the
    engine batches them, and each layer serves the weighted mean of the
    clients' per-layer effective updates (lossless case)."""
    from repro.core.strategy import get_strategy
    from repro.lora import init_pair, mask_pair

    rng = np.random.default_rng(9)
    n, L, r, fo, fi = 3, 2, 8, 12, 16
    ranks = [2, 1, 2]                    # sum(+scales) stays <= r
    cohort = []
    for i in range(n):
        p = dict(init_pair(jax.random.PRNGKey(i), fo, fi, r, ranks[i],
                           leading=(L,)))
        p["A"] = p["A"] + jnp.asarray(rng.normal(size=p["A"].shape),
                                      jnp.float32)
        p["B"] = p["B"] + jnp.asarray(rng.normal(size=p["B"].shape),
                                      jnp.float32)
        cohort.append({"blk": mask_pair(p)})
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    out = get_strategy("svd").with_options().aggregate_adapters(
        cohort, w, r_max=r, client_ranks=jnp.asarray(ranks, jnp.int32),
        backend="ref")
    wn = np.asarray(w) / np.asarray(w).sum()
    for l in range(L):
        got = (np.asarray(out["blk"]["B"][l])
               @ np.asarray(out["blk"]["A"][l])) / r
        want = sum(wn[i]
                   * np.asarray(cohort[i]["blk"]["B"][l])
                   @ np.asarray(cohort[i]["blk"]["A"][l]) / ranks[i]
                   for i in range(n))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"layer {l}")


# --------------------------------------------------- repo-wide invariant --
def test_no_dense_svd_call_sites_outside_lowrank():
    """The acceptance criterion, enforced: `jnp.linalg.svd` on a
    materialized product may appear only inside repro.core.lowrank (its
    dense fallback).  Every other call site must go through the engine."""
    offenders = []
    for path in SRC.rglob("*.py"):
        if path.name == "lowrank.py":
            continue
        if "linalg.svd" in path.read_text():
            offenders.append(str(path.relative_to(SRC)))
    assert not offenders, (
        f"dense SVD call sites outside repro.core.lowrank: {offenders}")
