"""Crash-recovery suite: checkpoint hardening, WAL, idempotency, chaos.

The load-bearing guarantee is **bit-identical recovery**: crash the
service at any WAL/checkpoint boundary, reconstruct it from disk, finish
the workload -- and the final adapters equal the uninterrupted run's
exactly (for ``supports_incremental`` strategies; within the parity
tolerance for replay-from-anchor ones).  Plus: the hardened checkpoint
io rejects corruption/shape/dtype drift loudly, the WAL tolerates torn
tails but refuses mid-stream corruption, the dedup window makes
at-least-once ingestion fold exactly once in every buffering mode, and
the chaos-injected simulator runs to completion deterministically.

Property tests run under ``tests/_hypothesis_stub.py`` (containers
without hypothesis) and real hypothesis alike -- zero-arg wrappers, so
no pytest fixtures inside (tempfile instead of tmp_path).
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (CheckpointError, load_blob, pack_obj, restore,
                              save, save_blob, unpack_obj)
from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from repro.fl import (AsyncAggregator, AsyncFLConfig, DedupWindow,
                      DurableAggregator, FaultPlan, RetryPolicy,
                      WriteAheadLog, run_async_simulation)
from repro.fl.chaos import flaky
from repro.lora import init_adapters

from _cohorts import R_MAX, SPECS, assert_trees_close, hetero_cohort

jax.config.update("jax_platform_name", "cpu")


def make_state(strategy, seed=99):
    r_storage = strategy.server_storage_rank(R_MAX) or R_MAX
    prev = init_adapters(jax.random.PRNGKey(seed), SPECS, r_storage, R_MAX)
    base = {"b": jnp.zeros((4,), jnp.float32)}
    return ServerState(adapters=prev, base_trainable=base, r_max=R_MAX)


def make_updates(n=8, seed=3):
    adapters, ranks, w, bases = hetero_cohort(n, seed=seed, with_bases=True)
    return [ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                         n_examples=float(w[i]), rank=int(ranks[i]))
            for i in range(n)]


def assert_trees_equal(a, b, msg=""):
    """Bit-exact tree equality (recovery's contract, not a tolerance)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


# ------------------------------------------------ checkpoint io hardening --
def test_checkpoint_roundtrips_bf16_scalars_and_keys(tmp_path):
    """bf16 (uint16 view + tag), python scalars, strings and typed PRNG
    keys all survive save/restore bit-exactly."""
    tree = {
        "w": jnp.asarray([[1.5, -2.25], [0.125, 3e-2]], jnp.bfloat16),
        "n": 7, "lr": 0.3, "on": True, "name": "rbla",
        "key": jax.random.key(42),
        "x": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
    }
    path = str(tmp_path / "ck")
    save(path, tree)
    back = restore(path, tree)
    assert back["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(back["w"], np.float32),
                          np.asarray(tree["w"], np.float32))
    assert back["n"] == 7 and back["lr"] == 0.3
    assert back["on"] is True and back["name"] == "rbla"
    assert np.array_equal(jax.random.key_data(back["key"]),
                          jax.random.key_data(tree["key"]))
    assert np.array_equal(back["x"], tree["x"])


def test_restore_rejects_shape_and_dtype_mismatch(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"a": jnp.zeros((2, 3), jnp.float32)})
    with pytest.raises(CheckpointError, match="shape"):
        restore(path, {"a": jnp.zeros((3, 2), jnp.float32)})
    with pytest.raises(CheckpointError, match="dtype"):
        restore(path, {"a": jnp.zeros((2, 3), jnp.int32)})
    with pytest.raises(CheckpointError):
        restore(path, {"b": jnp.zeros((2, 3), jnp.float32)})


def test_restore_detects_bit_rot(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"a": jnp.ones((16, 16), jnp.float32)})
    data = [n for n in os.listdir(path) if n.startswith("data-")]
    assert len(data) == 1            # stale blobs from prior saves pruned
    fp = os.path.join(path, data[0])
    raw = bytearray(open(fp, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(fp, "wb").write(bytes(raw))
    with pytest.raises(CheckpointError, match="checksum|corrupt"):
        restore(path, {"a": jnp.ones((16, 16), jnp.float32)})


def test_blob_roundtrip_and_corruption(tmp_path):
    obj = {"replay": [[{"A": np.arange(4.0)}, 1.5]],
           "ids": ("u1", "u2"), "none": None, "raw": b"\x00\xff",
           "bf": jnp.asarray([1.5, -0.25], jnp.bfloat16)}
    path = str(tmp_path / "blob.bin")
    save_blob(path, obj)
    back = load_blob(path)
    assert back["ids"] == ("u1", "u2")     # tuple stays a tuple
    assert back["none"] is None and back["raw"] == b"\x00\xff"
    assert back["bf"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(back["bf"], np.float32),
                          np.asarray(obj["bf"], np.float32))
    assert np.array_equal(back["replay"][0][0]["A"], obj["replay"][0][0]["A"])
    # truncation (torn write) and bit rot both fail loudly
    raw = open(path, "rb").read()
    open(path, "wb").write(raw[:len(raw) - 3])
    with pytest.raises(CheckpointError):
        load_blob(path)
    flipped = bytearray(raw)
    flipped[-1] ^= 0x01
    open(path, "wb").write(bytes(flipped))
    with pytest.raises(CheckpointError):
        load_blob(path)


def test_pack_obj_preserves_dict_order():
    obj = {"z": 1, "a": 2, "m": 3}
    assert list(unpack_obj(pack_obj(obj))) == ["z", "a", "m"]


# ----------------------------------------------------------------- the WAL --
def test_wal_roundtrip_and_torn_tail(tmp_path):
    d = str(tmp_path)
    wal = WriteAheadLog(d, fsync=False)
    for i in range(5):
        wal.append("submit", {"i": i})
    wal.close()
    seg = [os.path.join(d, n) for n in sorted(os.listdir(d))][0]
    with open(seg, "ab") as f:
        f.write(b"\x40\x00\x00\x00\xde\xad")   # crash mid-append
    wal2 = WriteAheadLog(d, fsync=False)
    recs = list(wal2.records())
    assert [b["i"] for _, _, b in recs] == [0, 1, 2, 3, 4]
    assert wal2.last_seq == 5 and wal2.n_torn >= 1
    # appends continue past the discarded torn frame
    assert wal2.append("submit", {"i": 5}) == 6


def test_wal_mid_stream_corruption_refuses(tmp_path):
    d = str(tmp_path)
    wal = WriteAheadLog(d, fsync=False)
    first = None
    for i in range(4):
        wal.append("submit", {"payload": "x" * 64, "i": i})
    wal.close()
    seg = [os.path.join(d, n) for n in sorted(os.listdir(d))][0]
    raw = bytearray(open(seg, "rb").read())
    raw[40] ^= 0xFF                    # inside the FIRST record's payload
    open(seg, "wb").write(bytes(raw))
    # a second, clean segment makes the corrupt one non-final: that is
    # silent record loss, not a torn tail -- refuse, don't skip
    wal2 = WriteAheadLog.__new__(WriteAheadLog)
    wal2.dir, wal2.fsync, wal2._fh, wal2._segment = d, False, None, None
    wal2.n_torn = wal2.bytes_written = wal2.n_records = wal2.last_seq = 0
    wal2._open_segment(100)
    wal2.append("submit", {"i": 99})
    wal2.close()
    with pytest.raises(CheckpointError, match="mid-stream"):
        list(wal2.records())


def test_wal_rotation_prunes_covered_segments(tmp_path):
    d = str(tmp_path)
    wal = WriteAheadLog(d, fsync=False)
    for i in range(3):
        wal.append("submit", {"i": i})
    wal.rotate(covered_seq=3)
    for i in range(3, 6):
        wal.append("submit", {"i": i})
    # the fully covered first segment is gone; only the live one remains
    assert [b["i"] for _, _, b in wal.records()] == [3, 4, 5]
    assert len([n for n in os.listdir(d) if n.startswith("wal-")]) == 1
    wal.close()


# ------------------------------------------------------- dedup and retries --
def test_dedup_window_slides():
    w = DedupWindow(3)
    for uid in ("a", "b", "c"):
        w.add(uid)
    assert "a" in w and len(w) == 3
    w.add("d")                         # evicts oldest
    assert "a" not in w and "b" in w and "d" in w
    w2 = DedupWindow(3)
    w2.load_state_dict(w.state_dict())
    assert "b" in w2 and "a" not in w2
    with pytest.raises(ValueError):
        DedupWindow(0)


def test_retry_policy_deterministic_bounded():
    p = RetryPolicy(base=0.5, factor=2.0, max_delay=4.0, max_retries=3,
                    jitter=0.2, seed=7)
    a = [p.delay(i, salt=11) for i in range(6)]
    b = [p.delay(i, salt=11) for i in range(6)]
    assert a == b                      # seeded: replays identically
    assert p.delay(0, salt=1) != p.delay(0, salt=2)   # clients decorrelate
    for i, d in enumerate(a):
        assert 0 < d <= 4.0 * 1.2
    assert not p.give_up(2) and p.give_up(3)
    with pytest.raises(ValueError):
        RetryPolicy(base=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)


# ----------------------------------------- idempotent at-least-once folding --
@pytest.mark.parametrize("mode", ["streaming", "buffered", "replay_anchor"])
def test_same_update_id_folds_exactly_once(mode):
    """The regression the dedup window exists for: redeliver every upload
    and the state must match a clean exactly-once run bit-for-bit, in
    all three fold paths (streaming incremental, buffered semi-async,
    and replay-from-anchor for non-incremental strategies)."""
    method = "rbla_median" if mode == "replay_anchor" else "rbla"
    buffer_size = 3 if mode == "buffered" else 1
    s = get_strategy(method)
    if mode == "replay_anchor":
        assert not s.supports_incremental
    updates = make_updates(6)

    clean = AsyncAggregator(s, make_state(s), buffer_size=buffer_size)
    dup = AsyncAggregator(s, make_state(s), buffer_size=buffer_size)
    for i, u in enumerate(updates):
        clean.submit(u, now=float(i), update_id=f"u{i}")
        dup.submit(u, now=float(i), update_id=f"u{i}")
        # at-least-once transport: every upload redelivered immediately
        assert dup.submit(u, now=float(i), update_id=f"u{i}") is False
    # ... and a late redelivery of the first id, many folds later
    assert dup.submit(updates[0], now=99.0, update_id="u0") is False
    clean.flush(now=100.0)
    dup.flush(now=100.0)
    assert dup.version == clean.version
    assert dup.n_received == clean.n_received
    assert_trees_equal(dup.state.adapters, clean.state.adapters,
                       f"{mode}: duplicate delivery changed the state")
    assert_trees_equal(dup.state.base_trainable, clean.state.base_trainable)


# ---------------------------------------------------------- crash recovery --
def run_to(agg, updates, stop, start=0, **kw):
    for i in range(start, stop):
        agg.submit(updates[i], model_version=0, now=float(i),
                   update_id=f"u{i}", **kw)


def test_crash_recovery_bit_identical(tmp_path):
    """Kill after 5 accepted uploads (checkpoint at 3 + WAL tail),
    recover, finish -- bit-identical to never having crashed, including
    the bf16 accumulators, stochastic-rounding PRNG stream, momentum
    and the dedup window."""
    s = get_strategy("rbla")
    kw = dict(accum_dtype="bfloat16", seed=7, server_momentum=0.5,
              buffer_size=2, deadline=5.0)
    oracle = AsyncAggregator(s, make_state(s), **kw)
    updates = make_updates(8)
    run_to(oracle, updates, 8)
    oracle.maybe_flush(now=100.0)

    d = str(tmp_path)
    first = DurableAggregator(s, make_state(s), dir=d, checkpoint_every=3,
                              wal_fsync=False, **kw)
    run_to(first, updates, 5)
    first.close()                      # crash: no clean shutdown

    second = DurableAggregator(s, make_state(s), dir=d, checkpoint_every=3,
                               wal_fsync=False, **kw)
    assert second.n_recoveries == 1 and second.n_replayed == 2
    # the restored dedup window still rejects a pre-crash id
    assert second.submit(updates[1], now=1.0, update_id="u1") is False
    run_to(second, updates, 8, start=5)
    second.maybe_flush(now=100.0)
    assert_trees_equal(second.state.adapters, oracle.state.adapters,
                       "recovered run diverged from the uninterrupted one")
    assert_trees_equal(second.state.base_trainable,
                       oracle.state.base_trainable)
    assert second.version == oracle.version
    assert second.n_received == oracle.n_received


def test_recovery_falls_back_past_corrupt_checkpoint(tmp_path):
    """A checkpoint torn by bit rot is skipped: recovery restores the
    previous snapshot and replays a longer WAL tail -- same final bits
    (the WAL pruning policy keeps every record the oldest retained
    checkpoint still needs)."""
    s = get_strategy("rbla")
    updates = make_updates(8)
    oracle = AsyncAggregator(s, make_state(s))
    run_to(oracle, updates, 7)

    d = str(tmp_path)
    first = DurableAggregator(s, make_state(s), dir=d, checkpoint_every=3,
                              keep_checkpoints=2, wal_fsync=False)
    run_to(first, updates, 7)          # checkpoints at 3 and 6
    first.close()
    ckpts = sorted(n for n in os.listdir(d) if n.startswith("ckpt-"))
    assert len(ckpts) == 2
    fp = os.path.join(d, ckpts[-1])
    raw = bytearray(open(fp, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(fp, "wb").write(bytes(raw))

    second = DurableAggregator(s, make_state(s), dir=d, checkpoint_every=3,
                               keep_checkpoints=2, wal_fsync=False)
    assert second.n_replayed == 4      # records 4..7 re-driven
    assert_trees_equal(second.state.adapters, oracle.state.adapters)


@settings(max_examples=6, deadline=None)
@given(spec=st.tuples(st.integers(1, 7), st.integers(1, 4),
                      st.sampled_from(["rbla", "rbla_median"])))
def test_crash_consistency_property(spec):
    """Property: for ANY crash point x checkpoint cadence x strategy,
    recover-and-finish equals the uninterrupted run -- bit-identical for
    exact-incremental strategies, within the parity tolerance for
    replay-from-anchor ones (their fold recomputes a joint aggregate
    whose float reassociation the contract does not pin)."""
    cut, every, method = spec
    s = get_strategy(method)
    updates = make_updates(8)
    oracle = AsyncAggregator(s, make_state(s), seed=11)
    run_to(oracle, updates, 8)

    with tempfile.TemporaryDirectory() as d:
        first = DurableAggregator(s, make_state(s), dir=d, seed=11,
                                  checkpoint_every=every, wal_fsync=False)
        run_to(first, updates, cut)
        first.close()
        second = DurableAggregator(s, make_state(s), dir=d, seed=11,
                                   checkpoint_every=every, wal_fsync=False)
        run_to(second, updates, 8, start=cut)
    assert second.version == oracle.version
    if s.supports_incremental:
        assert_trees_equal(second.state.adapters, oracle.state.adapters,
                           f"{method} cut={cut} every={every}")
    else:
        assert_trees_close(second.state.adapters, oracle.state.adapters,
                           msg=f"{method} cut={cut} every={every}")
    assert_trees_equal(second.state.base_trainable,
                       oracle.state.base_trainable)


# ------------------------------------------------------------------- chaos --
def test_fault_plan_is_deterministic_and_validated():
    p1 = FaultPlan(seed=5, p_drop=0.3, p_corrupt=0.2, crash_at=(10,))
    p2 = FaultPlan(seed=5, p_drop=0.3, p_corrupt=0.2, crash_at=(10,))
    draws1 = [(p1.drop(i), p1.corrupt(i)) for i in range(50)]
    assert draws1 == [(p2.drop(i), p2.corrupt(i)) for i in range(50)]
    assert any(d for d, _ in draws1) and not all(d for d, _ in draws1)
    assert p1.crash_now(10) and not p1.crash_now(9)
    # independent streams: a drop draw says nothing about a corrupt draw
    assert draws1 != [(c, d) for d, c in draws1]
    with pytest.raises(ValueError):
        FaultPlan(p_drop=1.5)


def test_corrupt_and_truncate_bounce_off_front_door():
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s))
    u = make_updates(1)[0]
    plan = FaultPlan(seed=0, p_corrupt=1.0)
    with pytest.raises(ValueError, match="non-finite"):
        agg.submit(plan.corrupt_update(u))
    with pytest.raises(ValueError, match="truncated|malformed"):
        agg.submit(plan.truncate_update(u))
    assert agg.version == 0            # nothing reached the fold


@pytest.mark.slow
def test_chaos_simulation_completes_and_is_deterministic(tmp_path):
    """The full gauntlet: drops + retries, duplicates, reordering,
    corruption, truncation, stale pulls and two crash-restarts -- the
    run completes, and an identical plan over a fresh directory lands on
    the identical accuracy trajectory."""
    cfg = AsyncFLConfig(
        n_clients=3, r_max=8, n_per_class=8, n_test_per_class=4,
        batch_size=8, total_updates=10, eval_every=5, buffer_size=2,
        buffer_deadline_s=3.0, wal_dir=str(tmp_path / "a"),
        checkpoint_every=4, retry_base_s=0.2)
    plan = FaultPlan(seed=1, p_drop=0.25, p_duplicate=0.25, p_reorder=0.2,
                     p_corrupt=0.1, p_truncate=0.1, p_stale_pull=0.2,
                     crash_at=(4, 7))
    h1 = run_async_simulation(cfg, fault_plan=plan)
    cfg2 = dataclasses.replace(cfg, wal_dir=str(tmp_path / "b"))
    h2 = run_async_simulation(cfg2, fault_plan=plan)
    assert len(h1.test_acc) == 2
    assert h1.test_acc == h2.test_acc
    assert h1.mean_staleness == h2.mean_staleness


def test_publish_failure_keeps_serving_last_snapshot():
    """Graceful serving degradation: a failing hot-swap quarantines the
    pending state, readers keep the last committed snapshot, and the
    retry (with backoff) publishes the NEWEST pending tree."""
    from repro.serving import AdapterStore, ServingEngine

    store = AdapterStore({"l0": (8, 6)}, r_max=4)
    rng = np.random.default_rng(0)
    weights = {"l0": jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)}
    eng = ServingEngine(weights, store, interpret=True)

    def tree(seed):
        r = np.random.default_rng(seed)
        return {"l0": {"A": jnp.asarray(r.normal(size=(4, 6)), jnp.float32),
                       "B": jnp.asarray(r.normal(size=(8, 4)), jnp.float32),
                       "rank": jnp.asarray(4, jnp.int32)}}

    eng.publish(tree(0))
    v0 = store.version
    orig, broken = store.publish, {"on": True}

    def flaky_publish(t):
        if broken["on"]:
            raise RuntimeError("injected publish fault")
        return orig(t)

    store.publish = flaky_publish
    pub = eng.publisher(max_backoff=4)
    state = dataclasses.make_dataclass("S", ["adapters"])

    pub(state(tree(1)))                # fails -> quarantined, skip 1
    assert store.version == v0 and eng.n_publish_failures == 1
    x = jnp.ones((3, 6), jnp.float32)
    y = eng.apply("l0", x, jnp.zeros((3,), jnp.int32))
    assert bool(jnp.all(jnp.isfinite(y)))     # still serving v0
    pub(state(tree(2)))                # inside backoff: skipped
    assert eng.n_publish_failures == 1
    pub(state(tree(3)))                # retry -> fails again, skip 2
    assert eng.n_publish_failures == 2 and store.version == v0
    broken["on"] = False
    pub(state(tree(4)))                # skipped (backoff 2)
    pub(state(tree(5)))                # skipped
    pub(state(tree(6)))                # retry succeeds, newest tree wins
    assert store.version == v0 + 1
    assert eng._publish_pending is None and eng._publish_fail_streak == 0


def test_flaky_wrapper_follows_plan():
    plan = FaultPlan(seed=3, p_publish_fail=0.5)
    calls = {"n": 0}

    def fn():
        calls["n"] += 1

    wrapped = flaky(fn, plan)
    outcomes = []
    for i in range(20):
        try:
            wrapped()
            outcomes.append(True)
        except RuntimeError:
            outcomes.append(False)
    assert outcomes == [not plan.publish_fail(i) for i in range(20)]
    assert calls["n"] == sum(outcomes)


# -------------------------------------------------- durability observability --
def test_health_reports_durability_section(tmp_path):
    from repro.obs import ServiceHealth

    s = get_strategy("rbla")
    agg = DurableAggregator(s, make_state(s), dir=str(tmp_path),
                            checkpoint_every=2, wal_fsync=False)
    run_to(agg, make_updates(3), 3)
    view = ServiceHealth(aggregator=agg).snapshot()
    dur = view["durability"]
    assert dur["wal_last_seq"] == 3
    assert dur["n_checkpoints"] == 1
    assert dur["replay_backlog"] == 1          # one record past the snapshot
    # the registry is process-global: earlier tests also checkpointed
    assert dur["checkpoint_latency"]["count"] >= 1
    # plain aggregators have no durability section
    plain = AsyncAggregator(s, make_state(s))
    assert "durability" not in ServiceHealth(aggregator=plain).snapshot()
