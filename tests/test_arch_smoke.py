"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture is instantiated as a REDUCED variant of the same
family (<=2 layers, d_model<=256, <=4 experts) and runs one forward and one
LoRA train step on CPU, asserting output shapes and the absence of NaNs.
Decode paths are exercised in test_serve_consistency.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import make_model
from repro.optim import adam, apply_updates
from repro.lora import strip_ranks, attach_ranks

jax.config.update("jax_platform_name", "cpu")

BATCH, SEQ = 2, 64


def _batch_for(cfg, batch=BATCH, seq=SEQ):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)}
    if cfg.is_encdec:
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.frontend_dim)),
            jnp.float32)
    if cfg.frontend == "vision_patches":
        b["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_prefix_tokens, cfg.frontend_dim)),
            jnp.float32)
    return b


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 256
    assert cfg.n_experts <= 4
    model = make_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    adapters = model.init_adapters(jax.random.PRNGKey(1), rank=4)
    batch = _batch_for(cfg)

    logits, _ = jax.jit(lambda p, a, b: model.forward(p, a, b))(
        params, adapters, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one LoRA-only train step
    factors, ranks = strip_ranks(adapters)
    opt = adam(1e-3)

    @jax.jit
    def step(factors, opt_state, batch):
        def loss_fn(f):
            return model.loss(params, attach_ranks(f, ranks), batch)
        loss, grads = jax.value_and_grad(loss_fn)(factors)
        updates, opt_state = opt.update(grads, opt_state, factors)
        return apply_updates(factors, updates), opt_state, loss

    st = opt.init(factors)
    f2, st, loss = step(factors, st, batch)
    assert np.isfinite(float(loss))
    # adapters actually moved (B starts at 0 and must receive gradient)
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(f2),
                                jax.tree.leaves(factors)))
    assert moved > 0.0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_metadata(name):
    cfg = get_config(name)
    assert cfg.n_layers >= 24
    assert cfg.vocab_size >= 32000
    # assignment table spot checks
    table = {
        "h2o-danube-3-4b": (24, 3840, 32, 8),
        "deepseek-v3-671b": (61, 7168, 128, 128),
        "mamba2-1.3b": (48, 2048, 0, 0),
        "whisper-large-v3": (32, 1280, 20, 20),
        "jamba-1.5-large-398b": (72, 8192, 64, 8),
        "granite-moe-3b-a800m": (32, 1536, 24, 8),
        "phi-3-vision-4.2b": (32, 3072, 32, 32),
        "gemma2-9b": (42, 3584, 16, 8),
        "yi-34b": (60, 7168, 56, 8),
        "chatglm3-6b": (28, 4096, 32, 2),
    }
    l, d, h, kv = table[name]
    assert cfg.n_layers == l and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
