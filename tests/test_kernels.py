"""Per-kernel allclose tests: shape/dtype sweeps against the jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (lora_matmul, lora_matmul_ref, rbla_agg,
                           rbla_agg_ref)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------- lora_matmul ----
LM_SHAPES = [
    # (m, k, n, r)
    (128, 128, 128, 8),
    (256, 512, 256, 16),
    (64, 384, 512, 64),
    (100, 200, 300, 4),      # unaligned -> padding path
    (512, 256, 128, 128),
]


@pytest.mark.parametrize("m,k,n,r", LM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_matches_ref(m, k, n, r, dtype):
    rng = np.random.default_rng(m + k + n + r)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, dtype)
    a = jnp.asarray(rng.normal(size=(r, k)) * 0.05, dtype)
    b = jnp.asarray(rng.normal(size=(n, r)) * 0.05, dtype)
    scale = 0.25
    got = lora_matmul(x, w, a, b, scale, interpret=True)
    want = lora_matmul_ref(x, w, a, b, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol * max(1.0, float(jnp.abs(want).max())))


def test_lora_matmul_batched_input():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)) * 0.05, jnp.float32)
    a = jnp.asarray(rng.normal(size=(8, 256)) * 0.05, jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 8)) * 0.05, jnp.float32)
    got = lora_matmul(x, w, a, b, 1.0, interpret=True)
    want = lora_matmul_ref(x.reshape(-1, 256), w, a, b, 1.0).reshape(
        4, 32, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_lora_matmul_zero_b_is_base_matmul():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
    b = jnp.zeros((128, 16), jnp.float32)
    got = lora_matmul(x, w, a, b, 7.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- rbla_agg ----
AGG_SHAPES = [
    # (n_clients, r_rows, d)
    (2, 8, 128),
    (5, 64, 256),
    (10, 64, 640),
    (3, 7, 100),             # unaligned
]


@pytest.mark.parametrize("n,r,d", AGG_SHAPES)
@pytest.mark.parametrize("method", ["rbla", "zeropad"])
def test_rbla_agg_matches_ref(n, r, d, method):
    rng = np.random.default_rng(n * 100 + r + d)
    ranks = jnp.asarray(rng.integers(1, r + 1, n), jnp.int32)
    masks = (np.arange(r)[None, :] < np.asarray(ranks)[:, None])
    x = rng.normal(size=(n, r, d)).astype(np.float32) * masks[:, :, None]
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    got = rbla_agg(jnp.asarray(x), ranks, w, method=method, interpret=True)
    want = rbla_agg_ref(jnp.asarray(x), ranks, w, method=method)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_rbla_agg_trailing_dims():
    """(N, R, out, r2) adapter-B-like layouts flatten correctly."""
    rng = np.random.default_rng(9)
    n, r = 4, 16
    ranks = jnp.asarray([4, 8, 16, 2], jnp.int32)
    x = jnp.asarray(rng.normal(size=(n, r, 8, 32)), jnp.float32)
    got = rbla_agg(x, ranks, jnp.ones(n), interpret=True)
    want = rbla_agg_ref(x.reshape(n, r, -1), ranks,
                        jnp.ones(n)).reshape(r, 8, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 6), r=st.integers(2, 32), d=st.integers(1, 257),
       seed=st.integers(0, 999))
def test_prop_rbla_agg_matches_core(n, r, d, seed):
    rng = np.random.default_rng(seed)
    ranks = jnp.asarray(rng.integers(1, r + 1, n), jnp.int32)
    masks = (np.arange(r)[None, :] < np.asarray(ranks)[:, None])
    x = rng.normal(size=(n, r, d)).astype(np.float32) * masks[:, :, None]
    w = jnp.asarray(rng.uniform(0.1, 2.0, n), jnp.float32)
    got = rbla_agg(jnp.asarray(x), ranks, w, interpret=True)
    want = rbla_agg_ref(jnp.asarray(x), ranks, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------- ssd_scan ----
SSD_SHAPES = [
    # (b, l, h, p, n, chunk)
    (1, 32, 2, 8, 16, 8),
    (2, 64, 4, 16, 32, 16),
    (1, 128, 2, 64, 128, 32),
    (2, 48, 3, 8, 8, 16),       # chunk not power-of-two divisor path
]


@pytest.mark.parametrize("b,l,h,r,n,chunk", SSD_SHAPES)
def test_ssd_scan_matches_ref(b, l, h, r, n, chunk):
    from repro.kernels import ssd_scan, ssd_scan_ref
    rng = np.random.default_rng(b * l + h + n)
    xdt = jnp.asarray(rng.normal(size=(b, l, h, r)), jnp.float32) * 0.5
    dta = -jnp.abs(jnp.asarray(rng.normal(size=(b, l, h)),
                               jnp.float32)) * 0.5
    bm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32) * 0.5
    cm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32) * 0.5
    y, hlast = ssd_scan(xdt, dta, bm, cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_scan_ref(xdt, dta, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)
