"""Integration tests: the FL system end to end (paper's central claims).

These are scaled-down versions of the paper's experiments -- small synthetic
datasets, few rounds -- asserting the *relative* behaviour the paper reports:
RBLA converges at least as fast as zero-padding under staircase non-IID with
heterogeneous ranks.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_dataset, staircase_partition
from repro.fl import FLConfig, run_simulation
from repro.fl.client import merge_base_params, split_base_params
from repro.models.paper_nets import mlp


def test_split_merge_roundtrip():
    import jax
    m = mlp()
    params = m.init(jax.random.PRNGKey(0))
    frozen, trainable = split_base_params(params, m.lora_specs)
    merged = merge_base_params(frozen, trainable)
    assert jax.tree.structure(merged) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staircase_partition_properties():
    ds = make_dataset("mnist", 50, seed=42)
    clients = staircase_partition(ds, 10, r_max=64)
    assert len(clients) == 10
    # client 0 holds only label 0; label sets grow along the stair
    assert clients[0].labels == (0,)
    for i in range(1, 10):
        assert set(clients[i - 1].labels) <= set(clients[i].labels)
    assert clients[-1].labels == tuple(range(10))
    # ranks scale with label count, capped at r_max
    assert clients[0].rank <= clients[-1].rank <= 64
    # padded arrays share a common length; true counts grow along the stair
    lens = {len(c.x) for c in clients}
    assert len(lens) == 1
    assert clients[0].n < clients[-1].n


# --------------------------------------------- per-method 3-round smoke ----
SMOKE_KW = dict(dataset="mnist", model="mlp", rounds=3, n_clients=3,
                n_per_class=12, n_test_per_class=6, batch_size=16,
                r_max=4, lr=0.01, seed=42)
ALL_SIM_METHODS = ("rbla", "zeropad", "fedavg", "rbla_ranked", "rbla_norm",
                   "svd", "flora", "fft")


@pytest.mark.parametrize("method", ALL_SIM_METHODS)
def test_three_round_smoke_finite_and_deterministic(method):
    """Every registered method (plus the fft baseline) survives a tiny
    3-round simulation: finite losses, sane accuracy, accuracy not
    collapsing across rounds, and bit-identical test_acc across two runs
    with the same seed (the determinism guard)."""
    cfg = FLConfig(method=method, **SMOKE_KW)
    h = run_simulation(cfg)
    assert len(h.test_acc) == 3
    assert np.isfinite(h.train_loss).all()
    assert all(0.0 <= a <= 1.0 for a in h.test_acc)
    # monotone-ish: 3 rounds of a tiny model must not actively collapse
    assert h.test_acc[-1] >= h.test_acc[0] - 0.1
    h2 = run_simulation(cfg)
    assert h.test_acc == h2.test_acc, "same seed must be bit-identical"


def test_flora_simulation_with_explicit_cap_runs():
    """flora end to end with heterogeneous ranks and a cap wide enough
    that the live global rank grows past r_max between rounds, while the
    clients keep training at r_max storage (one compile)."""
    from repro.core import get_strategy
    cfg = FLConfig(method="flora", stack_r_cap=24, **SMOKE_KW)
    h = run_simulation(cfg)
    assert len(h.test_acc) == 3 and np.isfinite(h.train_loss).all()
    # the storage the simulator allocates for the server is the cap
    s = get_strategy("flora").with_options(stack_r_cap=24)
    assert s.server_storage_rank(cfg.r_max) == 24
    # a cap below the largest client rank must refuse up front
    bad = FLConfig(method="flora", stack_r_cap=1, **SMOKE_KW)
    with pytest.raises(ValueError, match="stack_r_cap"):
        run_simulation(bad)


# ------------------------------------- clients must never alias the server --
def test_client_reslice_copies_never_aliases_server_state():
    """The simulator hands every client set_ranks(global, rank, r_storage)
    (fl/simulator.py); on a rank-growing global that re-slice must COPY.
    A numpy-backed server state (checkpoint restore) plus an in-place
    client optimizer would otherwise silently corrupt the global."""
    import jax
    from repro.lora import init_adapters, set_ranks
    server = init_adapters(jax.random.PRNGKey(0), mlp().lora_specs, 8, 8)
    server = jax.tree.map(np.asarray, server)          # numpy-backed
    snapshot = jax.tree.map(lambda x: np.array(x, copy=True), server)

    local = set_ranks(server, 3, r_storage=4)          # rank-grown re-slice
    for leaf in jax.tree.leaves(local):
        arr = np.asarray(leaf)
        for sleaf in jax.tree.leaves(server):
            assert not np.shares_memory(arr, sleaf), \
                "client adapters alias server storage"
    # and set_ranks itself must not have touched the server in place
    for a, b in zip(jax.tree.leaves(server), jax.tree.leaves(snapshot)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the same-storage path (the historical simulator call)
    local_same = set_ranks(server, 5, r_storage=8)
    for leaf in jax.tree.leaves(local_same):
        for sleaf in jax.tree.leaves(server):
            assert not np.shares_memory(np.asarray(leaf), sleaf)


def test_aggregate_does_not_mutate_server_adapters_in_place():
    """strategy.aggregate must build a new ServerState; the previous
    round's adapters object (which callers may still hold) stays intact
    bit for bit."""
    import jax
    from repro.core import ClientUpdate, ServerState, get_strategy
    from repro.lora import init_adapters, set_ranks
    specs = mlp().lora_specs
    prev = init_adapters(jax.random.PRNGKey(3), specs, 8, 8)
    snapshot = jax.tree.map(lambda x: np.array(x, copy=True), prev)
    state = ServerState(adapters=prev, base_trainable={}, r_max=8)
    updates = [
        ClientUpdate(adapters=set_ranks(prev, r), base_trainable={},
                     n_examples=float(r), rank=r)
        for r in (2, 3)]
    for method in ("rbla", "flora"):
        nxt = get_strategy(method).aggregate(state, updates, backend="ref")
        assert nxt.adapters is not prev
        for a, b in zip(jax.tree.leaves(prev), jax.tree.leaves(snapshot)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # per-leaf live rank is reported on the new state
        assert nxt.current_rank is not None
        for r in jax.tree.leaves(nxt.current_rank):
            assert int(np.max(np.asarray(r))) >= 1


@pytest.mark.slow
def test_rbla_beats_zeropad_and_learns():
    kw = dict(dataset="mnist", model="mlp", rounds=10, n_per_class=200,
              n_test_per_class=50, local_epochs=2, lr=0.1, seed=42)
    h_rbla = run_simulation(FLConfig(method="rbla", **kw))
    h_zp = run_simulation(FLConfig(method="zeropad", **kw))
    # learns well past chance
    assert h_rbla.test_acc[-1] > 0.5
    # no NaNs anywhere
    assert np.isfinite(h_rbla.train_loss).all()
    # paper claim: RBLA converges at least as fast (mean acc over rounds)
    assert np.mean(h_rbla.test_acc) >= np.mean(h_zp.test_acc) - 0.02


@pytest.mark.slow
def test_random_participation_runs():
    cfg = FLConfig(dataset="mnist", model="mlp", method="rbla", rounds=4,
                   n_per_class=100, n_test_per_class=30, participation=0.2)
    h = run_simulation(cfg)
    assert len(h.test_acc) == 4 and np.isfinite(h.train_loss).all()


@pytest.mark.slow
def test_cnn_path_runs():
    cfg = FLConfig(dataset="fmnist", model="cnn_mnist", method="rbla",
                   rounds=2, n_per_class=60, n_test_per_class=20,
                   local_epochs=1)
    h = run_simulation(cfg)
    assert len(h.test_acc) == 2 and np.isfinite(h.train_loss).all()


@pytest.mark.slow
def test_cifar_cnn_adam_runs():
    cfg = FLConfig(dataset="cifar", model="cnn_cifar", method="rbla",
                   rounds=2, n_per_class=40, n_test_per_class=20,
                   optimizer="adam", lr=1e-3)
    h = run_simulation(cfg)
    assert np.isfinite(h.train_loss).all()


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro import checkpoint
    m = mlp()
    params = m.init(jax.random.PRNGKey(1))
    checkpoint.save(str(tmp_path / "ck"), params)
    like = jax.tree.map(jnp.zeros_like, params)
    back = checkpoint.restore(str(tmp_path / "ck"), like)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_comm_cost_report():
    """LoRA upload cost << FFT upload cost (paper's communication claim);
    sliced uploads scale with client rank."""
    import jax
    from repro.fl.comm import round_cost_report, adapter_upload_bytes
    from repro.fl.client import split_base_params
    from repro.lora import init_adapters
    m = mlp()
    params = m.init(jax.random.PRNGKey(0))
    _, base_tr = split_base_params(params, m.lora_specs)
    adapters = init_adapters(jax.random.PRNGKey(1), m.lora_specs, 64, 64)
    rep = round_cost_report(params, adapters, base_tr, [6, 32, 64])
    assert rep["reduction_vs_fft"] > 2.0
    assert rep["lora_sliced_upload_bytes"][0] < \
        rep["lora_sliced_upload_bytes"][2]
    assert rep["lora_padded_upload_bytes"] >= \
        rep["lora_sliced_upload_bytes_mean"]
    # rank-sliced adapter bytes scale ~linearly with rank
    b16 = adapter_upload_bytes(adapters, 16)
    b64 = adapter_upload_bytes(adapters, 64)
    assert 3.5 < b64 / b16 < 4.5
