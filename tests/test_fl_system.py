"""Integration tests: the FL system end to end (paper's central claims).

These are scaled-down versions of the paper's experiments -- small synthetic
datasets, few rounds -- asserting the *relative* behaviour the paper reports:
RBLA converges at least as fast as zero-padding under staircase non-IID with
heterogeneous ranks.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_dataset, staircase_partition
from repro.fl import FLConfig, run_simulation
from repro.fl.client import merge_base_params, split_base_params
from repro.models.paper_nets import mlp


def test_split_merge_roundtrip():
    import jax
    m = mlp()
    params = m.init(jax.random.PRNGKey(0))
    frozen, trainable = split_base_params(params, m.lora_specs)
    merged = merge_base_params(frozen, trainable)
    assert jax.tree.structure(merged) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staircase_partition_properties():
    ds = make_dataset("mnist", 50, seed=42)
    clients = staircase_partition(ds, 10, r_max=64)
    assert len(clients) == 10
    # client 0 holds only label 0; label sets grow along the stair
    assert clients[0].labels == (0,)
    for i in range(1, 10):
        assert set(clients[i - 1].labels) <= set(clients[i].labels)
    assert clients[-1].labels == tuple(range(10))
    # ranks scale with label count, capped at r_max
    assert clients[0].rank <= clients[-1].rank <= 64
    # padded arrays share a common length; true counts grow along the stair
    lens = {len(c.x) for c in clients}
    assert len(lens) == 1
    assert clients[0].n < clients[-1].n


@pytest.mark.slow
def test_rbla_beats_zeropad_and_learns():
    kw = dict(dataset="mnist", model="mlp", rounds=10, n_per_class=200,
              n_test_per_class=50, local_epochs=2, lr=0.1, seed=42)
    h_rbla = run_simulation(FLConfig(method="rbla", **kw))
    h_zp = run_simulation(FLConfig(method="zeropad", **kw))
    # learns well past chance
    assert h_rbla.test_acc[-1] > 0.5
    # no NaNs anywhere
    assert np.isfinite(h_rbla.train_loss).all()
    # paper claim: RBLA converges at least as fast (mean acc over rounds)
    assert np.mean(h_rbla.test_acc) >= np.mean(h_zp.test_acc) - 0.02


@pytest.mark.slow
def test_random_participation_runs():
    cfg = FLConfig(dataset="mnist", model="mlp", method="rbla", rounds=4,
                   n_per_class=100, n_test_per_class=30, participation=0.2)
    h = run_simulation(cfg)
    assert len(h.test_acc) == 4 and np.isfinite(h.train_loss).all()


@pytest.mark.slow
def test_cnn_path_runs():
    cfg = FLConfig(dataset="fmnist", model="cnn_mnist", method="rbla",
                   rounds=2, n_per_class=60, n_test_per_class=20,
                   local_epochs=1)
    h = run_simulation(cfg)
    assert len(h.test_acc) == 2 and np.isfinite(h.train_loss).all()


@pytest.mark.slow
def test_cifar_cnn_adam_runs():
    cfg = FLConfig(dataset="cifar", model="cnn_cifar", method="rbla",
                   rounds=2, n_per_class=40, n_test_per_class=20,
                   optimizer="adam", lr=1e-3)
    h = run_simulation(cfg)
    assert np.isfinite(h.train_loss).all()


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro import checkpoint
    m = mlp()
    params = m.init(jax.random.PRNGKey(1))
    checkpoint.save(str(tmp_path / "ck"), params)
    like = jax.tree.map(jnp.zeros_like, params)
    back = checkpoint.restore(str(tmp_path / "ck"), like)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_comm_cost_report():
    """LoRA upload cost << FFT upload cost (paper's communication claim);
    sliced uploads scale with client rank."""
    import jax
    from repro.fl.comm import round_cost_report, adapter_upload_bytes
    from repro.fl.client import split_base_params
    from repro.lora import init_adapters
    m = mlp()
    params = m.init(jax.random.PRNGKey(0))
    _, base_tr = split_base_params(params, m.lora_specs)
    adapters = init_adapters(jax.random.PRNGKey(1), m.lora_specs, 64, 64)
    rep = round_cost_report(params, adapters, base_tr, [6, 32, 64])
    assert rep["reduction_vs_fft"] > 2.0
    assert rep["lora_sliced_upload_bytes"][0] < \
        rep["lora_sliced_upload_bytes"][2]
    assert rep["lora_padded_upload_bytes"] >= \
        rep["lora_sliced_upload_bytes_mean"]
    # rank-sliced adapter bytes scale ~linearly with rank
    b16 = adapter_upload_bytes(adapters, 16)
    b64 = adapter_upload_bytes(adapters, 64)
    assert 3.5 < b64 / b16 < 4.5
