"""repro.serving suite: batched multi-adapter kernel parity, the
no-retrace guard, store paging/growth/eviction, hot-swap atomicity,
publish donation safety, and the AsyncAggregator publish hook."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientUpdate, ServerState
from repro.fl import AsyncAggregator
from repro.kernels import (batched_lora_matmul, batched_lora_matmul_inline,
                           batched_lora_matmul_ref)
from repro.kernels.lora_matmul.ops import resolve_impl, trace_counts
from repro.lora import DEFAULT_ALPHA, init_adapters, set_ranks, strip_ranks
from repro.serving import AdapterStore, ServingEngine, merged_reference

from tests._cohorts import R_MAX, SPECS, assert_trees_close, hetero_cohort

# engine base weights for the shared SPECS: W is (fan_in, fan_out)
WEIGHTS = {p: jnp.asarray(
    np.random.default_rng(hash(p) % 2**31).normal(size=(fi, fo)) * 0.1,
    jnp.float32) for p, (fo, fi) in SPECS.items()}


def packed_case(m=12, k=16, n=10, n_slots=6, r_max=4, seed=0,
                dtype=jnp.float32):
    """Random packed buffers + tables + a mixed id batch.

    Slot 0 has rank 0 (the null adapter); rows outside live segments are
    deliberately garbage -- the segment mask must never read them.
    """
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.2, dtype)
    a_rows = jnp.asarray(rng.normal(size=(n_slots * r_max, k)), dtype)
    b_rows = jnp.asarray(rng.normal(size=(n_slots * r_max, n)), dtype)
    off = np.arange(n_slots, dtype=np.int32) * r_max
    rank = rng.integers(1, r_max + 1, n_slots).astype(np.int32)
    rank[0] = 0
    scale = (DEFAULT_ALPHA / np.maximum(rank, 1)).astype(np.float32)
    ids = jnp.asarray(rng.integers(0, n_slots, m), jnp.int32)
    return x, w, a_rows, b_rows, jnp.asarray(off), jnp.asarray(rank), \
        jnp.asarray(scale), ids


def ref_out(x, w, a_rows, b_rows, off, rank, scale, ids):
    idn = np.asarray(ids)
    return batched_lora_matmul_ref(
        x, w, a_rows, b_rows, np.asarray(off)[idn], np.asarray(rank)[idn],
        np.asarray(scale)[idn])


# ---------------------------------------------------------------- kernel --
@pytest.mark.parametrize("impl,interpret", [("xla", None),
                                            ("pallas", True)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_batched_matches_ref(impl, interpret, dtype, tol):
    case = packed_case(dtype=dtype)
    x, w, a_rows, b_rows, off, rank, scale, ids = case
    got = batched_lora_matmul_inline(x, w, a_rows, b_rows, ids, off, rank,
                                     scale, impl=impl, interpret=interpret)
    want = ref_out(*case)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("impl,interpret", [("xla", None),
                                            ("pallas", True)])
def test_adapter_id_permutation_equivariance(impl, interpret):
    """Permuting (rows, ids) together permutes the output -- adapter
    resolution is strictly per request row."""
    x, w, a_rows, b_rows, off, rank, scale, ids = packed_case(seed=3)
    perm = np.random.default_rng(7).permutation(x.shape[0])
    y = batched_lora_matmul_inline(x, w, a_rows, b_rows, ids, off, rank,
                                   scale, impl=impl, interpret=interpret)
    yp = batched_lora_matmul_inline(x[perm], w, a_rows, b_rows, ids[perm],
                                    off, rank, scale, impl=impl,
                                    interpret=interpret)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y)[perm],
                               rtol=1e-5, atol=1e-5)


def test_rank0_slot_serves_base_model():
    x, w, a_rows, b_rows, off, rank, scale, _ = packed_case()
    ids = jnp.zeros(x.shape[0], jnp.int32)        # slot 0: rank 0
    y = batched_lora_matmul_inline(x, w, a_rows, b_rows, ids, off, rank,
                                   scale, impl="xla")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


def test_no_retrace_across_tenant_mixes():
    """Ids, offsets, ranks, scales, and table *contents* are runtime
    data: the public jitted entry traces once for a given geometry."""
    x, w, a_rows, b_rows, off, rank, scale, ids = packed_case(seed=11)
    jax.block_until_ready(batched_lora_matmul(
        x, w, a_rows, b_rows, ids, off, rank, scale))
    before = trace_counts["batched_lora_matmul"]
    rng = np.random.default_rng(12)
    for s in range(4):                    # new mix + mutated tables
        ids2 = jnp.asarray(rng.integers(0, off.shape[0], x.shape[0]),
                           jnp.int32)
        rank2 = jnp.asarray(rng.integers(0, 5, off.shape[0]), jnp.int32)
        got = batched_lora_matmul(x, w, a_rows, b_rows, ids2, off, rank2,
                                  scale)
        want = ref_out(x, w, a_rows, b_rows, off, rank2, scale, ids2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    assert trace_counts["batched_lora_matmul"] == before


def test_resolve_impl():
    assert resolve_impl("auto") in ("xla", "pallas")
    assert resolve_impl("xla") == "xla"
    assert resolve_impl("pallas") == "pallas"
    with pytest.raises(ValueError, match="unknown batched"):
        resolve_impl("tpu")


# ----------------------------------------------------------------- store --
def one_tenant_adapters(rank, seed=0):
    ad = init_adapters(jax.random.PRNGKey(seed), SPECS, R_MAX, rank)
    rng = np.random.default_rng(seed)
    ad = jax.tree.map(
        lambda v: v + jnp.asarray(rng.normal(size=v.shape), v.dtype)
        if v.dtype == jnp.float32 else v, ad)
    return set_ranks(ad, rank)


def test_store_put_get_roundtrip():
    store = AdapterStore(SPECS, r_max=R_MAX)
    ad = one_tenant_adapters(3, seed=4)
    store.put("t0", ad)
    assert_trees_close(store.get("t0"), ad, msg="put/get roundtrip")


def test_store_paths_share_geometry_bucket():
    store = AdapterStore({"p": (8, 16), "q": (8, 16), "r": (8, 12)},
                         r_max=4)
    snap = store.snapshot()
    assert snap.bucket_of["p"] == snap.bucket_of["q"]
    assert snap.bucket_of["p"] != snap.bucket_of["r"]


def test_store_page_growth_and_remove():
    store = AdapterStore(SPECS, r_max=R_MAX, init_pages=1,
                         init_tenant_capacity=2)
    slots = [store.register(f"t{i}", rank=2 + i % 3) for i in range(5)]
    assert len(set(slots)) == 5 and 0 not in slots
    # each tenant owns a distinct page per path (distinct offsets)
    for p in SPECS:
        offs = [int(store.snapshot().table(p).off[s]) for s in slots]
        assert len(set(offs)) == 5
    store.remove("t2")
    assert store.n_tenants == 4
    evicted = slots[2]
    assert int(store.snapshot().table("fc1").rank[evicted]) == 0
    # freed page and slot are reused
    s_new = store.register("t9", rank=1)
    assert s_new == evicted


def test_store_rank_validation():
    store = AdapterStore(SPECS, r_max=R_MAX)
    with pytest.raises(ValueError, match="r_max"):
        store.register("t", rank=R_MAX + 1)
    with pytest.raises(ValueError, match="does not match"):
        bad = one_tenant_adapters(2)
        bad["fc1"]["A"] = bad["fc1"]["A"][:, :-1]
        store.put("t", bad)


def test_publish_reslices_per_tenant_rank():
    """publish() writes min(tenant_rank, global_rank) rows of the global
    into every segment -- the Alg. 2 re-slice, server-side."""
    store = AdapterStore(SPECS, r_max=R_MAX)
    store.register("lo", rank=2)
    store.register("hi", rank=R_MAX)
    glob = one_tenant_adapters(5, seed=8)       # global rank 5
    store.publish(glob)
    assert_trees_close(store.get("lo"), set_ranks(glob, 2),
                       msg="rank-2 tenant gets the first 2 global rows")
    # the rank-8 tenant keeps its registered rank (its table entry) but
    # only the 5 global rows carry signal -- rows 5.. are zeroed
    hi_factors, hi_ranks = strip_ranks(store.get("hi"))
    want_factors, _ = strip_ranks(set_ranks(glob, 5))
    assert_trees_close(hi_factors, want_factors,
                       msg="rank-8 tenant gets all 5; rows 5.. zeroed")
    assert all(int(r) == R_MAX for r in jax.tree.leaves(hi_ranks))


def test_snapshot_pins_buffers_across_publish():
    """Hot-swap atomicity: a pinned snapshot's bytes never change, and
    writes under a live pin copy instead of donating."""
    store = AdapterStore(SPECS, r_max=R_MAX)
    store.register("t", rank=4)
    store.publish(one_tenant_adapters(4, seed=1))
    snap = store.snapshot()
    frozen = {p: (np.asarray(snap.pair_buffers(p)[0]).copy(),
                  np.asarray(snap.pair_buffers(p)[1]).copy())
              for p in SPECS}
    v0 = snap.version
    store.publish(one_tenant_adapters(4, seed=2))
    for p in SPECS:
        a_rows, b_rows = snap.pair_buffers(p)
        assert not a_rows.is_deleted() and not b_rows.is_deleted()
        np.testing.assert_array_equal(np.asarray(a_rows), frozen[p][0])
        np.testing.assert_array_equal(np.asarray(b_rows), frozen[p][1])
    new = store.snapshot()
    assert new.version > v0
    assert any(not np.array_equal(np.asarray(new.pair_buffers(p)[0]),
                                  frozen[p][0]) for p in SPECS)


def test_publish_donates_when_unpinned():
    """With no live snapshot, publish updates buckets in place: the old
    buffer is donated into the scatter (freed, not copied)."""
    store = AdapterStore(SPECS, r_max=R_MAX)
    store.register("t", rank=4)
    store.publish(one_tenant_adapters(4, seed=1))
    snap = store.snapshot()
    old = {p: snap.pair_buffers(p) for p in SPECS}
    del snap                                    # drop the only pin
    store.publish(one_tenant_adapters(4, seed=2))
    assert all(a.is_deleted() and b.is_deleted()
               for a, b in old.values()), "unpinned buffers must donate"
    assert_trees_close(store.get("t"), set_ranks(
        one_tenant_adapters(4, seed=2), 4), msg="donated publish content")


# ---------------------------------------------------------------- engine --
def engine_with_tenants(n=6, seed=0):
    store = AdapterStore(SPECS, r_max=R_MAX)
    engine = ServingEngine(WEIGHTS, store)
    adapters, ranks, _ = hetero_cohort(n=n, seed=seed)
    ids = [store.put(f"t{i}", adapters[i]) for i in range(n)]
    return store, engine, ids


def test_engine_parity_vs_merged_reference():
    store, engine, slots = engine_with_tenants()
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.choice(slots + [0], 16), jnp.int32)
    for path, (fo, fi) in SPECS.items():
        x = jnp.asarray(rng.normal(size=(16, fi)), jnp.float32)
        got = engine.apply(path, x, ids)
        want = merged_reference(engine, path, x, ids)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_engine_forward_chains_one_snapshot():
    store, engine, slots = engine_with_tenants(seed=5)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(8, SPECS["fc1"][1])), jnp.float32)
    ids = jnp.asarray(rng.choice(slots, 8), jnp.int32)
    got = engine.forward(x, ids, paths=["fc1", "fc2"])
    h = merged_reference(engine, "fc1", x, ids)
    want = merged_reference(engine, "fc2", h, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_in_flight_batch_sees_one_version():
    """A batch pinned to a snapshot is immune to concurrent publishes;
    the next unpinned batch picks up the new version -- and neither side
    of the swap retraces the serving executable."""
    store, engine, slots = engine_with_tenants(seed=9)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(8, SPECS["fc1"][1])), jnp.float32)
    ids = jnp.asarray(rng.choice(slots, 8), jnp.int32)
    snap = engine.snapshot()
    before_swap = np.asarray(engine.apply("fc1", x, ids, snapshot=snap))
    jax.block_until_ready(before_swap)
    traces0 = trace_counts["batched_lora_matmul"]
    engine.publish(one_tenant_adapters(R_MAX, seed=77))   # mid-flight
    in_flight = np.asarray(engine.apply("fc1", x, ids, snapshot=snap))
    np.testing.assert_array_equal(in_flight, before_swap)
    fresh = np.asarray(engine.apply("fc1", x, ids))
    assert not np.array_equal(fresh, before_swap)
    np.testing.assert_allclose(
        fresh, np.asarray(merged_reference(engine, "fc1", x, ids)),
        rtol=1e-4, atol=1e-4)
    assert trace_counts["batched_lora_matmul"] == traces0


# ------------------------------------------------------- async publish hook --
def test_async_aggregator_on_publish():
    """AsyncAggregator(on_publish=engine.publisher()) hot-swaps each
    folded global into the store at the configured cadence."""
    store = AdapterStore(SPECS, r_max=R_MAX)
    engine = ServingEngine(WEIGHTS, store)
    store.register("t", rank=3)
    adapters, ranks, weights, bases = hetero_cohort(n=4, seed=2,
                                                    with_bases=True)
    state = ServerState(
        adapters=init_adapters(jax.random.PRNGKey(0), SPECS, R_MAX, R_MAX),
        base_trainable=bases[0], r_max=R_MAX)
    agg = AsyncAggregator("rbla", state, backend="ref",
                          on_publish=engine.publisher(), publish_every=2)
    v0 = store.version
    for i in range(4):
        agg.submit(ClientUpdate(adapters=adapters[i],
                                base_trainable=bases[i],
                                n_examples=float(weights[i]),
                                rank=int(ranks[i])))
    assert agg.n_published == 2          # publish_every=2 over 4 folds
    assert store.version > v0
    # the served segment is the live global re-sliced to the tenant rank
    assert_trees_close(store.get("t"), set_ranks(agg.state.adapters, 3),
                       msg="store serves the last published global")


def test_async_publish_every_validation():
    state = ServerState(
        adapters=init_adapters(jax.random.PRNGKey(0), SPECS, R_MAX, 2),
        base_trainable={}, r_max=R_MAX)
    with pytest.raises(ValueError, match="publish_every"):
        AsyncAggregator("rbla", state, publish_every=0)
