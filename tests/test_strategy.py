"""Strategy registry tests: every registered method's tree (reference),
distributed (shard_map psum), and Pallas (kernel) paths must agree
numerically on heterogeneous-rank fixtures, and unknown names must fail
with an actionable error."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategy import (AggregationStrategy, ClientUpdate,
                                 ServerState, get_strategy, list_strategies,
                                 register_strategy, resolve_backend,
                                 stack_trees)
from repro.lora import init_adapters, mask_adapters, set_ranks

from _cohorts import R_MAX, SPECS, assert_trees_close, hetero_cohort

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- registry --
def test_all_six_methods_registered():
    assert {"rbla", "zeropad", "fedavg", "rbla_ranked", "rbla_norm",
            "svd"} <= set(list_strategies())


def test_fft_alias_resolves_to_fedavg():
    assert get_strategy("fft") is get_strategy("fedavg")


def test_unknown_strategy_error_names_options():
    with pytest.raises(ValueError, match="unknown aggregation strategy"):
        get_strategy("definitely_not_a_method")
    with pytest.raises(ValueError, match="rbla"):
        get_strategy("definitely_not_a_method")


def test_register_custom_strategy_in_a_few_lines():
    @register_strategy
    class _Median(AggregationStrategy):
        name = "test_median"
        supports_distributed = False

        def leaf(self, stacked, mask, weights, prev=None):
            return jnp.median(stacked, axis=0)

    try:
        adapters, ranks, w = hetero_cohort(3)
        out = get_strategy("test_median").aggregate_adapters(
            adapters, w, r_max=R_MAX, backend="ref")
        assert out["fc1"]["A"].shape == (R_MAX, 16)
        assert int(out["fc1"]["rank"]) == R_MAX
    finally:
        from repro.core import strategy as _s
        _s._REGISTRY.pop("test_median", None)


def test_register_duplicate_name_raises():
    with pytest.raises(ValueError, match="already registered"):
        @register_strategy
        class _Clash(AggregationStrategy):
            name = "rbla"                  # collides with the paper method

    with pytest.raises(ValueError, match="already registered"):
        @register_strategy
        class _AliasClash(AggregationStrategy):
            name = "totally_new"
            aliases = ("fedavg",)          # alias collides with a name
    # the failed alias registration must not leave the primary name behind
    with pytest.raises(ValueError, match="unknown aggregation strategy"):
        get_strategy("totally_new")


def test_with_options_returns_configured_copy():
    s = get_strategy("flora")
    s2 = s.with_options(stack_r_cap=32, prev_weight=0.5)
    assert s2 is not s and s2.stack_r_cap == 32 and s2.prev_weight == 0.5
    assert s.stack_r_cap is None            # the singleton is untouched
    with pytest.raises(ValueError, match="no option"):
        s.with_options(not_a_knob=1)
    with pytest.raises(ValueError, match="no option"):
        get_strategy("rbla").with_options(stack_r_cap=8)


def test_flora_rank_cap_validation():
    adapters, ranks, w = hetero_cohort(3, seed=10, r_lo=4, r_hi=R_MAX)
    low = get_strategy("flora").with_options(stack_r_cap=2)
    with pytest.raises(ValueError, match="stack_r_cap"):
        low.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=ranks, backend="ref")
    with pytest.raises(ValueError, match="stack_r_cap"):
        low.server_storage_rank(R_MAX)


def test_flora_leafwise_distributed_hook_refuses():
    """The base make_distributed_aggregator is a masked psum -- on flora
    it would silently average stacked factors instead of concatenating,
    so the hook must refuse and point at the ragged-concat path."""
    with pytest.raises(NotImplementedError, match="ragged"):
        get_strategy("flora").make_distributed_aggregator(None)


def test_set_ranks_rejects_live_rank_beyond_storage():
    from repro.lora import init_adapters, set_ranks
    ad = init_adapters(jax.random.PRNGKey(0), SPECS, R_MAX, R_MAX)
    with pytest.raises(ValueError, match="storage"):
        set_ranks(ad, R_MAX, r_storage=2)


def test_pallas_backend_on_cpu_falls_back_to_interpret():
    """backend='pallas' must work on CPU (auto_interpret runs the kernel
    in interpreter mode) and agree with the reference path."""
    assert jax.default_backend() == "cpu"
    adapters, ranks, w = hetero_cohort(4, seed=11)
    for method in ("rbla", "flora"):
        s = get_strategy(method)
        if s.rank_contract == "stacked":
            s = s.with_options(stack_r_cap=int(ranks.sum()) + R_MAX)
        ref = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                   client_ranks=ranks, backend="ref")
        pal = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                   client_ranks=ranks, backend="pallas")
        assert_trees_close(ref, pal)


def test_resolve_backend_auto_is_ref_on_cpu():
    s = get_strategy("rbla")
    assert resolve_backend("auto", s) == "ref"
    assert resolve_backend("pallas", s) == "pallas"
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda", s)


def test_unsupported_paths_raise_actionable_errors():
    from repro.core.strategy import AggregationStrategy

    class NoKernel(AggregationStrategy):        # default: no Pallas path
        name = "no_kernel"

    with pytest.raises(NotImplementedError, match="Pallas"):
        NoKernel().aggregate_tree_pallas({}, jnp.ones(2), None)
    # svd's distributed collective is gathered factors, not the base
    # masked psum: the leafwise aggregator hook refuses with guidance
    with pytest.raises(NotImplementedError, match="distributed"):
        get_strategy("svd").make_distributed_aggregator(None)
    with pytest.raises(NotImplementedError, match="distributed"):
        get_strategy("rbla_norm").aggregate_tree_distributed(
            {}, {}, jnp.ones(2))


# ------------------------------------------------- backend parity (tree) ----
PARITY_METHODS = ["rbla", "zeropad", "fedavg", "rbla_ranked", "flora"]


@pytest.mark.parametrize("method", PARITY_METHODS)
def test_ref_vs_pallas_parity(method):
    adapters, ranks, w = hetero_cohort(5, seed=1)
    s = get_strategy(method)
    ref = s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                               backend="ref")
    pal = s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                               backend="pallas")
    assert_trees_close(ref, pal)


@pytest.mark.parametrize("method", PARITY_METHODS)
def test_ref_vs_distributed_parity(method):
    adapters, ranks, w = hetero_cohort(4, seed=2)
    s = get_strategy(method)
    ref = s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                               backend="ref")
    dist = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=ranks, backend="distributed")
    assert_trees_close(ref, dist)


@pytest.mark.parametrize("method", ["rbla_norm", "svd"])
def test_pair_structured_methods_run_on_ref(method):
    adapters, ranks, w = hetero_cohort(4, seed=3)
    out = get_strategy(method).aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=ranks, backend="ref")
    for pair in out.values():
        assert pair["A"].shape == (R_MAX, pair["A"].shape[-1])
        assert np.isfinite(np.asarray(pair["A"])).all()
        assert int(pair["rank"]) == R_MAX


# -------------------------------------------- prev_global retention parity --
@pytest.mark.parametrize("backend", ["ref", "pallas", "distributed"])
def test_rbla_prev_retention_across_backends(backend):
    """A cohort of all-low-rank clients must not wipe the high-rank rows
    the server already holds -- on every backend."""
    adapters, ranks, w = hetero_cohort(4, seed=4, r_lo=2, r_hi=3)
    prev = init_adapters(jax.random.PRNGKey(99), SPECS, R_MAX, R_MAX)
    prev = jax.tree.map(
        lambda x: x + 1.0 if x.dtype == jnp.float32 else x, prev)
    out = get_strategy("rbla").aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=ranks, prev_global=prev,
        backend=backend)
    top = slice(int(ranks.max()), R_MAX)      # rows no participant owns
    for name in SPECS:
        np.testing.assert_allclose(
            np.asarray(out[name]["A"][top]),
            np.asarray(prev[name]["A"][top]), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(out[name]["B"][:, top]),
            np.asarray(prev[name]["B"][:, top]), rtol=1e-6)


def test_flora_prev_as_contributor_parity_across_backends():
    """flora retains the previous global by stacking it as one more
    contributor; all three backends must place it identically (prev
    first, then the cohort in order)."""
    adapters, ranks, w = hetero_cohort(3, seed=6, r_lo=1, r_hi=3)
    s = get_strategy("flora").with_options(stack_r_cap=64)
    prev = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=ranks, backend="ref")
    outs = {b: s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                    client_ranks=ranks, prev_global=prev,
                                    backend=b)
            for b in ("ref", "pallas", "distributed")}
    r_prev = int(prev["fc1"]["rank"])
    want_rank = r_prev + int(ranks.sum())
    for b, out in outs.items():
        assert int(out["fc1"]["rank"]) == want_rank, b
        assert_trees_close(outs["ref"], out)
    # prev-first: the leading A rows of the new global are the old one's
    np.testing.assert_allclose(
        np.asarray(outs["ref"]["fc1"]["A"][:r_prev]),
        np.asarray(prev["fc1"]["A"][:r_prev]), rtol=1e-6)


def test_zeropad_does_not_retain_prev():
    adapters, ranks, w = hetero_cohort(4, seed=5, r_lo=2, r_hi=3)
    prev = init_adapters(jax.random.PRNGKey(7), SPECS, R_MAX, R_MAX)
    out = get_strategy("zeropad").aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=ranks, prev_global=prev)
    top = slice(int(ranks.max()), R_MAX)
    for name in SPECS:
        np.testing.assert_allclose(np.asarray(out[name]["A"][top]), 0.0,
                                   atol=1e-6)


# ------------------------------------------------------------ svd strategy --
def test_svd_single_client_preserves_effective_update():
    """One rank-r client: serving the aggregate at r_max must reproduce
    the client's effective delta (1/r_max) * B A == (1/r) * B_c A_c."""
    (ad,), ranks, _ = hetero_cohort(1, seed=6, r_lo=3, r_hi=3)
    out = get_strategy("svd").aggregate_adapters(
        [ad], jnp.ones(1), r_max=R_MAX, client_ranks=ranks)
    r = float(ranks[0])
    for name in SPECS:
        got = (np.asarray(out[name]["B"], np.float32)
               @ np.asarray(out[name]["A"], np.float32)) / R_MAX
        want = (np.asarray(ad[name]["B"], np.float32)
                @ np.asarray(ad[name]["A"], np.float32)) / r
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- high-level round --
def test_server_state_round_with_client_updates():
    adapters, ranks, w = hetero_cohort(3, seed=8)
    base = [{"b": jnp.full((4,), float(i))} for i in range(3)]
    state = ServerState(
        adapters=init_adapters(jax.random.PRNGKey(0), SPECS, R_MAX, R_MAX),
        base_trainable={"b": jnp.zeros((4,))}, round=0, r_max=R_MAX)
    updates = [ClientUpdate(adapters=a, base_trainable=b, rank=int(r))
               for a, b, r in zip(adapters, base, ranks)]
    nxt = get_strategy("rbla").aggregate(state, updates, w)
    assert nxt.round == 1
    np.testing.assert_array_equal(np.asarray(nxt.client_ranks),
                                  np.asarray(ranks))
    # base is plain weighted mean of the uploads
    want = float(jnp.sum(w * jnp.asarray([0., 1., 2.])) / jnp.sum(w))
    np.testing.assert_allclose(np.asarray(nxt.base_trainable["b"]), want,
                               rtol=1e-5)
    # adapters keep padded storage shapes and reset live rank to r_max
    assert nxt.adapters["fc1"]["A"].shape == (R_MAX, 16)
    assert int(nxt.adapters["fc1"]["rank"]) == R_MAX


def test_aggregate_defaults_weights_to_n_examples():
    state = ServerState(adapters=None, base_trainable={"w": jnp.zeros(2)})
    updates = [ClientUpdate(adapters=None,
                            base_trainable={"w": jnp.full((2,), float(i))},
                            n_examples=n)
               for i, n in enumerate([1.0, 3.0])]
    nxt = get_strategy("fedavg").aggregate(state, updates)
    np.testing.assert_allclose(np.asarray(nxt.base_trainable["w"]), 0.75,
                               rtol=1e-6)


def test_aggregate_without_adapters_is_fedavg_only():
    state = ServerState(adapters=None, base_trainable={"w": jnp.zeros(3)},
                        round=4)
    updates = [ClientUpdate(adapters=None,
                            base_trainable={"w": jnp.ones(3) * i})
               for i in range(2)]
    nxt = get_strategy("fft").aggregate(state, updates, jnp.ones(2))
    assert nxt.adapters is None and nxt.round == 5
    np.testing.assert_allclose(np.asarray(nxt.base_trainable["w"]), 0.5,
                               rtol=1e-6)


# ----------------------------------------- old entry points still dispatch --
def test_deprecated_server_wrappers_route_through_registry():
    adapters, ranks, w = hetero_cohort(3, seed=9)
    from repro.fl.server import aggregate_adapters
    with pytest.deprecated_call():
        old = aggregate_adapters(adapters, w, method="rbla", r_max=R_MAX,
                                 client_ranks=ranks)
    new = get_strategy("rbla").aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=ranks, backend="ref")
    assert_trees_close(old, new)


def test_core_aggregate_shim_rejects_unknown_method():
    from repro.core import aggregate
    tree = {"t": jnp.ones((2, 4, 3))}
    masks = {"t": jnp.ones(())}
    with pytest.raises(ValueError, match="unknown aggregation strategy"):
        aggregate(tree, masks, jnp.ones(2), method="nope")


def test_ranked_via_legacy_shims_never_silently_downgrades():
    """The old string-dispatch aggregate() rejected rbla_ranked; the shim
    must not quietly run it as plain rbla when ranks are unavailable."""
    from repro.core import aggregate, rbla_tree_allreduce
    tree = {"t": jnp.ones((2, 4, 3))}
    masks = {"t": jnp.ones(())}
    with pytest.raises(ValueError, match="client_ranks"):
        aggregate(tree, masks, jnp.ones(2), method="rbla_ranked")
    with pytest.raises(NotImplementedError, match="rank_proportional"):
        rbla_tree_allreduce(tree, masks, jnp.float32(1.0), "clients",
                            method="rbla_ranked")
