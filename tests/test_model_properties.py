"""Model-level property tests: causality, masking, rope, softcap, SSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.common import apply_rope, softcap
from repro.models.mamba import ssd_chunked, _segsum
from repro.models.model import make_model

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", ["h2o-danube-3-4b", "mamba2-1.3b",
                                  "gemma2-9b", "deepseek-v3-671b"])
def test_causality(name):
    """Changing tokens after position t must not change logits at <= t."""
    cfg = get_config(name).reduced()
    model = make_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 32))
    t = 16
    toks2 = toks.copy()
    toks2[:, t + 1:] = rng.integers(0, cfg.vocab_size,
                                    toks2[:, t + 1:].shape)
    l1, _ = model.forward(params, None, {"tokens": jnp.asarray(toks)})
    l2, _ = model.forward(params, None, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[:, :t + 1], np.float32),
                               np.asarray(l2[:, :t + 1], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_swa_locality():
    """With window w, logits at t depend only on tokens in (t-w, t]."""
    from dataclasses import replace
    from repro.configs.base import BlockSpec, Stage
    cfg = get_config("h2o-danube-3-4b").reduced()
    stages = tuple(Stage(unit=tuple(
        BlockSpec(kind=b.kind, ffn=b.ffn, window=4) for b in s.unit),
        repeat=s.repeat) for s in cfg.stages)
    cfg = replace(cfg, stages=stages)
    model = make_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, (1, 32))
    toks2 = toks.copy()
    toks2[:, :8] = rng.integers(0, cfg.vocab_size, (1, 8))  # far past
    l1, _ = model.forward(params, None, {"tokens": jnp.asarray(toks)})
    l2, _ = model.forward(params, None, {"tokens": jnp.asarray(toks2)})
    # last position: window 4 x 2 layers => receptive field ~8 < 24 back
    np.testing.assert_allclose(np.asarray(l1[:, -1], np.float32),
                               np.asarray(l2[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j (the rope invariant)."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)

    def dot_at(i, j):
        qr = apply_rope(q, jnp.asarray([[i]]), 10000.0, "full")
        kr = apply_rope(k, jnp.asarray([[j]]), 10000.0, "full")
        return float(jnp.sum(qr * kr))

    assert dot_at(5, 3) == pytest.approx(dot_at(15, 13), rel=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(9, 9), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


def test_rope_half_leaves_second_half_unrotated():
    x = jnp.ones((1, 1, 1, 8), jnp.float32)
    out = apply_rope(x, jnp.asarray([[7]]), 10000.0, "half")
    np.testing.assert_allclose(np.asarray(out[..., 4:]), 1.0)
    assert not np.allclose(np.asarray(out[..., :4]), 1.0)


def test_softcap_bounds():
    x = jnp.asarray([-1e6, -1.0, 0.0, 1.0, 1e6], jnp.float32)
    y = np.asarray(softcap(x, 30.0))
    assert (np.abs(y) <= 30.0 + 1e-4).all()
    assert y[2] == 0.0 and abs(y[1] + y[3]) < 1e-6
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


# ------------------------------------------------------------------ SSD ----
def _ssd_ref(xdt, dtA, Bm, Cm):
    """O(L^2)-free sequential recurrence oracle."""
    b, l, h, p = xdt.shape
    n = Bm.shape[-1]
    hstate = np.zeros((b, h, p, n), np.float64)
    ys = []
    for t in range(l):
        a = np.exp(np.asarray(dtA[:, t], np.float64))          # (b,h)
        hstate = hstate * a[..., None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(xdt[:, t], np.float64),
            np.asarray(Bm[:, t], np.float64))
        ys.append(np.einsum("bhpn,bn->bhp", hstate,
                            np.asarray(Cm[:, t], np.float64)))
    return np.stack(ys, 1), hstate


@settings(max_examples=10, deadline=None)
@given(l=st.sampled_from([8, 16, 32]), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 99))
def test_prop_ssd_chunked_matches_recurrence(l, chunk, seed):
    rng = np.random.default_rng(seed)
    b, h, p, n = 2, 3, 4, 5
    xdt = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32) * 0.5
    dtA = -jnp.abs(jnp.asarray(rng.normal(size=(b, l, h)),
                               jnp.float32)) * 0.5
    Bm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32) * 0.5
    Cm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32) * 0.5
    y, hlast = ssd_chunked(xdt, dtA, Bm, Cm, chunk)
    y_ref, h_ref = _ssd_ref(xdt, dtA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32), y_ref,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hlast, np.float32), h_ref,
                               rtol=2e-3, atol=2e-3)


def test_segsum_lower_triangular():
    x = jnp.asarray([[1.0, 2.0, 3.0]])
    s = np.asarray(_segsum(x))[0]
    assert s[0, 0] == 0.0 and s[1, 0] == 2.0 and s[2, 0] == 5.0
    assert s[2, 1] == 3.0
    assert np.isneginf(s[0, 1]) and np.isneginf(s[1, 2])
