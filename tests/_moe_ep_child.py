import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.moe import moe_init, moe_forward
from repro.models.moe_ep import moe_forward_ep

cfg = get_config("granite-moe-3b-a800m").reduced(
    d_model=64, n_experts=8, experts_per_token=2, moe_d_ff=32,
    capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = moe_init(key, cfg)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 16, 64)), jnp.float32)

# reference: single-device sort path with 1 group (same capacity math)
y_ref = moe_forward(p, None, x, cfg, n_groups=8)

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
pspec = jax.tree.map(lambda _: P(), p)
pspec["experts"] = {k: {"w": P("model", None, None)} for k in
                    ("gate", "up", "down")}

def body(p_local, x_local):
    return moe_forward_ep(p_local, None, x_local, cfg,
                          model_axis="model")

from repro.core.compat import shard_map_no_check
fn = jax.jit(shard_map_no_check(
    body, mesh, in_specs=(pspec, P(("data", "model"), None, None)),
    out_specs=P(("data", "model"), None, None)))
with mesh:
    pd = jax.device_put(p, jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec,
        is_leaf=lambda v: isinstance(v, P)))
    xd = jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None, None)))
    y = fn(pd, xd)
print("max diff", float(jnp.abs(y - y_ref).max()),
      "ref scale", float(jnp.abs(y_ref).max()))
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
print("EP_OK")
