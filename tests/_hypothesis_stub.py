"""Deterministic mini stand-in for `hypothesis`, used only when the real
package is not installed (the CPU container ships without it).

Implements exactly the subset this suite uses -- ``given`` with keyword
strategies, ``settings(max_examples, deadline)``, and
``strategies.integers / tuples / sampled_from`` -- by running each property
test on ``max_examples`` seeded-random samples.  No shrinking, no database;
CI installs the real hypothesis and bypasses this module entirely.
"""
from __future__ import annotations


import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, sample):
        self.sample = sample            # sample(rng) -> value


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def tuples(*strats):
    return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**kw_strategies):
    # NB: the wrapper must be zero-arg and must NOT expose fn's signature
    # (no functools.wraps/__wrapped__), or pytest mistakes the property
    # arguments for fixtures.
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            rng = np.random.default_rng(
                zlib.crc32(fn.__name__.encode("utf-8")))
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(**drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.tuples = tuples
strategies.sampled_from = sampled_from
