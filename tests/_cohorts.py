"""Shared heterogeneous-rank cohort fixtures for the strategy suites.

One place builds the noisy hetero-rank adapter cohorts and compares
pytrees, so tolerance semantics and cohort construction cannot silently
diverge between `tests/test_strategy.py` and `tests/test_async_agg.py`.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.lora import init_adapters, set_ranks

SPECS = {"fc1": (12, 16), "fc2": (10, 12)}
R_MAX = 8


def hetero_cohort(n=5, seed=0, r_lo=1, r_hi=R_MAX, with_bases=False):
    """n clients with random ranks in [r_lo, r_hi], noisy A and B.

    Returns ``(adapters, ranks, weights)`` -- plus a list of small
    non-LoRA base-trainable trees when ``with_bases`` (the async suite
    folds those too).
    """
    rng = np.random.default_rng(seed)
    ranks = rng.integers(r_lo, r_hi + 1, n)
    adapters, keys = [], jax.random.split(jax.random.PRNGKey(seed), n)
    for i in range(n):
        ad = init_adapters(keys[i], SPECS, R_MAX, int(ranks[i]))
        ad = jax.tree.map(     # B inits to zero: randomize both factors
            lambda x: x + jnp.asarray(rng.normal(size=x.shape), x.dtype)
            if x.dtype == jnp.float32 else x, ad)
        adapters.append(set_ranks(ad, int(ranks[i])))   # re-mask padding
    weights = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    if with_bases:
        bases = [{"b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
                 for _ in range(n)]
        return adapters, jnp.asarray(ranks, jnp.int32), weights, bases
    return adapters, jnp.asarray(ranks, jnp.int32), weights


def mixed_codec_cohort(n=5, seed=0, codecs=None, **kw):
    """A hetero cohort with per-client upload codecs applied.

    ``codecs`` is a per-client name sequence (cycled over
    ``("int8", "bf16", "none")`` by default).  Returns ``(encoded,
    decoded, ranks, weights, codecs)`` -- ``decoded`` is the fp32 oracle
    cohort (``decode_adapters`` of each encoded client, so int8 oracle
    comparisons see the same quantization error).
    """
    from repro.core import codec
    adapters, ranks, weights = hetero_cohort(n=n, seed=seed, **kw)
    if codecs is None:
        codecs = [("int8", "bf16", "none")[i % 3] for i in range(n)]
    codecs = tuple(codecs)
    encoded = [codec.encode_adapters(a, c)
               for a, c in zip(adapters, codecs)]
    decoded = [codec.decode_adapters(a) for a in encoded]
    return encoded, decoded, ranks, weights, codecs


def assert_trees_close(a, b, rtol=1e-4, atol=1e-5, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol, err_msg=msg)
