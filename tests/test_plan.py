"""Compiled AggregationPlan tests: packing parity, caching, donation.

The parity/property suites already drive every strategy through
``aggregate_adapters`` (which routes through plans); this file covers the
plan machinery itself: cache hit/miss keying, re-planning on
``with_options``, buffer donation (no-use-after-donate), the fused
layer-stacked path, the packed kernels against their oracles, the packed
per-update fold, dispatch accounting, and the in-jit fallback.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import (CohortSpec, PlanUnavailable, build_cohort_spec,
                             dispatch_counter)
from repro.core.strategy import (ClientUpdate, ServerState, get_strategy,
                                 stack_trees)
from repro.lora import init_adapters, init_pair, mask_pair, set_ranks

from _cohorts import R_MAX, SPECS, assert_trees_close, hetero_cohort

jax.config.update("jax_platform_name", "cpu")


def fresh(method, **options):
    """A configured copy with its own (empty) plan cache."""
    s = get_strategy(method)
    if s.rank_contract == "stacked" and "stack_r_cap" not in options:
        options["stack_r_cap"] = 64
    return s.with_options(**options) if options else s.with_options()


def layer_stacked_cohort(n=4, L=3, r=8, fo=12, fi=16, seed=0):
    rng = np.random.default_rng(seed)
    ranks = rng.integers(1, r + 1, n)
    cohort = []
    for i in range(n):
        p = init_pair(jax.random.PRNGKey(i), fo, fi, r, int(ranks[i]),
                      leading=(L,))
        p = {"A": p["A"] + jnp.asarray(rng.normal(size=p["A"].shape),
                                       jnp.float32),
             "B": p["B"] + jnp.asarray(rng.normal(size=p["B"].shape),
                                       jnp.float32),
             "rank": p["rank"]}
        cohort.append({"blk": mask_pair(p)})
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    return cohort, jnp.asarray(ranks, jnp.int32), w


# ------------------------------------------------------------ plan caching --
def test_plan_cache_hits_on_same_cohort_spec():
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(4, seed=1)
    for _ in range(3):
        s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                             backend="ref")
    assert s.plan_stats["hits"] == 2 and s.plan_stats["misses"] == 1


def test_plan_cache_misses_on_rank_multiset_change():
    s = fresh("rbla")
    a1, r1, w1 = hetero_cohort(4, seed=1, r_lo=1, r_hi=3)
    a2, r2, w2 = hetero_cohort(4, seed=2, r_lo=4, r_hi=R_MAX)
    s.aggregate_adapters(a1, w1, r_max=R_MAX, client_ranks=r1,
                         backend="ref")
    s.aggregate_adapters(a2, w2, r_max=R_MAX, client_ranks=r2,
                         backend="ref")
    # different rank multisets are different specs -> two plans...
    assert s.plan_stats["hits"] == 0 and s.plan_stats["misses"] == 2
    # ...and re-running either cohort hits its cached plan
    s.aggregate_adapters(a1, w1, r_max=R_MAX, client_ranks=r1,
                         backend="ref")
    assert s.plan_stats["hits"] == 1 and s.plan_stats["misses"] == 2


def test_plan_cache_keys_on_backend_and_prev():
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(3, seed=3)
    prev = init_adapters(jax.random.PRNGKey(5), SPECS, R_MAX, R_MAX)
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="ref")
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="pallas")
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         prev_global=prev, backend="ref")
    assert s.plan_stats["misses"] == 3 and s.plan_stats["hits"] == 0


def test_with_options_drops_compiled_plans():
    s = fresh("flora")
    adapters, ranks, w = hetero_cohort(3, seed=4)
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="ref")
    assert s.plan_stats["misses"] == 1
    s2 = s.with_options(stack_r_cap=48)
    assert "_plan_cache" not in s2.__dict__
    s2.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                          backend="ref")
    assert s2.plan_stats == {"hits": 0, "misses": 1}
    assert s.plan_stats["misses"] == 1       # original cache untouched


def test_plan_api_direct_and_unsupported_backend_raises():
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(3, seed=5)
    stacked = stack_trees(adapters)
    spec = build_cohort_spec(stacked, kind="ref", r_max=R_MAX,
                            client_ranks=ranks)
    round_ = s.plan(None, spec)
    out = round_(stacked, w)
    want = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=ranks, backend="ref",
                                use_plan=False)
    assert_trees_close(out, want)
    # rbla_norm packs on pallas now; its missing path is distributed
    with pytest.raises(NotImplementedError, match="rbla_norm"):
        bad = build_cohort_spec(stacked, kind="distributed", r_max=R_MAX,
                                client_ranks=ranks)
        get_strategy("rbla_norm").plan(None, bad)


def test_cohort_spec_is_hashable_and_value_keyed():
    adapters, ranks, w = hetero_cohort(3, seed=6)
    stacked = stack_trees(adapters)
    s1 = build_cohort_spec(stacked, kind="ref", r_max=R_MAX,
                           client_ranks=ranks)
    s2 = build_cohort_spec(stack_trees(adapters), kind="ref", r_max=R_MAX,
                           client_ranks=ranks)
    assert isinstance(s1, CohortSpec) and s1 == s2 and hash(s1) == hash(s2)


def test_spec_build_unavailable_under_tracing_and_on_bare_leaves():
    adapters, ranks, w = hetero_cohort(2, seed=7)
    stacked = stack_trees(adapters)
    with pytest.raises(PlanUnavailable):
        build_cohort_spec({"t": jnp.ones((2, 4, 3))}, kind="ref")

    def traced(tree):
        return build_cohort_spec(tree, kind="ref")
    with pytest.raises(PlanUnavailable):
        jax.eval_shape(lambda t: (traced(t), t)[1], stacked)


def test_aggregate_adapters_inside_jit_falls_back_to_legacy():
    """Under jit tracing the cohort cannot be described host-side; the
    round must silently run the in-trace reference path and agree."""
    adapters, ranks, w = hetero_cohort(3, seed=8)
    s = fresh("rbla")

    @jax.jit
    def round_(ads, wv):
        return s.aggregate_adapters(ads, wv, r_max=R_MAX,
                                    client_ranks=ranks)
    got = round_(adapters, w)
    want = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=ranks, backend="ref",
                                use_plan=False)
    assert_trees_close(got, want)


def test_mean_executor_shared_across_rank_multisets():
    """A new rank multiset is a new (cheap) plan but NOT a new XLA
    compile: mean-mode executors key on shapes only -- owner masks and
    ranks enter as runtime data.  A long-lived service with random
    cohort selection must not recompile every round."""
    s = fresh("rbla")
    for seed, (lo, hi) in enumerate([(1, 3), (4, R_MAX), (2, 5)]):
        a, r, w = hetero_cohort(4, seed=seed, r_lo=lo, r_hi=hi)
        s.aggregate_adapters(a, w, r_max=R_MAX, client_ranks=r,
                             backend="ref")
    assert s.plan_stats["misses"] == 3          # three plans...
    assert len(s.__dict__["_plan_exec_cache"]) == 1   # ...one executor


def test_plan_cache_is_bounded_lru():
    from repro.core import strategy as strategy_mod
    s = fresh("rbla")
    old = strategy_mod.PLAN_CACHE_SIZE
    strategy_mod.PLAN_CACHE_SIZE = 2
    try:
        for seed in range(4):
            a, r, w = hetero_cohort(3, seed=seed)
            s.aggregate_adapters(a, w, r_max=R_MAX, client_ranks=r,
                                 backend="ref")
        assert len(s.__dict__["_plan_cache"]) <= 2
    finally:
        strategy_mod.PLAN_CACHE_SIZE = old


def test_flora_fold_rejects_nonuniform_layer_ranks():
    """fold enforces the same uniform-per-layer contract the one-shot
    path does, with the same actionable error (not a shape crash)."""
    from repro.lora import init_pair, mask_pair
    s = fresh("flora", stack_r_cap=64)
    L, r, fo, fi = 2, 8, 12, 16
    state_pair = init_pair(jax.random.PRNGKey(0), fo, fi, r, 4,
                           leading=(L,))
    upd_pair = dict(init_pair(jax.random.PRNGKey(1), fo, fi, r, 3,
                              leading=(L,)))
    upd_pair["rank"] = jnp.asarray([3, 1], jnp.int32)   # non-uniform
    state = ServerState(adapters={"blk": mask_pair(state_pair)},
                        base_trainable={}, r_max=r)
    upd = ClientUpdate(adapters={"blk": mask_pair(upd_pair)},
                       base_trainable={}, n_examples=1.0)
    with pytest.raises(NotImplementedError, match="uniform"):
        s.fold(state, upd, backend="ref")


# -------------------------------------------------- weight-only plan reuse --
def test_same_cohort_reuses_packed_buffers_weight_only():
    """Satellite gate: when the same cohort re-participates (identical
    upload buffers resubmitted on consecutive rounds), the host-side
    re-stacking and re-packing are skipped -- only the combine re-runs
    with the new weights -- and the saving is visible in plan_stats.
    The payloads are kept only from the second sighting on (one-shot
    cohorts must not pin cohort-sized buffers)."""
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(4, seed=30)
    out1 = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=ranks, backend="ref")
    s.aggregate_adapters(adapters, w, r_max=R_MAX,
                         client_ranks=ranks, backend="ref")
    w2 = w * jnp.asarray(np.linspace(0.5, 2.0, 4), jnp.float32)
    out2 = s.aggregate_adapters(adapters, w2, r_max=R_MAX,
                                client_ranks=ranks, backend="ref")
    assert s.plan_stats["pack_reuses"] >= 1
    assert s.plan_stats["pack_runs"] <= 2
    # the weight-only update is numerically the full round
    want = s.aggregate_adapters(adapters, w2, r_max=R_MAX,
                                client_ranks=ranks, backend="ref",
                                use_plan=False)
    assert_trees_close(out2, want)
    # different weights must really change the result (no stale cache)
    with pytest.raises(AssertionError):
        assert_trees_close(out1, out2)


def test_mutable_numpy_uploads_are_never_memoized():
    """Regression: identity fingerprints are only sound for immutable
    jax arrays.  A caller that reuses preallocated numpy buffers and
    mutates them in place between rounds must get the fresh aggregate,
    not a stale memoized one."""
    s = fresh("fedavg")
    rng = np.random.default_rng(40)
    uploads = [{k: {"A": rng.normal(size=(R_MAX, fi)).astype(np.float32),
                    "B": rng.normal(size=(fo, R_MAX)).astype(np.float32),
                    "rank": np.int32(R_MAX)}
                for k, (fo, fi) in SPECS.items()} for _ in range(3)]
    w = jnp.ones(3, jnp.float32)
    ranks = jnp.full((3,), R_MAX, jnp.int32)
    out1 = s.aggregate_adapters(uploads, w, r_max=R_MAX,
                                client_ranks=ranks, backend="ref")
    for u in uploads:                       # in-place round-2 deltas
        for k in SPECS:
            u[k]["A"] *= 2.0
            u[k]["B"] *= 2.0
    out2 = s.aggregate_adapters(uploads, w, r_max=R_MAX,
                                client_ranks=ranks, backend="ref")
    for k in SPECS:
        np.testing.assert_allclose(np.asarray(out2[k]["A"]),
                                   2.0 * np.asarray(out1[k]["A"]),
                                   rtol=1e-5, atol=1e-6)


def test_stack_memo_releases_payload_when_uploads_die():
    """The memos must not pin cohort-sized buffers for the process
    lifetime: a one-shot cohort leaves only a fingerprint behind, and
    once a repeating cohort's uploads die the payload is released
    eagerly -- without waiting for the same plan to execute again."""
    import gc
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(4, seed=41)
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="ref")
    memo = s.__dict__["_stack_memo"]
    assert memo._entry is None         # first sight: fingerprint only
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="ref")
    assert memo._entry is not None     # repeat: payload kept
    del adapters
    gc.collect()
    # eager release: the upload finalizers fired, nothing pinned, even
    # though no further aggregate call has touched this plan
    assert memo._entry is None


def test_buffer_memo_invariants():
    import gc
    from repro.core.plan import BufferMemo
    m = BufferMemo()
    a, b = jnp.arange(3.0), jnp.arange(4.0)
    m.store([a, b], "payload")
    assert m.lookup([a, b]) == "payload"
    assert m.lookup([b, a]) is None              # order is identity
    m.store([np.arange(3.0)], "nope")            # mutable: refused
    assert m.lookup([a, b]) == "payload"         # ...and left intact
    del b
    gc.collect()
    assert m._entry is None                      # eager release

    # require_repeat: payload kept only for a repeated fingerprint
    m2 = BufferMemo(require_repeat=True)
    c = jnp.arange(5.0)
    m2.store([c], "one")
    assert m2.lookup([c]) is None and m2._entry is None
    m2.store([c], "two")
    assert m2.lookup([c]) == "two"


def test_new_cohort_arrays_repack():
    s = fresh("rbla")
    a1, ranks, w = hetero_cohort(4, seed=31)
    a2, _, _ = hetero_cohort(4, seed=31)     # equal values, NEW buffers
    s.aggregate_adapters(a1, w, r_max=R_MAX, client_ranks=ranks,
                         backend="ref")
    s.aggregate_adapters(a2, w, r_max=R_MAX, client_ranks=ranks,
                         backend="ref")
    assert s.plan_stats["pack_runs"] == 2
    assert s.plan_stats.get("pack_reuses", 0) == 0


# ------------------------------------------------------- svd packed plans --
def test_svd_plan_is_packed_batched_and_matches_oracle():
    """The tentpole gate: svd lowers to a packed plan (one batched
    factored SVD per same-shape bucket), not the old whole-round jit,
    and matches the per-leaf oracle."""
    s = fresh("svd")
    adapters, ranks, w = hetero_cohort(4, seed=32)
    got = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=ranks, backend="ref")
    want = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=ranks, backend="ref",
                                use_plan=False)
    assert_trees_close(got, want)
    rd = next(iter(s.__dict__["_plan_cache"].values()))
    assert rd.kind == "packed"
    # SPECS' two pairs have distinct shapes -> two buckets; same-shape
    # pairs share one batched launch (see the layer-stacked test below)
    assert rd.n_kernel_launches == 2


def test_svd_same_shape_pairs_share_one_batched_bucket():
    cohort, ranks, w = layer_stacked_cohort(seed=33)
    cohort = [{"x": c["blk"], "y": jax.tree.map(lambda v: v, c["blk"])}
              for c in cohort]
    s = fresh("svd")
    got = s.aggregate_adapters(cohort, w, r_max=8, client_ranks=ranks,
                               backend="ref")
    rd = next(iter(s.__dict__["_plan_cache"].values()))
    assert rd.kind == "packed"
    assert rd.n_kernel_launches == 1       # both pairs: same shapes
    want = s.aggregate_adapters(cohort, w, r_max=8, client_ranks=ranks,
                                backend="ref", use_plan=False)
    assert_trees_close(got, want)


def test_svd_executor_shared_across_rank_multisets():
    """Like the mean mode: a new rank multiset is a new (cheap) plan but
    not a new XLA compile -- scales enter as runtime data."""
    s = fresh("svd")
    for seed, (lo, hi) in enumerate([(1, 3), (4, R_MAX), (2, 5)]):
        a, r, w = hetero_cohort(4, seed=seed, r_lo=lo, r_hi=hi)
        s.aggregate_adapters(a, w, r_max=R_MAX, client_ranks=r,
                             backend="ref")
    assert s.plan_stats["misses"] == 3
    assert len(s.__dict__["_plan_exec_cache"]) == 1


def test_svd_dense_method_knob_matches_factored_in_product_space():
    s_auto = fresh("svd")
    s_dense = fresh("svd", svd_method="dense")
    adapters, ranks, w = hetero_cohort(3, seed=34, r_lo=1, r_hi=2)
    a = s_auto.aggregate_adapters(adapters, w, r_max=R_MAX,
                                  client_ranks=ranks, backend="ref")
    d = s_dense.aggregate_adapters(adapters, w, r_max=R_MAX,
                                   client_ranks=ranks, backend="ref")
    for k in SPECS:
        np.testing.assert_allclose(
            np.asarray(a[k]["B"], np.float32)
            @ np.asarray(a[k]["A"], np.float32),
            np.asarray(d[k]["B"], np.float32)
            @ np.asarray(d[k]["A"], np.float32), rtol=1e-3, atol=1e-4)


# ------------------------------------------------- rbla_norm pallas plans --
def test_rbla_norm_packs_on_pallas_and_matches_ref():
    """Satellite gate: the mean_norm lowering runs the packed kernel on
    the pallas backend (norm restore fused) and agrees with ref."""
    s = fresh("rbla_norm")
    adapters, ranks, w = hetero_cohort(4, seed=35)
    ref = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=ranks, backend="ref")
    pal = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=ranks, backend="pallas")
    assert_trees_close(ref, pal)
    rd = next(r for r in s.__dict__["_plan_cache"].values()
              if r.spec.kind == "pallas")
    assert rd.kind == "packed" and rd.n_fallback_pairs == 0
    # the legacy (per-pair kernel) path agrees too
    legacy = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                  client_ranks=ranks, backend="pallas",
                                  use_plan=False)
    assert_trees_close(ref, legacy)


def test_packed_agg_kernel_norm_restore_matches_oracle():
    from repro.kernels import packed_agg, packed_agg_ref
    rng = np.random.default_rng(36)
    n, r, d = 4, 16, 21
    x = jnp.asarray(rng.normal(size=(n, r, d)), jnp.float32)
    masks = jnp.asarray(rng.integers(0, 2, (n, r)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    got = packed_agg(x, masks, w, norm_by="mask", norm_restore=True,
                     interpret=True)
    want = packed_agg_ref(x, masks, w, norm_by="mask", norm_restore=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- donation --
def test_donated_prev_buffers_are_consumed():
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(4, seed=9, r_lo=2, r_hi=3)
    prev = init_adapters(jax.random.PRNGKey(11), SPECS, R_MAX, R_MAX)
    keep = jax.tree.map(lambda x: np.asarray(x), prev)   # host copy
    out = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=ranks, prev_global=prev,
                               backend="ref", donate=True)
    want = s.aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=ranks,
        prev_global=jax.tree.map(jnp.asarray, keep), backend="ref",
        use_plan=False)
    assert_trees_close(out, want)
    # the no-use-after-donate guard: donated A/B buffers are dead, and
    # touching them afterwards raises instead of reading stale memory
    donated = prev["fc1"]["A"]
    if donated.is_deleted():                 # backend supports donation
        with pytest.raises(RuntimeError):
            np.asarray(donated)


def test_non_donating_call_leaves_prev_alive():
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(4, seed=10, r_lo=2, r_hi=3)
    prev = init_adapters(jax.random.PRNGKey(12), SPECS, R_MAX, R_MAX)
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         prev_global=prev, backend="ref")
    assert not prev["fc1"]["A"].is_deleted()
    np.asarray(prev["fc1"]["A"])             # still readable


# -------------------------------------------------- layer-stacked packing --
@pytest.mark.parametrize("method", ["rbla", "zeropad", "fedavg"])
def test_layer_stacked_pairs_run_fused_on_pallas(method):
    """The acceptance criterion: layer-stacked (leading-dim) pairs no
    longer fall back to reference leaf math inside the Pallas backend --
    they pack into buckets like everything else."""
    cohort, ranks, w = layer_stacked_cohort()
    s = fresh(method)
    # oracle: the pre-plan path (whose layer-stacked pairs used the
    # reference per-pair leaf math)
    want = s.aggregate_adapters(cohort, w, r_max=8, client_ranks=ranks,
                                backend="pallas", use_plan=False)
    got = s.aggregate_adapters(cohort, w, r_max=8, client_ranks=ranks,
                               backend="pallas")
    assert_trees_close(want, got, msg=method)
    rd = next(r for r in s.__dict__["_plan_cache"].values()
              if r.spec.kind == "pallas")
    assert rd.kind == "packed" and rd.n_fallback_pairs == 0


def test_layer_stacked_flora_packs_into_stack_buckets():
    cohort, ranks, w = layer_stacked_cohort(seed=3)
    s = fresh("flora", stack_r_cap=64)
    want = s.aggregate_adapters(cohort, w, r_max=8, client_ranks=ranks,
                                backend="pallas", use_plan=False)
    got = s.aggregate_adapters(cohort, w, r_max=8, client_ranks=ranks,
                               backend="pallas")
    assert_trees_close(want, got)
    rd = next(r for r in s.__dict__["_plan_cache"].values()
              if r.spec.kind == "pallas")
    assert rd.kind == "packed" and rd.n_fallback_pairs == 0


def test_flora_over_cap_pairs_fall_back_inside_the_plan():
    adapters, ranks, w = hetero_cohort(4, seed=13, r_lo=4, r_hi=R_MAX)
    s = fresh("flora", stack_r_cap=R_MAX)    # sum(ranks) certainly > cap
    want = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=ranks, backend="pallas",
                                use_plan=False)
    got = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=ranks, backend="pallas")
    assert_trees_close(want, got, rtol=1e-3, atol=1e-4)
    rd = next(r for r in s.__dict__["_plan_cache"].values()
              if r.spec.kind == "pallas")
    assert rd.n_fallback_pairs == len(SPECS)


# ------------------------------------------------------ dispatch counting --
def test_plan_round_is_one_tracked_dispatch():
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(4, seed=14)
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="pallas")              # build plan
    dispatch_counter.reset()
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="pallas")
    assert dispatch_counter.reset() == 1


def test_legacy_pallas_path_dispatches_per_pair():
    s = fresh("rbla")
    adapters, ranks, w = hetero_cohort(4, seed=14)
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="pallas", use_plan=False)   # compile
    dispatch_counter.reset()
    s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=ranks,
                         backend="pallas", use_plan=False)
    # two kernel launches (A + B) per pair: the dispatch gap the plan
    # closes (>= 5x for any tree with >= 3 pairs)
    assert dispatch_counter.reset() == 2 * len(SPECS)


# --------------------------------------------------------- packed kernels --
def test_packed_agg_kernel_matches_oracle():
    from repro.kernels import packed_agg, packed_agg_ref
    rng = np.random.default_rng(0)
    n, r, d = 5, 24, 40
    x = jnp.asarray(rng.normal(size=(n, r, d)), jnp.float32)
    masks = jnp.asarray(rng.integers(0, 2, (n, r)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    prev = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
    for norm_by, pv in (("mask", prev), ("mask", None), ("weight", None)):
        got = packed_agg(x, masks, w, pv, norm_by=norm_by, interpret=True)
        want = packed_agg_ref(x, masks, w, pv, norm_by=norm_by)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{norm_by}/prev={pv is not None}")


def test_packed_stack_kernel_places_and_scales():
    from repro.kernels import packed_stack
    rng = np.random.default_rng(1)
    n, r_in, d = 3, 8, 17
    x = jnp.asarray(rng.normal(size=(n, r_in, d)), jnp.float32)
    prev = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    scales = jnp.asarray([1.0, 0.5, 2.0], jnp.float32)
    #          (client, src_row, dst_row, rows, scale_idx)
    copies_x = ((0, 0, 2, 3, 1), (2, 1, 5, 2, 2))
    copies_prev = ((1, 0, 2, 0),)
    out = packed_stack(x, scales, prev, copies_x=copies_x,
                       copies_prev=copies_prev, out_rows=9, interpret=True)
    want = np.zeros((9, d), np.float32)
    want[2:5] = 0.5 * np.asarray(x)[0, 0:3]
    want[5:7] = 2.0 * np.asarray(x)[2, 1:3]
    want[0:2] = 1.0 * np.asarray(prev)[1:3]
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6, atol=1e-6)


def test_packed_stack_rejects_bad_copies():
    from repro.kernels import packed_stack
    x = jnp.ones((2, 4, 8), jnp.float32)
    with pytest.raises(ValueError, match="bad copy"):
        packed_stack(x, jnp.ones(1), copies_x=((0, 0, 0, 9, 0),),
                     out_rows=4, interpret=True)
    with pytest.raises(ValueError, match="no prev"):
        packed_stack(x, jnp.ones(1), copies_prev=((0, 0, 2, 0),),
                     out_rows=4, interpret=True)


# -------------------------------------------------------- packed fold path --
def test_rbla_packed_fold_matches_ref_fold_and_launch_count():
    s = get_strategy("rbla")
    adapters, ranks, w, bases = hetero_cohort(4, seed=15, with_bases=True)

    def mk():
        return ServerState(
            adapters=init_adapters(jax.random.PRNGKey(2), SPECS, R_MAX,
                                   R_MAX),
            base_trainable={"b": jnp.zeros(4)}, r_max=R_MAX)
    st_r, fs_r = mk(), s.init_fold(mk())
    st_p, fs_p = mk(), s.init_fold(mk())
    for i in range(4):
        u = ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                         n_examples=float(w[i]), rank=int(ranks[i]))
        st_r, fs_r = s.fold(st_r, u, fold_state=fs_r, backend="ref")
        st_p, fs_p = s.fold(st_p, u, fold_state=fs_p, backend="pallas")
    assert_trees_close(st_r.adapters, st_p.adapters, 1e-4, 1e-5)
    assert_trees_close(fs_r.row_mass, fs_p.row_mass, 1e-5, 1e-6)
    # the packed fold buckets SPECS' two widths x (A, B) into <= 4 fused
    # launches per fold, vs 2 launches per pair on the legacy path
    entry = next(iter(s.__dict__["_fold_plan_cache"].values()))
    assert entry[1] <= 2 * len(SPECS)


def test_flora_streaming_fold_is_exact_below_cap_nonuniform():
    """Satellite gate: flora's fold streams the one-shot stack exactly
    below the cap -- non-uniform masses included (the old fold was only
    exact for uniform ones)."""
    s = fresh("flora", stack_r_cap=256)
    adapters, ranks, w, bases = hetero_cohort(5, seed=16, with_bases=True)
    updates = [ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                            n_examples=float(w[i]), rank=int(ranks[i]))
               for i in range(5)]

    def mk():
        rs = s.server_storage_rank(R_MAX)
        return ServerState(
            adapters=init_adapters(jax.random.PRNGKey(6), SPECS, rs, R_MAX),
            base_trainable={"b": jnp.zeros(4)}, r_max=R_MAX)
    st, fs = mk(), s.init_fold(mk())
    for u in updates:
        st, fs = s.fold(st, u, fold_state=fs, backend="ref")
    want = s.aggregate(mk(), updates, weights=w, backend="ref")
    assert_trees_close(st.adapters, want.adapters, 2e-5, 2e-6)
    assert_trees_close(st.base_trainable, want.base_trainable, 2e-5, 2e-6)


def test_flora_streaming_fold_cap_crossing_reprojects():
    s = fresh("flora", stack_r_cap=12)
    adapters, ranks, w, bases = hetero_cohort(4, seed=17, r_lo=3, r_hi=6,
                                              with_bases=True)

    def mk():
        rs = s.server_storage_rank(R_MAX)
        return ServerState(
            adapters=init_adapters(jax.random.PRNGKey(8), SPECS, rs, R_MAX),
            base_trainable={"b": jnp.zeros(4)}, r_max=R_MAX)
    st, fs = mk(), s.init_fold(mk())
    crossed = False
    for i in range(4):
        u = ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                         n_examples=float(w[i]), rank=int(ranks[i]))
        before = int(np.max(np.asarray(st.adapters["fc1"]["rank"])))
        st, fs = s.fold(st, u, fold_state=fs, backend="ref")
        after = int(np.max(np.asarray(st.adapters["fc1"]["rank"])))
        if after < before + int(ranks[i]):
            crossed = True
            assert after == R_MAX        # re-projected back to r_max
    assert crossed, "cohort never crossed the cap; fixture broken"
    assert np.isfinite(np.asarray(st.adapters["fc1"]["A"])).all()
    for leaf in jax.tree.leaves(st.adapters):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


# ---------------------------------------------------- codec-aware caching --
def test_codec_mix_in_executor_cache_key():
    """Same codec mix across rank multisets shares one jitted executor
    (masks, ranks, payloads, and scales are all runtime data); changing
    the mix is a different wire layout and must build a new one."""
    from repro.core import codec
    s = fresh("rbla")
    for seed, (lo, hi) in enumerate([(1, 3), (4, R_MAX)]):
        a, r, w = hetero_cohort(4, seed=seed, r_lo=lo, r_hi=hi)
        enc = [codec.encode_adapters(x, "int8") for x in a]
        s.aggregate_adapters(enc, w, r_max=R_MAX, client_ranks=r,
                             backend="ref")
    assert s.plan_stats["misses"] == 2          # two plans...
    assert len(s.__dict__["_plan_exec_cache"]) == 1   # ...one executor
    a, r, w = hetero_cohort(4, seed=9)
    mix = ("int8", "bf16", "int8", "bf16")
    enc = [codec.encode_adapters(x, c) for x, c in zip(a, mix)]
    s.aggregate_adapters(enc, w, r_max=R_MAX, client_ranks=r,
                         backend="ref")
    assert s.plan_stats["misses"] == 3
    assert len(s.__dict__["_plan_exec_cache"]) == 2


def test_codec_change_replans_while_rank_repeat_hits():
    """The codec mix is part of the plan key: a repeat cohort under the
    same mix hits, the same cohort under a different mix re-plans, and
    the LRU keeps both warm."""
    from repro.core import codec
    s = fresh("rbla")
    a, r, w = hetero_cohort(4, seed=2)
    int8 = [codec.encode_adapters(x, "int8") for x in a]
    for _ in range(2):
        s.aggregate_adapters(int8, w, r_max=R_MAX, client_ranks=r,
                             backend="ref")
    assert s.plan_stats == {
        "hits": 1, "misses": 1, **{k: v for k, v in s.plan_stats.items()
                                   if k not in ("hits", "misses")}}
    mixed = [codec.encode_adapters(x, "bf16" if i == 0 else "int8")
             for i, x in enumerate(a)]
    s.aggregate_adapters(mixed, w, r_max=R_MAX, client_ranks=r,
                         backend="ref")
    assert s.plan_stats["misses"] == 2
    s.aggregate_adapters(int8, w, r_max=R_MAX, client_ranks=r,
                         backend="ref")
    assert s.plan_stats["hits"] == 2 and s.plan_stats["misses"] == 2


def test_encoded_plan_matches_decoded_oracle_with_prev():
    """Fused-dequant plan vs eager decode, with prev-retention in play
    (unowned rows fall back to the dequantized-path prev identically)."""
    from repro.core import codec
    from _cohorts import mixed_codec_cohort
    enc, dec, ranks, w, _ = mixed_codec_cohort(n=5, seed=11, r_lo=1,
                                               r_hi=3)
    prev = init_adapters(jax.random.PRNGKey(77), SPECS, R_MAX, R_MAX)
    s_enc, s_dec = fresh("rbla"), fresh("rbla")
    got = s_enc.aggregate_adapters(enc, w, r_max=R_MAX, client_ranks=ranks,
                                   prev_global=prev, backend="ref")
    want = s_dec.aggregate_adapters(dec, w, r_max=R_MAX,
                                    client_ranks=ranks, prev_global=prev,
                                    backend="ref")
    assert_trees_close(want, got, 1e-5, 1e-6)
    assert s_enc.plan_stats["misses"] == 1      # planned, not eager
