"""Distributed aggregation + small-mesh dry-run integration tests.

These spawn SUBPROCESSES with forced host device counts so the rest of the
suite keeps its single-device jax runtime.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=ROOT)


@pytest.mark.slow
def test_distributed_rbla_matches_host():
    code = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import aggregate, stacked_rank_masks
from repro.core.distributed import make_distributed_aggregator

n, r, d = 8, 32, 512
rng = np.random.default_rng(0)
ranks = jnp.asarray(rng.integers(1, r + 1, n), jnp.int32)
masks = stacked_rank_masks(r, ranks)[:, :, None]
x = jnp.asarray(rng.normal(size=(n, r, d)), jnp.float32) * masks
w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("clients",))
for method in ("rbla", "zeropad"):
    agg = make_distributed_aggregator(mesh, "clients", method)
    sh = NamedSharding(mesh, P("clients"))
    out = agg(jax.device_put(x, sh),
              jax.device_put(jnp.broadcast_to(masks, x.shape), sh),
              jax.device_put(w, sh))
    want = aggregate({"t": x}, {"t": masks}, w, method=method)["t"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
print("OK")
"""
    res = run_child(code)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout


@pytest.mark.slow
def test_dryrun_small_mesh_lowers():
    """The dry-run machinery on a 4-device (2,2) mesh with a reduced arch:
    proves the sharded train/prefill/decode lowering path end to end
    without the 512-device cost."""
    code = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models.model import make_model
from repro.sharding import rules
from repro.launch.dryrun import (build_train_step, build_decode_step,
                                 input_specs, decode_input_specs,
                                 model_state_specs)
from repro.configs.base import InputShape
from repro.lora import strip_ranks
from repro.optim import adam

mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
            ("data", "model"))
cfg = get_config("granite-moe-3b-a800m").reduced(
    vocab_size=512, n_experts=4, experts_per_token=2)
model = make_model(cfg, remat=True)
shape = InputShape("t", 64, 8, "train")
with mesh:
    params, adapters, _, _ = model_state_specs(cfg, mesh, model)
    step, opt = build_train_step(model, cfg)
    factors, _ = strip_ranks(adapters)
    opt_state = jax.eval_shape(opt.init, factors)
    opt_state = rules.shaped(
        opt_state, rules.to_shardings(rules.adapter_specs(opt_state, mesh),
                                      mesh))
    batch = input_specs(cfg, shape, mesh)
    compiled = jax.jit(step).lower(params, adapters, opt_state,
                                   batch).compile()
    assert compiled.cost_analysis() is not None

    dshape = InputShape("d", 128, 8, "decode")
    serve = build_decode_step(model)
    caches, token, pos = decode_input_specs(cfg, dshape, mesh, model)
    jax.jit(serve).lower(params, adapters, caches, token, pos).compile()
print("OK")
"""
    res = run_child(code, devices=4)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout


@pytest.mark.slow
def test_fl_round_spmd():
    """FLaaS round as one SPMD program: 8 clients on 8 devices run a local
    LoRA step and RBLA-aggregate via masked psum -- the pod-scale FL path."""
    code = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.compat import shard_map_no_check
from repro.core.distributed import rbla_tree_allreduce
from repro.lora import (adapter_masks, attach_ranks, init_adapters,
                        strip_ranks, set_ranks)

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("clients",))

specs = {"fc1": (16, 8)}
server = init_adapters(jax.random.PRNGKey(0), specs, r_max=8, rank=8)

def client_round(adapters, rank, x):
    ad = set_ranks(adapters, rank[0])
    # fake local update: push A toward the data mean (stands in for SGD)
    upd = jax.tree.map(lambda a: a, ad)
    upd["fc1"] = dict(upd["fc1"])
    upd["fc1"]["A"] = upd["fc1"]["A"] + 0.1 * jnp.mean(x)
    ad = set_ranks(upd, rank[0])   # re-mask
    masks = adapter_masks(ad)
    agg = rbla_tree_allreduce(ad, masks, jnp.float32(1.0), "clients")
    return agg

ranks = jnp.arange(1, 9, dtype=jnp.int32)        # heterogeneous ranks
xs = jnp.arange(8, dtype=jnp.float32)[:, None] * jnp.ones((8, 4))
fn = shard_map_no_check(client_round, mesh,
                        in_specs=(P(), P("clients"), P("clients")),
                        out_specs=P())
out = fn(server, ranks, xs)
A = np.asarray(out["fc1"]["A"])
# row 7 owned only by the rank-8 client (client 7): preserved verbatim
base = np.asarray(server["fc1"]["A"])
np.testing.assert_allclose(A[7], base[7] + 0.1 * 7.0, rtol=1e-5)
# row 0 owned by all: mean of all client updates
np.testing.assert_allclose(A[0], base[0] + 0.1 * np.mean(np.arange(8)),
                           rtol=1e-5)
print("OK")
"""
    res = run_child(code)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout


@pytest.mark.slow
def test_moe_ep_a2a_matches_pjit_path():
    """Explicit expert-parallel all-to-all dispatch (moe_ep) against the
    sort/pjit path on a (data=2, model=4) mesh with 8 experts."""
    with open("/dev/null"):
        pass
    code = open(os.path.join(ROOT, "tests", "_moe_ep_child.py")).read()
    res = run_child(code, devices=8)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "EP_OK" in res.stdout
