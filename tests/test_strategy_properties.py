"""Property-based invariants for every registered aggregation strategy.

Runs under ``tests/_hypothesis_stub.py`` (containers without hypothesis)
and under real hypothesis (the CI matrix leg installs it); only the stub's
API subset is used: ``given`` with keyword strategies, ``settings``, and
``strategies.integers / tuples / sampled_from``.

Invariants, over randomized rank multisets:

* homogeneous-rank cohorts reduce to FedAvg, in the space each strategy
  *declares* (``fedavg_equivalence``: "factors" | "product" | None);
* aggregation is invariant to client permutation (product space -- flora
  permutes factor segments but not the served update);
* weights are convex: scaling every weight by the same constant changes
  nothing (scale-by-n invariance);
* output shapes match the strategy's declared rank contract
  (``rank_contract``: fixed ``r_max`` storage+rank vs. stacked);
* every (strategy x backend) pair either matches the reference path
  numerically or raises the documented ``NotImplementedError``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.strategy import get_strategy, list_strategies
from repro.lora import init_adapters, set_ranks

jax.config.update("jax_platform_name", "cpu")

SPECS = {"fc1": (9, 7), "fc2": (6, 9)}
R_MAX = 6
ALL_METHODS = ("fedavg", "flora", "rbla", "rbla_clipped", "rbla_median",
               "rbla_norm", "rbla_ranked", "rbla_trimmed", "svd",
               "zeropad")
ROBUST_METHODS = ("rbla_clipped", "rbla_trimmed", "rbla_median")
#: large enough that a cohort of <= 6 clients plus prev never hits the
#: cap -- properties about *stacking* must not silently test the SVD path
BIG_CAP = 8 * R_MAX


def configured(method):
    s = get_strategy(method)
    if s.rank_contract == "stacked":
        s = s.with_options(stack_r_cap=BIG_CAP)
    return s


def make_cohort(seed, ranks):
    """Clients with the given ranks; both factors randomized (B inits 0)."""
    rng = np.random.default_rng(seed)
    adapters = []
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ranks))
    for i, r in enumerate(ranks):
        ad = init_adapters(keys[i], SPECS, R_MAX, int(r))
        ad = jax.tree.map(
            lambda x: x + jnp.asarray(rng.normal(size=x.shape), x.dtype)
            if x.dtype == jnp.float32 else x, ad)
        adapters.append(set_ranks(ad, int(r)))
    weights = jnp.asarray(rng.uniform(0.5, 2.0, len(ranks)), jnp.float32)
    return adapters, jnp.asarray(ranks, jnp.int32), weights


def random_ranks(rng_seed, n):
    return tuple(int(r) for r in
                 np.random.default_rng(rng_seed).integers(1, R_MAX + 1, n))


def effective_deltas(tree):
    """Served update per pair under the alpha/rank convention (alpha
    dropped): (1/rank) * B @ A.  The space in which rank-changing
    aggregation must be compared."""
    out = {}
    for k, pair in tree.items():
        r = max(int(np.max(np.asarray(pair["rank"]))), 1)
        out[k] = (np.asarray(pair["B"], np.float32)
                  @ np.asarray(pair["A"], np.float32)) / r
    return out


def assert_delta_close(a, b, rtol=1e-3, atol=1e-4):
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=rtol, atol=atol,
                                   err_msg=f"pair {k}")


def mean_effective_delta(adapters, weights):
    w = np.asarray(weights, np.float32)
    what = w / w.sum()
    out = {}
    for k in adapters[0]:
        out[k] = sum(
            what[i] * np.asarray(ad[k]["B"], np.float32)
            @ np.asarray(ad[k]["A"], np.float32) / max(int(ad[k]["rank"]), 1)
            for i, ad in enumerate(adapters))
    return out


# ------------------------------------------------------------ registration --
def test_exactly_ten_strategies_registered():
    assert tuple(list_strategies()) == ALL_METHODS


def test_every_strategy_declares_its_contracts():
    for m in ALL_METHODS:
        s = get_strategy(m)
        assert s.rank_contract in ("fixed", "stacked"), m
        assert s.fedavg_equivalence in ("factors", "product", None), m
        assert s.robustness in ("none", "clipped", "trimmed", "median"), m


def test_robustness_contracts_match_registry():
    for m in ALL_METHODS:
        want = m.removeprefix("rbla_") if m in ROBUST_METHODS else "none"
        assert get_strategy(m).robustness == want, m


# ------------------------------------------- homogeneous cohorts == FedAvg --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(2, 4),
       rank=st.integers(1, R_MAX), method=st.sampled_from(ALL_METHODS))
def test_homogeneous_cohort_reduces_to_fedavg(seed, n, rank, method):
    s = configured(method)
    if s.fedavg_equivalence is None:        # rbla_norm / svd: deliberate
        return
    adapters, ranks, w = make_cohort(seed, (rank,) * n)
    out = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=ranks, backend="ref")
    if s.fedavg_equivalence == "factors":
        ref = get_strategy("fedavg").aggregate_adapters(
            adapters, w, r_max=R_MAX, client_ranks=ranks, backend="ref")
        for k in SPECS:
            for f in ("A", "B"):
                np.testing.assert_allclose(
                    np.asarray(out[k][f]), np.asarray(ref[k][f]),
                    rtol=1e-4, atol=1e-5, err_msg=f"{method} {k} {f}")
    else:                                   # "product": flora
        assert_delta_close(effective_deltas(out),
                           mean_effective_delta(adapters, w))


# ---------------------------------------------------- permutation in order --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(2, 5),
       method=st.sampled_from(ALL_METHODS))
def test_client_order_permutation_invariance(seed, n, method):
    s = configured(method)
    ranks = random_ranks(seed + 1, n)
    adapters, rvec, w = make_cohort(seed, ranks)
    perm = np.random.default_rng(seed + 2).permutation(n)
    out = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend="ref")
    out_p = s.aggregate_adapters(
        [adapters[i] for i in perm], w[jnp.asarray(perm)], r_max=R_MAX,
        client_ranks=rvec[jnp.asarray(perm)], backend="ref")
    # product space: flora permutes rank segments, svd's factors are only
    # unique up to the truncation basis -- the served update must agree
    assert_delta_close(effective_deltas(out), effective_deltas(out_p))
    for k in SPECS:
        np.testing.assert_array_equal(np.asarray(out[k]["rank"]),
                                      np.asarray(out_p[k]["rank"]))


# ------------------------------------------------- weights stay convex ------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(2, 4),
       scale=st.sampled_from([0.25, 3.0, 17.0]),
       method=st.sampled_from(ALL_METHODS))
def test_weight_scale_invariance(seed, n, scale, method):
    """Scaling every client weight by the same constant (e.g. reporting
    n_examples in different units) must not change the aggregate: the
    combination is convex."""
    s = configured(method)
    adapters, rvec, w = make_cohort(seed, random_ranks(seed + 3, n))
    out = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend="ref")
    out_s = s.aggregate_adapters(adapters, w * scale, r_max=R_MAX,
                                 client_ranks=rvec, backend="ref")
    assert_delta_close(effective_deltas(out), effective_deltas(out_s),
                       rtol=1e-3, atol=1e-5)


# ------------------------------------------------------ the rank contract --
@pytest.mark.parametrize("method", ALL_METHODS)
def test_output_matches_declared_rank_contract(method):
    s = configured(method)
    adapters, rvec, w = make_cohort(11, (1, 3, R_MAX, 2))
    out = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend="ref")
    if s.rank_contract == "fixed":
        for k, (fo, fi) in SPECS.items():
            assert out[k]["A"].shape == (R_MAX, fi)
            assert out[k]["B"].shape == (fo, R_MAX)
            assert int(out[k]["rank"]) == R_MAX
    else:
        r_sum = int(np.asarray(rvec).sum())
        assert r_sum <= BIG_CAP
        for k, (fo, fi) in SPECS.items():
            assert out[k]["A"].shape == (BIG_CAP, fi)   # storage = the cap
            assert out[k]["B"].shape == (fo, BIG_CAP)
            assert int(out[k]["rank"]) == r_sum         # live rank = sum


def test_stacked_contract_counts_prev_as_contributor():
    s = configured("flora")
    adapters, rvec, w = make_cohort(12, (2, 3))
    first = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                 client_ranks=rvec, backend="ref")
    assert int(first["fc1"]["rank"]) == 5
    second = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                  client_ranks=rvec, prev_global=first,
                                  backend="ref")
    assert int(second["fc1"]["rank"]) == 5 + 5


def test_stacked_contract_caps_to_r_max_via_svd():
    s = get_strategy("flora").with_options(stack_r_cap=R_MAX)
    adapters, rvec, w = make_cohort(13, (4, 5, 6))     # sum 15 > cap 6
    out = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend="ref")
    for k, (fo, fi) in SPECS.items():
        assert out[k]["A"].shape == (R_MAX, fi)
        assert int(out[k]["rank"]) == R_MAX
    # the re-projection is the best rank-R_MAX factorization of the
    # convex product-space combination
    want = mean_effective_delta(adapters, w)
    for k in SPECS:
        u, sv, vt = np.linalg.svd(want[k], full_matrices=False)
        trunc = (u[:, :R_MAX] * sv[:R_MAX]) @ vt[:R_MAX]
        np.testing.assert_allclose(effective_deltas(out)[k], trunc,
                                   rtol=1e-3, atol=1e-4)


# ------------------------------------------- flora stacking is noise-free --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(1, 5))
def test_flora_stacking_is_product_exact(seed, n):
    """The central FLoRA claim: below the cap, stacking introduces *no*
    aggregation noise -- the served update is exactly the convex
    combination of client updates, for arbitrary heterogeneous ranks."""
    s = configured("flora")
    adapters, rvec, w = make_cohort(seed, random_ranks(seed + 7, n))
    out = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend="ref")
    assert_delta_close(effective_deltas(out),
                       mean_effective_delta(adapters, w),
                       rtol=1e-4, atol=1e-5)


# ------------------------------------- svd parity under the packed lowering --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(1, 5))
def test_svd_parity_holds_under_packed_lowering(seed, n):
    """The factored-engine lowering (batched per-bucket SVD, no dense
    delta) must reproduce the per-leaf oracle exactly, and agree with
    the explicit dense fallback in product space, over random rank
    multisets."""
    s = get_strategy("svd").with_options()
    adapters, rvec, w = make_cohort(seed, random_ranks(seed + 9, n))
    got = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend="ref")
    want = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=rvec, backend="ref",
                                use_plan=False)
    for k in SPECS:
        for f in ("A", "B", "rank"):
            np.testing.assert_allclose(
                np.asarray(got[k][f], np.float32),
                np.asarray(want[k][f], np.float32),
                rtol=1e-4, atol=1e-5, err_msg=f"plan vs oracle {k} {f}")
    # the dense fallback is the binding oracle in product space (factors
    # are only unique up to the truncation basis)
    dense = get_strategy("svd").with_options(
        svd_method="dense").aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=rvec, backend="ref")
    assert_delta_close(effective_deltas(got), effective_deltas(dense),
                       rtol=1e-3, atol=1e-4)


# ----------------------------------- every backend: parity or loud refusal --
@pytest.mark.parametrize("method", ALL_METHODS)
@pytest.mark.parametrize("backend", ["pallas", "distributed"])
def test_backend_parity_or_documented_refusal(method, backend):
    s = configured(method)
    adapters, rvec, w = make_cohort(21, (2, 4, R_MAX))
    ref = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend="ref")
    supported = (s.supports_pallas if backend == "pallas"
                 else s.supports_distributed)
    if not supported:
        with pytest.raises(NotImplementedError, match=method):
            s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                 client_ranks=rvec, backend=backend)
        return
    got = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                               client_ranks=rvec, backend=backend)
    for k in SPECS:
        for f in ("A", "B", "rank"):
            np.testing.assert_allclose(
                np.asarray(ref[k][f], np.float32),
                np.asarray(got[k][f], np.float32),
                rtol=1e-4, atol=1e-5, err_msg=f"{method}/{backend} {k} {f}")


# --------------------------------------------- the robustness contract ------
def scale_client(adapters, i, factor):
    """Return the cohort with client ``i``'s float factors scaled."""
    out = list(adapters)
    out[i] = jax.tree.map(
        lambda x: x * factor if x.dtype == jnp.float32 else x, out[i])
    return out


def max_factor_dist(a, b):
    return max(float(np.max(np.abs(np.asarray(a[k][f], np.float32)
                                   - np.asarray(b[k][f], np.float32))))
               for k in SPECS for f in ("A", "B"))


@pytest.mark.parametrize("method", ROBUST_METHODS)
def test_breakdown_single_adversary_moves_global_boundedly(method):
    """One malicious client uploading 1e6x-norm factors moves the robust
    aggregate by a bounded amount; the mean family follows the adversary
    to ~1e5.  Homogeneous full-rank cohort: every row has 5 owners, so
    trimming (k >= 1) and the median (majority honest) both exclude the
    outlier, and clipping caps its mass contribution."""
    s = configured(method)
    adapters, rvec, w = make_cohort(41, (R_MAX,) * 5)
    honest = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                  client_ranks=rvec, backend="ref")
    attacked_cohort = scale_client(adapters, 0, 1e6)
    attacked = s.aggregate_adapters(attacked_cohort, w, r_max=R_MAX,
                                    client_ranks=rvec, backend="ref")
    move = max_factor_dist(honest, attacked)
    assert move < 50.0, f"{method} moved {move} under one adversary"
    mean = get_strategy("rbla")
    mean_move = max_factor_dist(
        mean.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=rvec, backend="ref"),
        mean.aggregate_adapters(attacked_cohort, w, r_max=R_MAX,
                                client_ranks=rvec, backend="ref"))
    assert mean_move > 1e4, "the mean family should follow the adversary"


def test_breakdown_bound_holds_on_every_supported_backend():
    s = configured("rbla_median")
    adapters, rvec, w = make_cohort(43, (R_MAX,) * 5)
    attacked_cohort = scale_client(adapters, 1, 1e6)
    for backend in ("ref", "pallas"):
        honest = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                      client_ranks=rvec, backend=backend)
        attacked = s.aggregate_adapters(attacked_cohort, w, r_max=R_MAX,
                                        client_ranks=rvec, backend=backend)
        assert max_factor_dist(honest, attacked) < 50.0, backend


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(2, 5))
def test_clipped_with_loose_clip_matches_rbla(seed, n):
    """Honest-case parity: while every rank-row norm is under the clip,
    rbla_clipped IS rbla -- heterogeneous ranks, prev retention and all."""
    ranks = random_ranks(seed + 13, n)
    adapters, rvec, w = make_cohort(seed, ranks)
    prev, _, _ = make_cohort(seed + 1, (R_MAX,))
    prev = get_strategy("rbla").aggregate_adapters(
        prev, jnp.ones((1,), jnp.float32), r_max=R_MAX,
        client_ranks=jnp.asarray([R_MAX], jnp.int32), backend="ref")
    s = get_strategy("rbla_clipped").with_options(clip_norm=1e9)
    got = s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=rvec,
                               prev_global=prev, backend="ref")
    want = get_strategy("rbla").aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=rvec, prev_global=prev,
        backend="ref")
    for k in SPECS:
        for f in ("A", "B"):
            np.testing.assert_allclose(
                np.asarray(got[k][f]), np.asarray(want[k][f]),
                rtol=1e-5, atol=1e-6, err_msg=f"{k} {f}")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(2, 5))
def test_trimmed_without_trimming_matches_unweighted_rbla(seed, n):
    """Honest-case parity: trim_frac=0 + uniform weights reduce the
    trimmed mean to the plain per-row owner mean (= rbla with uniform
    weights)."""
    adapters, rvec, w = make_cohort(seed, random_ranks(seed + 17, n))
    ones = jnp.ones_like(w)
    got = get_strategy("rbla_trimmed").with_options(
        trim_frac=0.0).aggregate_adapters(
        adapters, ones, r_max=R_MAX, client_ranks=rvec, backend="ref")
    want = get_strategy("rbla").aggregate_adapters(
        adapters, ones, r_max=R_MAX, client_ranks=rvec, backend="ref")
    for k in SPECS:
        for f in ("A", "B"):
            np.testing.assert_allclose(
                np.asarray(got[k][f]), np.asarray(want[k][f]),
                rtol=1e-5, atol=1e-6, err_msg=f"{k} {f}")


@pytest.mark.parametrize("method", ROBUST_METHODS)
def test_identical_uploads_match_mean_family(method):
    """Honest-case parity: when every client uploads the same adapters,
    any robust reduction returns that common value, exactly like rbla."""
    s = configured(method)
    one, _, _ = make_cohort(47, (3,))
    adapters = [one[0]] * 4
    rvec = jnp.asarray([3] * 4, jnp.int32)
    w = jnp.asarray([0.5, 1.0, 2.0, 1.5], jnp.float32)
    got = s.aggregate_adapters(adapters, w, r_max=R_MAX, client_ranks=rvec,
                               backend="ref")
    want = get_strategy("rbla").aggregate_adapters(
        adapters, w, r_max=R_MAX, client_ranks=rvec, backend="ref")
    for k in SPECS:
        for f in ("A", "B"):
            np.testing.assert_allclose(
                np.asarray(got[k][f]), np.asarray(want[k][f]),
                rtol=1e-5, atol=1e-6, err_msg=f"{method} {k} {f}")


@pytest.mark.parametrize("method", ROBUST_METHODS)
def test_partial_round_dropout_is_deterministic_and_retains_prev(method):
    """A dropout round (only some of the cohort reports) is well-defined:
    aggregating the survivors twice is bitwise identical, and rank rows
    no survivor owns retain the previous global."""
    s = configured(method)
    adapters, rvec, w = make_cohort(53, (2, 4, R_MAX, 3))
    prev = s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                client_ranks=rvec, backend="ref")
    keep = jnp.asarray([0, 3])                 # survivors: ranks 2 and 3
    survivors = [adapters[0], adapters[3]]
    out1 = s.aggregate_adapters(survivors, w[keep], r_max=R_MAX,
                                client_ranks=rvec[keep], prev_global=prev,
                                backend="ref")
    out2 = s.aggregate_adapters(survivors, w[keep], r_max=R_MAX,
                                client_ranks=rvec[keep], prev_global=prev,
                                backend="ref")
    for k in SPECS:
        for f in ("A", "B"):
            np.testing.assert_array_equal(np.asarray(out1[k][f]),
                                          np.asarray(out2[k][f]),
                                          err_msg=f"{method} {k} {f}")
        # the survivors have ranks 2 and 3, so rows >= 3 have no owner
        np.testing.assert_array_equal(
            np.asarray(out1[k]["A"])[3:], np.asarray(prev[k]["A"])[3:],
            err_msg=f"{method} {k} prev retention")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(1, 6),
       d=st.sampled_from([3, 17, 130]),
       mode=st.sampled_from(["clipped", "trimmed", "median"]))
def test_packed_robust_kernel_matches_ref(seed, n, d, mode):
    from repro.kernels import (packed_robust, packed_robust_ref,
                               packed_robust_xla)
    rng = np.random.default_rng(seed)
    r = R_MAX
    x = jnp.asarray(rng.normal(size=(n, r, d)), jnp.float32)
    masks = jnp.asarray(rng.random((n, r)) < 0.7, jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    prev = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
    want = packed_robust_ref(x, masks, w, prev, mode=mode, clip_norm=2.5,
                             trim_frac=0.25)
    got = packed_robust(x, masks, w, prev, mode=mode, clip_norm=2.5,
                        trim_frac=0.25, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # the fused-XLA network lowering (interpret-mode plan path) obeys
    # the same oracle
    got_xla = packed_robust_xla(x, masks, w, prev, mode=mode,
                                clip_norm=2.5, trim_frac=0.25)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ----------------------------------------------- flora_stack kernel oracle --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(1, 5),
       d=st.sampled_from([3, 17, 130]))
def test_flora_stack_kernel_matches_ref(seed, n, d):
    from repro.kernels import flora_stack, flora_stack_ref
    rng = np.random.default_rng(seed)
    r_st = R_MAX
    segs = tuple(int(v) for v in rng.integers(1, r_st + 1, n))
    out_rows = sum(segs) + int(rng.integers(0, 4))
    x = jnp.asarray(rng.normal(size=(n, r_st, d)), jnp.float32)
    scales = jnp.asarray(rng.uniform(0.1, 2.0, n), jnp.float32)
    got = flora_stack(x, scales, segs=segs, out_rows=out_rows,
                      interpret=True)
    want = flora_stack_ref(x, scales, segs, out_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
