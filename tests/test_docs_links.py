"""Docs stay wired: required pages exist and intra-repo links resolve.

The CI docs leg runs ``scripts/check_docs_links.py`` standalone; this
wrapper keeps the same check in the tier-1 suite so a broken link fails
locally too.
"""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _checker():
    sys.path.insert(0, str(REPO / "scripts"))
    try:
        import check_docs_links
    finally:
        sys.path.pop(0)
    return check_docs_links


def test_required_docs_exist():
    for p in ("README.md", "docs/async.md", "docs/strategies.md",
              "ROADMAP.md", "CHANGES.md"):
        assert (REPO / p).exists(), f"missing {p}"


def test_no_broken_intra_repo_links():
    mod = _checker()
    failures = {str(md): mod.broken_links(md) for md in mod.doc_files()}
    failures = {k: v for k, v in failures.items() if v}
    assert not failures, f"broken doc links: {failures}"


def test_checker_flags_a_broken_link(tmp_path):
    mod = _checker()
    md = tmp_path / "bad.md"
    md.write_text("[gone](does/not/exist.md) and [ok](https://x.org)")
    assert mod.broken_links(md) == ["does/not/exist.md"]
