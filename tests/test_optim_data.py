"""Unit tests: optimizers, schedules, synthetic data, pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (make_dataset, make_lm_dataset,
                        sample_batch_indices)
from repro.optim import (adam, adamw, apply_updates, clip_by_global_norm,
                         constant, cosine, sgd)


def _quadratic_converges(opt, steps=200):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["x"] - target) ** 2)
    g = jax.grad(loss)
    for _ in range(steps):
        updates, state = opt.update(g(params), state, params)
        params = apply_updates(params, updates)
    return float(loss(params))


def test_sgd_converges():
    assert _quadratic_converges(sgd(0.1)) < 1e-6


def test_sgd_momentum_converges():
    assert _quadratic_converges(sgd(0.05, momentum=0.9)) < 1e-6


def test_adam_converges():
    assert _quadratic_converges(adam(0.1)) < 1e-4


def test_adamw_decays_toward_zero():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"x": jnp.ones(3) * 10.0}
    state = opt.init(params)
    zeros = {"x": jnp.zeros(3)}
    for _ in range(100):
        updates, state = opt.update(zeros, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["x"]).max()) < 1.0


def test_clip_by_global_norm():
    opt = clip_by_global_norm(sgd(1.0), max_norm=1.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    big = {"x": jnp.full(4, 100.0)}
    updates, _ = opt.update(big, state, params)
    assert abs(float(jnp.linalg.norm(updates["x"])) - 1.0) < 1e-5


def test_schedules():
    c = constant(0.1)
    assert float(c(jnp.asarray(5))) == pytest.approx(0.1)
    sch = cosine(1.0, 100, warmup=10)
    assert float(sch(jnp.asarray(5))) == pytest.approx(0.5, abs=0.01)
    assert float(sch(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    mid = float(sch(jnp.asarray(55)))
    assert 0.4 < mid < 0.6


# ------------------------------------------------------------------ data ----
def test_datasets_shapes_and_determinism():
    a = make_dataset("mnist", 20, seed=42)
    b = make_dataset("mnist", 20, seed=42)
    np.testing.assert_array_equal(a.x, b.x)
    assert a.x.shape == (200, 28, 28, 1)
    c = make_dataset("cifar", 10, seed=42)
    assert c.x.shape == (100, 32, 32, 3)
    # train and test splits differ
    t = make_dataset("mnist", 20, seed=42, split="test")
    assert not np.allclose(a.x[:10], t.x[:10])
    # balanced labels
    counts = np.bincount(a.y, minlength=10)
    assert (counts == 20).all()


def test_lm_dataset_learnable_structure():
    toks = make_lm_dataset(64, 256, 8, seed=0, p_follow=1.0)
    # deterministic bigram chain: next token is a function of prev
    trans = {}
    for seq in toks:
        for a, b in zip(seq[:-1], seq[1:]):
            assert trans.setdefault(int(a), int(b)) == int(b)


def test_sample_batch_indices_bounds():
    idx = sample_batch_indices(jax.random.PRNGKey(0),
                               jnp.asarray(17), 8, 5)
    assert idx.shape == (5, 8)
    assert int(idx.max()) < 17 and int(idx.min()) >= 0
