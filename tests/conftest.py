import sys

import pytest

try:                                    # property tests prefer the real thing
    import hypothesis                   # noqa: F401
except ImportError:                     # container without hypothesis: stub it
    import _hypothesis_stub as _stub

    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
