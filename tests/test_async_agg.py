"""Async aggregation service tests.

The load-bearing guarantee is the **parity gate**: for every registered
strategy, folding a cohort's updates one at a time through the
:class:`AsyncAggregator` with zero staleness reproduces the one-shot
``aggregate(state, updates, weights)`` -- exactly (up to float
reassociation) on the ref backend, and within the strategy parity
tolerance or with the documented refusal on pallas/distributed.  Plus:
staleness schedules are monotone discounts, the semi-async buffer
flushes on K and on deadline, staleness actually down-weights (flora
keeps the stale contributor), and the event-driven simulator is finite
and deterministic.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from repro.fl import (AsyncAggregator, AsyncFLConfig, STALENESS_SCHEDULES,
                      UpdateBuffer, make_staleness_fn, run_async_simulation,
                      run_simulation)
from repro.fl.simulator import FLConfig
from repro.lora import init_adapters

from _cohorts import R_MAX, SPECS, assert_trees_close, hetero_cohort

jax.config.update("jax_platform_name", "cpu")


def make_state(strategy, seed=99):
    r_storage = strategy.server_storage_rank(R_MAX) or R_MAX
    prev = init_adapters(jax.random.PRNGKey(seed), SPECS, r_storage, R_MAX)
    base = {"b": jnp.zeros((4,), jnp.float32)}
    return ServerState(adapters=prev, base_trainable=base, r_max=R_MAX)


def configured(method):
    s = get_strategy(method)
    if s.rank_contract == "stacked":
        s = s.with_options(stack_r_cap=256)   # wide: no mid-test reproject
    return s


# ------------------------------------------------------------ parity gate --
ALL_METHODS = ["rbla", "zeropad", "fedavg", "rbla_ranked", "rbla_norm",
               "svd", "flora", "rbla_clipped", "rbla_trimmed",
               "rbla_median"]


def fold_cohort(strategy, backend):
    """Fold the cohort one update at a time; return (async, sync) states."""
    adapters, ranks, w, bases = hetero_cohort(5, seed=3, with_bases=True)
    updates = [ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                            n_examples=float(w[i]), rank=int(ranks[i]))
               for i in range(len(ranks))]
    sync = strategy.aggregate(make_state(strategy), updates, weights=w,
                              backend=backend)
    agg = AsyncAggregator(strategy, make_state(strategy),
                          staleness="constant", backend=backend)
    for u in updates:
        agg.submit(u)                      # model_version=None: staleness 0
    return agg.state, sync


@pytest.mark.parametrize("method", ALL_METHODS)
def test_zero_staleness_fold_matches_sync_aggregate_ref(method):
    """THE parity gate (ref backend, tight tolerance): one-at-a-time
    folding with zero staleness == the one-shot cohort aggregate."""
    got, want = fold_cohort(configured(method), "ref")
    assert_trees_close(got.adapters, want.adapters, 2e-5, 2e-6, method)
    assert_trees_close(got.base_trainable, want.base_trainable,
                       2e-5, 2e-6, method)


@pytest.mark.parametrize("method", ALL_METHODS)
@pytest.mark.parametrize("backend", ["pallas", "distributed"])
def test_fold_backend_parity_or_documented_refusal(method, backend):
    """Parity-or-refusal, matching the registry convention: backends the
    strategy supports agree within parity tolerance; unsupported ones
    raise the documented NotImplementedError."""
    s = configured(method)
    supported = (s.supports_pallas if backend == "pallas"
                 else s.supports_distributed)
    if not supported:
        with pytest.raises(NotImplementedError, match=method):
            fold_cohort(s, backend)
        return
    got, want = fold_cohort(s, backend)
    assert_trees_close(got.adapters, want.adapters, 1e-4, 1e-5,
                       f"{method}/{backend}")


def test_fold_hook_direct_matches_async_service():
    """The strategy-level fold hook is what the service drives: calling
    it directly reproduces the AsyncAggregator's fully-async state."""
    s = get_strategy("rbla")
    adapters, ranks, w, bases = hetero_cohort(4, seed=7, with_bases=True)
    updates = [ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                            n_examples=float(w[i]), rank=int(ranks[i]))
               for i in range(len(ranks))]
    agg = AsyncAggregator(s, make_state(s), staleness="constant")
    st, fs = make_state(s), s.init_fold(make_state(s))
    for u in updates:
        agg.submit(u)
        st, fs = s.fold(st, u, fold_state=fs, backend="ref")
    assert_trees_close(agg.state.adapters, st.adapters, 1e-6, 1e-7)


# ------------------------------------------------------ staleness schedules --
@pytest.mark.parametrize("name", sorted(STALENESS_SCHEDULES))
def test_staleness_schedules_are_monotone_discounts(name):
    """Every schedule: s(0) == 1, s in (0, 1], monotone non-increasing."""
    fn = make_staleness_fn(name, a=0.5, b=4.0)
    taus = np.arange(0, 50)
    vals = np.asarray([fn(float(t)) for t in taus])
    assert vals[0] == pytest.approx(1.0)
    assert np.all(vals > 0) and np.all(vals <= 1.0)
    assert np.all(np.diff(vals) <= 1e-12)


def test_polynomial_and_hinge_shapes():
    poly = make_staleness_fn("polynomial", a=0.5)
    assert poly(3.0) == pytest.approx((1 + 3.0) ** -0.5)
    hinge = make_staleness_fn("hinge", a=2.0, b=4.0)
    assert hinge(4.0) == pytest.approx(1.0)      # inside the grace period
    assert hinge(6.0) == pytest.approx(1.0 / (2.0 * 2.0 + 1.0))


def test_unknown_schedule_and_bad_params_raise():
    with pytest.raises(ValueError, match="unknown staleness"):
        make_staleness_fn("exponential_not_a_schedule")
    with pytest.raises(ValueError, match="decay"):
        make_staleness_fn("polynomial", a=0.0)
    fn = make_staleness_fn(lambda tau: 0.5)      # callables pass through
    assert fn(0) == 0.5


def test_stale_update_moves_state_less_than_fresh():
    """The same update folded at staleness 10 must move the server less
    than at staleness 0 (the whole point of the discount)."""
    s = get_strategy("rbla")
    adapters, ranks, w, bases = hetero_cohort(2, seed=11, r_lo=R_MAX,
                                              with_bases=True)
    mk = lambda: make_state(s)
    upd = ClientUpdate(adapters=adapters[1], base_trainable=bases[1],
                       n_examples=4.0, rank=int(ranks[1]))
    warm = ClientUpdate(adapters=adapters[0], base_trainable=bases[0],
                        n_examples=4.0, rank=int(ranks[0]))

    def drift(tau):
        agg = AsyncAggregator(s, mk(), staleness="polynomial",
                              staleness_a=0.5)
        agg.submit(warm)                          # version -> 1
        before = agg.state.adapters["fc1"]["A"]
        # a client that pulled at version 1 - tau reports now
        agg.submit(upd, model_version=agg.version - int(tau))
        return float(jnp.linalg.norm(agg.state.adapters["fc1"]["A"]
                                     - before))
    assert drift(10) < drift(0)


def test_flora_stale_contributor_downweighted_not_dropped():
    """flora's async contract: a stale client still lands in the stack
    (rank grows by its rank) but its B-column mass shrinks."""
    s = configured("flora")
    adapters, ranks, w, bases = hetero_cohort(2, seed=13, r_lo=2, r_hi=4,
                                              with_bases=True)
    upd = ClientUpdate(adapters=adapters[1], base_trainable=bases[1],
                       n_examples=1.0, rank=int(ranks[1]))
    warm = ClientUpdate(adapters=adapters[0], base_trainable=bases[0],
                        n_examples=1.0, rank=int(ranks[0]))

    def stacked_mass(tau):
        agg = AsyncAggregator(s, make_state(s), staleness="polynomial",
                              staleness_a=1.0)
        agg.submit(warm)
        r_before = int(agg.state.adapters["fc1"]["rank"])
        agg.submit(upd, model_version=agg.version - int(tau))
        r_after = int(agg.state.adapters["fc1"]["rank"])
        assert r_after == r_before + int(ranks[1])     # stacked, not dropped
        # the stale contributor's rows are the trailing ones (arrival order)
        B = agg.state.adapters["fc1"]["B"]
        return float(jnp.linalg.norm(B[:, r_before:r_after]))
    assert stacked_mass(20) < stacked_mass(0)


def test_flora_direct_fold_streaming_form():
    """FloraStrategy.fold called directly (the documented streaming
    approximation): every fold stacks the arrival (rank grows by its
    rank), and with uniform masses the prev bookkeeping coincides with
    the one-shot cohort aggregate, so streaming == joint exactly."""
    s = configured("flora")
    adapters, ranks, w, bases = hetero_cohort(3, seed=23, with_bases=True)
    updates = [ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                            n_examples=2.0, rank=int(ranks[i]))
               for i in range(len(ranks))]
    st, fs = make_state(s), s.init_fold(make_state(s))
    live = R_MAX                               # prev global's live rank
    for i, u in enumerate(updates):
        st, fs = s.fold(st, u, fold_state=fs, backend="ref")
        live += int(ranks[i])
        assert int(st.adapters["fc1"]["rank"]) == live
        assert fs.n_folds == i + 1
    want = s.aggregate(make_state(s), updates, weights=[2.0] * 3,
                       backend="ref")
    assert_trees_close(st.adapters, want.adapters, 1e-5, 1e-6,
                       "flora streaming vs joint (uniform masses)")


def test_flora_streams_without_replay():
    """flora declares supports_incremental now: the service streams its
    fold (segment-ledger re-scaling) instead of replaying from the
    anchor, and the zero-staleness gate above holds exactly."""
    s = configured("flora")
    assert s.supports_incremental
    adapters, ranks, w, bases = hetero_cohort(4, seed=29, with_bases=True)
    agg = AsyncAggregator(s, make_state(s), staleness="constant")
    for i in range(4):
        agg.submit(ClientUpdate(adapters=adapters[i],
                                base_trainable=bases[i],
                                n_examples=float(w[i]),
                                rank=int(ranks[i])))
    assert agg.n_folded == 4 and len(agg._replay) == 0


# ------------------------------------------------- wall-clock staleness ----
def test_wall_clock_staleness_discounts_by_elapsed_time():
    """staleness_clock='wall': the same upload moves the server less the
    longer ago its global was pulled, regardless of version churn."""
    s = get_strategy("rbla")
    adapters, ranks, w, bases = hetero_cohort(2, seed=31, r_lo=R_MAX,
                                              with_bases=True)
    warm = ClientUpdate(adapters=adapters[0], base_trainable=bases[0],
                        n_examples=4.0, rank=int(ranks[0]))
    upd = ClientUpdate(adapters=adapters[1], base_trainable=bases[1],
                       n_examples=4.0, rank=int(ranks[1]))

    def drift(age_s):
        agg = AsyncAggregator(s, make_state(s), staleness="polynomial",
                              staleness_a=0.5, staleness_clock="wall")
        agg.submit(warm, now=100.0, pulled_at=100.0)
        before = agg.state.adapters["fc1"]["A"]
        agg.submit(upd, now=100.0, pulled_at=100.0 - age_s)
        return float(jnp.linalg.norm(agg.state.adapters["fc1"]["A"]
                                     - before))
    drifts = [drift(a) for a in (0.0, 5.0, 50.0)]
    assert drifts[0] > drifts[1] > drifts[2]


@pytest.mark.parametrize("clock", ["version", "wall"])
def test_staleness_schedule_monotone_in_both_clocks(clock):
    """The effective weight s(tau) * n is monotone non-increasing in tau
    whichever clock measures tau."""
    s = get_strategy("fedavg")
    base = {"b": jnp.zeros((4,), jnp.float32)}
    upd = ClientUpdate(adapters=None, base_trainable={"b": jnp.ones(4)},
                       n_examples=2.0)
    weights = []
    for tau in range(0, 30, 3):
        agg = AsyncAggregator(
            s, ServerState(adapters=None, base_trainable=base, round=50),
            staleness="polynomial", staleness_a=0.7, staleness_clock=clock)
        if clock == "version":
            weights.append(agg.staleness_weight(
                agg.version - (agg.version - tau)))
        else:
            weights.append(agg.staleness_weight(float(tau)))
        if clock == "wall":     # exercises the submit-side tau path too
            agg.submit(upd, now=float(tau), pulled_at=0.0)
    assert all(a >= b for a, b in zip(weights, weights[1:]))
    assert weights[0] == pytest.approx(1.0)


def test_unknown_staleness_clock_raises():
    s = get_strategy("rbla")
    with pytest.raises(ValueError, match="staleness_clock"):
        AsyncAggregator(s, make_state(s), staleness_clock="lamport")


def test_wall_clock_skew_clamps_staleness_at_zero():
    """Regression: a client whose pull timestamp is *ahead* of the server
    clock (clock skew) must be treated as fresh -- negative tau would
    feed s(tau) > 1 into the weight (inflating the skewed client) and
    trip the schedule range check."""
    s = get_strategy("rbla")
    adapters, ranks, w, bases = hetero_cohort(2, seed=41, r_lo=R_MAX,
                                              with_bases=True)
    upd = ClientUpdate(adapters=adapters[1], base_trainable=bases[1],
                       n_examples=4.0, rank=int(ranks[1]))

    def folded(pulled_at):
        agg = AsyncAggregator(s, make_state(s), staleness="polynomial",
                              staleness_a=0.5, staleness_clock="wall")
        agg.submit(upd, now=100.0, pulled_at=pulled_at)
        assert agg.staleness_sum >= 0.0
        return np.asarray(agg.state.adapters["fc1"]["A"])
    # skewed (pulled "in the future") == fresh, bit-for-bit
    np.testing.assert_array_equal(folded(150.0), folded(100.0))


# ------------------------------------------------- ingestion validation ----
def _one_update(seed=43):
    adapters, ranks, w, bases = hetero_cohort(2, seed=seed, r_lo=R_MAX,
                                              with_bases=True)
    return ClientUpdate(adapters=adapters[0], base_trainable=bases[0],
                        n_examples=4.0, rank=int(ranks[0]))


@pytest.mark.parametrize("n_examples", [0.0, -3.0, float("nan"),
                                        float("inf")])
def test_submit_rejects_invalid_example_counts(n_examples):
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s))
    upd = dataclasses.replace(_one_update(), n_examples=n_examples)
    with pytest.raises(ValueError, match="n_examples"):
        agg.submit(upd)
    assert agg.n_received == 0 and len(agg.buffer) == 0
    assert agg.version == 0


@pytest.mark.parametrize("poison", [float("nan"), float("inf")])
def test_submit_rejects_non_finite_tensors(poison):
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s))
    upd = _one_update()
    bad = jax.tree.map(lambda x: x, upd.adapters)
    bad["fc1"]["A"] = bad["fc1"]["A"].at[0, 0].set(poison)
    with pytest.raises(ValueError, match="non-finite"):
        agg.submit(dataclasses.replace(upd, adapters=bad))
    base = {"b": jnp.full((4,), poison, jnp.float32)}
    with pytest.raises(ValueError, match="non-finite"):
        agg.submit(dataclasses.replace(upd, base_trainable=base))
    assert agg.n_received == 0 and len(agg.buffer) == 0


def test_zero_mass_flush_is_a_noop():
    """A batch whose staleness-discounted masses sum to 0 has no convex
    combination: the flush must drop it without advancing (or NaN-ing)
    the state."""
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s), buffer_size=2, deadline=1.0)
    before = np.asarray(agg.state.adapters["fc1"]["A"])
    upd = _one_update()
    agg.buffer.add(upd, weight=0.0, now=0.0)     # mass underflowed to 0
    agg.buffer.add(upd, weight=0.0, now=0.0)
    assert agg.buffer.total_weight() == 0.0
    agg.flush(now=10.0)
    assert agg.version == 0 and agg.n_flushes == 0
    assert agg.n_dropped == 2 and len(agg.buffer) == 0
    np.testing.assert_array_equal(
        before, np.asarray(agg.state.adapters["fc1"]["A"]))
    assert np.isfinite(np.asarray(agg.state.adapters["fc1"]["A"])).all()


# --------------------------------------------------- quantized transport ----
def _encoded_update(codec_name, seed=43):
    from repro.core import codec
    return codec.encode_update(_one_update(seed=seed), codec_name)


@pytest.mark.parametrize("poison", [float("nan"), 0.0, -1.0])
def test_submit_rejects_bad_quantization_scales(poison):
    """Scale sanity sits next to the NaN/inf gate: non-finite or
    non-positive scales name the scale (not the generic tensor message)
    and leave every service counter untouched."""
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s))
    upd = _encoded_update("int8")
    bad = {k: dict(v) for k, v in upd.adapters.items()}
    bad["fc1"]["A_scale"] = bad["fc1"]["A_scale"].at[0].set(poison)
    with pytest.raises(ValueError, match="scale"):
        agg.submit(dataclasses.replace(upd, adapters=bad))
    assert agg.n_received == 0 and len(agg.buffer) == 0
    assert agg.wire_bytes_received == 0 and agg.version == 0


def test_submit_rejects_overflowing_decoded_norm():
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s))
    upd = _encoded_update("int8")
    bad = {k: dict(v) for k, v in upd.adapters.items()}
    bad["fc2"]["B_scale"] = bad["fc2"]["B_scale"].at[0].set(3.0e36)
    with pytest.raises(ValueError, match="overflow"):
        agg.submit(dataclasses.replace(upd, adapters=bad))
    assert agg.n_received == 0 and len(agg.buffer) == 0


def test_codec_negotiation_rejects_unlisted_wire_formats():
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s), codecs="none")
    with pytest.raises(ValueError, match="codec"):
        agg.submit(_encoded_update("int8"))
    with pytest.raises(ValueError, match="codec"):
        agg.submit(_encoded_update("bf16"))
    assert agg.n_received == 0 and len(agg.buffer) == 0
    agg.submit(_encoded_update("none"))             # negotiated: accepted
    assert agg.n_received == 1
    with pytest.raises(ValueError, match="codec"):
        AsyncAggregator(s, make_state(s), codecs=("none", "fp4"))
    with pytest.raises(ValueError, match="accum_dtype"):
        AsyncAggregator(s, make_state(s), accum_dtype="float16")


@pytest.mark.parametrize("wire", ["int8", "bf16"])
@pytest.mark.parametrize("buffer_size", [1, 5])
def test_quantized_uploads_track_plain_folds(wire, buffer_size):
    """The full service path (incremental fold and buffered mini-cohort)
    under quantized uploads stays within the codec's tolerance of the
    fp32 run, and wire accounting reflects the compression."""
    adapters, ranks, w, bases = hetero_cohort(5, seed=3, with_bases=True)
    updates = [ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                            n_examples=float(w[i]), rank=int(ranks[i]))
               for i in range(len(ranks))]
    from repro.core import codec
    s = get_strategy("rbla")
    plain = AsyncAggregator(s, make_state(s), buffer_size=buffer_size)
    quant = AsyncAggregator(get_strategy("rbla"), make_state(s),
                            buffer_size=buffer_size)
    for u in updates:
        plain.submit(u)
        quant.submit(codec.encode_update(u, wire))
    tol = 2e-2 if wire == "int8" else 8e-3
    assert_trees_close(plain.state.adapters, quant.state.adapters,
                       rtol=0.1, atol=tol, msg=f"{wire}/K={buffer_size}")
    assert quant.wire_bytes_received < plain.wire_bytes_received
    ratio = plain.wire_bytes_received / quant.wire_bytes_received
    assert ratio > (2.5 if wire == "int8" else 1.5)


def _rejection_counts(agg):
    metric = agg.obs_registry.get("fl_updates_rejected_total")
    if metric is None:
        return {}
    return {key.partition("=")[2]: int(v)
            for key, v in metric.samples().items() if v}


@pytest.mark.parametrize("reason", ["bad_mass", "nan_tensor", "bad_scale",
                                    "overflow", "codec_not_allowed",
                                    "zero_mass_flush"])
def test_each_rejection_path_increments_exactly_its_own_counter(reason):
    """Satellite regression for the per-reason rejection split: every
    ingestion/flush rejection path bumps ``fl_updates_rejected_total``
    under its own reason label and nothing else (catalog in
    ``docs/observability.md``)."""
    from repro.obs import MetricsRegistry
    s = get_strategy("rbla")
    codecs = "none" if reason == "codec_not_allowed" else ("none", "int8")
    agg = AsyncAggregator(s, make_state(s), codecs=codecs,
                          buffer_size=2, deadline=1.0,
                          registry=MetricsRegistry())
    if reason == "zero_mass_flush":
        upd = _one_update()
        agg.buffer.add(upd, weight=0.0, now=0.0)
        agg.buffer.add(upd, weight=0.0, now=0.0)
        agg.flush(now=10.0)
        assert _rejection_counts(agg) == {"zero_mass_flush": 2}
        assert agg.n_dropped == 2
        return
    if reason == "bad_mass":
        bad = dataclasses.replace(_one_update(), n_examples=0.0)
    elif reason == "nan_tensor":
        upd = _one_update()
        adapters = jax.tree.map(lambda x: x, upd.adapters)
        adapters["fc1"]["A"] = adapters["fc1"]["A"].at[0, 0].set(
            float("nan"))
        bad = dataclasses.replace(upd, adapters=adapters)
    else:
        bad = _encoded_update("int8")
        if reason == "bad_scale":
            adapters = {k: dict(v) for k, v in bad.adapters.items()}
            adapters["fc1"]["A_scale"] = \
                adapters["fc1"]["A_scale"].at[0].set(float("nan"))
            bad = dataclasses.replace(bad, adapters=adapters)
        elif reason == "overflow":
            adapters = {k: dict(v) for k, v in bad.adapters.items()}
            adapters["fc2"]["B_scale"] = \
                adapters["fc2"]["B_scale"].at[0].set(3.0e36)
            bad = dataclasses.replace(bad, adapters=adapters)
    with pytest.raises(ValueError):
        agg.submit(bad)
    assert _rejection_counts(agg) == {reason: 1}
    assert agg.n_received == 0 and len(agg.buffer) == 0


def test_buffer_wire_byte_accounting():
    from repro.core import codec
    from repro.fl.comm import tree_bytes
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s), buffer_size=3)
    upds = [_encoded_update("int8"), _encoded_update("none", seed=44)]
    for u in upds:
        agg.submit(u)
    expect = sum(tree_bytes(u.adapters) + tree_bytes(u.base_trainable)
                 for u in upds)
    assert agg.buffer.total_wire_bytes() == expect
    assert agg.wire_bytes_received == expect
    agg.submit(_encoded_update("bf16", seed=45))    # 3rd arrival flushes
    assert len(agg.buffer) == 0 and agg.buffer.total_wire_bytes() == 0
    assert agg.wire_bytes_received > expect         # lifetime counter


# ----------------------------------------------------- bf16 accumulators ----
def _fold_many(accum, seed, n_folds=100, beta=0.0):
    adapters, ranks, w, bases = hetero_cohort(10, seed=5, with_bases=True)
    s = get_strategy("rbla")
    agg = AsyncAggregator(s, make_state(s), accum_dtype=accum, seed=seed,
                          server_momentum=beta)
    for i in range(n_folds):
        j = i % len(ranks)
        agg.submit(ClientUpdate(adapters=adapters[j],
                                base_trainable=bases[j],
                                n_examples=float(w[j]), rank=int(ranks[j])))
    return agg


def test_bf16_accumulator_deterministic_under_fixed_seed():
    a = _fold_many("bfloat16", seed=7, n_folds=20)
    b = _fold_many("bfloat16", seed=7, n_folds=20)
    for x, y in zip(jax.tree.leaves(a.state.adapters),
                    jax.tree.leaves(b.state.adapters)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    assert jnp.asarray(a.state.adapters["fc1"]["A"]).dtype == jnp.bfloat16
    c = _fold_many("bfloat16", seed=8, n_folds=20)
    diff = max(float(jnp.max(jnp.abs(
        jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32))))
        for x, y in zip(jax.tree.leaves(a.state.adapters),
                        jax.tree.leaves(c.state.adapters)))
    assert diff > 0.0        # the noise really is seeded, not constant


@pytest.mark.parametrize("beta", [0.0, 0.9])
def test_bf16_accumulator_100_fold_drift_regression(beta):
    """100 folds (with and without server momentum) in bf16 storage with
    stochastic rounding must track the fp32 run: SR errors are unbiased,
    so drift grows like a sqrt(n)-step random walk of half-ulp steps,
    nowhere near the linear pile-up of round-to-nearest."""
    fp32 = _fold_many(None, seed=0, n_folds=100, beta=beta)
    bf16 = _fold_many("bfloat16", seed=0, n_folds=100, beta=beta)
    num = den = 0.0
    for x, y in zip(jax.tree.leaves(fp32.state.adapters),
                    jax.tree.leaves(bf16.state.adapters)):
        if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            continue
        d = jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32)
        num += float(jnp.sum(d * d))
        den += float(jnp.sum(jnp.asarray(x, jnp.float32) ** 2))
    rel = (num / max(den, 1e-30)) ** 0.5
    # ~sqrt(100) * 2^-9 ~ 2% if every fold moved every value a half-ulp;
    # well under 5%, vs ~100 * 2^-9 ~ 20% for a biased rounder
    assert rel < 0.05, f"beta={beta}: bf16 accumulator drifted {rel:.3f}"
    assert fp32.n_folded == bf16.n_folded == 100
    # masses are denominators and must never be quantized
    assert bf16._fold_state.row_mass is None or all(
        jnp.asarray(v).dtype == jnp.float32
        for v in jax.tree.leaves(bf16._fold_state.row_mass))


# ------------------------------------------------------- server momentum ----
def test_server_momentum_zero_is_exact_noop():
    s = get_strategy("rbla")
    upd = _one_update()
    plain = AsyncAggregator(s, make_state(s))
    mom = AsyncAggregator(s, make_state(s), server_momentum=0.0)
    for _ in range(3):
        plain.submit(upd)
        mom.submit(upd)
    np.testing.assert_array_equal(
        np.asarray(plain.state.adapters["fc1"]["A"]),
        np.asarray(mom.state.adapters["fc1"]["A"]))


def test_server_momentum_accelerates_a_consistent_direction():
    """Folding the same update repeatedly: momentum accumulates the
    per-fold displacement, so the published state moves further toward
    the (consistent) client than the momentum-free service."""
    s = get_strategy("rbla")
    upd = _one_update()
    start = np.asarray(make_state(s).adapters["fc1"]["A"])

    def run(beta):
        agg = AsyncAggregator(s, make_state(s), server_momentum=beta)
        for _ in range(4):
            agg.submit(upd)
        out = np.asarray(agg.state.adapters["fc1"]["A"])
        assert np.isfinite(out).all()
        return float(np.linalg.norm(out - start))
    assert run(0.5) > run(0.0)


def test_server_momentum_buffer_survives_semiasync_reanchor():
    s = get_strategy("rbla")
    upd = _one_update()
    agg = AsyncAggregator(s, make_state(s), buffer_size=2,
                          server_momentum=0.5)
    agg.submit(upd)
    agg.submit(upd)                              # flush + re-anchor
    assert agg.n_flushes == 1
    assert agg._fold_state.momentum is not None
    m0 = np.asarray(agg._fold_state.momentum["fc1"]["A"])
    agg.submit(upd)
    agg.submit(upd)
    m1 = np.asarray(agg._fold_state.momentum["fc1"]["A"])
    assert not np.array_equal(m0, m1)            # still accumulating


def test_server_momentum_requires_fixed_rank_contract():
    s = configured("flora")
    with pytest.raises(ValueError, match="fixed-rank"):
        AsyncAggregator(s, make_state(s), server_momentum=0.5)
    with pytest.raises(ValueError, match="server_momentum"):
        AsyncAggregator(get_strategy("rbla"), make_state(get_strategy(
            "rbla")), server_momentum=1.5)


@pytest.mark.parametrize("method", ["rbla_clipped", "rbla_trimmed",
                                    "rbla_median"])
def test_robust_strategies_use_exact_replay_path(method):
    """Robust reductions are not running means: the service must replay
    them (supports_incremental=False), keeping sequential folds exactly
    equal to the one-shot aggregate (the parity gate above)."""
    s = get_strategy(method)
    assert not s.supports_incremental
    adapters, ranks, w, bases = hetero_cohort(3, seed=47, with_bases=True)
    agg = AsyncAggregator(s, make_state(s))
    for i in range(3):
        agg.submit(ClientUpdate(adapters=adapters[i],
                                base_trainable=bases[i],
                                n_examples=float(w[i]),
                                rank=int(ranks[i])))
    assert agg.n_folded == 3 and len(agg._replay) == 3


def test_async_simulation_wall_clock_smoke_and_determinism():
    cfg = AsyncFLConfig(method="rbla", staleness="polynomial",
                        staleness_clock="wall", staleness_a=0.3,
                        **ASYNC_SMOKE_KW)
    h = run_async_simulation(cfg)
    assert len(h.test_acc) == 2
    assert np.isfinite(h.train_loss).all()
    assert all(t >= 0 for t in h.mean_staleness)
    # wall staleness is measured in simulated seconds since pull, so it
    # tracks the latency distribution (order ~ the 1s median), not folds
    h2 = run_async_simulation(cfg)
    assert h.test_acc == h2.test_acc


# ------------------------------------------------------- semi-async buffer --
def test_update_buffer_flushes_on_size_and_deadline():
    buf = UpdateBuffer(size=3, deadline=5.0)
    buf.add("u1", weight=1.0, now=0.0)
    assert not buf.due(now=1.0)
    buf.add("u2", weight=1.0, now=1.0)
    assert not buf.due(now=2.0)
    assert buf.due(now=5.0)                  # oldest waited >= deadline
    buf.add("u3", weight=1.0, now=2.0)
    assert buf.due(now=2.0)                  # size reached
    items = buf.pop()
    assert [b.update for b in items] == ["u1", "u2", "u3"]
    assert len(buf) == 0 and not buf.due(now=100.0)
    with pytest.raises(ValueError, match="size"):
        UpdateBuffer(size=0)
    with pytest.raises(ValueError, match="deadline"):
        UpdateBuffer(size=2, deadline=-1.0)


def test_semiasync_single_flush_is_one_sync_round():
    """buffer_size == cohort size, zero staleness: the one flush must be
    exactly the classic synchronous aggregate."""
    s = get_strategy("rbla")
    adapters, ranks, w, bases = hetero_cohort(4, seed=17, with_bases=True)
    updates = [ClientUpdate(adapters=adapters[i], base_trainable=bases[i],
                            n_examples=float(w[i]), rank=int(ranks[i]))
               for i in range(len(ranks))]
    want = s.aggregate(make_state(s), updates, weights=w, backend="ref")
    agg = AsyncAggregator(s, make_state(s), buffer_size=len(updates),
                          backend="ref")
    for u in updates[:-1]:
        assert not agg.submit(u)             # buffering, no state change
        assert agg.version == 0
    assert agg.submit(updates[-1])           # K reached -> flush
    assert agg.version == 1 and agg.n_flushes == 1
    assert_trees_close(agg.state.adapters, want.adapters, 1e-6, 1e-7)


def test_replay_window_reanchors():
    """Non-incremental strategies re-anchor after replay_window folds and
    keep folding from the accumulated state (bounded memory)."""
    s = configured("flora")
    adapters, ranks, w, bases = hetero_cohort(5, seed=19, r_lo=1, r_hi=2, with_bases=True)
    agg = AsyncAggregator(s, make_state(s), replay_window=2)
    for i in range(len(ranks)):
        agg.submit(ClientUpdate(adapters=adapters[i],
                                base_trainable=bases[i],
                                n_examples=float(w[i]),
                                rank=int(ranks[i])))
    assert agg.n_folded == 5 and agg.version == 5
    assert len(agg._replay) <= 2
    assert np.isfinite(np.asarray(agg.state.adapters["fc1"]["A"])).all()


# --------------------------------------------------- event-driven simulator --
ASYNC_SMOKE_KW = dict(dataset="mnist", model="mlp", rounds=2, n_clients=3,
                      n_per_class=12, n_test_per_class=6, batch_size=16,
                      r_max=4, lr=0.01, seed=42)


@pytest.mark.parametrize("method", ["rbla", "zeropad", "flora", "fft"])
def test_async_simulation_smoke_and_determinism(method):
    extra = {"stack_r_cap": 16} if method == "flora" else {}
    cfg = AsyncFLConfig(method=method, staleness="polynomial", **extra,
                        **ASYNC_SMOKE_KW)
    h = run_async_simulation(cfg)
    assert len(h.test_acc) == 2              # rounds * n_clients uploads,
    assert len(h.sim_time_s) == 2            # eval every n_clients
    assert np.isfinite(h.train_loss).all()
    assert all(0.0 <= a <= 1.0 for a in h.test_acc)
    assert all(t >= 0 for t in h.mean_staleness)
    assert h.sim_time_s == sorted(h.sim_time_s)
    h2 = run_async_simulation(cfg)
    assert h.test_acc == h2.test_acc, "same seed must be bit-identical"


def test_async_vs_sync_same_config_both_learn():
    """Async folding with a straggler distribution must not wreck the
     3-round tiny run the sync path survives (same budget of uploads)."""
    sync = run_simulation(FLConfig(method="rbla", **ASYNC_SMOKE_KW))
    async_h = run_async_simulation(
        AsyncFLConfig(method="rbla", straggler_sigma=1.5,
                      **ASYNC_SMOKE_KW))
    assert np.isfinite(async_h.train_loss).all()
    assert async_h.test_acc[-1] >= sync.test_acc[-1] - 0.25


def test_client_latency_model_straggler_tail_and_determinism():
    from repro.fl import ClientLatencyModel
    lat = ClientLatencyModel(8, median_s=1.0, sigma=0.25,
                             straggler_sigma=1.0, seed=0)
    lat2 = ClientLatencyModel(8, median_s=1.0, sigma=0.25,
                              straggler_sigma=1.0, seed=0)
    draws = [lat.sample(i) for i in range(8)]
    assert draws == [lat2.sample(i) for i in range(8)]   # per-client streams
    assert all(d > 0 for d in draws)
    med = lat.client_median_s
    assert med.max() / med.min() > 2.0       # heterogeneity is real
